"""Pallas TPU kernels: changepoint (the paper's SSE scan), windowvet (the
fused block-sparse window-vet kernel), flash_attention, ssd.

Interpret-vs-compiled is a platform policy, not a hardcoded flag:
``runtime.resolve_interpret`` picks compiled on TPU and interpret mode
elsewhere, with the ``REPRO_PALLAS_INTERPRET`` env var as the override."""
