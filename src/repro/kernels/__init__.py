"""Pallas TPU kernels (validated in interpret mode on CPU):
changepoint (the paper's SSE scan), flash_attention, ssd."""
