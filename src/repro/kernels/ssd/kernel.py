"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid (B, H, NC) with NC innermost-sequential; the inter-chunk SSM state
(P, N) lives in VMEM scratch and carries across chunk steps:

  x block  (1, C, 1, P)   dt block (1, C, 1)    b/c blocks (1, C, N)
  per-head scalars a, d: (1,) blocks indexed by h
  y block  (1, C, 1, P)

Within a chunk (C x C intra-chunk "attention-like" matmuls — MXU work):
  seg   = cumsum(dt * a)                       (matmul with lower-tri ones)
  y_in  = ((C B^T) o L o dt) X                 intra-chunk
  y_out = (C o exp(seg)) h_prev                inter-chunk (carried state)
  h    <- exp(sum dt a) h_prev + sum_j w_j x_j b_j^T

Chunk C defaults to 64; all recurrence math f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref, *,
            chunk: int, n_state: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (C, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (C,)
    b = b_ref[0].astype(jnp.float32)  # (C, N)
    c = c_ref[0].astype(jnp.float32)  # (C, N)
    a = a_ref[0]  # scalar (f32): -exp(A_log) precomputed by ops
    d = d_ref[0]

    da = dt * a  # (C,)
    # cumsum via lower-triangular ones matmul (TPU-native)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    seg = tri @ da  # inclusive cumsum (C,)

    # intra-chunk: scores[i,j] = (c_i . b_j) * exp(seg_i - seg_j) * dt_j, i>=j
    li = seg[:, None] - seg[None, :]
    li = jnp.where(tri > 0, li, -jnp.inf)
    decay = jnp.exp(li)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (C, C)
    scores = cb * decay * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, P)

    # inter-chunk: y += (c exp(seg)) @ h_prev^T  with h_prev (P, N)
    h_prev = h_ref[...]  # (P, N)
    c_seg = c * jnp.exp(seg)[:, None]  # (C, N)
    y = y + jax.lax.dot_general(c_seg, h_prev, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: h = exp(sum da) h_prev + sum_j exp(seg_last - seg_j) dt_j x_j b_j^T
    last = seg[chunk - 1]
    w = jnp.exp(last - seg) * dt  # (C,)
    xw = x * w[:, None]  # (C, P)
    s_chunk = jax.lax.dot_general(xw, b, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (P, N)
    h_ref[...] = h_prev * jnp.exp(last) + s_chunk

    y = y + d * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_neg, b, c, d, *, chunk: int = 64, interpret: bool = True):
    """x: (B,T,H,P)  dt: (B,T,H)  a_neg: (H,) = -exp(A_log)  b,c: (B,T,N)
    d: (H,).  Returns y: (B,T,H,P).  T must be a multiple of chunk."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    kern = functools.partial(_kernel, chunk=chunk, n_state=n)
    return pl.pallas_call(
        kern,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ic: (b_, ic, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, ic: (b_, ic, h_)),
            pl.BlockSpec((1,), lambda b_, h_, ic: (h_,)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ic: (b_, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ic: (b_, ic, 0)),
            pl.BlockSpec((1,), lambda b_, h_, ic: (h_,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ic: (b_, ic, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, a_neg, b, c, d)
