"""Jit'd wrapper for the SSD kernel, taking model-layer conventions
(A_log, D) and handling the -exp(A_log) precompute."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan

__all__ = ["ssd"]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_log, b, c, d, *, chunk: int = 64, interpret: bool = True):
    """Mamba2 SSD, kernel-backed.  Signature mirrors ``ref.ssd_ref``."""
    a_neg = -jnp.exp(a_log.astype(jnp.float32))
    return ssd_scan(x, dt, a_neg, b, c,
                    d.astype(jnp.float32), chunk=chunk, interpret=interpret)
