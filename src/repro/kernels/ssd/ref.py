"""Pure-jnp oracle for the Mamba2 SSD recurrence: literal stepwise scan.

    h_t = exp(dt_t * a_h) * h_{t-1} + dt_t * x_t (outer) b_t
    y_t = c_t . h_t + d_h * x_t

x: (B,T,H,P)  dt: (B,T,H)  a_log: (H,)  b,c: (B,T,N)  d: (H,) -> y: (B,T,H,P)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_ref"]


def ssd_ref(x, dt, a_log, b, c, d) -> jax.Array:
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    def step(hprev, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        dec = jnp.exp(dtt * a)  # (B,H)
        hnew = hprev * dec[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, bt
        )
        yt = jnp.einsum("bn,bhpn->bhp", ct, hnew)
        return hnew, yt

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + d.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)
