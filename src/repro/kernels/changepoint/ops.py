"""Jit'd wrapper: sorted record times -> change-point via the Pallas SSE scan.

Numerical notes: the prefix sums are computed *exactly* as the jnp reference
scan computes them (same jnp.cumsum on uncentered f32 inputs, same closed
forms in the kernel), so the kernel's SSE landscape tracks the reference to
~ulp level.  That consistency is deliberate: on near-flat landscapes (heavy
tails in raw cut space, bucketed log curves) the argmin sits on 1e-4-relative
near-ties, and an implementation that disagrees with the reference by more
than an ulp flips the chosen cut even though both answers are "valid" — the
cross-backend equivalence the VetEngine relies on would be lost.  (An earlier
version centered y for better absolute f32 conditioning; that bought accuracy
vs float64 but cost agreement with the uncentered reference, which is the
contract that matters here.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK, sse_scan

__all__ = ["changepoint_pallas", "two_segment_sse_pallas", "auto_block"]


def auto_block(n: int) -> int:
    """Smallest 128-multiple block covering n, capped at DEFAULT_BLOCK.

    Short inputs (e.g. the engine's bucketed curves, B ~ 64-1000) would
    otherwise pad 16x out to the default 1024-wide block."""
    return min(DEFAULT_BLOCK, max(128, ((n + 127) // 128) * 128))


def _prefix_inputs(y_sorted, block):
    y = jnp.asarray(y_sorted, jnp.float32)
    n = y.shape[0]
    idx = jnp.arange(1, n + 1, dtype=jnp.float32)
    cy = jnp.cumsum(y)
    cyy = jnp.cumsum(y * y)
    cxy = jnp.cumsum(idx * y)
    totals = jnp.stack([cy[-1], cyy[-1], cxy[-1]])
    pad = (-n) % block
    if pad:
        cy = jnp.concatenate([cy, jnp.broadcast_to(cy[-1], (pad,))])
        cyy = jnp.concatenate([cyy, jnp.broadcast_to(cyy[-1], (pad,))])
        cxy = jnp.concatenate([cxy, jnp.broadcast_to(cxy[-1], (pad,))])
    return cy, cyy, cxy, totals, n


@functools.partial(jax.jit, static_argnames=("omega", "block", "interpret"))
def two_segment_sse_pallas(y_sorted, omega: int = 3, block: int = DEFAULT_BLOCK,
                           interpret=None):
    cy, cyy, cxy, totals, n = _prefix_inputs(y_sorted, block)
    sse = sse_scan(cy, cyy, cxy, totals, true_n=n, omega=omega, block=block,
                   interpret=interpret)
    return sse[:n]


@functools.partial(jax.jit, static_argnames=("omega", "block", "interpret"))
def changepoint_pallas(y_sorted, omega: int = 3, block: int = DEFAULT_BLOCK,
                       interpret=None):
    """t-hat (1-indexed prefix size), matching ``core.estimate_changepoint``.

    ``interpret=None`` picks the platform default (compiled on TPU,
    interpret elsewhere; ``REPRO_PALLAS_INTERPRET`` overrides)."""
    sse = two_segment_sse_pallas(y_sorted, omega=omega, block=block,
                                 interpret=interpret)
    return (jnp.argmin(sse) + 1).astype(jnp.int32)
