"""Jit'd wrapper: sorted record times -> change-point via the Pallas SSE scan.

Numerical notes: the prefix sums are computed *exactly* as the jnp reference
scan computes them (the same midpoint-element centering ``y - y[(n-1)//2]``
before the same jnp.cumsum, and the same f64-precomputed index closed forms
— ``core.changepoint.index_closed_forms`` rounded once to f32 — shipped
into the kernel), so the kernel's SSE landscape tracks the reference to
~ulp level.  That consistency is deliberate: on near-flat landscapes (heavy
tails in raw cut space, bucketed log curves) the argmin sits on
1e-4-relative near-ties, and an implementation that disagrees with the
reference by more than an ulp flips the chosen cut even though both answers
are "valid" — the cross-backend equivalence the VetEngine relies on would
be lost.  Centering subtracts an exact element (zero rounding on the shift
itself) and keeps the cumsum magnitudes small, so the argmin also stays
within a few samples of the f64 oracle at n ~ 8k where uncentered f32
cumsums drifted by dozens (``tests/test_changepoint_edges.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.changepoint import index_closed_forms
from .kernel import DEFAULT_BLOCK, sse_scan

__all__ = ["changepoint_pallas", "two_segment_sse_pallas", "auto_block"]


def auto_block(n: int) -> int:
    """Smallest 128-multiple block covering n, capped at DEFAULT_BLOCK.

    Short inputs (e.g. the engine's bucketed curves, B ~ 64-1000) would
    otherwise pad 16x out to the default 1024-wide block."""
    return min(DEFAULT_BLOCK, max(128, ((n + 127) // 128) * 128))


def _prefix_inputs(y_sorted, block):
    y = jnp.asarray(y_sorted, jnp.float32)
    n = y.shape[0]
    idx = jnp.arange(1, n + 1, dtype=jnp.float32)
    # Same midpoint-element centering as the reference scan (see
    # core.changepoint): shift-stable landscape, and the pivot is an exact
    # element pick so the parity contract holds bitwise.
    y = y - y[(n - 1) // 2]
    cy = jnp.cumsum(y)
    cyy = jnp.cumsum(y * y)
    cxy = jnp.cumsum(idx * y)
    totals = jnp.stack([cy[-1], cyy[-1], cxy[-1]])
    # Index closed forms: f64 at trace time, rounded once to f32 — the same
    # arrays the jnp reference casts at combine (see kernel.py docstring).
    forms = [jnp.asarray(a, jnp.float32) for a in index_closed_forms(n)]
    pad = (-n) % block
    if pad:
        cy = jnp.concatenate([cy, jnp.broadcast_to(cy[-1], (pad,))])
        cyy = jnp.concatenate([cyy, jnp.broadcast_to(cyy[-1], (pad,))])
        cxy = jnp.concatenate([cxy, jnp.broadcast_to(cxy[-1], (pad,))])
        forms = [jnp.concatenate([a, jnp.broadcast_to(a[-1], (pad,))])
                 for a in forms]
    sx1, sxx1, sx2, sxx2 = forms
    return cy, cyy, cxy, sx1, sxx1, sx2, sxx2, totals, n


@functools.partial(jax.jit, static_argnames=("omega", "block", "interpret"))
def two_segment_sse_pallas(y_sorted, omega: int = 3, block: int = DEFAULT_BLOCK,
                           interpret=None):
    cy, cyy, cxy, sx1, sxx1, sx2, sxx2, totals, n = \
        _prefix_inputs(y_sorted, block)
    sse = sse_scan(cy, cyy, cxy, sx1, sxx1, sx2, sxx2, totals, true_n=n,
                   omega=omega, block=block, interpret=interpret)
    return sse[:n]


@functools.partial(jax.jit, static_argnames=("omega", "block", "interpret"))
def changepoint_pallas(y_sorted, omega: int = 3, block: int = DEFAULT_BLOCK,
                       interpret=None):
    """t-hat (1-indexed prefix size), matching ``core.estimate_changepoint``.

    ``interpret=None`` picks the platform default (compiled on TPU,
    interpret elsewhere; ``REPRO_PALLAS_INTERPRET`` overrides).

    Raises:
        ValueError: ``n < 2*omega`` — no valid split exists (the SSE scan
            is all +inf).  Same trace-time guard as the jnp path; the
            naive oracle returns ``-1`` for this condition.
    """
    n = jnp.shape(y_sorted)[0]
    if n < 2 * omega:
        raise ValueError(
            f"changepoint_pallas needs n >= 2*omega points to probe a "
            f"split (omega={omega} on each side), got n={n}")
    sse = two_segment_sse_pallas(y_sorted, omega=omega, block=block,
                                 interpret=interpret)
    return (jnp.argmin(sse) + 1).astype(jnp.int32)
