"""Jit'd wrapper: sorted record times -> change-point via the Pallas SSE scan.

Numerical notes: y is centered (y - mean) before the prefix sums so the f32
segment-SSE cancellations stay well-conditioned (centering shifts both
segments' intercepts, leaving every SSE unchanged).  Prefix sums are computed
in f64-equivalent fashion via jnp.cumsum on f32 — adequate for the profile
sizes the estimator runs on (<= a few million records per task).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK, sse_scan

__all__ = ["changepoint_pallas", "two_segment_sse_pallas"]


def _prefix_inputs(y_sorted, block):
    y = jnp.asarray(y_sorted, jnp.float32)
    n = y.shape[0]
    y = y - jnp.mean(y)  # centering: SSEs are translation-invariant
    idx = jnp.arange(1, n + 1, dtype=jnp.float32)
    cy = jnp.cumsum(y)
    cyy = jnp.cumsum(y * y)
    cxy = jnp.cumsum(idx * y)
    totals = jnp.stack([cy[-1], cyy[-1], cxy[-1]])
    pad = (-n) % block
    if pad:
        cy = jnp.concatenate([cy, jnp.broadcast_to(cy[-1], (pad,))])
        cyy = jnp.concatenate([cyy, jnp.broadcast_to(cyy[-1], (pad,))])
        cxy = jnp.concatenate([cxy, jnp.broadcast_to(cxy[-1], (pad,))])
    return cy, cyy, cxy, totals, n


@functools.partial(jax.jit, static_argnames=("omega", "block", "interpret"))
def two_segment_sse_pallas(y_sorted, omega: int = 3, block: int = DEFAULT_BLOCK,
                           interpret: bool = True):
    cy, cyy, cxy, totals, n = _prefix_inputs(y_sorted, block)
    sse = sse_scan(cy, cyy, cxy, totals, true_n=n, omega=omega, block=block,
                   interpret=interpret)
    return sse[:n]


@functools.partial(jax.jit, static_argnames=("omega", "block", "interpret"))
def changepoint_pallas(y_sorted, omega: int = 3, block: int = DEFAULT_BLOCK,
                       interpret: bool = True):
    """t-hat (1-indexed prefix size), matching ``core.estimate_changepoint``."""
    sse = two_segment_sse_pallas(y_sorted, omega=omega, block=block,
                                 interpret=interpret)
    return (jnp.argmin(sse) + 1).astype(jnp.int32)
