"""Pallas TPU kernel for the change-point SSE scan (the paper's hot loop).

For n profiled records the two-segment LSE must evaluate SSE(k) at every
candidate split k — the paper writes this as an O(n^2) regression loop; the
prefix-sum formulation makes each SSE O(1).  The kernel evaluates a block of
candidates per grid step from three prefix-sum arrays resident in VMEM:

  grid  = (n // BLOCK,)
  in    : cy, cyy, cxy blocks (BLOCK,) VMEM; totals (3,) replicated
  out   : sse block (BLOCK,)

Closed forms: Sx(k) = k(k+1)/2, Sxx(k) = k(k+1)(2k+1)/6 — no extra arrays.
All math f32, on the same uncentered prefix sums the jnp reference scan uses
(see ops.py for why reference-consistency beats absolute conditioning here).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..runtime import resolve_interpret

__all__ = ["sse_scan", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 1024


def _seg_sse(n1, sx, sy, sxx, sxy, syy):
    n1 = jnp.maximum(n1, 1.0)
    sxx_c = sxx - sx * sx / n1
    sxy_c = sxy - sx * sy / n1
    syy_c = syy - sy * sy / n1
    safe = sxx_c > 0.0
    sse = syy_c - jnp.where(safe, sxy_c * sxy_c / jnp.where(safe, sxx_c, 1.0), 0.0)
    return jnp.maximum(sse, 0.0)


def _kernel(cy_ref, cyy_ref, cxy_ref, tot_ref, sse_ref, *, block: int, n: int,
            omega: int):
    pid = pl.program_id(0)
    base = (pid * block).astype(jnp.float32)
    k = base + jax.lax.broadcasted_iota(jnp.float32, (block,), 0) + 1.0

    cy = cy_ref[...]
    cyy = cyy_ref[...]
    cxy = cxy_ref[...]
    tot_y = tot_ref[0]
    tot_yy = tot_ref[1]
    tot_xy = tot_ref[2]

    nf = jnp.float32(n)
    sx1 = k * (k + 1.0) * 0.5
    sxx1 = k * (k + 1.0) * (2.0 * k + 1.0) / 6.0
    sx_tot = nf * (nf + 1.0) * 0.5
    sxx_tot = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 6.0

    sse1 = _seg_sse(k, sx1, cy, sxx1, cxy, cyy)
    n2 = nf - k
    sse2 = _seg_sse(n2, sx_tot - sx1, tot_y - cy, sxx_tot - sxx1,
                    tot_xy - cxy, tot_yy - cyy)

    total = sse1 + sse2
    valid = (k >= jnp.float32(omega)) & (k <= nf - jnp.float32(omega))
    sse_ref[...] = jnp.where(valid, total, jnp.float32(jnp.inf))


@functools.partial(jax.jit, static_argnames=("true_n", "omega", "block", "interpret"))
def sse_scan(cy, cyy, cxy, totals, *, true_n: int, omega: int = 3,
             block: int = DEFAULT_BLOCK, interpret=None):
    """SSE for every candidate k from prefix sums (padded to a block multiple).

    cy/cyy/cxy: (n_padded,) f32 prefix sums (pad region repeats the totals);
    totals: (3,) f32 = [sum y, sum y^2, sum x*y]; true_n: unpadded length.
    ``interpret=None`` resolves the platform policy (compiled on TPU,
    interpret elsewhere; ``REPRO_PALLAS_INTERPRET`` overrides) at trace
    time — pass an explicit bool to pin the mode.
    Returns sse: (n_padded,) f32 (+inf outside the probing window / padding).
    """
    interpret = resolve_interpret(interpret)
    n = cy.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    kern = functools.partial(_kernel, block=block, n=true_n, omega=omega)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(cy, cyy, cxy, totals)
