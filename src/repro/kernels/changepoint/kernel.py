"""Pallas TPU kernel for the change-point SSE scan (the paper's hot loop).

For n profiled records the two-segment LSE must evaluate SSE(k) at every
candidate split k — the paper writes this as an O(n^2) regression loop; the
prefix-sum formulation makes each SSE O(1).  The kernel evaluates a block of
candidates per grid step from the prefix-sum arrays resident in VMEM:

  grid  = (n // BLOCK,)
  in    : cy, cyy, cxy blocks (BLOCK,) VMEM; sx1, sxx1, sx2, sxx2 blocks
          (BLOCK,) VMEM (precomputed index closed forms); totals (3,)
          replicated
  out   : sse block (BLOCK,)

Closed forms Sx(k) = k(k+1)/2, Sxx(k) = k(k+1)(2k+1)/6 and their segment-2
complements arrive precomputed (f64 on the host, rounded once to f32 —
``core.changepoint.index_closed_forms``): evaluating the cubic in f32
inside the kernel compounds rounding beyond the f32 mantissa for n of a
few thousand, and — the contract that actually matters — would diverge
from the jnp reference scan, which consumes the same precomputed arrays.
All remaining math f32, on the same uncentered prefix sums the reference
uses (see ops.py for why reference-consistency beats absolute
conditioning here).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..runtime import resolve_interpret

__all__ = ["sse_scan", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 1024


def _seg_sse(n1, sx, sy, sxx, sxy, syy):
    n1 = jnp.maximum(n1, 1.0)
    sxx_c = sxx - sx * sx / n1
    sxy_c = sxy - sx * sy / n1
    syy_c = syy - sy * sy / n1
    safe = sxx_c > 0.0
    sse = syy_c - jnp.where(safe, sxy_c * sxy_c / jnp.where(safe, sxx_c, 1.0), 0.0)
    return jnp.maximum(sse, 0.0)


def _kernel(cy_ref, cyy_ref, cxy_ref, sx1_ref, sxx1_ref, sx2_ref, sxx2_ref,
            tot_ref, sse_ref, *, block: int, n: int, omega: int):
    pid = pl.program_id(0)
    base = (pid * block).astype(jnp.float32)
    k = base + jax.lax.broadcasted_iota(jnp.float32, (block,), 0) + 1.0

    cy = cy_ref[...]
    cyy = cyy_ref[...]
    cxy = cxy_ref[...]
    sx1 = sx1_ref[...]
    sxx1 = sxx1_ref[...]
    sx2 = sx2_ref[...]
    sxx2 = sxx2_ref[...]
    tot_y = tot_ref[0]
    tot_yy = tot_ref[1]
    tot_xy = tot_ref[2]

    nf = jnp.float32(n)
    sse1 = _seg_sse(k, sx1, cy, sxx1, cxy, cyy)
    n2 = nf - k
    sse2 = _seg_sse(n2, sx2, tot_y - cy, sxx2, tot_xy - cxy, tot_yy - cyy)

    total = sse1 + sse2
    valid = (k >= jnp.float32(omega)) & (k <= nf - jnp.float32(omega))
    sse_ref[...] = jnp.where(valid, total, jnp.float32(jnp.inf))


@functools.partial(jax.jit, static_argnames=("true_n", "omega", "block", "interpret"))
def sse_scan(cy, cyy, cxy, sx1, sxx1, sx2, sxx2, totals, *, true_n: int,
             omega: int = 3, block: int = DEFAULT_BLOCK, interpret=None):
    """SSE for every candidate k from prefix sums (padded to a block multiple).

    cy/cyy/cxy: (n_padded,) f32 prefix sums (pad region repeats the totals);
    sx1/sxx1/sx2/sxx2: (n_padded,) f32 precomputed index closed forms
    (``core.changepoint.index_closed_forms``, rounded once to f32);
    totals: (3,) f32 = [sum y, sum y^2, sum x*y]; true_n: unpadded length.
    ``interpret=None`` resolves the platform policy (compiled on TPU,
    interpret elsewhere; ``REPRO_PALLAS_INTERPRET`` overrides) at trace
    time — pass an explicit bool to pin the mode.
    Returns sse: (n_padded,) f32 (+inf outside the probing window / padding).
    """
    interpret = resolve_interpret(interpret)
    n = cy.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    kern = functools.partial(_kernel, block=block, n=true_n, omega=omega)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(cy, cyy, cxy, sx1, sxx1, sx2, sxx2, totals)
