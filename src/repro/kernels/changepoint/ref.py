"""Pure-jnp oracle for the two-segment SSE scan (paper §4.3).

This re-exports the O(n) prefix-sum formulation from ``repro.core`` — the
kernel must match it exactly (same closed forms, same masking).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.changepoint import two_segment_sse

__all__ = ["two_segment_sse_ref", "changepoint_ref"]


def two_segment_sse_ref(y_sorted: jax.Array, omega: int = 3) -> jax.Array:
    return two_segment_sse(y_sorted, omega=omega)


def changepoint_ref(y_sorted: jax.Array, omega: int = 3) -> jax.Array:
    sse = two_segment_sse_ref(y_sorted, omega=omega)
    return (jnp.argmin(sse) + 1).astype(jnp.int32)
