"""Jit'd public wrapper for the flash attention kernel."""

from .kernel import flash_attention

__all__ = ["flash_attention"]
