"""Pure-jnp oracle for flash attention: dense masked softmax attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,S,KH,D). f32 softmax, dense masks."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window > 0:
        mask &= pos[:, None] - pos[None, :] < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
