"""Pallas TPU flash attention (causal / sliding-window, GQA).

Online-softmax attention with explicit BlockSpec VMEM tiling:

  grid = (B, H, nQ, nK)   — nK is the innermost ("arbitrary") dimension;
  q block   (1, 1, BQ, D) indexed (b, h, iq, 0)
  k/v block (1, 1, BK, D) indexed (b, h // group, ik, 0)   — GQA head map
  out block (1, 1, BQ, D) indexed (b, h, iq, 0)
  scratch: m (BQ,), l (BQ,), acc (BQ, D) f32 VMEM persisting across nK.

Causal/SWA block skipping: blocks entirely above the diagonal (or entirely
outside the window) are skipped with pl.when — compute is O(S*W) for SWA.
Block sizes default to 128x128 (MXU-aligned).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = float(-1e30)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = iq * bq
    k0 = ik * bk
    # static-shape positions for masking
    qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Block-level skip: causal => k0 > q_end is dead; SWA => k_end < q0-window.
    def live_block():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)
        mask = kpos < seq_len
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    if causal or window > 0:
        q_end = q0 + bq - 1
        dead = k0 > q_end
        if window > 0:
            dead |= (k0 + bk - 1) < (q0 - window + 1)
        pl.when(jnp.logical_not(dead))(live_block)
    else:
        live_block()

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, S, KH, D), H % KH == 0. Returns (B, S, H, D).

    S is padded internally to a block multiple; padded keys are masked out.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    assert h % kh == 0
    group = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    pad = (-s) % max(bq, bk)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nq, nk = sp // bq, sp // bk

    # (B, H, S, D) layout for clean blocking
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk,
        nk=nk, seq_len=s,
    )
    out = pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    out = jnp.moveaxis(out, 1, 2)
    return out[:, :s]
