"""Scalar reference for the fused window-vet kernel (the ladder's root).

A plain host loop of ``core.vet.vet_pipeline`` over the requested windows —
no batching, no kernel, no shared prefix sums.  The differential ladder is

    fused kernel (ops.fused_window_vet)
      -> engine gather path (vet_windows / vet_sliding, backend="jax")
        -> this scalar loop        (== the numpy backend's per-row oracle)

Each rung must match the one below it at 1e-5 with identical change-points
on the framework-default estimator (see tests/test_windowvet*.py).
"""

from __future__ import annotations

import numpy as np

from ...core.vet import vet_pipeline

__all__ = ["ref_window_vet"]


def ref_window_vet(arena, starts, lengths, *, omega: int = 3,
                   buckets=None, cut_space: str = "log"):
    """Vet each window ``arena[starts[r] : starts[r] + lengths[r]]``.

    Returns ``(vet, ei, oc, pr, t, n)`` host arrays in row order.  The
    fused kernel only serves non-bucketed rows (the engine gate keeps
    ``n >= 4 * buckets`` rows on the gather path), so ``buckets=None`` is
    the matching default.
    """
    arena = np.asarray(arena, dtype=np.float64)
    starts = np.asarray(starts, dtype=np.int64)
    lengths = np.asarray(lengths, dtype=np.int64)
    out = {k: [] for k in ("vet", "ei", "oc", "pr", "t")}
    for s, ln in zip(starts, lengths):
        vet, ei, oc, pr, t = vet_pipeline(arena[s:s + ln], omega=omega,
                                          buckets=buckets,
                                          cut_space=cut_space)
        out["vet"].append(float(vet))
        out["ei"].append(float(ei))
        out["oc"].append(float(oc))
        out["pr"].append(float(pr))
        out["t"].append(int(t))
    return (np.asarray(out["vet"]), np.asarray(out["ei"]),
            np.asarray(out["oc"]), np.asarray(out["pr"]),
            np.asarray(out["t"], dtype=np.int32),
            lengths.astype(np.int64))
