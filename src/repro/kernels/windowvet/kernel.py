"""Fused Pallas window-vet kernel: ragged windows -> (vet, ei, oc, pr, t).

One launch walks a shared **arena** (a stream's ring-buffer span, or several
streams' spans concatenated) and emits the complete vet pipeline for every
window in a block-sparse row set: row ``r`` covers arena records
``[starts[r], starts[r] + lengths[r])``.  This retires the engine's
one-dispatch-per-window-length rule — mixed-length window sets that the
gather path had to bucket by shape become rows of one padded launch — and
its O(windows x length) gather matrices: the kernel reads each window with a
dynamic slice of the arena resident in VMEM, so staged memory is O(arena).

Layout (the graphax ``BlockSparseTensor`` idiom: dense blocks + an index map
describing where each block lives in the sparse whole):

  grid   = (rows / BLOCK_ROWS,)
  in     : arena (alen,) VMEM, replicated to every grid step
           starts, lengths, pr, sq (BLOCK_ROWS,) per-step row metadata
  out    : (BLOCK_ROWS, LANES) result lanes
           [vet, ei, oc, pr, t, n, 0, 0]

Per row the kernel fuses what used to be four dispatches worth of work:

  slice -> bitonic sort -> prefix-sum SSE scan -> argmin cut -> capped
  linear extrapolation -> EI/OC reduction

Numerical contracts (the differential ladder leans on these):

- **Sort-in-kernel.**  The bitonic network is exact: comparisons and
  selects only, so the sorted rows are bitwise ``jnp.sort`` (+inf padding
  sorts to the tail and is masked off).  This folds in the long-standing
  "fused sort" kernel item — callers hand the kernel *raw* windows.
- **Reference-rounding, padding-invariant scans.**  In interpret mode the
  prefix sums are ``jnp.cumsum`` — the *same rounding* as the jnp reference
  scan, so the SSE landscape tracks ``core.changepoint.two_segment_sse`` to
  the ulp and near-tie argmins (1e-4-relative ties are routine on bucketed
  log curves) never flip across the ladder.  ``jnp.cumsum``'s per-position
  value is also independent of the padded row width (verified bitwise on
  CPU), so a window vets identically whether launched from its own stream
  (rows padded to its window) or from a coalesced mux / shard launch padded
  to the fleet's longest window — which keeps sharded fleets equal to the
  single-mux oracle.  The compiled path swaps in an unrolled Hillis-Steele
  ladder (Mosaic has no cumsum primitive); it is padding-invariant by
  construction — position ``i`` is final after ceil(log2(i+1)) steps, later
  steps add shifted-in zeros — but its rounding differs from the reference
  by a few ulp, so compiled-vs-interpret near-tie flips carry the same
  documented caveat as ``kernels.changepoint``.
- **Ring prefix sums.**  PR comes from f64 prefix sums over the arena,
  computed once on the host and handed in per row — overlapping windows
  share that work instead of re-reducing their rows, and a window's PR is
  exact to f32 rounding rather than carrying f32 accumulation error across
  the window.  (The SSE totals are *not* taken from the ring sums: the
  scan is centered, so its totals are read from the centered cumsum tails,
  exactly as the reference computes them.)
- Everything else is f32 on midpoint-element-centered prefix sums — the
  same centering, same f64-precomputed closed forms as
  ``core.changepoint.two_segment_sse``.  The pivot is an exact element
  pick (no reduction rounding), so reference-consistency and absolute
  conditioning agree here instead of trading off (see
  ``kernels.changepoint`` for the history of that trade).

TPU caveat: per-row slice starts are read from the VMEM metadata block; a
production TPU build would prefetch them to SMEM (PrefetchScalarGridSpec).
The compiled path is best-effort on this CPU container — interpret mode is
the tested oracle (see ``kernels.runtime``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_window_vet_scan", "BLOCK_ROWS", "LANES"]

BLOCK_ROWS = 8  # rows (windows) per grid step
LANES = 8  # output lanes per row: [vet, ei, oc, pr, t, n, pad, pad]

_TINY = 1e-12  # matches core.vet._TINY (log-space floor)


def _prefix_sum(x, *, reference_rounding: bool):
    """Inclusive prefix sum along the last axis.

    ``reference_rounding=True`` (interpret mode) uses ``jnp.cumsum`` — the
    jnp reference scan's exact rounding, which is what keeps near-tie
    argmins from flipping across the differential ladder.  The compiled
    path unrolls a Hillis-Steele ladder instead (the width is static and
    pow2); both are invariant to the padded row width — the additions
    contributing to position ``i`` depend only on ``i`` — so differently
    padded launches agree bitwise.
    """
    if reference_rounding:
        return jnp.cumsum(x, axis=-1)
    width = x.shape[-1]
    d = 1
    while d < width:
        shifted = jnp.concatenate(
            [jnp.zeros_like(x[..., :d]), x[..., :-d]], axis=-1)
        x = x + shifted
        d *= 2
    return x


def _bitonic_sort(x):
    """Ascending bitonic sort of each row; width must be pow2.

    Exact (compare/select only): bitwise ``jnp.sort`` per row.  +inf padding
    sorts to the tail.
    """
    rows, width = x.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (rows, width), 1)
    k = 2
    while k <= width:
        j = k // 2
        while j >= 1:
            partner = x.reshape(rows, -1, 2, j)[:, :, ::-1, :] \
                .reshape(rows, width)
            ascending = (iota & k) == 0
            keep_min = ascending == ((iota & j) == 0)
            x = jnp.where(keep_min, jnp.minimum(x, partner),
                          jnp.maximum(x, partner))
            j //= 2
        k *= 2
    return x


def _seg_sse(n1, sx, sy, sxx, sxy, syy):
    # Identical closed form to core.changepoint.segment_sse_terms.
    n1 = jnp.maximum(n1, 1.0)
    sxx_c = sxx - sx * sx / n1
    sxy_c = sxy - sx * sy / n1
    syy_c = syy - sy * sy / n1
    safe = sxx_c > 0.0
    sse = syy_c - jnp.where(safe,
                            sxy_c * sxy_c / jnp.where(safe, sxx_c, 1.0), 0.0)
    return jnp.maximum(sse, 0.0)


def _pick(values, index):
    """values[r, index[r]] via a masked reduction (no gather primitive)."""
    rows, width = values.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (rows, width), 1)
    return jnp.sum(jnp.where(iota == index[:, None], values, 0.0), axis=1)


def _kernel(arena_ref, starts_ref, lengths_ref, pr_ref, sq_ref, out_ref, *,
            lmax: int, block_rows: int, omega: int, log_space: bool,
            reference_rounding: bool):
    # ---- block-sparse load: one dynamic arena slice per row --------------
    rows = [arena_ref[pl.ds(starts_ref[j], lmax)] for j in range(block_rows)]
    y = jnp.stack(rows)  # (B, lmax) f32
    n = lengths_ref[...]  # (B,) int32
    pr = pr_ref[...]  # (B,) f32: f64 ring prefix-sum window totals
    del sq_ref  # totals of squares: unused since the centered scan landed

    iota = jax.lax.broadcasted_iota(jnp.int32, (block_rows, lmax), 1)
    mask = iota < n[:, None]
    nf = n.astype(jnp.float32)[:, None]

    # ---- sort-in-kernel (exact) ------------------------------------------
    y = _bitonic_sort(jnp.where(mask, y, jnp.inf))

    # ---- change-point scan on the (optionally logged) sorted row ---------
    if log_space:
        z = jnp.log(jnp.maximum(y, _TINY))
    else:
        z = y
    # Midpoint-element centering, mirroring core.changepoint.two_segment_sse:
    # an element pick is exact, so this row subtracts the bitwise-same pivot
    # the reference scan subtracts and the SSE landscapes stay in ulp
    # agreement (a mean pivot would round differently over padded rows).
    pivot = _pick(jnp.where(mask, z, 0.0), (n - 1) // 2)
    zm = jnp.where(mask, z - pivot[:, None], 0.0)
    kf = (iota + 1).astype(jnp.float32)

    cy = _prefix_sum(zm, reference_rounding=reference_rounding)
    cyy = _prefix_sum(zm * zm, reference_rounding=reference_rounding)
    cxy = _prefix_sum(kf * zm, reference_rounding=reference_rounding)

    # Totals read from the centered scans at the row's last valid position —
    # the same values the reference's cumsum tail yields.  (The host's f64
    # ring totals pr/sq can't serve the centered scan; pr still feeds the
    # PR output lane below.)
    last = iota == n[:, None] - 1
    tot_y = jnp.sum(jnp.where(last, cy, 0.0), axis=1)[:, None]
    tot_yy = jnp.sum(jnp.where(last, cyy, 0.0), axis=1)[:, None]
    tot_xy = jnp.sum(jnp.where(last, cxy, 0.0), axis=1)[:, None]

    sx1 = kf * (kf + 1.0) * 0.5
    sxx1 = kf * (kf + 1.0) * (2.0 * kf + 1.0) / 6.0
    sx_tot = nf * (nf + 1.0) * 0.5
    sxx_tot = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 6.0

    sse1 = _seg_sse(kf, sx1, cy, sxx1, cxy, cyy)
    sse2 = _seg_sse(nf - kf, sx_tot - sx1, tot_y - cy, sxx_tot - sxx1,
                    tot_xy - cxy, tot_yy - cyy)

    omf = jnp.float32(omega)
    valid = (kf >= omf) & (kf <= nf - omf) & mask
    sse = jnp.where(valid, sse1 + sse2, jnp.inf)
    tb = (jnp.argmin(sse, axis=1) + 1).astype(jnp.int32)  # (B,) 1-indexed

    # ---- capped linear extrapolation -> EI / OC --------------------------
    i = jnp.clip(tb - 1, 1, n - 1)
    anchor = _pick(y, i)
    slope = jnp.maximum(anchor - _pick(y, i - 1), 0.0)
    rank = iota + 1
    prefix = rank <= tb[:, None]
    g = anchor[:, None] + slope[:, None] * (rank - tb[:, None]) \
        .astype(jnp.float32)
    g = jnp.minimum(g, y)  # ideal never exceeds observed
    ei = jnp.sum(jnp.where(mask, jnp.where(prefix, y, g), 0.0), axis=1)
    oc = jnp.sum(jnp.where(mask, jnp.where(prefix, 0.0, y - g), 0.0), axis=1)

    out = jnp.stack([pr / ei, ei, oc, pr, tb.astype(jnp.float32),
                     nf[:, 0], jnp.zeros_like(ei), jnp.zeros_like(ei)],
                    axis=1)
    out_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("lmax", "block_rows", "omega", "log_space", "interpret"))
def fused_window_vet_scan(arena, starts, lengths, pr, sq, *, lmax: int,
                          block_rows: int = BLOCK_ROWS, omega: int = 3,
                          log_space: bool = True, interpret: bool = True):
    """One fused launch over a padded block-sparse window set.

    arena: (alen,) f32, alen pow2 and >= max(starts) + lmax (no slice clamp);
    starts/lengths: (rows,) int32, rows a multiple of ``block_rows``;
    pr/sq: (rows,) f32 window sums / sums of squares from the host's f64
    arena prefix sums (``sq`` is kept for call-site stability; the centered
    SSE scan derives its totals in-kernel); lmax: pow2 padded window width.
    Returns (rows, LANES) f32: [vet, ei, oc, pr, t, n, 0, 0] per row.
    """
    rows = starts.shape[0]
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    kern = functools.partial(_kernel, lmax=lmax, block_rows=block_rows,
                             omega=omega, log_space=log_space,
                             reference_rounding=interpret)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(arena.shape, lambda i: (0,)),  # whole-arena VMEM
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(arena, starts, lengths, pr, sq)
