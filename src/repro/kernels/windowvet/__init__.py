"""Fused block-sparse window-vet kernel (kernel/ops/ref triple).

``fused_window_vet`` vets an arbitrary ragged window set over a shared
arena in one Pallas launch — sort, change-point scan, and EI/OC
extrapolation fused per row, PR via shared f64 ring prefix sums.  See
``kernel.py`` for the launch layout and the numerical contracts,
``ref.py`` for the scalar oracle at the root of the differential ladder.
"""

from .kernel import BLOCK_ROWS, LANES, fused_window_vet_scan
from .ops import fused_window_vet
from .ref import ref_window_vet

__all__ = ["BLOCK_ROWS", "LANES", "fused_window_vet",
           "fused_window_vet_scan", "ref_window_vet"]
