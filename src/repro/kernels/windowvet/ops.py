"""Host wrapper: ragged (arena, windows) -> one fused kernel launch.

``fused_window_vet`` is the entry point ``repro.engine`` routes through: it
computes the f64 ring prefix sums once (PR and the raw-space SSE totals for
*every* window come from two O(arena) cumsums — overlapping windows share
the work), pads the row set and the arena to launch-stable pow2 shapes, and
hands the kernel the block-sparse row map.  Staged bytes are O(arena + rows)
— never the O(windows x length) gather matrix of the materialized path.

Padding contract:

- rows pad to pow2 (>= BLOCK_ROWS) by repeating the last row, so live
  window counts share O(log) compiled shapes — same policy as
  ``VetEngine.pad_rows_pow2`` on the gather path;
- ``lmax`` (the padded window width) is the pow2 cover of the longest
  window: per-row work keys on the launch's longest window, not on the
  fleet's (rows are masked past their own length, and the scans are
  padding-invariant — see kernel.py);
- the arena pads to a pow2 at least ``arena + lmax`` so every row's
  ``pl.ds(start, lmax)`` slice stays in bounds (XLA clamps out-of-range
  dynamic slices — padding keeps clamping from ever triggering).
"""

from __future__ import annotations

import numpy as np

from ..runtime import resolve_interpret
from .kernel import BLOCK_ROWS, fused_window_vet_scan

__all__ = ["fused_window_vet", "staged_bytes"]


def _pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def staged_bytes(arena_len: int, rows: int, max_len: int) -> int:
    """Bytes one fused launch stages for the device: the padded f32 arena
    plus four per-row metadata vectors (starts/lengths/pr/sq).  O(arena +
    rows) — the number the benchmarks compare against the gather path's
    O(windows x length) matrix."""
    lmax = max(8, _pow2(int(max_len)))
    rows_p = max(BLOCK_ROWS, _pow2(int(rows)))
    return 4 * _pow2(int(arena_len) + lmax) + 4 * 4 * rows_p


def fused_window_vet(arena, starts, lengths, *, omega: int = 3,
                     cut_space: str = "log", interpret=None,
                     block_rows: int = BLOCK_ROWS):
    """Vet every window ``arena[starts[r] : starts[r] + lengths[r])`` fused.

    Args:
        arena: 1-D record-time buffer the windows index into.
        starts: (rows,) window start offsets into ``arena``.
        lengths: (rows,) window lengths (each >= 2, fitting the arena).
        omega / cut_space: estimator parameters (``vet_task`` semantics;
            the fused path is the non-bucketed estimator — the engine's
            gate keeps bucketed rows on the gather path).
        interpret: Pallas mode; ``None`` resolves the platform policy
            (``kernels.runtime.resolve_interpret``).
        block_rows: kernel rows per grid step.

    Returns:
        ``(vet, ei, oc, pr, t, n)`` host arrays, one entry per input row.
    """
    a64 = np.asarray(arena, dtype=np.float64).ravel()
    starts = np.asarray(starts, dtype=np.int64).ravel()
    lengths = np.asarray(lengths, dtype=np.int64).ravel()
    rows = starts.size
    if rows == 0:
        raise ValueError("fused_window_vet needs at least one window")
    if rows != lengths.size:
        raise ValueError(f"starts ({rows}) and lengths ({lengths.size}) "
                         f"disagree")
    if lengths.min() < 2:
        raise ValueError("every window must cover >= 2 records")
    if starts.min() < 0 or (starts + lengths).max() > a64.size:
        raise ValueError("window out of arena bounds")

    # Ring prefix sums (and of squares), one f64 pass over the arena: every
    # window's PR / sum-of-squares is a difference of two entries.
    ps = np.concatenate([[0.0], np.cumsum(a64)])
    ps2 = np.concatenate([[0.0], np.cumsum(a64 * a64)])
    pr64 = ps[starts + lengths] - ps[starts]
    sq64 = ps2[starts + lengths] - ps2[starts]

    lmax = max(8, _pow2(int(lengths.max())))
    rows_p = max(block_rows, _pow2(rows))
    pad = rows_p - rows
    if pad:
        starts_p = np.concatenate([starts, np.repeat(starts[-1:], pad)])
        lengths_p = np.concatenate([lengths, np.repeat(lengths[-1:], pad)])
        pr_p = np.concatenate([pr64, np.repeat(pr64[-1:], pad)])
        sq_p = np.concatenate([sq64, np.repeat(sq64[-1:], pad)])
    else:
        starts_p, lengths_p, pr_p, sq_p = starts, lengths, pr64, sq64

    alen = _pow2(a64.size + lmax)
    arena_f32 = np.zeros(alen, dtype=np.float32)
    arena_f32[:a64.size] = a64

    out = fused_window_vet_scan(
        arena_f32,
        starts_p.astype(np.int32),
        lengths_p.astype(np.int32),
        pr_p.astype(np.float32),
        sq_p.astype(np.float32),
        lmax=lmax,
        block_rows=block_rows,
        omega=omega,
        log_space=(cut_space == "log"),
        interpret=resolve_interpret(interpret),
    )
    out = np.asarray(out)[:rows]
    ei = out[:, 1].astype(np.float64)
    oc = out[:, 2].astype(np.float64)
    # PR (and vet's numerator) from the f64 ring prefix sums — exact to f32
    # rounding, matching the scalar oracle's sum to well under 1e-5.
    return (pr64 / ei, ei, oc, pr64, out[:, 4].astype(np.int32),
            lengths.astype(np.int64))
