"""Shared Pallas runtime policy for the kernel packages.

Every kernel here runs in one of two modes:

- **compiled** — ``pl.pallas_call(..., interpret=False)``: the real Mosaic
  lowering.  Only meaningful on a TPU host.
- **interpret** — the kernel body is evaluated op-by-op by XLA on the host.
  Bit-for-bit the semantics of the kernel jaxpr, so it doubles as the
  *oracle* for the compiled path (the differential suites run it on CPU
  containers).

Historically each kernel hardcoded ``interpret=True`` — correct on the CPU
containers the tests run on, silently wrong on a real TPU (the kernel would
interpret instead of compile and the "kernel" benchmark numbers would be
the interpreter's).  ``resolve_interpret`` centralizes the default:

1. an explicit ``interpret=`` argument always wins;
2. else the ``REPRO_PALLAS_INTERPRET`` environment variable (``1/true/yes``
   forces interpret mode, ``0/false/no`` forces compiled — the escape hatch
   for debugging a miscompile on TPU or smoke-testing lowering on CPU).
   Child processes inherit the parent's environment, so exporting it is
   also the blanket *worker-side* override for the transport layer
   (``repro.fleet.transport``) — every shard worker resolves the same mode
   without any probe;
3. else the platform: ``jax.default_backend()`` is probed once per process
   — TPU hosts compile, everything else interprets.

The platform probe is **lazy and fork-safe**: it runs on the first kernel
dispatch that actually needs it, never at import or engine-construction
time.  Backend discovery spins up threads (and on TPU touches the device
runtime), so a probe baked into a constructor would fire inside every
transport worker the moment it builds its engine — and a ``fork()``ed
child re-running discovery mid-probe can deadlock TPU initialization.
Workers instead inherit the parent's already-resolved policy via
``seed_platform_default`` and never probe at all.
"""

from __future__ import annotations

import os
from typing import Optional

import jax

__all__ = [
    "resolve_interpret",
    "default_interpret",
    "seed_platform_default",
    "platform_default_hint",
]

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

ENV_VAR = "REPRO_PALLAS_INTERPRET"

# Memoized platform policy.  None = not probed yet; the probe is deferred
# to the first resolve that needs it (module state instead of lru_cache so
# a worker process can be seeded without triggering the probe — see
# seed_platform_default).
_PLATFORM: Optional[bool] = None


def _platform_default() -> bool:
    # Probed once per process: backend discovery is stable for its lifetime.
    global _PLATFORM
    if _PLATFORM is None:
        _PLATFORM = jax.default_backend() != "tpu"
    return _PLATFORM


def seed_platform_default(interpret: Optional[bool]) -> None:
    """Install a pre-resolved platform policy without probing.

    The transport driver calls this in every shard worker with the parent
    process's already-memoized policy (``platform_default_hint()``), so
    workers never run backend discovery themselves — the fork-safety half
    of the lazy-probe contract.  ``None`` (parent never probed either)
    leaves the lazy probe armed.  ``REPRO_PALLAS_INTERPRET`` still wins
    over the seed: ``default_interpret`` checks the environment first.
    """
    global _PLATFORM
    if interpret is not None:
        _PLATFORM = bool(interpret)


def platform_default_hint() -> Optional[bool]:
    """This process's memoized platform policy, or ``None`` if it has never
    been probed (nor seeded) — what a driver forwards to its workers."""
    return _PLATFORM


def default_interpret() -> bool:
    """The resolved process-wide default (env override, else platform)."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        val = env.strip().lower()
        if val in _TRUTHY:
            return True
        if val in _FALSY:
            return False
        raise ValueError(
            f"{ENV_VAR}={env!r} is not a boolean; use one of "
            f"{_TRUTHY + _FALSY}")
    return _platform_default()


def resolve_interpret(interpret=None) -> bool:
    """Resolve an ``interpret=`` kernel argument to a concrete bool.

    ``None`` (the kernel-op default) means "platform policy": compiled on
    TPU, interpret elsewhere, overridable via ``REPRO_PALLAS_INTERPRET``.
    An explicit bool passes through untouched.
    """
    if interpret is None:
        return default_interpret()
    return bool(interpret)
