"""Shared Pallas runtime policy for the kernel packages.

Every kernel here runs in one of two modes:

- **compiled** — ``pl.pallas_call(..., interpret=False)``: the real Mosaic
  lowering.  Only meaningful on a TPU host.
- **interpret** — the kernel body is evaluated op-by-op by XLA on the host.
  Bit-for-bit the semantics of the kernel jaxpr, so it doubles as the
  *oracle* for the compiled path (the differential suites run it on CPU
  containers).

Historically each kernel hardcoded ``interpret=True`` — correct on the CPU
containers the tests run on, silently wrong on a real TPU (the kernel would
interpret instead of compile and the "kernel" benchmark numbers would be
the interpreter's).  ``resolve_interpret`` centralizes the default:

1. an explicit ``interpret=`` argument always wins;
2. else the ``REPRO_PALLAS_INTERPRET`` environment variable (``1/true/yes``
   forces interpret mode, ``0/false/no`` forces compiled — the escape hatch
   for debugging a miscompile on TPU or smoke-testing lowering on CPU);
3. else the platform: ``jax.default_backend()`` is probed once per process
   — TPU hosts compile, everything else interprets.
"""

from __future__ import annotations

import functools
import os

import jax

__all__ = ["resolve_interpret", "default_interpret"]

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

ENV_VAR = "REPRO_PALLAS_INTERPRET"


@functools.lru_cache(maxsize=None)
def _platform_default() -> bool:
    # Probed once per process: backend discovery is stable for its lifetime.
    return jax.default_backend() != "tpu"


def default_interpret() -> bool:
    """The resolved process-wide default (env override, else platform)."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        val = env.strip().lower()
        if val in _TRUTHY:
            return True
        if val in _FALSY:
            return False
        raise ValueError(
            f"{ENV_VAR}={env!r} is not a boolean; use one of "
            f"{_TRUTHY + _FALSY}")
    return _platform_default()


def resolve_interpret(interpret=None) -> bool:
    """Resolve an ``interpret=`` kernel argument to a concrete bool.

    ``None`` (the kernel-op default) means "platform policy": compiled on
    TPU, interpret elsewhere, overridable via ``REPRO_PALLAS_INTERPRET``.
    An explicit bool passes through untouched.
    """
    if interpret is None:
        return default_interpret()
    return bool(interpret)
