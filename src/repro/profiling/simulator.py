"""Ground-truth record-time simulator (paper Fig. 4/5 cost model).

Generates synthetic record processing times with the paper's decomposition —

    time(record) = base cost (CPU + memory, near-constant with slight ramp)
                 + unavoidable I/O cost (sparse, fixed-ish: disk access every
                   ~few ms of work; the paper's "normal (CPU+I/O)" records)
                 + reducible overhead (sparse, heavy-tailed Pareto: context
                   switching, blocked I/O — what an optimizer could remove)

— and returns the *true* ideal total alongside, so tests can verify that EI
recovers the ideal and OC recovers the injected overhead.  This is the
controlled-validation path; the contention harness provides the real-measurement
path.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["SimProfile", "simulate_records", "simulate_job"]


class SimProfile(NamedTuple):
    times: np.ndarray  # observed per-record seconds
    ideal: np.ndarray  # per-record seconds without reducible overhead
    overhead: np.ndarray  # injected reducible overhead per record
    true_ei: float  # sum(ideal)
    true_oc: float  # sum(overhead)

    @property
    def true_vet(self) -> float:
        return float((self.true_ei + self.true_oc) / self.true_ei)


def simulate_records(
    n: int,
    *,
    base: float = 1e-3,
    base_jitter: float = 0.03,
    ramp: float = 0.10,
    io_frac: float = 0.05,
    io_cost: float = 4e-3,
    overhead_frac: float = 0.15,
    pareto_alpha: float = 1.3,
    overhead_scale: float = 5e-3,
    seed: int = 0,
) -> SimProfile:
    """One task's worth of records.

    base/base_jitter/ramp: the ideal CPU curve i(x) — near-flat with a mild
    deterministic ramp (the paper's i(x) is drawn slightly increasing).
    io_frac/io_cost: fraction of records that pay an unavoidable disk access.
    overhead_frac/pareto_alpha/overhead_scale: the reducible heavy tail
    (alpha ~ 1.3 as measured by the paper).
    """
    rng = np.random.default_rng(seed)
    jitter = rng.normal(0.0, base_jitter * base, n).clip(-0.5 * base, None)
    ramp_part = base * ramp * np.linspace(0.0, 1.0, n)
    cpu = base + jitter + ramp_part

    io_mask = rng.random(n) < io_frac
    io = np.where(io_mask, io_cost * (0.8 + 0.4 * rng.random(n)), 0.0)

    ov_mask = rng.random(n) < overhead_frac
    ov = np.where(ov_mask, overhead_scale * rng.pareto(pareto_alpha, n), 0.0)

    ideal = cpu + io
    times = ideal + ov
    return SimProfile(
        times=times,
        ideal=ideal,
        overhead=ov,
        true_ei=float(ideal.sum()),
        true_oc=float(ov.sum()),
    )


def simulate_job(
    n_tasks: int,
    records_per_task: int,
    *,
    utilization_factor: float = 1.0,
    seed: int = 0,
    **kwargs,
) -> list:
    """A job = several tasks from the same population.  ``utilization_factor``
    scales only the *overhead* channel (more slots sharing the core => more
    reducible overhead => higher vet, constant EI — the Table 2 mechanism)."""
    profiles = []
    for i in range(n_tasks):
        kw = dict(kwargs)
        kw["overhead_scale"] = kw.get("overhead_scale", 5e-3) * utilization_factor
        kw["overhead_frac"] = min(
            0.95, kw.get("overhead_frac", 0.15) * max(1.0, utilization_factor ** 0.5)
        )
        profiles.append(simulate_records(records_per_task, seed=seed * 1000 + i, **kw))
    return profiles
