"""Real-measurement oversubscription harness (paper Table 2 / Fig. 13).

Runs W concurrent worker "tasks" on the host, each processing a stream of
records (small blocking computations), timing every record.  With W workers
sharing the host core(s) — exactly the paper's "slots per node > cores"
regime — most records still complete within their OS scheduling quantum
(record work is ~0.1-1 ms << quantum), but a heavy tail of records absorbs the
context switches and run-queue waits.  PR grows with W while EI stays put:
the paper's Table 2 phenomenon, measured for real.

NumPy/JAX release the GIL during compute, so plain threads genuinely contend
for the core.
"""

from __future__ import annotations

import threading
from typing import Callable, List, Optional

import numpy as np

from .recorder import RecordProfiler

__all__ = ["make_record_work", "run_contended_job"]


def make_record_work(size: int = 96, reps: int = 2) -> Callable[[], float]:
    """A deterministic ~0.2-1 ms record computation (GIL-releasing matmuls).

    Returns a closure; calling it processes "one record" and returns a checksum
    (prevents dead-code elimination).
    """
    a = np.random.default_rng(0).standard_normal((size, size)).astype(np.float32)

    def work() -> float:
        x = a
        for _ in range(reps):
            x = x @ a
        return float(x[0, 0])

    return work


def run_contended_job(
    n_tasks: int,
    records_per_task: int,
    *,
    work: Optional[Callable[[], float]] = None,
    unit: int = 5,
    per_record_hook: Optional[Callable[[int, int], None]] = None,
) -> List[np.ndarray]:
    """Run ``n_tasks`` concurrent tasks; return per-task unit-grouped times.

    ``per_record_hook(task_id, record_id)`` (optional) runs outside the timed
    region — e.g. to inject I/O stalls for the Fig. 13 HDD/SSD contrast.
    """
    work = work or make_record_work()
    profilers = [RecordProfiler(unit=unit, name=f"task{i}") for i in range(n_tasks)]
    barrier = threading.Barrier(n_tasks)
    errors: List[BaseException] = []

    def run(task_id: int) -> None:
        try:
            prof = profilers[task_id]
            work()  # warm caches outside the profile
            barrier.wait()
            for r in range(records_per_task):
                if per_record_hook is not None:
                    per_record_hook(task_id, r)
                with prof.record():
                    work()
        except BaseException as e:  # pragma: no cover - surfaced below
            errors.append(e)

    threads = [threading.Thread(target=run, args=(i,)) for i in range(n_tasks)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return [p.unit_times() for p in profilers]
