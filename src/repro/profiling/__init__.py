"""Profiling substrate: record-level timing (paper §5.2), ground-truth
simulation (Fig. 4/5 cost model), and a real oversubscription harness
(Table 2 regime)."""

from .contention import make_record_work, run_contended_job
from .recorder import PhaseTimer, RecordProfiler
from .simulator import SimProfile, simulate_job, simulate_records

__all__ = [
    "make_record_work",
    "run_contended_job",
    "PhaseTimer",
    "RecordProfiler",
    "SimProfile",
    "simulate_job",
    "simulate_records",
]
