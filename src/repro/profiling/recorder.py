"""Record-level profiler (paper §5.2).

The paper modifies Hadoop to time the processing of *records* rather than
sub-phases, grouping records into units (empirically 5 records/unit) to keep
profiling overhead ~5% instead of Starfish's 10-50%.  Here a "record" is one
profiled work unit of the framework — a microbatch step, a decode-step batch,
or a data-pipeline fetch — and the same unit-grouping knob applies.

Also provides sub-phase timing ("spill"-analogue phases: data fetch,
checkpoint write) so the Fig. 3 constancy benchmark can contrast them with
record times.

Both timers are thin shims over ``repro.obs.trace.timed`` — one clock
source for the whole repo.  Pass ``tracer=`` and every record / phase also
lands in the trace as a ``record.<name>`` / ``phase.<name>`` span; without
a tracer the stopwatch path is allocation-free and nothing else changes.
"""

from __future__ import annotations

import contextlib
from collections import defaultdict
from typing import Callable, Dict, List

import numpy as np

from ..obs.trace import timed as _timed

__all__ = ["RecordProfiler", "PhaseTimer"]


class RecordProfiler:
    """Accumulates per-record wall times, grouped in units of ``unit`` records.

    Usage::

        prof = RecordProfiler(unit=5)
        for batch in stream:
            with prof.record():
                out = step(batch)            # must block (sync dispatch on CPU)
        times = prof.unit_times()            # seconds per unit, np.float64
    """

    def __init__(self, unit: int = 5, name: str = "task", tracer=None):
        if unit < 1:
            raise ValueError("unit must be >= 1")
        self.unit = unit
        self.name = name
        self.tracer = tracer
        self._raw_ns: List[int] = []

    @contextlib.contextmanager
    def record(self):
        sw = _timed(self.tracer, "record." + self.name)
        try:
            with sw:
                yield
        finally:
            # sw.dur is set by __exit__ (before this finally runs), so a
            # record is kept even when the timed body raises — same contract
            # as the old perf_counter_ns try/finally.
            self._raw_ns.append(int(sw.dur * 1e9))

    def wrap(self, fn: Callable) -> Callable:
        """Return fn wrapped so every call is timed as one record."""

        def timed(*args, **kwargs):
            with self.record():
                return fn(*args, **kwargs)

        return timed

    @property
    def num_records(self) -> int:
        return len(self._raw_ns)

    def record_times(self) -> np.ndarray:
        """Raw per-record seconds."""
        return np.asarray(self._raw_ns, dtype=np.float64) * 1e-9

    def unit_times(self, start: int = 0) -> np.ndarray:
        """Per-unit seconds: consecutive groups of ``unit`` records summed
        (the paper's cost/accuracy balance). Trailing partial unit dropped.

        ``start`` skips the first ``start`` units, touching only the newer
        records — O(new units), so a live consumer polling for freshly
        completed units inside a hot loop pays for the delta, not the run.
        """
        m = (len(self._raw_ns) // self.unit) * self.unit
        lo = int(start) * self.unit
        if lo >= m:
            return np.zeros((0,), np.float64)
        raw = np.asarray(self._raw_ns[lo:m], dtype=np.float64) * 1e-9
        return raw.reshape(-1, self.unit).sum(axis=1)

    def total(self) -> float:
        return float(self.record_times().sum())

    def reset(self) -> None:
        self._raw_ns.clear()


class PhaseTimer:
    """Sub-phase wall times keyed by name (read-map / spill / merge analogue)."""

    def __init__(self, tracer=None):
        self.tracer = tracer
        self._ns: Dict[str, List[int]] = defaultdict(list)

    @contextlib.contextmanager
    def phase(self, name: str):
        sw = _timed(self.tracer, "phase." + name)
        try:
            with sw:
                yield
        finally:
            self._ns[name].append(int(sw.dur * 1e9))

    def times(self, name: str) -> np.ndarray:
        return np.asarray(self._ns.get(name, ()), dtype=np.float64) * 1e-9

    def totals(self) -> Dict[str, float]:
        return {k: float(np.sum(v) * 1e-9) for k, v in self._ns.items()}

    def names(self):
        return list(self._ns)
