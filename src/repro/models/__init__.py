"""Model zoo substrate: pure-jnp blocks + segment-scanned full models."""

from .layers import NULL_CTX, ShardCtx
from .model import (
    decode_step,
    embed_inputs,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    segments_of,
)

__all__ = [
    "NULL_CTX",
    "ShardCtx",
    "decode_step",
    "embed_inputs",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
    "segments_of",
]
