"""Block-level assembly: attention blocks (GQA / SWA / MLA), Mamba2 blocks,
pre-norm residual wiring, and their decode-step variants with caches."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import NULL_CTX, ShardCtx

# =============================================================== GQA attention


def attn_init(key, cfg, dtype):
    """Projections stored FLAT (D, H*Dh): the fused dim is always a multiple
    of 128, so weights shard evenly over TP-16 even when the head count
    doesn't (e.g. 40 heads); the per-head reshape happens in apply, where
    GSPMD is free to pad the intermediate head sharding."""
    d, kh, dh = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    h = cfg.num_heads_padded
    if cfg.attention == "mla":
        return mla_init(key, cfg, dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, (h * dh,), dtype),
        "wk": L.dense_init(ks[1], d, (kh * dh,), dtype),
        "wv": L.dense_init(ks[2], d, (kh * dh,), dtype),
        "wo": L.dense_init(ks[3], h * dh, (d,), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kh * dh,), dtype)
        p["bv"] = jnp.zeros((kh * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _head_mask(cfg, dtype):
    """(Hp, 1) mask zeroing outputs of padded q heads (exact math)."""
    hp, h = cfg.num_heads_padded, cfg.num_heads
    if hp == h:
        return None
    return (jnp.arange(hp) < h).astype(dtype)[:, None]


def _project_qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    h, kh, dh = cfg.num_heads_padded, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kh, dh)
    v = v.reshape(b, s, kh, dh)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p, x, cfg, ctx: ShardCtx = NULL_CTX, *, q_chunk: int = 1024,
               unroll_chunks: bool = False):
    """Full-sequence attention (train / prefill). x: (B,S,D)."""
    if cfg.attention == "mla":
        return mla_apply(p, x, cfg, ctx, q_chunk=q_chunk, unroll_chunks=unroll_chunks)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions)
    # Force head-sharded attention internals (GSPMD pads 40 heads over 16).
    q = ctx.constrain(q, ctx.dp, None, ctx.tp_axis, None)
    k = ctx.constrain(k, ctx.dp, None, ctx.tp_axis, None)
    v = ctx.constrain(v, ctx.dp, None, ctx.tp_axis, None)
    window = cfg.swa_window if cfg.attention == "swa" else 0
    o = L.attention(q, k, v, causal=cfg.causal, window=window, q_chunk=q_chunk,
                    unroll_chunks=unroll_chunks)
    o = ctx.constrain(o, ctx.dp, None, ctx.tp_axis, None)
    hm = _head_mask(cfg, o.dtype)
    if hm is not None:
        o = o * hm
    return o.reshape(b, s, -1) @ p["wo"]


def attn_prefill(p, x, cfg, cache, *, q_chunk: int = 1024, unroll_chunks: bool = False):
    """Prefill: run full attention AND fill the cache for positions [0, S)."""
    if cfg.attention == "mla":
        return mla_prefill(p, x, cfg, cache, q_chunk=q_chunk, unroll_chunks=unroll_chunks)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions)
    window = cfg.swa_window if cfg.attention == "swa" else 0
    o = L.attention(q, k, v, causal=cfg.causal, window=window, q_chunk=q_chunk,
                    unroll_chunks=unroll_chunks)
    hm = _head_mask(cfg, o.dtype)
    if hm is not None:
        o = o * hm
    if "k_scale" in cache:
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, 0, axis=1),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks.astype(cache["k_scale"].dtype), 0, axis=1),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs.astype(cache["v_scale"].dtype), 0, axis=1),
        }
    else:
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=1),
        }
    return o.reshape(b, s, -1) @ p["wo"], new_cache


def attn_decode(p, x, cfg, cache, pos):
    """One-token decode. x: (B,1,D); cache {"k","v"}: (B,S_max,KH,Dh); pos is
    the index of the current token (cache holds pos valid entries before it)."""
    if cfg.attention == "mla":
        return mla_decode(p, x, cfg, cache, pos)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    int8kv = "k_scale" in cache
    if int8kv:
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, pos, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, pos, axis=1),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks.astype(cache["k_scale"].dtype), pos, axis=1),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs.astype(cache["v_scale"].dtype), pos, axis=1),
        }
        kc = _kv_dequant(cache["k"], cache["k_scale"], x.dtype)
        vc = _kv_dequant(cache["v"], cache["v_scale"], x.dtype)
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        cache = {"k": kc, "v": vc}
    window = cfg.swa_window if cfg.attention == "swa" else 0
    o = L.decode_attention(q, kc, vc, pos + 1, window=window)
    hm = _head_mask(cfg, o.dtype)
    if hm is not None:
        o = o * hm
    return o.reshape(b, 1, -1) @ p["wo"], cache


def attn_cache_shape(cfg, batch: int, s_max: int, dtype):
    if cfg.attention == "mla":
        return {
            "ckv": jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, s_max, cfg.qk_rope_dim), dtype),
        }
    kh, dh = cfg.num_kv_heads, cfg.head_dim
    if getattr(cfg, "kv_cache_dtype", "bf16") == "int8":
        # per-token-per-head symmetric int8 quantization; scales in `dtype`
        return {
            "k": jnp.zeros((batch, s_max, kh, dh), jnp.int8),
            "v": jnp.zeros((batch, s_max, kh, dh), jnp.int8),
            "k_scale": jnp.zeros((batch, s_max, kh), dtype),
            "v_scale": jnp.zeros((batch, s_max, kh), dtype),
        }
    return {
        "k": jnp.zeros((batch, s_max, kh, dh), dtype),
        "v": jnp.zeros((batch, s_max, kh, dh), dtype),
    }


def _kv_quant(x):
    """x: (..., Dh) -> (int8 payload, scale (...,))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)


# ================================================================ MLA (DSv2)


def mla_init(key, cfg, dtype):
    d, h = cfg.d_model, cfg.num_heads
    nope, rope, vdim, lora = (
        cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank,
    )
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], d, (h * (nope + rope),), dtype),
        "wkv_a": L.dense_init(ks[1], d, (lora + rope,), dtype),
        "kv_norm": jnp.ones((lora,), dtype),
        "wkv_b": L.dense_init(ks[2], lora, (h * (nope + vdim),), dtype),
        "wo": L.dense_init(ks[3], h * vdim, (d,), dtype),
    }


def _mla_qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    lora = cfg.kv_lora_rank
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    kv_a = x @ p["wkv_a"]  # (B,S,lora+rope)
    ckv, k_rope = kv_a[..., :lora], kv_a[..., lora:]
    ckv = L.rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def mla_apply(p, x, cfg, ctx: ShardCtx = NULL_CTX, *, q_chunk: int = 1024,
              unroll_chunks: bool = False):
    """Training/prefill MLA: materialize per-head K/V from the latent."""
    b, s, _ = x.shape
    nope, vdim = cfg.qk_nope_dim, cfg.v_head_dim
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, cfg, positions)
    q_nope = ctx.constrain(q_nope, ctx.dp, None, ctx.tp_axis, None)
    kv = (ckv @ p["wkv_b"]).reshape(b, s, cfg.num_heads, nope + vdim)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    h = k_nope.shape[2]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, cfg.qk_rope_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = ctx.constrain(k, ctx.dp, None, ctx.tp_axis, None)
    v = ctx.constrain(v, ctx.dp, None, ctx.tp_axis, None)
    scale = 1.0 / math.sqrt(nope + cfg.qk_rope_dim)
    o = L.attention(q, k, v, causal=cfg.causal, q_chunk=q_chunk, scale=scale,
                    unroll_chunks=unroll_chunks)
    return o.reshape(b, s, h * vdim) @ p["wo"]


def mla_prefill(p, x, cfg, cache, *, q_chunk: int = 1024, unroll_chunks: bool = False):
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    _, _, ckv, k_rope = _mla_qkv(p, x, cfg, positions)
    out = mla_apply(p, x, cfg, q_chunk=q_chunk, unroll_chunks=unroll_chunks)  # noqa: ctx default
    cc = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1)
    kc = jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope.astype(cache["krope"].dtype), 0, axis=1)
    return out, {"ckv": cc, "krope": kc}


def mla_decode(p, x, cfg, cache, pos):
    """Absorbed MLA decode: attention runs in the latent (lora) space —
    scores = q_nope W_uk . c_kv + q_rope . k_rope; values stay latent until
    the final W_uv @ W_o.  Cache per token is lora+rope floats (~576)."""
    b = x.shape[0]
    nope, rope, vdim, lora = (
        cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank,
    )
    h = cfg.num_heads
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, ckv_new, krope_new = _mla_qkv(p, x, cfg, positions)
    cc = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1)
    kc = jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope_new.astype(cache["krope"].dtype), pos, axis=1)

    wkb = p["wkv_b"].reshape(lora, h, nope + vdim)
    w_uk, w_uv = wkb[..., :nope], wkb[..., nope:]
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)  # (B,1,H,lora)
    s_lat = jnp.einsum("bqhl,bkl->bhqk", q_lat.astype(jnp.float32), cc.astype(jnp.float32))
    s_rope = jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(jnp.float32), kc.astype(jnp.float32))
    scale = 1.0 / math.sqrt(nope + rope)
    s = (s_lat + s_rope) * scale
    kpos = jnp.arange(cc.shape[1])
    s = jnp.where((kpos < pos + 1)[None, None, None, :], s, -jnp.inf)
    prob = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhqk,bkl->bqhl", prob, cc.astype(jnp.float32))
    o = jnp.einsum("bqhl,lhv->bqhv", ctx_lat, w_uv.astype(jnp.float32)).astype(x.dtype)
    return o.reshape(b, 1, h * vdim) @ p["wo"], {"ckv": cc, "krope": kc}


# ========================================================== transformer block


def block_init(key, cfg, dtype, *, moe: bool):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_init(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }
    if moe:
        p["moe"] = L.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def block_apply(p, x, cfg, ctx: ShardCtx = NULL_CTX, *, q_chunk: int = 1024,
                unroll_chunks: bool = False):
    """Pre-norm transformer block. Returns (x, aux_loss)."""
    h = x + attn_apply(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
                       ctx, q_chunk=q_chunk, unroll_chunks=unroll_chunks)
    z = L.rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = L.moe_apply(p["moe"], z, cfg, ctx)
    else:
        y, aux = L.mlp_apply(p["mlp"], z, ctx), jnp.zeros((), jnp.float32)
    return h + y, aux


def block_decode(p, x, cfg, cache, pos, ctx: ShardCtx = NULL_CTX):
    a, new_cache = attn_decode(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                               cfg, cache, pos)
    h = x + a
    z = L.rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = L.moe_apply(p["moe"], z, cfg, ctx)
    else:
        y = L.mlp_apply(p["mlp"], z, ctx)
    return h + y, new_cache


def block_prefill(p, x, cfg, cache, ctx: ShardCtx = NULL_CTX, *, q_chunk: int = 1024,
                  unroll_chunks: bool = False):
    a, new_cache = attn_prefill(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                                cfg, cache, q_chunk=q_chunk, unroll_chunks=unroll_chunks)
    h = x + a
    z = L.rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = L.moe_apply(p["moe"], z, cfg, ctx)
    else:
        y = L.mlp_apply(p["mlp"], z, ctx)
    return h + y, new_cache


# ================================================================ Mamba block


def mamba_block_init(key, cfg, dtype):
    return {"ln": jnp.ones((cfg.d_model,), dtype), "mixer": L.mamba_init(key, cfg, dtype)}


def mamba_block_apply(p, x, cfg, ctx: ShardCtx = NULL_CTX, *,
                      sequential: bool = False):
    return x + L.mamba_apply(p["mixer"], L.rms_norm(x, p["ln"], cfg.norm_eps),
                             cfg, sequential=sequential, ctx=ctx)


def mamba_block_decode(p, x, cfg, state):
    y, new_state = L.mamba_decode_step(p["mixer"], L.rms_norm(x, p["ln"], cfg.norm_eps),
                                       cfg, state)
    return x + y, new_state


def mamba_state_shape(cfg, batch: int, dtype):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }
