"""Model building blocks, pure-jnp (GSPMD-friendly), dtype-disciplined.

Everything here is a pure function over parameter pytrees.  Attention comes in
query-chunked form (each query block computes its complete score row, so no
online-softmax state is needed) to keep prefill_32k memory bounded; SWA slices
a static window of KV per query block, making compute O(T * window).

Precision policy: params/activations in ``dtype`` (bf16 for dry-run realism),
softmax/norms/SSD recurrences accumulate in float32.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

# ----------------------------------------------------------------- shard hooks


class ShardCtx(NamedTuple):
    """Sharding context threaded through model code.

    mesh=None => single-device (smoke tests); otherwise used by shard_map-based
    blocks (MoE) and with_sharding_constraint hints.  ``dp_axes``/``tp_axis``
    are logical mesh axis names.
    """

    mesh: Optional[object] = None
    dp_axes: tuple = ("data",)
    tp_axis: str = "model"
    # set inside shard_map bodies so blocks know to psum:
    inside_shard_map: bool = False

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def constrain(self, x, *spec_entries):
        """with_sharding_constraint when a mesh is present, else identity.

        Uneven sharding is allowed for intermediates (GSPMD pads), but axes
        larger than the dim itself (e.g. batch=1 over dp=16) are dropped —
        padding waste would exceed 2x there.
        """
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec

        def axis_size(entry):
            names = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in names:
                n *= self.mesh.shape[a]
            return n

        clean = []
        for dim, entry in zip(x.shape, spec_entries):
            if entry is not None and dim < axis_size(entry):
                entry = None
            clean.append(entry)
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, PartitionSpec(*clean))
        )


NULL_CTX = ShardCtx()


# ---------------------------------------------------------------------- inits
def dense_init(key, in_dim: int, out_shape, dtype) -> jax.Array:
    """Truncated-normal-ish fan-in init, flattened out dims."""
    shape = (in_dim,) + tuple(out_shape)
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------- RoPE
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotated pairwise; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,S,1,d/2)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ attention
def _sdpa_block(q, k, v, mask, scale):
    """Flat-head SDPA: q: (B,Sq,H,Dh)  k/v: (B,Sk,H,Dh)  mask: (Sq,Sk)|None.

    KV is pre-repeated to the full head count so the head dim shards cleanly
    over the TP axis even when kv_heads doesn't divide it (GQA on TP-16)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if mask is not None:
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return o


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    scale: Optional[float] = None,
    unroll_chunks: bool = False,
) -> jax.Array:
    """Query-chunked grouped attention.

    q: (B, S, H, Dh); k, v: (B, S, KH, Dh) with H % KH == 0.
    window > 0 => sliding-window (causal) attention with O(S*window) compute:
    each query block attends to a statically-sliced KV span of
    window + q_chunk positions ending at the block end.
    ``unroll_chunks`` unrolls the query-block loop (used by the dry-run cost
    compiles: XLA cost_analysis counts a scan body once, so rolled loops would
    undercount FLOPs by the trip count).
    Returns (B, S, H, Dh).
    """
    b, s, h, dh = q.shape
    kh = k.shape[2]
    dv = v.shape[-1]  # may differ from dh (MLA)
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    if g > 1:  # repeat KV to flat heads (shards over TP by q-heads)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qg = q

    if s <= q_chunk:  # single-block fast path
        pos = jnp.arange(s)
        mask = None
        if causal:
            mask = pos[:, None] >= pos[None, :]
        if window > 0:
            wmask = pos[:, None] - pos[None, :] < window
            mask = wmask if mask is None else (mask & wmask)
        o = _sdpa_block(qg, k, v, mask, scale)
        return o.reshape(b, s, h, dv)

    assert s % q_chunk == 0, (s, q_chunk)
    n_blocks = s // q_chunk

    def run_blocks(blk):
        if unroll_chunks:
            outs = [blk(jnp.asarray(i)) for i in range(n_blocks)]
            return jnp.stack(outs, axis=0)
        return lax.map(blk, jnp.arange(n_blocks))

    if window > 0:
        # Pad KV in front by `window` so every block slices a static span.
        pad = ((0, 0), (window, 0), (0, 0), (0, 0))
        kp, vp = jnp.pad(k, pad), jnp.pad(v, pad)
        span = window + q_chunk

        def blk(i):
            q0 = i * q_chunk
            qb = lax.dynamic_slice_in_dim(qg, q0, q_chunk, axis=1)
            kb = lax.dynamic_slice_in_dim(kp, q0, span, axis=1)
            vb = lax.dynamic_slice_in_dim(vp, q0, span, axis=1)
            qpos = q0 + jnp.arange(q_chunk)
            kpos = q0 - window + jnp.arange(span)  # absolute (pre-pad) positions
            m = (kpos[None, :] >= 0) & (qpos[:, None] >= kpos[None, :])
            m &= qpos[:, None] - kpos[None, :] < window
            return _sdpa_block(qb, kb, vb, m, scale)

        o = run_blocks(blk)  # (n, B, qc, H, Dv)
        o = jnp.moveaxis(o, 0, 1).reshape(b, s, h, dv)
        return o

    def blk(i):
        q0 = i * q_chunk
        qb = lax.dynamic_slice_in_dim(qg, q0, q_chunk, axis=1)
        qpos = q0 + jnp.arange(q_chunk)
        kpos = jnp.arange(s)
        m = qpos[:, None] >= kpos[None, :] if causal else None
        return _sdpa_block(qb, k, v, m, scale)

    o = run_blocks(blk)
    o = jnp.moveaxis(o, 0, 1).reshape(b, s, h, dv)
    return o


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos,
    *,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """One-token attention against a cache.

    q: (B, 1, H, Dh); caches: (B, S_max, KH, Dh); pos: current length (tokens
    written so far INCLUDING the current one at index pos-1).
    """
    b, _, h, dh = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, 1, kh, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache, preferred_element_type=jnp.float32)
    s = s * scale
    kpos = jnp.arange(k_cache.shape[1])
    valid = kpos < pos
    if window > 0:
        valid &= kpos >= pos - window
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, 1, h, dh)


# ------------------------------------------------------------------ gated MLP
def mlp_init(key, d: int, ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, (ff,), dtype),
        "up": dense_init(k2, d, (ff,), dtype),
        "down": dense_init(k3, ff, (d,), dtype),
    }


def mlp_apply(p, x, ctx: "ShardCtx" = None):
    """Gated MLP.  The hidden is pinned to (dp, None, tp): without the
    constraint GSPMD may replicate the (D,F) weights across BOTH mesh axes
    (observed on mistral-123B: three full f32 weight gathers per layer)."""
    h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    if ctx is not None and ctx.mesh is not None and h.ndim == 3:
        h = ctx.constrain(h, ctx.dp, None, ctx.tp_axis)
    return h @ p["down"]


# ------------------------------------------------------------------------ MoE
def moe_init(key, cfg, dtype):
    """Stacked routed experts + fused shared expert + router."""
    d, e, f = cfg.d_model, cfg.n_routed_experts, cfg.moe_d_ff
    keys = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    params = {
        "router": dense_init(keys[0], d, (e,), jnp.float32),
        "wg": (jax.random.normal(keys[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "wu": (jax.random.normal(keys[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "wd": (jax.random.normal(keys[3], (e, f, d), jnp.float32) / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        params["shared"] = mlp_init(keys[4], d, cfg.n_shared_experts * f, dtype)
    return params


def _moe_local(p, x2d, *, top_k: int, capacity: int, tp_axis: Optional[str],
               dp_axes: tuple = ()):
    """Token-choice MoE over the *local* expert shard.

    x2d: (T, D) local tokens; p["wg"/"wu"/"wd"]: (E_loc, D, F) local experts;
    p["router"]: (D, E_global) replicated.  Per expert, the top-`capacity`
    tokens by combine weight are gathered, processed, and scattered back;
    contributions are psum-ed over the expert-parallel axis.
    Returns (y, aux_loss).
    """
    t, d = x2d.shape
    e_glob = p["router"].shape[1]
    e_loc = p["wg"].shape[0]
    xf = x2d.astype(jnp.float32)
    logits = xf @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = lax.top_k(probs, top_k)  # (T, k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)
    combine = jnp.zeros((t, e_glob), jnp.float32).at[
        jnp.arange(t)[:, None], top_idx
    ].set(top_vals)

    # Which global experts are local to this shard?
    if tp_axis is not None:
        shard = lax.axis_index(tp_axis)
        first = shard * e_loc
    else:
        first = 0
    local_cols = first + jnp.arange(e_loc)
    combine_loc = combine[:, local_cols].T  # (E_loc, T)

    def one_expert(weights, wg, wu, wd):
        vals, idx = lax.top_k(weights, capacity)  # (C,)
        xs = x2d[idx]  # (C, D)
        h = jax.nn.silu(xs @ wg) * (xs @ wu)
        ys = (h @ wd).astype(jnp.float32) * vals[:, None]
        return idx, ys

    idxs, ys = jax.vmap(one_expert)(combine_loc, p["wg"], p["wu"], p["wd"])
    out = jnp.zeros((t, d), jnp.float32).at[idxs.reshape(-1)].add(
        ys.reshape(-1, d)
    )
    if tp_axis is not None:
        out = lax.psum(out, tp_axis)

    # Switch-style load-balance aux loss (global fractions: mean over ALL
    # mesh axes — tokens are dp-sharded, so a tp-only mean would leave the
    # "replicated" aux value shard-dependent).
    frac_tokens = jnp.mean(combine > 0, axis=0)  # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    axes = tuple(a for a in ((tp_axis,) if tp_axis else ()) + tuple(dp_axes))
    if axes:
        frac_tokens = lax.pmean(frac_tokens, axes)
        frac_probs = lax.pmean(frac_probs, axes)
    aux = e_glob * jnp.sum(frac_tokens * frac_probs)
    return out.astype(x2d.dtype), aux


def moe_apply(p, x, cfg, ctx: ShardCtx):
    """x: (B, S, D) -> (y, aux).  Sharded path: tokens stay sharded over the DP
    axes, experts are sharded over the TP axis, contributions psum over TP —
    the same collective pattern as a tensor-parallel MLP."""
    b, s, d = x.shape
    tokens = b * s

    def run(xloc, params, tp_axis, t_local, dp_axes=()):
        cap = max(1, int(t_local * cfg.moe_top_k * cfg.capacity_factor)
                  // cfg.n_routed_experts)
        cap = min(cap, t_local)
        y, aux = _moe_local(params, xloc.reshape(-1, d), top_k=cfg.moe_top_k,
                            capacity=cap, tp_axis=tp_axis, dp_axes=dp_axes)
        return y.reshape(xloc.shape), aux

    if ctx.mesh is None:
        y, aux = run(x, p, None, tokens)
    else:
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
        dp_size = 1
        for a in ctx.dp_axes:
            dp_size *= ctx.mesh.shape[a]
        t_local = tokens // dp_size
        x_spec = P(dp, None, None)
        p_spec = {
            "router": P(None, None),
            "wg": P(ctx.tp_axis, None, None),
            "wu": P(ctx.tp_axis, None, None),
            "wd": P(ctx.tp_axis, None, None),
        }
        routed = {k: p[k] for k in ("router", "wg", "wu", "wd")}
        y, aux = shard_map(
            lambda xl, pl: run(xl, pl, ctx.tp_axis, t_local, tuple(ctx.dp_axes)),
            mesh=ctx.mesh,
            in_specs=(x_spec, p_spec),
            out_specs=(x_spec, P()),
            check_rep=False,
        )(x, routed)

    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x)
    return y, aux


# ---------------------------------------------------------------- Mamba2 SSD
def mamba_init(key, cfg, dtype):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    keys = jax.random.split(key, 8)
    common = {
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(keys[2], di, (d,), dtype),
    }
    if getattr(cfg, "ssm_split_proj", False):
        # Split projections: z/x/dt shard over TP on the inner/head dim; the
        # depthwise conv splits exactly (per-channel).  Identical math to the
        # fused in_proj, TPU-shardable layout.
        return {
            "wz": dense_init(keys[0], d, (di,), dtype),
            "wx": dense_init(keys[1], d, (di,), dtype),
            "wb": dense_init(keys[3], d, (n,), dtype),
            "wc": dense_init(keys[4], d, (n,), dtype),
            "wdt": dense_init(keys[5], d, (h,), dtype),
            "conv_wx": (jax.random.normal(keys[6], (cfg.ssm_conv, di), jnp.float32)
                        * (1.0 / math.sqrt(cfg.ssm_conv))).astype(dtype),
            "conv_bx": jnp.zeros((di,), dtype),
            "conv_wbc": (jax.random.normal(keys[7], (cfg.ssm_conv, 2 * n), jnp.float32)
                         * (1.0 / math.sqrt(cfg.ssm_conv))).astype(dtype),
            "conv_bbc": jnp.zeros((2 * n,), dtype),
            **common,
        }
    return {
        "in_proj": dense_init(keys[0], d, (2 * di + 2 * n + h,), dtype),
        "conv_w": (jax.random.normal(keys[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * (1.0 / math.sqrt(cfg.ssm_conv))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        **common,
    }


def _ssd_chunked(x, dt, a_log, b_in, c_in, d_skip, chunk: int,
                 sequential: bool = False, ctx=None):
    """Chunked SSD scan (Mamba2, state-space duality).

    x: (B,T,H,P)  dt: (B,T,H)  a_log: (H,)  b_in/c_in: (B,T,N)  -> (B,T,H,P)
    All recurrence math in float32.

    sequential=True processes chunks through a lax.scan (live set = one
    chunk's intra tensors instead of all NC at once) — used by long-sequence
    inference paths where the vectorized form's (B,NC,C,C,H) intermediates
    dominate memory.  Identical math.
    """
    bsz, t, h, p = x.shape
    n = b_in.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = b_in.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cf = c_in.astype(jnp.float32).reshape(bsz, nc, chunk, n)

    a = -jnp.exp(a_log)  # (H,) negative decay rates

    if sequential:
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))

        def chunk_step(hprev, inp):
            xc, dtc, bc, cc = inp  # (B,C,H,P), (B,C,H), (B,C,N), (B,C,N)
            da = dtc * a
            seg = jnp.cumsum(da, axis=1)  # (B,C,H)
            li = seg[:, :, None, :] - seg[:, None, :, :]
            li = jnp.where(tri[None, :, :, None], li, -jnp.inf)
            decay = jnp.exp(li)
            cb = jnp.einsum("zin,zjn->zij", cc, bc)
            scores = cb[..., None] * decay * dtc[:, None, :, :]
            y = jnp.einsum("zijh,zjhp->zihp", scores, xc)
            y = y + jnp.einsum("zcn,zch,zhpn->zchp", cc, jnp.exp(seg), hprev)
            y = y + d_skip[None, None, :, None] * xc
            last = seg[:, -1:, :]
            w = jnp.exp(last - seg) * dtc
            s_chunk = jnp.einsum("zch,zchp,zcn->zhpn", w, xc, bc)
            hnew = hprev * jnp.exp(last[:, 0])[:, :, None, None] + s_chunk
            # stack in the model dtype: an f32 (B,T,H,P) ys stack costs GBs
            return hnew, y.astype(x.dtype)

        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
        xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
              jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
        _, ys = lax.scan(chunk_step, h0, xs)
        y = jnp.moveaxis(ys, 0, 1)  # (B,NC,C,H,P)
        return y.reshape(bsz, t, h, p)

    if ctx is not None and ctx.mesh is not None:
        # Context-parallel SSD: chunk dim sharded over TP (NC % tp == 0 for
        # the assigned shapes).  Intra-chunk tensors (the (B,NC,C,C,H) bulk)
        # stay sharded; only the (B,H,P,N) inter-chunk state scan crosses
        # ranks (MBs, not GBs).
        xf = ctx.constrain(xf, ctx.dp, ctx.tp_axis, None, None, None)
        dtf = ctx.constrain(dtf, ctx.dp, ctx.tp_axis, None, None)
        bf = ctx.constrain(bf, ctx.dp, ctx.tp_axis, None, None)
        cf = ctx.constrain(cf, ctx.dp, ctx.tp_axis, None, None)

    da = dtf * a  # (B,NC,C,H) log-decay increments
    seg = jnp.cumsum(da, axis=2)  # within-chunk cumulative

    # Intra-chunk (quadratic in chunk size): L[i,j] = exp(seg_i - seg_j), i>=j.
    # Mask the *exponent* (not the result): masked entries have seg_i - seg_j
    # > 0 and exp overflows to inf, which would leak NaN through the backward
    # pass of where(mask, exp(li), 0).
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # (B,NC,C,C,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    li = jnp.where(tri[None, None, :, :, None], li, -jnp.inf)
    decay = jnp.exp(li)
    cb = jnp.einsum("zgin,zgjn->zgij", cf, bf)  # (B,NC,C,C)
    scores = cb[..., None] * decay * dtf[:, :, None, :, :]  # (B,NC,C,C,H)
    y_intra = jnp.einsum("zgijh,zgjhp->zgihp", scores, xf)

    # Chunk summary states: S_g = sum_j exp(seg_last - seg_j) dt_j x_j B_j^T
    last = seg[:, :, -1:, :]  # (B,NC,1,H)
    w = jnp.exp(last - seg) * dtf  # (B,NC,C,H)
    s_chunk = jnp.einsum("zgch,zgchp,zgcn->zghpn", w, xf, bf)

    # Inter-chunk recurrence over NC chunks.
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))  # (B,NC,H)

    def step(hprev, inp):
        dec, s = inp  # dec: (B,H), s: (B,H,P,N)
        hnew = hprev * dec[:, :, None, None] + s
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, h_prevs = lax.scan(
        step,
        h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_chunk, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,NC,H,P,N) state entering chunk

    y_inter = jnp.einsum(
        "zgcn,zgch,zghpn->zgchp", cf, jnp.exp(seg), h_prevs
    )
    y = y_intra + y_inter + d_skip[None, None, None, :, None] * xf
    return y.reshape(bsz, t, h, p).astype(x.dtype)


def mamba_apply(p, x, cfg, *, sequential: bool = False, ctx=None):
    """Full-sequence Mamba2 block. x: (B,T,D) -> (B,T,D)."""
    bsz, t, d = x.shape
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    if "wz" in p:  # split projections (TP-sharded SSM)
        z = x @ p["wz"]
        xin = x @ p["wx"]
        b_in = x @ p["wb"]
        c_in = x @ p["wc"]
        dt = x @ p["wdt"]
        xin = causal_conv1d(xin, p["conv_wx"], p["conv_bx"])
        bc = causal_conv1d(jnp.concatenate([b_in, c_in], axis=-1),
                           p["conv_wbc"], p["conv_bbc"])
        b_in, c_in = jnp.split(bc, [n], axis=-1)
    else:
        zxbcdt = x @ p["in_proj"]
        z, xin, b_in, c_in, dt = jnp.split(
            zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
        )
        # causal depthwise conv over (x, B, C)
        xbc = jnp.concatenate([xin, b_in, c_in], axis=-1)
        xbc = causal_conv1d(xbc, p["conv_w"], p["conv_b"])
        xin, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    xin = jax.nn.silu(xin)
    b_in, c_in = jax.nn.silu(b_in), jax.nn.silu(c_in)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    xh = xin.reshape(bsz, t, h, hp)
    y = _ssd_chunked(xh, dt, p["A_log"], b_in, c_in, p["D"], cfg.ssm_chunk,
                     sequential=sequential, ctx=ctx)
    y = y.reshape(bsz, t, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"]


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: (B,T,C), w: (K,C), b: (C,)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # K is tiny (4): unrolled taps
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def mamba_decode_step(p, x, cfg, state):
    """Single-token Mamba2 step.

    x: (B,1,D); state: {"h": (B,H,P,N) f32, "conv": (B,K-1,conv_dim)}.
    Returns (y (B,1,D), new_state).
    """
    bsz = x.shape[0]
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    if "wz" in p:  # split projections: same math via concatenation
        xt = x[:, 0]
        z = xt @ p["wz"]
        xin = xt @ p["wx"]
        b_in = xt @ p["wb"]
        c_in = xt @ p["wc"]
        dt = xt @ p["wdt"]
        conv_w = jnp.concatenate([p["conv_wx"], p["conv_wbc"]], axis=-1)
        conv_b = jnp.concatenate([p["conv_bx"], p["conv_bbc"]], axis=-1)
    else:
        zxbcdt = x[:, 0] @ p["in_proj"]
        z, xin, b_in, c_in, dt = jnp.split(
            zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
        )
        conv_w, conv_b = p["conv_w"], p["conv_b"]
    xbc = jnp.concatenate([xin, b_in, c_in], axis=-1)  # (B, conv_dim)
    conv_hist = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)  # (B,K,cd)
    acc = jnp.einsum("bkc,kc->bc", conv_hist.astype(jnp.float32),
                     conv_w.astype(jnp.float32)) + conv_b.astype(jnp.float32)
    xin, b_in, c_in = jnp.split(acc.astype(x.dtype), [di, di + n], axis=-1)
    xin = jax.nn.silu(xin)
    b_in, c_in = jax.nn.silu(b_in), jax.nn.silu(c_in)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)

    a = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * a)  # (B,H)
    xh = xin.reshape(bsz, h, hp).astype(jnp.float32)
    hnew = state["h"] * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, b_in.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", c_in.astype(jnp.float32), hnew)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = (y @ p["out_proj"])[:, None]
    return out, {"h": hnew, "conv": conv_hist[:, 1:]}
