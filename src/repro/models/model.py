"""Full-model assembly: segments of homogeneous blocks scanned with stacked
parameters (compile time independent of depth), embeddings/head, loss, and
decode-step with per-segment caches.

Segment layout per family:
  dense/vlm/audio : [("dense", L)]
  moe             : [("dense", first_dense_layers), ("moe", L - fd)]
  ssm             : [("mamba", L)]
  hybrid (zamba2) : [("zamba", L)] + 2 shared attention blocks applied every
                    k-th layer (alternating), each application with its own
                    KV-cache slot.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import blocks as B
from . import layers as L
from .layers import NULL_CTX, ShardCtx

Params = Dict[str, Any]


# ------------------------------------------------------------------ structure
def segments_of(cfg) -> Tuple[Tuple[str, int], ...]:
    if cfg.family == "ssm":
        return (("mamba", cfg.num_layers),)
    if cfg.family == "hybrid":
        return (("zamba", cfg.num_layers),)
    if cfg.is_moe:
        fd = cfg.first_dense_layers
        segs = []
        if fd:
            segs.append(("dense", fd))
        segs.append(("moe", cfg.num_layers - fd))
        return tuple(segs)
    return (("dense", cfg.num_layers),)


def _stack_init(init_fn, key, count: int):
    keys = jax.random.split(key, count)
    return jax.vmap(init_fn)(keys)


def init_params(cfg, key, dtype=jnp.bfloat16) -> Params:
    keys = jax.random.split(key, 8)
    vp = getattr(cfg, "vocab_padded", cfg.vocab_size)
    p: Params = {"embed": L.embed_init(keys[0], vp, cfg.d_model, dtype)}
    for i, (kind, count) in enumerate(segments_of(cfg)):
        if kind == "dense":
            fn = lambda k: B.block_init(k, cfg, dtype, moe=False)
        elif kind == "moe":
            fn = lambda k: B.block_init(k, cfg, dtype, moe=True)
        else:  # mamba / zamba backbone
            fn = lambda k: B.mamba_block_init(k, cfg, dtype)
        p[f"seg{i}"] = _stack_init(fn, keys[1 + i], count)
    if cfg.family == "hybrid":
        p["shared_attn"] = _stack_init(
            lambda k: B.block_init(k, cfg, dtype, moe=False),
            keys[6],
            cfg.n_shared_attn_blocks,
        )
    p["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(keys[7], cfg.d_model, (vp,), dtype)
    return p


# ------------------------------------------------------------------ embedding
def embed_inputs(cfg, params, batch) -> jax.Array:
    """Token / frontend-stub embedding.  VLM: patch embeddings occupy the
    first frontend_seq positions, text tokens the rest.  Audio: the whole
    sequence arrives as precomputed frame embeddings."""
    if cfg.frontend == "audio_frames":
        return batch["embeddings"]
    tok = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.frontend == "vision_patches":
        emb = batch["embeddings"].astype(tok.dtype)  # (B, fs, D)
        return jnp.concatenate([emb, tok], axis=1)
    return tok


def _n_attn_apps(cfg) -> int:
    return -(-cfg.num_layers // cfg.hybrid_attn_every)  # ceil


def _mask_pad_logits(cfg, logits):
    """-inf on the padded vocab tail (vocab_padded > vocab_size)."""
    vp = logits.shape[-1]
    if vp == cfg.vocab_size:
        return logits
    col = jnp.arange(vp)
    neg = jnp.asarray(jnp.finfo(jnp.float32).min, logits.dtype)
    return jnp.where(col < cfg.vocab_size, logits, neg)


# -------------------------------------------------------------------- forward
def forward(
    cfg,
    params: Params,
    batch,
    ctx: ShardCtx = NULL_CTX,
    *,
    remat: str = "full",
    q_chunk: int = 1024,
    unroll: bool = False,
):
    """Returns (logits (B,S,V), aux_loss scalar)."""
    x = embed_inputs(cfg, params, batch)
    x = ctx.constrain(x, ctx.dp, None, None)
    aux_total = jnp.zeros((), jnp.float32)

    for i, (kind, count) in enumerate(segments_of(cfg)):
        stacked = params[f"seg{i}"]
        if kind in ("dense", "moe"):

            def body(h, lp):
                out, aux = B.block_apply(lp, h, cfg, ctx, q_chunk=q_chunk,
                                         unroll_chunks=unroll)
                return out, aux

        elif kind == "mamba":

            def body(h, lp):
                return (B.mamba_block_apply(lp, h, cfg, ctx),
                        jnp.zeros((), jnp.float32))

        else:  # zamba: shared attention every k-th layer, alternating blocks
            shared = params["shared_attn"]
            every, nshared = cfg.hybrid_attn_every, cfg.n_shared_attn_blocks

            def body(h, lp_idx):
                lp, idx = lp_idx

                def with_attn(hh):
                    sel = (idx // every) % nshared
                    sp = jax.tree.map(lambda a: a[sel], shared)
                    out, _ = B.block_apply(sp, hh, cfg, ctx, q_chunk=q_chunk,
                                           unroll_chunks=unroll)
                    return out

                h = lax.cond(idx % every == 0, with_attn, lambda hh: hh, h)
                return (B.mamba_block_apply(lp, h, cfg, ctx),
                        jnp.zeros((), jnp.float32))

        if remat == "full":
            body = jax.checkpoint(body)
        elif remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            )

        xs = (stacked, jnp.arange(count)) if kind == "zamba" else stacked
        # Sequence-parallel residual layout between blocks: the remat-saved
        # carry is sharded over (dp, tp) so residual memory scales with the
        # full chip count (GSPMD inserts the Megatron-SP gather/scatter).
        if unroll:
            for li in range(count):
                x = ctx.constrain(x, ctx.dp, ctx.tp_axis, None)
                lp = jax.tree.map(lambda a: a[li], stacked)
                x, aux = body(x, (lp, jnp.asarray(li)) if kind == "zamba" else lp)
                aux_total = aux_total + aux
        else:

            def scan_body(carry, inp):
                h, acc = carry
                h = ctx.constrain(h, ctx.dp, ctx.tp_axis, None)
                h, aux = body(h, inp)
                return (h, acc + aux), None

            (x, aux_total), _ = lax.scan(scan_body, (x, aux_total), xs)
        x = ctx.constrain(x, ctx.dp, None, None)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["head"]
    logits = ctx.constrain(logits, ctx.dp, None, ctx.tp_axis)
    return logits, aux_total


# ----------------------------------------------------------------------- loss
def loss_fn(
    cfg,
    params: Params,
    batch,
    ctx: ShardCtx = NULL_CTX,
    *,
    remat: str = "full",
    q_chunk: int = 1024,
    unroll: bool = False,
    aux_weight: float = 0.01,
):
    """Next-token (or frame-label) cross entropy, vocab-shard friendly:
    the label logit is taken via a one-hot einsum so GSPMD keeps the vocab
    dimension sharded (no full-logits gather)."""
    logits, aux = forward(
        cfg, params, batch, ctx, remat=remat, q_chunk=q_chunk, unroll=unroll
    )
    labels = batch["labels"]  # (B, S_out) int32, -1 => ignore
    if logits.shape[1] != labels.shape[1]:  # vlm: loss over text tail only
        logits = logits[:, logits.shape[1] - labels.shape[1] :]
    lf = _mask_pad_logits(cfg, logits.astype(jnp.float32))
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), lf.shape[-1], dtype=lf.dtype)
    ll = jnp.einsum("bsv,bsv->bs", lf, onehot)
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------- cache
def init_cache(cfg, batch: int, s_max: int, dtype=jnp.bfloat16):
    """Per-segment stacked caches for decode."""
    cache: Dict[str, Any] = {}
    for i, (kind, count) in enumerate(segments_of(cfg)):
        if kind in ("dense", "moe"):
            one = B.attn_cache_shape(cfg, batch, s_max, dtype)
            cache[f"seg{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape).copy(), one
            )
        elif kind == "mamba":
            one = B.mamba_state_shape(cfg, batch, dtype)
            cache[f"seg{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape).copy(), one
            )
        else:  # zamba: mamba states for all layers + attn cache per application
            st = B.mamba_state_shape(cfg, batch, dtype)
            cache[f"seg{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape).copy(), st
            )
            ac = B.attn_cache_shape(cfg, batch, s_max, dtype)
            napps = _n_attn_apps(cfg)
            cache["shared_attn"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (napps,) + a.shape).copy(), ac
            )
    return cache


# --------------------------------------------------------------------- decode
def decode_step(cfg, params: Params, cache, tokens, pos, ctx: ShardCtx = NULL_CTX,
                *, unroll: bool = False):
    """One decode step.  tokens: (B, 1) int32; pos: scalar index of the token
    being generated.  Returns (logits (B, V), new_cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    new_cache = dict(cache)

    def _unrolled(body, x, stacked, seg_cache, count):
        cs = []
        for li in range(count):
            lp = jax.tree.map(lambda a: a[li], stacked)
            c = jax.tree.map(lambda a: a[li], seg_cache)
            x, cn = body(x, (lp, c))
            cs.append(cn)
        return x, jax.tree.map(lambda *a: jnp.stack(a), *cs)

    for i, (kind, count) in enumerate(segments_of(cfg)):
        stacked = params[f"seg{i}"]
        seg_cache = cache[f"seg{i}"]
        if kind in ("dense", "moe"):

            def body(h, inp):
                lp, c = inp
                out, cnew = B.block_decode(lp, h, cfg, c, pos, ctx)
                return out, cnew

            if unroll:
                x, cnew = _unrolled(body, x, stacked, seg_cache, count)
            else:
                x, cnew = lax.scan(body, x, (stacked, seg_cache))
            new_cache[f"seg{i}"] = cnew
        elif kind == "mamba":

            def body(h, inp):
                lp, st = inp
                out, snew = B.mamba_block_decode(lp, h, cfg, st)
                return out, snew

            if unroll:
                x, cnew = _unrolled(body, x, stacked, seg_cache, count)
            else:
                x, cnew = lax.scan(body, x, (stacked, seg_cache))
            new_cache[f"seg{i}"] = cnew
        else:  # zamba
            shared = params["shared_attn"]
            attn_cache = cache["shared_attn"]
            every, nshared = cfg.hybrid_attn_every, cfg.n_shared_attn_blocks

            if unroll:
                sns = []
                for li in range(count):
                    if li % every == 0:
                        app, sel = li // every, (li // every) % nshared
                        sp = jax.tree.map(lambda a: a[sel], shared)
                        c_app = jax.tree.map(lambda a: a[app], attn_cache)
                        x, cn = B.block_decode(sp, x, cfg, c_app, pos, ctx)
                        attn_cache = jax.tree.map(
                            lambda a, c: a.at[app].set(c), attn_cache, cn
                        )
                    lp = jax.tree.map(lambda a: a[li], stacked)
                    st = jax.tree.map(lambda a: a[li], seg_cache)
                    x, sn = B.mamba_block_decode(lp, x, cfg, st)
                    sns.append(sn)
                snew = jax.tree.map(lambda *a: jnp.stack(a), *sns)
            else:

                def body(carry, inp):
                    h, ac = carry
                    lp, st, idx = inp

                    def with_attn(args):
                        hh, acc = args
                        app = idx // every
                        sel = app % nshared
                        sp = jax.tree.map(lambda a: a[sel], shared)
                        c_app = jax.tree.map(lambda a: a[app], acc)
                        out, cnew = B.block_decode(sp, hh, cfg, c_app, pos, ctx)
                        acc = jax.tree.map(
                            lambda a, cn: a.at[app].set(cn), acc, cnew
                        )
                        return out, acc

                    h, ac = lax.cond(idx % every == 0, with_attn, lambda a: a, (h, ac))
                    h, snew = B.mamba_block_decode(lp, h, cfg, st)
                    return (h, ac), snew

                (x, attn_cache), snew = lax.scan(
                    body, (x, attn_cache), (stacked, seg_cache, jnp.arange(count))
                )
            new_cache[f"seg{i}"] = snew
            new_cache["shared_attn"] = attn_cache

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["head"]
    return _mask_pad_logits(cfg, logits)[:, 0], new_cache


def prefill(cfg, params: Params, cache, batch, ctx: ShardCtx = NULL_CTX,
            *, q_chunk: int = 1024, unroll: bool = False):
    """Prefill the cache from a full prompt; returns (last-token logits, cache).

    (Used by serve paths; attention segments write K/V for all positions.)"""
    x = embed_inputs(cfg, params, batch)
    x = ctx.constrain(x, ctx.dp, None, None)
    new_cache = dict(cache)
    for i, (kind, count) in enumerate(segments_of(cfg)):
        stacked = params[f"seg{i}"]
        if kind in ("dense", "moe"):
            seg_cache = cache[f"seg{i}"]

            def body(h, inp):
                lp, c = inp
                h = ctx.constrain(h, ctx.dp, None, None)
                out, cnew = B.block_prefill(lp, h, cfg, c, ctx, q_chunk=q_chunk,
                                            unroll_chunks=unroll)
                return out, cnew

            if unroll:
                cs = []
                for li in range(count):
                    lp = jax.tree.map(lambda a: a[li], stacked)
                    c = jax.tree.map(lambda a: a[li], seg_cache)
                    x, cn = body(x, (lp, c))
                    cs.append(cn)
                cnew = jax.tree.map(lambda *a: jnp.stack(a), *cs)
            else:
                x, cnew = lax.scan(body, x, (stacked, seg_cache))
            new_cache[f"seg{i}"] = cnew
        else:
            # SSM segments: sequential chunk-scan SSD when rolled (live set =
            # one chunk; the vectorized form's (B,NC,C,C,H) intermediates
            # dominate 32k-prefill memory), vectorized when unrolled (cost
            # compiles need the flops visible).  SSM prefill-*state* capture
            # is exercised via decode; hybrid shared-attention caches ARE
            # filled here (required for decode after prefill).
            seq = not unroll
            if kind == "zamba":
                shared = params["shared_attn"]
                attn_cache = cache["shared_attn"]
                every, nshared = cfg.hybrid_attn_every, cfg.n_shared_attn_blocks

                def zbody(carry, inp):
                    h, ac = carry
                    lp, idx = inp

                    def with_attn(args):
                        hh, acc = args
                        app = idx // every
                        sel = app % nshared
                        sp = jax.tree.map(lambda a: a[sel], shared)
                        c_app = jax.tree.map(lambda a: a[app], acc)
                        out, cn = B.block_prefill(sp, hh, cfg, c_app, ctx,
                                                  q_chunk=q_chunk,
                                                  unroll_chunks=unroll)
                        acc = jax.tree.map(lambda a, c: a.at[app].set(c), acc, cn)
                        return out, acc

                    h = ctx.constrain(h, ctx.dp, None, None)
                    h, ac = lax.cond(idx % every == 0, with_attn,
                                     lambda a: a, (h, ac))
                    h = B.mamba_block_apply(lp, h, cfg, ctx, sequential=seq)
                    return (h, ac), None

                if unroll:
                    for li in range(count):
                        lp = jax.tree.map(lambda a: a[li], stacked)
                        (x, attn_cache), _ = zbody((x, attn_cache),
                                                   (lp, jnp.asarray(li)))
                else:
                    (x, attn_cache), _ = lax.scan(
                        zbody, (x, attn_cache), (stacked, jnp.arange(count))
                    )
                new_cache["shared_attn"] = attn_cache
            else:

                def body(h, lp):
                    h = ctx.constrain(h, ctx.dp, None, None)
                    return B.mamba_block_apply(lp, h, cfg, ctx,
                                               sequential=seq), None

                if unroll:
                    for li in range(count):
                        lp = jax.tree.map(lambda a: a[li], stacked)
                        x, _ = body(x, lp)
                else:
                    x, _ = lax.scan(body, x, stacked)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], params["embed"])
    else:
        logits = x[:, -1:] @ params["head"]
    return _mask_pad_logits(cfg, logits)[:, 0], new_cache
