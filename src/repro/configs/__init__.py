"""Architecture configs: the 10 assigned archs + shape cells + registry."""

import importlib

from .base import ArchConfig, get_config, list_configs, register
from .shapes import SHAPES, ShapeSpec, all_cells, cell_is_runnable, get_shape

_MODULES = [
    "h2o_danube_3_4b",
    "qwen2_5_32b",
    "mistral_large_123b",
    "qwen3_14b",
    "internvl2_26b",
    "deepseek_v2_lite_16b",
    "deepseek_moe_16b",
    "hubert_xlarge",
    "zamba2_7b",
    "mamba2_130m",
]

_loaded = False


def _load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _MODULES:
        importlib.import_module(f"{__name__}.{m}")


ARCH_NAMES = [
    "h2o-danube-3-4b",
    "qwen2.5-32b",
    "mistral-large-123b",
    "qwen3-14b",
    "internvl2-26b",
    "deepseek-v2-lite-16b",
    "deepseek-moe-16b",
    "hubert-xlarge",
    "zamba2-7b",
    "mamba2-130m",
]

__all__ = [
    "ArchConfig",
    "get_config",
    "list_configs",
    "register",
    "SHAPES",
    "ShapeSpec",
    "all_cells",
    "cell_is_runnable",
    "get_shape",
    "ARCH_NAMES",
]
