"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + fine-grained MoE.

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400, 64 routed top-6 + 2 shared
[arXiv:2405.04434; hf]

Pool-line note (DESIGN.md §5): the line mentions both "64e top-6" and
"2 shared+160 routed"; 160 routed is DeepSeek-V2-*full*.  We follow the primary
spec and HF DeepSeek-V2-Lite: 64 routed / top-6 / 2 shared, first layer dense
(d_ff=10944), MLA with kv_lora_rank=512, rope_dim=64, nope_dim=128, v_dim=128.
"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,  # MLA: per-head latent, kv head count == q heads
        d_ff=10944,  # dense first layer
        vocab_size=102400,
        attention="mla",
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        head_dim=128,
        n_routed_experts=64,
        n_shared_experts=2,
        moe_top_k=6,
        moe_d_ff=1408,
        first_dense_layers=1,
        source="arXiv:2405.04434 / hf:deepseek-ai/DeepSeek-V2-Lite",
    )
)
