"""The four assigned input-shape cells and per-arch skip rules (DESIGN.md §5)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from .base import ArchConfig

__all__ = ["ShapeSpec", "SHAPES", "get_shape", "cell_is_runnable", "all_cells"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Sub-quadratic decode (SSM state / hybrid / sliding-window cache): the only
# archs long_500k runs for.  Pure full-attention archs skip it per assignment.
_LONG_OK_FAMILIES = ("ssm", "hybrid")


def cell_is_runnable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch x shape) cell."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in _LONG_OK_FAMILIES or cfg.attention == "swa"
        if not sub_quadratic:
            return False, "pure full-attention arch; long_500k needs sub-quadratic attention"
    return True, ""


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def all_cells(configs: List[ArchConfig]):
    """Yield (cfg, shape, runnable, reason) for the full 40-cell grid."""
    for cfg in configs:
        for shape in SHAPES.values():
            ok, why = cell_is_runnable(cfg, shape)
            yield cfg, shape, ok, why
