"""Architecture config schema + registry.

One ``ArchConfig`` per assigned architecture (exact published numbers) plus a
``reduced()`` view for CPU smoke tests (same structure, tiny dims).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

__all__ = ["ArchConfig", "register", "get_config", "list_configs"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int  # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # default d_model // num_heads

    # attention flavor
    attention: str = "full"  # full | swa | mla | none
    swa_window: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    causal: bool = True
    supports_decode: bool = True

    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # hybrid (Zamba2): apply a shared attention block every k-th backbone layer
    hybrid_attn_every: int = 0
    n_shared_attn_blocks: int = 2

    # modality frontend stub: none | audio_frames | vision_patches
    frontend: str = "none"
    frontend_seq: int = 0  # portion of seq provided as precomputed embeddings

    # runtime knob (set by launchers): pad Q-head count up to a multiple of
    # the TP axis so attention internals shard evenly (outputs of padded
    # heads are masked to zero -> math is exact).
    q_head_pad_multiple: int = 1
    # decode cache dtype: "bf16" (default) or "int8" (per-token-per-head
    # block quantization; halves the mandatory cache streaming, the dominant
    # decode roofline term).
    kv_cache_dtype: str = "bf16"
    # sharding policy: split the fused Mamba in_proj into separate z/x/B/C/dt
    # projections so the SSM inner dim shards over TP (requires ssm_heads %
    # tp == 0; identical math — depthwise conv and SSD are per-channel/head).
    ssm_split_proj: bool = False
    # sharding policy: FSDP-shard weights over the data axis (ZeRO-3 style).
    # For models whose per-TP-shard weights fit comfortably (<= ~4 GiB),
    # replicating weights over data removes ALL per-pass weight gathers
    # (moments/grad-accumulator stay dp-sharded = ZeRO-1).
    weights_fsdp: bool = True

    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------ sizes
    @property
    def vocab_padded(self) -> int:
        """Embedding/logits tables are padded to a multiple of 256 so the
        vocab dim shards evenly over the TP axis (standard TPU practice).
        Logits above ``vocab_size`` are masked to -inf in loss/sampling."""
        return -(-self.vocab_size // 256) * 256

    @property
    def num_heads_padded(self) -> int:
        m = max(self.q_head_pad_multiple, 1)
        return -(-self.num_heads // m) * m if self.num_heads else 0

    @property
    def is_moe(self) -> bool:
        return self.n_routed_experts > 0

    @property
    def is_ssm_layer_model(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k routed)."""
        return _param_count(self, active_only=True)

    # ------------------------------------------------------------- reductions
    def reduced(self) -> "ArchConfig":
        """Structure-preserving tiny config for CPU smoke tests."""
        changes: Dict = dict(
            num_layers=min(self.num_layers, 4),
            d_model=128,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=min(self.vocab_size, 512),
            head_dim=32,
            num_heads=0 if self.num_heads == 0 else 4,
            num_kv_heads=0 if self.num_kv_heads == 0 else min(self.num_kv_heads, 2),
        )
        if self.attention == "swa":
            changes["swa_window"] = 16
        if self.attention == "mla":
            changes.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        if self.is_moe:
            changes.update(n_routed_experts=8, moe_top_k=2, moe_d_ff=64,
                           n_shared_experts=min(self.n_shared_experts, 1))
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
        if self.hybrid_attn_every:
            changes.update(hybrid_attn_every=2, num_layers=4)
        if self.num_kv_heads and self.num_kv_heads == self.num_heads:
            changes["num_kv_heads"] = changes["num_heads"]  # keep MHA structure
        if self.frontend_seq:
            changes["frontend_seq"] = 8
        return dataclasses.replace(self, **changes)


def _param_count(c: ArchConfig, active_only: bool = False) -> int:
    d = c.d_model
    total = c.vocab_size * d  # embedding (tied head)
    if not c.tie_embeddings:
        total += c.vocab_size * d
    total += d  # final norm

    def attn_params() -> int:
        if c.attention == "mla":
            q = d * c.num_heads * (c.qk_nope_dim + c.qk_rope_dim)
            kv_a = d * (c.kv_lora_rank + c.qk_rope_dim)
            kv_b = c.kv_lora_rank * c.num_heads * (c.qk_nope_dim + c.v_head_dim)
            o = c.num_heads * c.v_head_dim * d
            return q + kv_a + kv_b + o
        if c.attention == "none":
            return 0
        q = d * c.num_heads * c.head_dim
        kv = 2 * d * c.num_kv_heads * c.head_dim
        o = c.num_heads * c.head_dim * d
        b = (c.num_heads + 2 * c.num_kv_heads) * c.head_dim if c.qkv_bias else 0
        return q + kv + o + b

    def mlp_params(ff: int) -> int:
        return 3 * d * ff  # gated (gate, up, down)

    def moe_params() -> int:
        routed = c.n_routed_experts if not active_only else c.moe_top_k
        p = routed * mlp_params(c.moe_d_ff)
        p += c.n_shared_experts * mlp_params(c.moe_d_ff)
        p += d * c.n_routed_experts  # router
        return p

    def mamba_params() -> int:
        di, n, h = c.d_inner, c.ssm_state, c.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)  # z, x, B, C, dt
        conv = c.ssm_conv * (di + 2 * n)
        out = di * d
        extra = 2 * h + di  # A, D, dt_bias-ish + norm
        return in_proj + conv + out + extra + d

    if c.family in ("ssm",):
        total += c.num_layers * (mamba_params() + d)
        return total
    if c.family == "hybrid":
        total += c.num_layers * (mamba_params() + d)
        # shared attention blocks (parameters shared across applications)
        shared = attn_params() + mlp_params(c.d_ff) + 2 * d
        total += c.n_shared_attn_blocks * shared
        return total

    per_layer = attn_params() + 2 * d  # two norms
    if c.is_moe:
        dense_layer = per_layer + mlp_params(c.d_ff)
        moe_layer = per_layer + moe_params()
        total += c.first_dense_layers * dense_layer
        total += (c.num_layers - c.first_dense_layers) * moe_layer
    else:
        total += c.num_layers * (per_layer + mlp_params(c.d_ff))
    return total


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from . import _load_all  # populate registry lazily

    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from . import _load_all

    _load_all()
    return sorted(_REGISTRY)
