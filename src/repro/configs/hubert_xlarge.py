"""hubert-xlarge [audio]: encoder-only transformer backbone (w2v2 arch).

48L d_model=1280 16H (kv=16 => MHA) d_ff=5120 vocab=504 (codebook labels)
[arXiv:2106.07447; unverified]

Encoder-only: bidirectional attention, no decode step (decode shapes skipped).
The CNN feature extractor is a STUB: ``input_specs()`` provides precomputed
frame embeddings (B, S, d_model).
"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        supports_decode=False,
        frontend="audio_frames",
        frontend_seq=-1,  # the whole sequence is frame embeddings
        tie_embeddings=False,
        source="arXiv:2106.07447",
    )
)
