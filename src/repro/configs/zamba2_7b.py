"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32 => MHA in the shared block) d_ff=14336
vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified]

Structure: 81 Mamba2 blocks; every 6th block boundary applies one of 2 *shared*
transformer blocks (alternating), Zamba2-style.  The shared blocks' parameters
are reused across all applications; each application has its own KV cache.
"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_chunk=64,
        hybrid_attn_every=6,
        n_shared_attn_blocks=2,
        source="arXiv:2411.15242",
    )
)
