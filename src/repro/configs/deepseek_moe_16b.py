"""deepseek-moe-16b [moe]: fine-grained experts, 2 shared + 64 routed top-6.

28L d_model=2048 16H (kv=16 => MHA) d_ff(expert)=1408 vocab=102400
[arXiv:2401.06066; hf]
"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=10944,  # dense first layer
        vocab_size=102400,
        n_routed_experts=64,
        n_shared_experts=2,
        moe_top_k=6,
        moe_d_ff=1408,
        first_dense_layers=1,
        source="arXiv:2401.06066 / hf:deepseek-ai/deepseek-moe-16b-base",
    )
)
