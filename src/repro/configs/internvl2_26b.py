"""internvl2-26b [vlm]: InternViT + InternLM2 — transformer BACKBONE only.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf]

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings occupying the first ``frontend_seq`` positions;
the remaining positions are text tokens.
"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        frontend="vision_patches",
        frontend_seq=1024,  # patch positions per sequence
        tie_embeddings=False,
        source="arXiv:2404.16821",
    )
)
