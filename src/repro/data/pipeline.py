"""Deterministic synthetic data pipeline.

Produces sharded token batches from a seeded PRNG stream — each (host, step)
pair maps to a unique, reproducible batch, so checkpoint-resume yields
byte-identical training data without any data-state checkpointing beyond the
step counter.  A configurable per-fetch stall emulates slow/fast input devices
(the paper's HDD vs SSD contrast, Fig. 13), and every fetch is a profiled
"record" for the vet pipeline.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticTokenPipeline"]


class SyntheticTokenPipeline:
    """Deterministic (seed, step, host) -> batch generator.

    batch layout matches the model's expectations: tokens/labels (B, S) int32
    (labels = next-token shifted stream), optional frontend embeddings.
    """

    def __init__(
        self,
        vocab_size: int,
        batch: int,
        seq_len: int,
        *,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
        d_model: int = 0,
        frontend: str = "none",
        frontend_seq: int = 0,
        fetch_stall_s: float = 0.0,
    ):
        if batch % num_hosts != 0:
            raise ValueError("global batch must divide across hosts")
        self.vocab = vocab_size
        self.batch = batch // num_hosts
        self.seq = seq_len
        self.seed = seed
        self.host = host_id
        self.num_hosts = num_hosts
        self.d_model = d_model
        self.frontend = frontend
        self.frontend_seq = frontend_seq
        self.fetch_stall_s = fetch_stall_s

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The batch for a global step (deterministic, host-sharded)."""
        if self.fetch_stall_s:
            time.sleep(self.fetch_stall_s)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host])
        )
        out: Dict[str, np.ndarray] = {}
        if self.frontend == "audio_frames":
            out["embeddings"] = rng.standard_normal(
                (self.batch, self.seq, self.d_model), dtype=np.float32
            )
            out["labels"] = rng.integers(
                0, self.vocab, (self.batch, self.seq), dtype=np.int32
            )
            return out
        # Markov-ish token stream: correlated tokens so the loss is learnable.
        base = rng.integers(0, self.vocab, (self.batch, self.seq + 1), dtype=np.int32)
        drift = rng.integers(0, 17, (self.batch, 1), dtype=np.int32)
        stream = (base + drift) % self.vocab
        text_seq = self.seq
        if self.frontend == "vision_patches":
            fs = self.frontend_seq
            out["embeddings"] = rng.standard_normal(
                (self.batch, fs, self.d_model), dtype=np.float32
            )
            text_seq = self.seq - fs
        out["tokens"] = stream[:, :text_seq]
        out["labels"] = stream[:, 1 : text_seq + 1]
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    @classmethod
    def for_config(cls, cfg, shape, **kw):
        return cls(
            cfg.vocab_size,
            shape.global_batch,
            shape.seq_len,
            d_model=cfg.d_model,
            frontend=cfg.frontend,
            frontend_seq=max(cfg.frontend_seq, 0),
            **kw,
        )
