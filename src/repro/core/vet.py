"""The vet optimality measure (paper §4.2-4.4).

    PR  = sum_r Y_r                       (profiled real cost)
    EI  = sum_{r<=t} Y_r + sum_{r>t} g(r) (estimated ideal cost)
    OC  = sum_{r>t} (Y_r - g(r))          (estimated overhead cost)
    vet_task = (EI + OC) / EI  ==  PR / EI
    vet_job  = mean_i vet_task^(i)

vet == 1 means "no reducible overhead left"; vet == 4 means the task spent 4x
its ideal lower bound.  EI's defining property (paper Table 2/3) is
*consistency*: it is invariant to hardware utilization while PR is not.

Two estimator modes for the change-point location:

- ``cut_space="raw"``   — the paper's literal LSE on the sorted times.  On
  self-similar (Pareto) tails the squared error is dominated by the extreme
  top records and the cut drifts to ~99%+, losing EI consistency (documented
  in EXPERIMENTS.md).  Kept as the faithful baseline.
- ``cut_space="log"``   — LSE on the *log* sorted times (scale-equivariant,
  outlier-resistant).  Restores the paper's claimed EI-consistency on both
  simulated and real contention profiles; the framework default.

``buckets``: the paper's figures (Fig. 8) and its omega=3 probing window both
operate on a ~1000-bucket view of the sorted records (the O(n^2) LSE it writes
is infeasible on raw record counts).  With ``buckets=B`` the cut (and the
extrapolation slope) are estimated on the B-bucket mean curve and mapped back
to record rank; EI/OC are always computed over raw records.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .changepoint import estimate_changepoint

__all__ = [
    "VetResult",
    "VetJobResult",
    "vet_task",
    "vet_job",
    "vet_pipeline",
    "ei_oc",
]

_TINY = 1e-12


class VetResult(NamedTuple):
    """Per-task vet diagnostics (0-dim arrays; .item() for python floats)."""

    vet: jax.Array  # PR / EI
    ei: jax.Array  # estimated ideal cost (seconds)
    oc: jax.Array  # estimated overhead cost (seconds)
    pr: jax.Array  # profiled real cost (seconds) == EI + OC
    t: jax.Array  # change-point (1-indexed record-rank prefix size)
    n: int  # number of records


class VetJobResult(NamedTuple):
    vet_job: jax.Array
    tasks: tuple  # tuple[VetResult, ...]

    @property
    def ei_mean(self):
        return jnp.mean(jnp.stack([r.ei for r in self.tasks]))

    @property
    def ei_std(self):
        return jnp.std(jnp.stack([r.ei for r in self.tasks]))

    @property
    def pr_mean(self):
        return jnp.mean(jnp.stack([r.pr for r in self.tasks]))

    @property
    def pr_std(self):
        return jnp.std(jnp.stack([r.pr for r in self.tasks]))


def _cut_and_slope(y: jax.Array, omega: int, buckets, cut_space: str,
                   changepoint_fn=None):
    """Locate the change-point on (optionally bucketed, optionally logged)
    sorted times; return (t_records, anchor_value, per-record slope).

    ``changepoint_fn(z, omega=...) -> t`` swaps the SSE-scan implementation
    (e.g. the Pallas kernel used by ``repro.engine``); default is the jnp
    prefix-sum scan.
    """
    n = y.shape[0]
    use_buckets = buckets is not None and n >= 4 * buckets
    if use_buckets:
        per = n // buckets
        curve = jnp.mean(y[: per * buckets].reshape(buckets, per), axis=1)
    else:
        per = 1
        curve = y
    z = jnp.log(jnp.maximum(curve, _TINY)) if cut_space == "log" else curve
    if curve.shape[0] < 2 * omega:
        # Degenerate profile, shorter than the probing span: no valid split
        # exists and ``estimate_changepoint`` refuses to pick one.  The
        # pipeline's historical fallback is t=1 (the argmin of the all-inf
        # landscape) — everything past the first record is treated as
        # extrapolated — which the fused window-vet kernel reproduces for
        # its padded degenerate rows.
        tb = jnp.asarray(1, jnp.int32)
    else:
        cp = estimate_changepoint if changepoint_fn is None else changepoint_fn
        tb = cp(z, omega=omega)  # 1-indexed on the curve
    i = jnp.clip(tb - 1, 1, curve.shape[0] - 1)
    anchor = curve[i]
    slope = jnp.maximum(curve[i] - curve[i - 1], 0.0) / per
    t = tb * per  # record-rank prefix size
    return t.astype(jnp.int32), anchor, slope


def ei_oc(y_sorted: jax.Array, t, anchor=None, slope=None):
    """EI and OC for a sorted profile with change-point t (record rank).

    g(r) = anchor + (r - t) * slope for r > t; defaults reproduce the paper's
    g exactly (anchor = Y_t, slope = Y_t - Y_{t-1}).

    The extrapolation is capped elementwise at the observation,
    g~(r) = min(g(r), Y_r): a record's ideal time cannot exceed its actual
    time (the paper draws g strictly below p, Fig. 5; without the cap a noisy
    local slope at t can push g above Y and make OC negative).  This keeps
    EI <= PR, OC >= 0 and the exact decomposition EI + OC = PR.
    """
    y = jnp.asarray(y_sorted)
    y = y.astype(jnp.promote_types(y.dtype, jnp.float32))
    n = y.shape[0]
    t = jnp.asarray(t, jnp.int32)
    i = jnp.clip(t - 1, 1, n - 1)
    if anchor is None:
        anchor = y[i]
    if slope is None:
        slope = jnp.maximum(y[i] - y[i - 1], 0.0)
    ranks = jnp.arange(1, n + 1)
    prefix = ranks <= t
    g = anchor + slope * (ranks - t).astype(y.dtype)
    g = jnp.minimum(g, y)  # ideal never exceeds observed
    ei = jnp.sum(jnp.where(prefix, y, g))
    oc = jnp.sum(jnp.where(prefix, 0.0, y - g))
    return ei, oc


def vet_pipeline(
    times: jax.Array,
    omega: int = 3,
    buckets: int | None = 1000,
    cut_space: str = "log",
    changepoint_fn=None,
):
    """The traceable single-profile pipeline: raw (unsorted) record times ->
    ``(vet, ei, oc, pr, t)`` as 0-dim arrays.

    This is the body of ``vet_task`` without the jit wrapper or the Python
    result container, so ``jax.vmap`` can map it over a (workers, window)
    matrix — the ``repro.engine`` batched backends compile exactly this
    function, which keeps them numerically identical to the scalar oracle.
    """
    if cut_space not in ("raw", "log"):
        raise ValueError(f"cut_space must be 'raw' or 'log', got {cut_space!r}")
    x = jnp.asarray(times)
    x = x.astype(jnp.promote_types(x.dtype, jnp.float32))
    y = jnp.sort(x)
    t, anchor, slope = _cut_and_slope(y, omega, buckets, cut_space, changepoint_fn)
    ei, oc = ei_oc(y, t, anchor, slope)
    pr = jnp.sum(y)
    return pr / ei, ei, oc, pr, t


@functools.partial(jax.jit, static_argnames=("omega", "buckets", "cut_space"))
def vet_task(
    times: jax.Array,
    omega: int = 3,
    buckets: int | None = 1000,
    cut_space: str = "log",
) -> VetResult:
    """vet for one task from its raw (unsorted) record processing times.

    Defaults are the framework estimator (bucketed log-cut). For the paper's
    literal estimator use ``buckets=None, cut_space="raw"``.
    """
    vet, ei, oc, pr, t = vet_pipeline(times, omega, buckets, cut_space)
    return VetResult(vet=vet, ei=ei, oc=oc, pr=pr, t=t,
                     n=int(jnp.shape(times)[0]))


def vet_job(
    task_times: Sequence[jax.Array],
    omega: int = 3,
    buckets: int | None = 1000,
    cut_space: str = "log",
) -> VetJobResult:
    """vet_job = simple average of per-task vet scores (paper §4.4).

    Tasks may have different record counts, so this loops on the host; each
    per-task computation is the jitted ``vet_task``.
    """
    results = tuple(
        vet_task(t, omega=omega, buckets=buckets, cut_space=cut_space)
        for t in task_times
    )
    if not results:
        raise ValueError("vet_job needs at least one task profile")
    return VetJobResult(
        vet_job=jnp.mean(jnp.stack([r.vet for r in results])), tasks=results
    )
