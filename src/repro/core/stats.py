"""Statistical utilities used by the paper's evaluation (§4.4, §5).

- Two-sample Kolmogorov-Smirnov test (paper Fig. 6: are vet_task samples of two
  same-config jobs from the same population?)  D statistic + asymptotic p-value
  via the Kolmogorov distribution series (Massey 1951 [12]).
- Pearson correlation (paper Fig. 14: vet_task vs task processing time).
- 1000-bucket aggregation used by the paper's distribution figures (Fig. 8).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ks_2samp", "KSResult", "pearson", "bucketize"]


class KSResult(NamedTuple):
    statistic: float
    pvalue: float


def _kolmogorov_sf(x: float, terms: int = 101) -> float:
    """Survival function of the Kolmogorov distribution,
    Q(x) = 2 sum_{j>=1} (-1)^{j-1} exp(-2 j^2 x^2)."""
    if x <= 0:
        return 1.0
    j = np.arange(1, terms, dtype=np.float64)
    s = 2.0 * np.sum((-1.0) ** (j - 1) * np.exp(-2.0 * (j * x) ** 2))
    return float(min(max(s, 0.0), 1.0))


def ks_2samp(a, b) -> KSResult:
    """Two-sample KS test (asymptotic p-value, two-sided)."""
    a = np.sort(np.asarray(a, dtype=np.float64))
    b = np.sort(np.asarray(b, dtype=np.float64))
    na, nb = a.size, b.size
    if na == 0 or nb == 0:
        raise ValueError("empty sample")
    both = np.concatenate([a, b])
    cdf_a = np.searchsorted(a, both, side="right") / na
    cdf_b = np.searchsorted(b, both, side="right") / nb
    d = float(np.max(np.abs(cdf_a - cdf_b)))
    en = np.sqrt(na * nb / (na + nb))
    p = _kolmogorov_sf((en + 0.12 + 0.11 / en) * d)
    return KSResult(statistic=d, pvalue=p)


def pearson(x, y) -> float:
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    xc = x - jnp.mean(x)
    yc = y - jnp.mean(y)
    denom = jnp.sqrt(jnp.sum(xc * xc) * jnp.sum(yc * yc))
    return float(jnp.sum(xc * yc) / jnp.where(denom > 0, denom, 1.0))


def bucketize(times, n_buckets: int = 1000):
    """Paper Fig. 8 view: sort records by processing time, split into
    ``n_buckets`` rank buckets, return the per-bucket *sum* of times."""
    y = jnp.sort(jnp.asarray(times))
    n = y.shape[0]
    if n % n_buckets != 0:
        pad = n_buckets - n % n_buckets
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
    return jnp.sum(y.reshape(n_buckets, -1), axis=1)
