"""Least-squares change-point estimation (paper §4.3).

Given sorted record processing times ``Y_1 <= ... <= Y_n`` (order statistics),
the change-point ``t`` separates "normal" records from "overhead-laden" ones:

    t = argmin_{omega <= k <= n-omega}  SSE(Y[1:k]; linear) + SSE(Y[k+1:n]; linear)

The paper writes this as an O(n^2) double loop (a fresh regression per k).  We
compute every segment SSE in O(1) from prefix sums, making the whole scan O(n)
— this is the vectorized form both the jnp implementation here and the Pallas
kernel (``repro.kernels.changepoint``) share.

For a segment with raw sums (m, Sx, Sy, Sxx, Sxy, Syy) over x in {a..b}:

    Sxx_c = Sxx - Sx^2/m,  Sxy_c = Sxy - Sx*Sy/m,  Syy_c = Syy - Sy^2/m
    SSE   = Syy_c - Sxy_c^2 / Sxx_c          (Syy_c if the segment is degenerate)

Because x is just the rank 1..n, Sx and Sxx have closed forms; only three
prefix-sum arrays over y are needed (y, y^2, x*y).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["index_closed_forms", "two_segment_sse", "estimate_changepoint",
           "segment_sse_terms"]


def _promote(y: jax.Array) -> jax.Array:
    y = jnp.asarray(y)
    return y.astype(jnp.promote_types(y.dtype, jnp.float32))


def segment_sse_terms(n1, sx, sy, sxx, sxy, syy):
    """SSE of the best linear fit given raw segment sums. Vectorized over k."""
    n1 = jnp.maximum(n1, 1.0)
    sxx_c = sxx - sx * sx / n1
    sxy_c = sxy - sx * sy / n1
    syy_c = syy - sy * sy / n1
    # Degenerate segments (m < 2 or constant x) fall back to total variation.
    safe = sxx_c > 0.0
    sse = syy_c - jnp.where(safe, sxy_c * sxy_c / jnp.where(safe, sxx_c, 1.0), 0.0)
    # Guard tiny negative values from cancellation.
    return jnp.maximum(sse, 0.0)


def index_closed_forms(n: int):
    """Closed-form index sums Sx(k), Sxx(k), and their segment-2 complements,
    computed in float64 (``n`` is static, so these are trace-time constants).

    ``k*(k+1)*(2k+1)/6`` exceeds the f32 mantissa for n of a few thousand;
    evaluating the polynomial *in* f32 compounds the rounding at every
    multiply and skews the SSE landscape (and hence the chosen cut) on long
    inputs.  Evaluating in f64 and rounding once at the combine keeps every
    entry correctly rounded in the working dtype.  Both the jnp scan below
    and the Pallas kernel (``repro.kernels.changepoint``) consume exactly
    these arrays, so the two SSE landscapes stay in ulp-level agreement.

    Returns four float64 numpy arrays of shape (n,): ``sx1``, ``sxx1``,
    ``sx2``, ``sxx2`` (prefix sums over ranks 1..k and their suffix
    complements over k+1..n).
    """
    k = np.arange(1, n + 1, dtype=np.float64)
    sx1 = k * (k + 1.0) / 2.0
    sxx1 = k * (k + 1.0) * (2.0 * k + 1.0) / 6.0
    nf = float(n)
    sx_tot = nf * (nf + 1.0) / 2.0
    sxx_tot = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 6.0
    return sx1, sxx1, sx_tot - sx1, sxx_tot - sxx1


def two_segment_sse(y_sorted: jax.Array, omega: int = 3) -> jax.Array:
    """Total SSE for every candidate split k (1-indexed count of the prefix).

    Returns an array ``sse`` of shape (n,) where ``sse[k-1]`` is the two-segment
    SSE for the split {Y_1..Y_k | Y_{k+1}..Y_n}.  Entries outside the probing
    window ``omega <= k <= n - omega`` are +inf (for ``n < 2*omega`` every
    entry is: there is no valid split).
    """
    y = _promote(y_sorted)
    n = y.shape[0]
    dt = y.dtype
    idx = jnp.arange(1, n + 1, dtype=dt)

    # Center y before the prefix sums.  The two-segment SSE is exactly
    # invariant to y -> y + c (the intercept absorbs the shift), but the
    # uncentered f32 cumsums are not: their rounding scales with the offset,
    # and on near-flat landscapes that noise alone can move the argmin
    # (e.g. scaling times by c shifts the log curve by log c and used to
    # flip the cut).  The pivot is the midpoint *element* rather than the
    # mean: an element pick carries no reduction rounding, so every
    # implementation of this scan (here, the Pallas wrapper, the fused
    # window-vet kernel with its padded rows) subtracts the bitwise-same
    # value and the landscapes stay in ulp agreement.
    y = y - y[(n - 1) // 2]

    cy = jnp.cumsum(y)
    cyy = jnp.cumsum(y * y)
    cxy = jnp.cumsum(idx * y)

    k = idx  # candidate prefix length, as float
    # Closed-form sums of x and x^2: f64 at trace time, cast at combine.
    sx1_64, sxx1_64, sx2_64, sxx2_64 = index_closed_forms(n)
    sx1 = jnp.asarray(sx1_64, dt)
    sxx1 = jnp.asarray(sxx1_64, dt)
    sx2 = jnp.asarray(sx2_64, dt)
    sxx2 = jnp.asarray(sxx2_64, dt)
    nf = jnp.asarray(float(n), dt)

    sy1, syy1, sxy1 = cy, cyy, cxy
    sse1 = segment_sse_terms(k, sx1, sy1, sxx1, sxy1, syy1)

    n2 = nf - k
    sy2 = cy[-1] - cy
    syy2 = cyy[-1] - cyy
    sxy2 = cxy[-1] - cxy
    sse2 = segment_sse_terms(n2, sx2, sy2, sxx2, sxy2, syy2)

    total = sse1 + sse2
    valid = (k >= omega) & (k <= nf - omega)
    return jnp.where(valid, total, jnp.inf)


@functools.partial(jax.jit, static_argnames=("omega",))
def estimate_changepoint(y_sorted: jax.Array, omega: int = 3) -> jax.Array:
    """The paper's t-hat: 1-indexed size of the "normal" prefix segment.

    ``y_sorted`` must be ascending.  Returns an int32 scalar in
    [omega, n - omega].  Jit-safe (dynamic value, static shapes).

    Raises:
        ValueError: ``n < 2*omega`` — every split is outside the probing
            window (``two_segment_sse`` is all +inf), so there is no
            change-point to estimate.  The shape is static, so this raises
            at trace time even under jit; the naive oracle signals the same
            condition by returning ``-1``.
    """
    n = jnp.shape(y_sorted)[0]
    if n < 2 * omega:
        raise ValueError(
            f"estimate_changepoint needs n >= 2*omega points to probe a "
            f"split (omega={omega} on each side), got n={n}")
    sse = two_segment_sse(y_sorted, omega=omega)
    return (jnp.argmin(sse) + 1).astype(jnp.int32)


def estimate_changepoint_naive(y_sorted, omega: int = 3) -> int:
    """O(n^2) literal transcription of the paper's estimator (test oracle).

    Returns ``-1`` when no valid split exists (``n < 2*omega``) — the same
    condition ``estimate_changepoint`` raises ``ValueError`` for.
    """

    y = np.asarray(y_sorted, dtype=np.float64)
    n = y.shape[0]
    x = np.arange(1, n + 1, dtype=np.float64)
    best_k, best = -1, np.inf
    for k in range(omega, n - omega + 1):
        sse = 0.0
        for (xs, ys) in ((x[:k], y[:k]), (x[k:], y[k:])):
            if xs.size >= 2:
                a = np.stack([np.ones_like(xs), xs], axis=1)
                coef, res, rank, _ = np.linalg.lstsq(a, ys, rcond=None)
                r = ys - a @ coef
                sse += float(r @ r)
        if sse < best:
            best, best_k = sse, k
    return best_k
