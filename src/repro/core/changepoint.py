"""Least-squares change-point estimation (paper §4.3).

Given sorted record processing times ``Y_1 <= ... <= Y_n`` (order statistics),
the change-point ``t`` separates "normal" records from "overhead-laden" ones:

    t = argmin_{omega <= k <= n-omega}  SSE(Y[1:k]; linear) + SSE(Y[k+1:n]; linear)

The paper writes this as an O(n^2) double loop (a fresh regression per k).  We
compute every segment SSE in O(1) from prefix sums, making the whole scan O(n)
— this is the vectorized form both the jnp implementation here and the Pallas
kernel (``repro.kernels.changepoint``) share.

For a segment with raw sums (m, Sx, Sy, Sxx, Sxy, Syy) over x in {a..b}:

    Sxx_c = Sxx - Sx^2/m,  Sxy_c = Sxy - Sx*Sy/m,  Syy_c = Syy - Sy^2/m
    SSE   = Syy_c - Sxy_c^2 / Sxx_c          (Syy_c if the segment is degenerate)

Because x is just the rank 1..n, Sx and Sxx have closed forms; only three
prefix-sum arrays over y are needed (y, y^2, x*y).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["two_segment_sse", "estimate_changepoint", "segment_sse_terms"]


def _promote(y: jax.Array) -> jax.Array:
    y = jnp.asarray(y)
    return y.astype(jnp.promote_types(y.dtype, jnp.float32))


def segment_sse_terms(n1, sx, sy, sxx, sxy, syy):
    """SSE of the best linear fit given raw segment sums. Vectorized over k."""
    n1 = jnp.maximum(n1, 1.0)
    sxx_c = sxx - sx * sx / n1
    sxy_c = sxy - sx * sy / n1
    syy_c = syy - sy * sy / n1
    # Degenerate segments (m < 2 or constant x) fall back to total variation.
    safe = sxx_c > 0.0
    sse = syy_c - jnp.where(safe, sxy_c * sxy_c / jnp.where(safe, sxx_c, 1.0), 0.0)
    # Guard tiny negative values from cancellation.
    return jnp.maximum(sse, 0.0)


def two_segment_sse(y_sorted: jax.Array, omega: int = 3) -> jax.Array:
    """Total SSE for every candidate split k (1-indexed count of the prefix).

    Returns an array ``sse`` of shape (n,) where ``sse[k-1]`` is the two-segment
    SSE for the split {Y_1..Y_k | Y_{k+1}..Y_n}.  Entries outside the probing
    window ``omega <= k <= n - omega`` are +inf.
    """
    y = _promote(y_sorted)
    n = y.shape[0]
    dt = y.dtype
    idx = jnp.arange(1, n + 1, dtype=dt)

    cy = jnp.cumsum(y)
    cyy = jnp.cumsum(y * y)
    cxy = jnp.cumsum(idx * y)

    k = idx  # candidate prefix length, as float
    # Closed-form sums of x and x^2 over 1..k and totals over 1..n.
    sx1 = k * (k + 1.0) / 2.0
    sxx1 = k * (k + 1.0) * (2.0 * k + 1.0) / 6.0
    nf = jnp.asarray(float(n), dt)
    sx_tot = nf * (nf + 1.0) / 2.0
    sxx_tot = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 6.0

    sy1, syy1, sxy1 = cy, cyy, cxy
    sse1 = segment_sse_terms(k, sx1, sy1, sxx1, sxy1, syy1)

    n2 = nf - k
    sx2 = sx_tot - sx1
    sxx2 = sxx_tot - sxx1
    sy2 = cy[-1] - cy
    syy2 = cyy[-1] - cyy
    sxy2 = cxy[-1] - cxy
    sse2 = segment_sse_terms(n2, sx2, sy2, sxx2, sxy2, syy2)

    total = sse1 + sse2
    valid = (k >= omega) & (k <= nf - omega)
    return jnp.where(valid, total, jnp.inf)


@functools.partial(jax.jit, static_argnames=("omega",))
def estimate_changepoint(y_sorted: jax.Array, omega: int = 3) -> jax.Array:
    """The paper's t-hat: 1-indexed size of the "normal" prefix segment.

    ``y_sorted`` must be ascending.  Returns an int32 scalar in
    [omega, n - omega].  Jit-safe (dynamic value, static shapes).
    """
    sse = two_segment_sse(y_sorted, omega=omega)
    return (jnp.argmin(sse) + 1).astype(jnp.int32)


def estimate_changepoint_naive(y_sorted, omega: int = 3) -> int:
    """O(n^2) literal transcription of the paper's estimator (test oracle)."""
    import numpy as np

    y = np.asarray(y_sorted, dtype=np.float64)
    n = y.shape[0]
    x = np.arange(1, n + 1, dtype=np.float64)
    best_k, best = -1, np.inf
    for k in range(omega, n - omega + 1):
        sse = 0.0
        for (xs, ys) in ((x[:k], y[:k]), (x[k:], y[k:])):
            if xs.size >= 2:
                a = np.stack([np.ones_like(xs), xs], axis=1)
                coef, res, rank, _ = np.linalg.lstsq(a, ys, rcond=None)
                r = ys - a @ coef
                sse += float(r @ r)
        if sse < best:
            best, best_k = sse, k
    return best_k
