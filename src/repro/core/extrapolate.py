"""Monotone extrapolation of the ideal record-time curve g-hat (paper §4.3).

The paper's three-point-moving-average filter

    g(r+1) = 2 g(r) - g(r-1),   g(t-1) = Y_{t-1},  g(t) = Y_t

telescopes to the closed form

    g(t + j) = Y_t + j * (Y_t - Y_{t-1}),   j >= 0

i.e. a linear continuation with the local slope at the change-point.  Since the
observations are ordered, the slope is non-negative, so g is monotonically
non-decreasing and continuous at t — the paper's two stated restrictions.

All functions are jit-safe for *dynamic* t (static shapes, masked selects).
Indices follow the paper: t is the 1-indexed size of the "normal" prefix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ghat_curve", "local_slope"]


def _promote(y: jax.Array) -> jax.Array:
    y = jnp.asarray(y)
    return y.astype(jnp.promote_types(y.dtype, jnp.float32))


def local_slope(y_sorted: jax.Array, t, robust: bool = False) -> jax.Array:
    """Slope used for the continuation: Y_t - Y_{t-1} (paper), or a robust
    variant (median of the last 5 pre-change-point diffs) for noisy profiles."""
    y = _promote(y_sorted)
    n = y.shape[0]
    t = jnp.asarray(t, jnp.int32)
    i = jnp.clip(t - 1, 0, n - 1)  # 0-indexed position of Y_t
    if not robust:
        prev = jnp.clip(i - 1, 0, n - 1)
        return jnp.maximum(y[i] - y[prev], 0.0)
    # Median of the last few diffs before t (window 5, masked).
    d = jnp.diff(y, prepend=y[:1])
    offs = jnp.arange(5)
    pos = jnp.clip(i - offs, 0, n - 1)
    window = d[pos]
    return jnp.maximum(jnp.median(window), 0.0)


def ghat_curve(y_sorted: jax.Array, t, robust_slope: bool = False) -> jax.Array:
    """Full estimated-ideal curve g(x), x = 1..n (paper's g):

        g(x) = Y_x                                  for x <= t
        g(x) = Y_t + (x - t) * slope                for x >  t
    """
    y = _promote(y_sorted)
    n = y.shape[0]
    t = jnp.asarray(t, jnp.int32)
    slope = local_slope(y, t, robust=robust_slope)
    ranks = jnp.arange(1, n + 1)
    y_t = y[jnp.clip(t - 1, 0, n - 1)]
    extrap = y_t + slope * (ranks - t).astype(y.dtype)
    return jnp.where(ranks <= t, y, extrap)
