"""The paper's contribution: the vet optimality measure for distributed jobs.

Pipeline:  record times -> order statistics -> LSE change-point ->
monotone extrapolation g-hat -> (EI, OC) -> vet_task -> vet_job.
"""

from .online import OnlineVet, OnlineVetSnapshot
from .changepoint import (
    estimate_changepoint,
    estimate_changepoint_naive,
    two_segment_sse,
)
from .extrapolate import ghat_curve, local_slope
from .stats import KSResult, bucketize, ks_2samp, pearson
from .tail import TailReport, emplot, hill_estimator, hill_plot, tail_report
from .vet import VetJobResult, VetResult, ei_oc, vet_job, vet_pipeline, vet_task

__all__ = [
    "OnlineVet",
    "OnlineVetSnapshot",
    "estimate_changepoint",
    "estimate_changepoint_naive",
    "two_segment_sse",
    "ghat_curve",
    "local_slope",
    "KSResult",
    "bucketize",
    "ks_2samp",
    "pearson",
    "TailReport",
    "emplot",
    "hill_estimator",
    "hill_plot",
    "tail_report",
    "VetJobResult",
    "VetResult",
    "ei_oc",
    "vet_job",
    "vet_pipeline",
    "vet_task",
]
