"""Streaming vet (beyond-paper): windowed online estimation for live jobs.

The paper computes vet post-hoc over a task's full profile.  A production
dashboard needs it *during* the run: this maintains a bounded reservoir of
recent records and re-estimates (EI, OC, vet) incrementally, with exponential
forgetting across windows so regime changes (a straggler appearing, input
storage degrading) surface within one window.

Properties kept from the batch estimator: scale-equivariance, EI+OC == PR
per window, vet >= 1 on well-formed profiles.
"""

from __future__ import annotations

from typing import Deque, NamedTuple, Optional

import collections

import numpy as np

from .vet import vet_task

__all__ = ["OnlineVet", "OnlineVetSnapshot"]


class OnlineVetSnapshot(NamedTuple):
    vet: float
    ei_rate: float  # EI per record (seconds) — the live ideal-cost estimate
    pr_rate: float  # PR per record
    n_window: int
    smoothed_vet: float


class OnlineVet:
    """Bounded-memory online vet.

    feed(times) appends record times; every ``window`` records a fresh batch
    estimate runs on the newest window and folds into an EMA.  O(window) memory
    regardless of stream length.
    """

    def __init__(self, window: int = 512, alpha: float = 0.3,
                 buckets: Optional[int] = 64):
        if window < 64:
            raise ValueError("window must be >= 64")
        self.window = window
        self.alpha = alpha
        self.buckets = buckets
        self._buf: Deque[float] = collections.deque(maxlen=window)
        self._since_update = 0
        self._smoothed: Optional[float] = None
        self._last: Optional[OnlineVetSnapshot] = None

    def feed(self, times) -> Optional[OnlineVetSnapshot]:
        """Add record times; returns a new snapshot when a window completes."""
        arr = np.atleast_1d(np.asarray(times, dtype=np.float64))
        out = None
        for t in arr:
            self._buf.append(float(t))
            self._since_update += 1
            if len(self._buf) >= self.window and self._since_update >= self.window // 2:
                out = self._estimate()
                self._since_update = 0
        return out

    def _estimate(self) -> OnlineVetSnapshot:
        window = np.asarray(self._buf)
        r = vet_task(window, buckets=self.buckets)
        vet = float(r.vet)
        self._smoothed = (vet if self._smoothed is None
                          else self.alpha * vet + (1 - self.alpha) * self._smoothed)
        self._last = OnlineVetSnapshot(
            vet=vet,
            ei_rate=float(r.ei) / window.size,
            pr_rate=float(r.pr) / window.size,
            n_window=window.size,
            smoothed_vet=self._smoothed,
        )
        return self._last

    @property
    def snapshot(self) -> Optional[OnlineVetSnapshot]:
        return self._last
