"""Streaming vet (beyond-paper): windowed online estimation for live jobs.

The paper computes vet post-hoc over a task's full profile.  A production
dashboard needs it *during* the run: this maintains a bounded reservoir of
recent records and re-estimates (EI, OC, vet) incrementally, with exponential
forgetting across windows so regime changes (a straggler appearing, input
storage degrading) surface within one window.

Estimation is delegated to a ``repro.engine.stream.VetStream`` — this class
is only the EMA wrapper around it.  ``feed`` appends whole chunks (O(chunk),
no per-record Python loop) and window completions fall out of the stream's
arithmetic; each completed half-window-spaced window is vetted by the
stream's *incremental* tick (only the new windows are dispatched, earlier
rows are reused, and replayed ticks hit the engine's result cache via the
stream's rolling fingerprint).  Properties kept from the batch estimator:
scale-equivariance, EI+OC == PR per window, vet >= 1 on well-formed profiles.
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional

__all__ = ["OnlineVet", "OnlineVetSnapshot"]


class OnlineVetSnapshot(NamedTuple):
    vet: float
    ei_rate: float  # EI per record (seconds) — the live ideal-cost estimate
    pr_rate: float  # PR per record
    n_window: int
    smoothed_vet: float


class OnlineVet:
    """Online vet with an O(window) ring of live records.

    feed(times) appends record times; every ``window // 2`` records (once the
    first full window has filled) a fresh estimate runs on the newest window
    and folds into an EMA.  Live records occupy an O(window) ring; the
    backing stream additionally retains six scalars per completed window of
    result history (its prefix-oracle contract), which grows with stream
    length unless ``history=`` caps it — an estimator meant to live for the
    whole deployment should pass a cap (the EMA itself only ever needs the
    newest rows; evicted rows shift the stream's ``first_retained`` and the
    fold accounts for the offset).

    ``engine`` is the backing ``VetEngine``; when omitted, a shared default
    (jax backend, ``buckets`` as given) is used.  With an explicit engine its
    own bucketing configuration wins over ``buckets``.

    Args:
        window: records per estimate (>= 64; refresh every ``window // 2``).
        alpha: EMA weight for the newest window's vet.
        buckets: change-point bucketing for the default engine.
        engine: explicit backing ``VetEngine``.
        history: cap on retained per-window result rows (clamped up to the
            stream's geometric safe minimum; pass one for long-lived
            estimators).

    Raises:
        ValueError: ``window < 64``.

    Example::

        >>> import numpy as np
        >>> from repro.engine import VetEngine
        >>> ov = OnlineVet(window=64, engine=VetEngine("numpy", buckets=16))
        >>> snaps = ov.feed(np.linspace(1e-3, 2e-3, 200))
        >>> len(snaps)                 # windows complete at 64, 96, ... 192
        5
        >>> ov.snapshot is snaps[-1] and snaps[-1].n_window == 64
        True
    """

    def __init__(self, window: int = 512, alpha: float = 0.3,
                 buckets: Optional[int] = 64, engine=None,
                 history: Optional[int] = None):
        if window < 64:
            raise ValueError("window must be >= 64")
        self.window = window
        self.alpha = alpha
        self.buckets = buckets
        if engine is None:
            from ..engine import default_engine  # deferred: engine -> core.vet

            engine = default_engine("jax", buckets=buckets)
        self.engine = engine
        from ..engine import VetStream  # deferred: engine -> core.vet

        # Half-window stride = the refresh cadence; 4x capacity keeps the
        # sliding() drill-down view resident and bounds per-feed sub-chunks.
        stride = max(1, window // 2)
        capacity = 4 * window
        # The stream may not evict a row before feed() has folded it: one
        # tick commits at most (capacity - window) // stride + 1 rows (every
        # unvetted window is still ring-resident), and feed() folds after
        # every tick, so clamping the stream cap to that geometric bound
        # keeps any user history= exact (it is a small constant — memory
        # stays O(window)).
        if history is not None:
            history = max(int(history), (capacity - window) // stride + 1)
        self._stream = VetStream(engine, window=window, stride=stride,
                                 capacity=capacity, history=history)
        self._emitted = 0  # windows already folded into the EMA
        self._smoothed: Optional[float] = None
        self._last: Optional[OnlineVetSnapshot] = None

    def feed(self, times) -> List[OnlineVetSnapshot]:
        """Add a chunk of record times; returns every snapshot it emits.

        A single call can span several window completions (e.g. a large chunk
        of buffered records arriving at once) — each completed window yields
        its own snapshot, in stream order.  An empty list means no window
        completed.  Chunks are appended vectorized; completions are computed
        arithmetically by the backing stream, so chunked and record-at-a-time
        feeds emit identical snapshot lists.

        Args:
            times: 1-D chunk of record times (seconds), any size.

        Returns:
            The ``OnlineVetSnapshot`` list this chunk completed (possibly
            empty), oldest first.

        Example::

            >>> import numpy as np
            >>> from repro.engine import VetEngine
            >>> ov = OnlineVet(window=64,
            ...                engine=VetEngine("numpy", buckets=16))
            >>> ov.feed(np.linspace(1e-3, 2e-3, 63))    # one short of a window
            []
            >>> [round(s.smoothed_vet, 6) == round(s.vet, 6)
            ...  for s in ov.feed([2e-3])]              # first fold: EMA seed
            [True]
        """
        out: List[OnlineVetSnapshot] = []
        # The stream sub-chunks by its ring budget; the pressure hook folds
        # after *every* forced tick: with a bounded history a tick's commit
        # evicts rows past the cap, so folding must never lag a tick or
        # capped streams would skip snapshots on large chunks (the history
        # clamp in __init__ holds exactly because of this pairing).
        self._stream.feed(
            times,
            on_pressure=lambda: self._fold_new(self._stream.tick(), out))
        self._fold_new(self._stream.tick(), out)
        return out

    def _fold_new(self, res, out: List[OnlineVetSnapshot]) -> None:
        """Fold every not-yet-emitted row of a tick result into the EMA."""
        if res is None:
            return
        # Windows re-vetted via stream.amend()/invalidate() since the
        # last feed re-fold from the first corrected row (the EMA is
        # order-sensitive, so a correction perturbs rather than rewrites
        # the smoothed history — but snapshots reflect corrected data).
        rewound = self._stream.consume_rewind()
        if rewound is not None:
            self._emitted = min(self._emitted, rewound)
        # With a bounded history, row j of the result is window base + j.
        base = self._stream.first_retained
        self._emitted = max(self._emitted, base)
        for k in range(self._emitted, base + res.workers):
            out.append(self._fold(float(res.vet[k - base]),
                                  float(res.ei[k - base]),
                                  float(res.pr[k - base])))
        self._emitted = base + res.workers

    def _fold(self, vet: float, ei: float, pr: float) -> OnlineVetSnapshot:
        self._smoothed = (vet if self._smoothed is None
                          else self.alpha * vet + (1 - self.alpha) * self._smoothed)
        self._last = OnlineVetSnapshot(
            vet=vet,
            ei_rate=ei / self.window,
            pr_rate=pr / self.window,
            n_window=self.window,
            smoothed_vet=self._smoothed,
        )
        return self._last

    def sliding(self, window: int, stride: int = 1):
        """Batched vet over every sliding sub-window of the current buffer.

        The dashboard drill-down view: one ``VetEngine.vet_sliding`` call
        (cached across ticks while the buffer is unchanged) over the newest
        ``self.window`` records.  Raises if fewer than ``window`` records
        are buffered.

        Args:
            window: sub-window length (>= 2, <= buffered records).
            stride: records between sub-window starts.

        Returns:
            ``BatchVetResult`` over the sub-windows, oldest first.

        Raises:
            ValueError: when fewer than ``window`` records are buffered
                (or the geometry is invalid).

        Example::

            >>> import numpy as np
            >>> from repro.engine import VetEngine
            >>> ov = OnlineVet(window=64,
            ...                engine=VetEngine("numpy", buckets=16))
            >>> _ = ov.feed(np.linspace(1e-3, 2e-3, 96))
            >>> ov.sliding(window=32, stride=16).workers
            3
        """
        return self.engine.vet_sliding(self._stream.latest(self.window),
                                       window=window, stride=stride)

    @property
    def stream(self):
        """The backing ``VetStream`` (stats, resident buffer, amend hooks)."""
        return self._stream

    @property
    def snapshot(self) -> Optional[OnlineVetSnapshot]:
        return self._last
