"""Streaming vet (beyond-paper): windowed online estimation for live jobs.

The paper computes vet post-hoc over a task's full profile.  A production
dashboard needs it *during* the run: this maintains a bounded reservoir of
recent records and re-estimates (EI, OC, vet) incrementally, with exponential
forgetting across windows so regime changes (a straggler appearing, input
storage degrading) surface within one window.

Estimation is delegated to a ``repro.engine.VetEngine`` — this class is only
the windowing/EMA wrapper around it.  Every estimate goes through the
engine's memoized result cache, so a dashboard that re-ticks (``_estimate``
re-run, or the ``sliding()`` per-sub-window view) over an unchanged buffer is
served from the cache instead of re-running the compiled batch.  Properties
kept from the batch estimator: scale-equivariance, EI+OC == PR per window,
vet >= 1 on well-formed profiles.
"""

from __future__ import annotations

from typing import Deque, List, NamedTuple, Optional

import collections

import numpy as np

__all__ = ["OnlineVet", "OnlineVetSnapshot"]


class OnlineVetSnapshot(NamedTuple):
    vet: float
    ei_rate: float  # EI per record (seconds) — the live ideal-cost estimate
    pr_rate: float  # PR per record
    n_window: int
    smoothed_vet: float


class OnlineVet:
    """Bounded-memory online vet.

    feed(times) appends record times; every ``window`` records a fresh batch
    estimate runs on the newest window and folds into an EMA.  O(window) memory
    regardless of stream length.

    ``engine`` is the backing ``VetEngine``; when omitted, a shared default
    (jax backend, ``buckets`` as given) is used.  With an explicit engine its
    own bucketing configuration wins over ``buckets``.
    """

    def __init__(self, window: int = 512, alpha: float = 0.3,
                 buckets: Optional[int] = 64, engine=None):
        if window < 64:
            raise ValueError("window must be >= 64")
        self.window = window
        self.alpha = alpha
        self.buckets = buckets
        if engine is None:
            from ..engine import default_engine  # deferred: engine -> core.vet

            engine = default_engine("jax", buckets=buckets)
        self.engine = engine
        self._buf: Deque[float] = collections.deque(maxlen=window)
        self._since_update = 0
        self._smoothed: Optional[float] = None
        self._last: Optional[OnlineVetSnapshot] = None

    def feed(self, times) -> List[OnlineVetSnapshot]:
        """Add record times; returns every snapshot emitted by this call.

        A single call can span several window completions (e.g. a large chunk
        of buffered records arriving at once) — each completed window yields
        its own snapshot, in stream order.  An empty list means no window
        completed.  (Earlier versions returned only the last snapshot,
        silently dropping the intermediate ones.)
        """
        arr = np.atleast_1d(np.asarray(times, dtype=np.float64))
        out: List[OnlineVetSnapshot] = []
        for t in arr:
            self._buf.append(float(t))
            self._since_update += 1
            if len(self._buf) >= self.window and self._since_update >= self.window // 2:
                out.append(self._estimate())
                self._since_update = 0
        return out

    def sliding(self, window: int, stride: int = 1):
        """Batched vet over every sliding sub-window of the current buffer.

        The dashboard drill-down view: one ``VetEngine.vet_sliding`` call
        (cached across ticks while the buffer is unchanged) instead of a
        per-sub-window scalar loop.  Raises if fewer than ``window`` records
        are buffered.
        """
        return self.engine.vet_sliding(np.asarray(self._buf), window=window,
                                       stride=stride)

    def _estimate(self) -> OnlineVetSnapshot:
        # vet_one funnels through the engine's cached vet_batch: a re-tick
        # over an unchanged buffer is a cache hit, not a compiled call.
        window = np.asarray(self._buf)
        r = self.engine.vet_one(window)
        vet = float(r.vet)
        self._smoothed = (vet if self._smoothed is None
                          else self.alpha * vet + (1 - self.alpha) * self._smoothed)
        self._last = OnlineVetSnapshot(
            vet=vet,
            ei_rate=float(r.ei) / window.size,
            pr_rate=float(r.pr) / window.size,
            n_window=window.size,
            smoothed_vet=self._smoothed,
        )
        return self._last

    @property
    def snapshot(self) -> Optional[OnlineVetSnapshot]:
        return self._last
