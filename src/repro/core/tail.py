"""Heavy-tail diagnostics (paper §5.3): Hill estimator, Hill plot, emplot.

The paper establishes that record processing times are heavy-tailed
(P(X > x) ~ c x^{-alpha}, alpha ≈ 1.3 for its read-map profiles) — finite mean,
infinite variance — which is exactly why a lower-bound estimate must cut the
tail off statistically rather than average it.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["hill_estimator", "hill_plot", "emplot", "TailReport", "tail_report"]


def _sorted_desc(x: jax.Array) -> jax.Array:
    x = jnp.asarray(x)
    x = x.astype(jnp.promote_types(x.dtype, jnp.float32))
    return jnp.sort(x)[::-1]


def hill_estimator(x: jax.Array, k: int) -> jax.Array:
    """Hill tail-index estimate using the k largest observations.

    alpha-hat(k) = [ (1/k) sum_{i=1..k} (log Y_{n+1-i} - log Y_{n-k}) ]^{-1}

    (The paper's displayed formula gives 1/alpha — the average log-excess; we
    return alpha itself, matching its quoted "alpha around 1.3".)
    """
    y = _sorted_desc(x)
    top = jnp.log(y[:k])
    ref = jnp.log(y[k])
    gamma = jnp.mean(top - ref)  # = 1/alpha
    return 1.0 / gamma


def hill_plot(x: jax.Array, k_max: int | None = None):
    """(k, alpha-hat(k)) pairs for k = 2..k_max (vectorized, O(n))."""
    y = _sorted_desc(x)
    n = y.shape[0]
    if k_max is None:
        k_max = n - 1
    k_max = min(k_max, n - 1)
    logs = jnp.log(y)
    csum = jnp.cumsum(logs)
    ks = jnp.arange(2, k_max + 1)
    gamma = csum[ks - 1] / ks - logs[ks]
    return ks, 1.0 / gamma


def emplot(x: jax.Array):
    """Tail empirical-distribution plot data: (log y_i, log(1 - F-hat(y_i))).

    Heavy tails appear linear with slope -alpha.
    """
    x = jnp.asarray(x)
    x = x.astype(jnp.promote_types(x.dtype, jnp.float32))
    y = jnp.sort(x)
    n = y.shape[0]
    # Survival at the i-th order statistic: (n - i) / n, drop the last point.
    surv = (n - jnp.arange(1, n + 1)) / n
    return jnp.log(y[:-1]), jnp.log(surv[:-1])


class TailReport(NamedTuple):
    alpha: float
    alpha_stable_band: tuple  # (lo, hi) of alpha-hat over the stable k range
    emplot_slope: float  # OLS slope of emplot (should be ~ -alpha)
    heavy: bool  # alpha < 2  =>  infinite variance


def tail_report(x: jax.Array, k_frac: float = 0.1) -> TailReport:
    """Summarize the tail: point estimate at k = k_frac*n, stability band over
    k in [5%, 20%] of n, and the emplot OLS slope as a cross-check."""
    x = jnp.asarray(x)
    n = int(x.shape[0])
    k = max(2, int(n * k_frac))
    alpha = float(hill_estimator(x, k))
    ks, alphas = hill_plot(x, k_max=max(3, int(n * 0.2)))
    lo_i = max(0, int(n * 0.05) - 2)
    band = alphas[lo_i:]
    lx, ls = emplot(x)
    # OLS slope over the top half of the tail.
    h = lx.shape[0] // 2
    lx_t, ls_t = lx[h:], ls[h:]
    lx_c = lx_t - jnp.mean(lx_t)
    denom = jnp.sum(lx_c * lx_c)
    slope = float(jnp.sum(lx_c * (ls_t - jnp.mean(ls_t))) / jnp.where(denom > 0, denom, 1.0))
    return TailReport(
        alpha=alpha,
        alpha_stable_band=(float(jnp.min(band)), float(jnp.max(band))),
        emplot_slope=slope,
        heavy=alpha < 2.0,
    )
