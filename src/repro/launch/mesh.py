"""Production mesh construction (a FUNCTION, so importing this module never
touches jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading pod=2 axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (reduced meshes for tests, elastic rescale)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
