"""Launchers: mesh construction, step factories, dry-run, train, serve."""
