"""Step factories: jit-able train / prefill / decode steps with the sharding
rules applied at the jit boundary (in_shardings/out_shardings + donation)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..distributed.sharding import (
    MeshAxes,
    batch_specs,
    cache_specs,
    opt_state_specs,
    param_specs,
)
from ..models import ShardCtx, decode_step, loss_fn, prefill
from ..models.layers import NULL_CTX
from ..optim.adamw import AdamWConfig, adamw_update

__all__ = ["make_ctx", "make_train_step", "make_prefill_step", "make_decode_step",
           "jit_train_step", "jit_prefill_step", "jit_decode_step"]


def make_ctx(mesh) -> ShardCtx:
    if mesh is None:
        return NULL_CTX
    ax = MeshAxes(mesh)
    return ShardCtx(mesh=mesh, dp_axes=ax.dp, tp_axis=ax.tp)


def make_train_step(cfg, mesh=None, *, opt_cfg: AdamWConfig = AdamWConfig(),
                    remat: str = "full", q_chunk: int = 1024,
                    unroll: bool = False, aux_weight: float = 0.01,
                    n_micro: int = 1):
    """n_micro > 1 => gradient accumulation over microbatches (splits the
    global batch on axis 0), the standard lever for fitting activation
    memory.  The dry-run auto-tunes it per cell."""
    ctx = make_ctx(mesh)

    def one_loss(params, mb):
        def lf(p):
            return loss_fn(cfg, p, mb, ctx, remat=remat, q_chunk=q_chunk,
                           unroll=unroll, aux_weight=aux_weight)

        (loss, parts), grads = jax.value_and_grad(lf, has_aux=True)(params)
        return loss, parts, grads

    def train_step(params, opt, batch):
        if n_micro == 1:
            loss, parts, grads = one_loss(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro) + a.shape[1:]),
                batch,
            )
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            if ctx.mesh is not None:
                # ZeRO-shard the f32 accumulator (with replicated weights it
                # would otherwise replicate a params-sized f32 buffer)
                ax_ = MeshAxes(ctx.mesh)
                zsp = opt_state_specs(params, ax_, cfg)
                acc0 = jax.tree.map(
                    lambda z, sp: jax.lax.with_sharding_constraint(
                        z, NamedSharding(ctx.mesh, sp)), acc0, zsp)

            def mb_body(carry, mb):
                acc, loss_acc = carry
                loss, parts, grads = one_loss(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n_micro, acc, grads
                )
                return (acc, loss_acc + loss / n_micro), parts

            if unroll:
                acc, loss = acc0, 0.0
                for i in range(n_micro):
                    mb = jax.tree.map(lambda a: a[i], micro)
                    (acc, loss), parts = mb_body((acc, loss), mb)
            else:
                (acc, loss), parts = jax.lax.scan(
                    mb_body, (acc0, jnp.zeros((), jnp.float32)), micro
                )
                parts = jax.tree.map(lambda x: x[-1], parts)
            grads = acc
        new_params, new_opt, om = adamw_update(opt_cfg, params, grads, opt)
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg, mesh=None, *, q_chunk: int = 1024,
                      unroll: bool = False, n_micro: int = 1):
    """n_micro > 1 => chunked prefill (split the prompt batch, concat caches)
    — the standard serving lever for prefill activation memory."""
    ctx = make_ctx(mesh)

    def one(params, cache, batch):
        if not cfg.supports_decode:  # encoder: prefill == forward logits
            from ..models import forward

            logits, _ = forward(cfg, params, batch, ctx, remat="none",
                                q_chunk=q_chunk, unroll=unroll)
            return logits[:, -1], cache
        return prefill(cfg, params, cache, batch, ctx, q_chunk=q_chunk,
                       unroll=unroll)

    def prefill_step(params, cache, batch):
        if n_micro == 1:
            return one(params, cache, batch)
        b = jax.tree.leaves(batch)[0].shape[0]
        bb = b // n_micro
        outs = []
        for i in range(n_micro):
            mb = jax.tree.map(lambda a: a[i * bb:(i + 1) * bb], batch)
            sub = jax.tree.map(
                lambda a: jnp.zeros(a.shape[:1] + (a.shape[1] // n_micro,)
                                    + a.shape[2:], a.dtype), cache)
            outs.append(one(params, sub, mb))
        logits = jnp.concatenate([o[0] for o in outs], axis=0)
        if not cfg.supports_decode:
            return logits, cache
        new_cache = jax.tree.map(
            lambda *cs: jnp.concatenate(cs, axis=1), *[o[1] for o in outs])
        return logits, new_cache

    return prefill_step


def make_decode_step(cfg, mesh=None, *, unroll: bool = False):
    ctx = make_ctx(mesh)

    def step(params, cache, tokens, pos):
        return decode_step(cfg, params, cache, tokens, pos, ctx, unroll=unroll)

    return step


# --------------------------------------------------------------- jit bundling
def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)


def jit_train_step(cfg, mesh, p_shape, o_shape, b_shape, **kw):
    """jit(train_step) with FSDP/TP/ZeRO shardings + state donation."""
    ax = MeshAxes(mesh)
    ps = _named(mesh, param_specs(p_shape, ax, cfg))
    os_ = _named(mesh, opt_state_specs(p_shape, ax, cfg))
    from ..optim.adamw import OptState
    from jax.sharding import PartitionSpec as P

    o_shard = OptState(step=NamedSharding(mesh, P()), mu=os_, nu=os_)
    bs = _named(mesh, batch_specs(cfg, ax, b_shape))
    fn = make_train_step(cfg, mesh, **kw)
    return jax.jit(
        fn,
        in_shardings=(ps, o_shard, bs),
        out_shardings=(ps, o_shard, None),
        donate_argnums=(0, 1),
    )


def jit_prefill_step(cfg, mesh, p_shape, c_shape, b_shape, **kw):
    ax = MeshAxes(mesh)
    ps = _named(mesh, param_specs(p_shape, ax, cfg))
    cs = _named(mesh, cache_specs(c_shape, ax, cfg))
    bs = _named(mesh, batch_specs(cfg, ax, b_shape))
    fn = make_prefill_step(cfg, mesh, **kw)
    return jax.jit(
        fn, in_shardings=(ps, cs, bs), out_shardings=(None, cs),
        donate_argnums=(1,),
    )


def jit_decode_step(cfg, mesh, p_shape, c_shape, batch: int, **kw):
    ax = MeshAxes(mesh)
    ps = _named(mesh, param_specs(p_shape, ax, cfg))
    cs = _named(mesh, cache_specs(c_shape, ax, cfg))
    from jax.sharding import PartitionSpec as P

    b_axis = ax.dp_spec() if batch % ax.dp_size == 0 else None
    tok = NamedSharding(mesh, P(b_axis, None))
    fn = make_decode_step(cfg, mesh, **kw)
    return jax.jit(
        fn,
        in_shardings=(ps, cs, tok, NamedSharding(mesh, P())),
        out_shardings=(None, cs),
        donate_argnums=(1,),
    )
