"""ShapeDtypeStruct stand-ins for every model input (no device allocation),
plus the jit sharding bundles for train / prefill / decode steps."""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs import ArchConfig, ShapeSpec
from ..models import init_cache, init_params
from ..optim.adamw import init_opt_state

__all__ = ["input_specs", "params_shape", "opt_shape", "cache_shape"]


def input_specs(cfg: ArchConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for an (arch x shape) cell.

    train  : tokens/embeddings + labels
    prefill: tokens/embeddings only
    decode : one new token (B, 1) + scalar position
    """
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": sds((b, 1), jnp.int32), "pos": sds((), jnp.int32)}

    batch: Dict[str, Any] = {}
    if cfg.frontend == "audio_frames":
        batch["embeddings"] = sds((b, s, cfg.d_model), dtype)
        if shape.kind == "train":
            batch["labels"] = sds((b, s), jnp.int32)
        return batch
    if cfg.frontend == "vision_patches":
        fs = min(cfg.frontend_seq, s // 2)
        batch["embeddings"] = sds((b, fs, cfg.d_model), dtype)
        batch["tokens"] = sds((b, s - fs), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = sds((b, s - fs), jnp.int32)
        return batch
    batch["tokens"] = sds((b, s), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = sds((b, s), jnp.int32)
    return batch


def params_shape(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_params, cfg, dtype=dtype), jax.random.PRNGKey(0)
    )


def opt_shape(p_shape, moment_dtype=jnp.float32):
    return jax.eval_shape(
        functools.partial(init_opt_state, moment_dtype=moment_dtype), p_shape
    )


def cache_shape(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, s_max, dtype=dtype)
    )
