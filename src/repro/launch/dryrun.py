import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms (DESIGN.md §5).

Per cell:
  1. FULL compile (scan-over-layers): memory_analysis() proves per-chip fit and
     sharding coherence (this is the pass/fail gate, incl. the 2-pod mesh).
  2. COST decomposition (single-pod): XLA cost_analysis counts scan bodies
     once, so we compile *unrolled* variants with num_layers = L1, L2 (and L7
     for the hybrid, to separate the shared-attention application cost) and
     extrapolate: total(L) = cost(L1) + (L - L1) * per_layer [+ extra attn
     applications for the hybrid].
  3. Collective bytes parsed from the unrolled post-SPMD HLO the same way.

Usage:
  python -m repro.launch.dryrun --cell <arch> <shape> <single|multi>   # one cell (JSON to stdout)
  python -m repro.launch.dryrun --sweep --out benchmarks/results/dryrun.json
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

# TPU v5e hardware constants (targets; the container itself is CPU-only).
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 2 * 50e9  # bytes/s / chip (bidirectional links, ring per axis)
HBM_LIMIT = 16 * 2 ** 30  # 16 GiB per chip


def _cell_key(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}|{shape}|{mesh}"


# ---------------------------------------------------------------- single cell
def run_cell(arch: str, shape_name: str, mesh_kind: str, skip_cost: bool = False,
             overrides: dict | None = None):
    import jax
    import jax.numpy as jnp

    from repro.configs import cell_is_runnable, get_config, get_shape
    from repro.distributed.hlo_analysis import collective_bytes
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import jit_decode_step, jit_prefill_step, jit_train_step

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    runnable, why = cell_is_runnable(cfg, shape)
    if not runnable:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.devices.size
    t0 = time.perf_counter()

    def build(cfg_v, unroll: bool, n_micro: int = 1, moment_dtype=None):
        from repro.optim.adamw import AdamWConfig

        moment_dtype = moment_dtype or jnp.float32
        cfg_v = dataclasses.replace(cfg_v, q_head_pad_multiple=16)
        p_shape = S.params_shape(cfg_v)
        binp = S.input_specs(cfg_v, shape)
        if shape.kind == "train":
            o_shape = S.opt_shape(p_shape, moment_dtype)
            # single-block attention for training seqs: the chunk-loop's
            # backward (dynamic_slice + map) partitions badly under GSPMD
            step = jit_train_step(cfg_v, mesh, p_shape, o_shape, binp,
                                  q_chunk=shape.seq_len, unroll=unroll,
                                  n_micro=n_micro,
                                  opt_cfg=AdamWConfig(moment_dtype=moment_dtype))
            return step.lower(p_shape, o_shape, binp)
        if shape.kind == "prefill":
            c_shape = (S.cache_shape(cfg_v, shape.global_batch, shape.seq_len)
                       if cfg_v.supports_decode else {})
            step = jit_prefill_step(cfg_v, mesh, p_shape, c_shape, binp,
                                    q_chunk=2048, unroll=unroll,
                                    n_micro=n_micro)
            return step.lower(p_shape, c_shape, binp)
        # decode
        c_shape = S.cache_shape(cfg_v, shape.global_batch, shape.seq_len)
        step = jit_decode_step(cfg_v, mesh, p_shape, c_shape,
                               shape.global_batch, unroll=unroll)
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return step.lower(p_shape, c_shape, tok, pos)

    # ---- 1. full compile: memory + coherence ------------------------------
    # Auto-fit microbatching (gradient accumulation) to the 16 GiB budget —
    # the framework's Starfish-analogue config tuner.
    if shape.kind == "train":
        micro_opts = [1, 2, 4, 8, 16]
    elif shape.kind == "prefill":
        micro_opts = [1, 2]  # chunked prefill (serving-style)
    else:
        micro_opts = [1]
    per_dev_batch = max(shape.global_batch // 16, 1)
    micro_opts = [m for m in micro_opts if per_dev_batch % m == 0] or [1]
    attempts = [(m, jnp.float32) for m in micro_opts]
    if shape.kind == "train":  # last resort: bf16 Adam moments
        attempts.append((micro_opts[-1], jnp.bfloat16))
    for n_micro, moment_dtype in attempts:
        with jax.set_mesh(mesh):
            lowered = build(cfg, unroll=False, n_micro=n_micro,
                            moment_dtype=moment_dtype)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        if peak <= HBM_LIMIT or (n_micro, moment_dtype) == attempts[-1]:
            break
        del compiled, lowered
    # CPU-backend artifact (decode): XLA CPU has no native bf16 dot, so it
    # hoists f32 converts of the WHOLE stacked KV cache out of the layer
    # scan (verified in the buffer assignment: two f32[cache] temp values,
    # `wrapped_convert`).  TPU lowering has no such converts.  We report the
    # raw peak AND a tpu-estimate with exactly those two copies removed.
    artifact = 0
    if shape.kind == "decode":
        from repro.distributed.sharding import MeshAxes, cache_specs

        ax = MeshAxes(mesh)
        c_shape = S.cache_shape(cfg, shape.global_batch, shape.seq_len)
        cspec = cache_specs(c_shape, ax, cfg)

        def dev_bytes(leaf, spec):
            shards = 1
            for e in spec:
                if e is None:
                    continue
                for a in (e if isinstance(e, tuple) else (e,)):
                    shards *= mesh.shape[a]
            import numpy as _np

            return int(_np.prod(leaf.shape)) * leaf.dtype.itemsize // shards

        cache_dev = sum(
            dev_bytes(l, sp)
            for l, sp in zip(jax.tree.leaves(c_shape), jax.tree.leaves(cspec))
        )
        artifact = 2 * cache_dev  # f32 copy of the bf16 K and V stacks
        # memory floor for the decode roofline fraction: every step must
        # stream params + the KV/state cache once
        params_dev = 2 * cfg.param_count() / n_chips  # bf16
        result_extra = {"mandatory_bytes_per_chip": float(params_dev + cache_dev)}

    peak_tpu = peak - artifact
    if shape.kind != "decode":
        result_extra = {}
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": "ok",
        "n_chips": int(n_chips),
        "n_micro": n_micro,
        "moment_dtype": str(jnp.dtype(moment_dtype).name),
        "cpu_f32_cache_artifact_bytes": int(artifact),
        "peak_tpu_estimate_bytes": int(peak_tpu),
        **result_extra,
        "fits_hbm": bool(peak_tpu <= HBM_LIMIT),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_device_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes
            + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "full_compile_s": round(time.perf_counter() - t0, 1),
    }
    del compiled, lowered

    if skip_cost or multi:
        return result

    # ---- 2/3. cost decomposition (single-pod roofline terms) ---------------
    def cost_of(cfg_v):
        with jax.set_mesh(mesh):
            low = build(cfg_v, unroll=True, n_micro=n_micro,
                        moment_dtype=moment_dtype)
            comp = low.compile()
        ca = comp.cost_analysis()
        coll = collective_bytes(comp.as_text())
        return {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "ici_bytes": coll["ici_bytes"],
            "coll": coll,
        }

    fd = cfg.first_dense_layers if cfg.is_moe else 0
    L1, L2 = fd + 1, fd + 2
    levels = [L1, L2]
    if cfg.family == "hybrid":
        levels.append(cfg.hybrid_attn_every + 1)  # second attn application
    costs = {}
    for lv in levels:
        costs[lv] = cost_of(dataclasses.replace(cfg, num_layers=lv))

    L = cfg.num_layers

    def combine(field):
        c1, c2 = costs[L1][field], costs[L2][field]
        per_layer = max(c2 - c1, 0.0)
        total = c1 + (L - L1) * per_layer
        if cfg.family == "hybrid":
            c7 = costs[levels[-1]][field]
            attn_cost = max(c7 - c1 - (levels[-1] - L1) * per_layer, 0.0)
            n_apps = -(-L // cfg.hybrid_attn_every)
            total += (n_apps - 1) * attn_cost
        return total

    flops = combine("flops")
    bytes_ = combine("bytes")
    ici = combine("ici_bytes")

    # per-chip HLO numbers: CPU cost_analysis reports the single (SPMD)
    # program, which is already the per-device shard.
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = ici / ICI_BW
    dominant = max(
        [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
        key=lambda kv: kv[1],
    )[0]

    n_params = cfg.param_count() if shape.kind == "train" else cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * (cfg.active_param_count() if cfg.is_moe else cfg.param_count()) * tokens
    model_flops_per_chip = model_flops / n_chips

    result.update({
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_,
        "ici_bytes_per_chip": ici,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flop_ratio": model_flops_per_chip / flops if flops else 0.0,
        "roofline_bound_s": max(t_compute, t_memory, t_coll),
        "collective_detail": costs[L2]["coll"]["bytes_by_kind"],
        "levels": {str(k): v for k, v in costs.items()},
    })
    return result


# --------------------------------------------------------------------- sweep
def sweep(out_path: str, meshes, only_arch=None, only_shape=None, timeout=3600):
    from repro.configs import ARCH_NAMES, SHAPES

    try:
        with open(out_path) as f:
            results = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        results = {}

    cells = []
    for arch in ARCH_NAMES:
        if only_arch and arch != only_arch:
            continue
        for shape in SHAPES:
            if only_shape and shape != only_shape:
                continue
            for mesh in meshes:
                if _cell_key(arch, shape, mesh) not in results:
                    cells.append((arch, shape, mesh))

    print(f"[dryrun] {len(cells)} cells to run", flush=True)
    for i, (arch, shape, mesh) in enumerate(cells):
        key = _cell_key(arch, shape, mesh)
        print(f"[dryrun] ({i+1}/{len(cells)}) {key}", flush=True)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--cell", arch, shape, mesh]
        try:
            proc = subprocess.run(
                cmd, capture_output=True, text=True, timeout=timeout,
                env={**os.environ, "PYTHONPATH": "src"},
            )
            if proc.returncode == 0:
                payload = json.loads(proc.stdout.strip().splitlines()[-1])
            else:
                payload = {"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "error",
                           "error": proc.stderr.strip()[-2000:]}
        except subprocess.TimeoutExpired:
            payload = {"arch": arch, "shape": shape, "mesh": mesh,
                       "status": "timeout", "timeout_s": timeout}
        results[key] = payload
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
        status = payload.get("status")
        extra = ""
        if status == "ok" and "dominant" in payload:
            extra = (f" dominant={payload['dominant']}"
                     f" bound={payload['roofline_bound_s']:.4f}s"
                     f" useful={payload['useful_flop_ratio']:.2f}")
        print(f"[dryrun]   -> {status}{extra}", flush=True)
    print("[dryrun] sweep complete", flush=True)


def run_test_cell(arch: str):
    """CI smoke: reduced config on a 2x2 mesh (4 host devices), full compile
    of a small train step — exercises the sharding rules + step factories
    without the production-scale sweep."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch import specs as S
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import jit_train_step

    cfg = get_config(arch).reduced()
    mesh = make_mesh((2, 2), ("data", "model"))
    p_shape = S.params_shape(cfg, dtype=jnp.float32)
    o_shape = S.opt_shape(p_shape)
    binp = {
        "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
    }
    if cfg.frontend == "audio_frames":
        binp = {
            "embeddings": jax.ShapeDtypeStruct((8, 32, cfg.d_model), jnp.float32),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        }
    if cfg.frontend == "vision_patches":
        fs = cfg.frontend_seq
        binp = {
            "embeddings": jax.ShapeDtypeStruct((8, fs, cfg.d_model), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((8, 32 - fs), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 32 - fs), jnp.int32),
        }
    step = jit_train_step(cfg, mesh, p_shape, o_shape, binp, q_chunk=32)
    with jax.set_mesh(mesh):
        compiled = step.lower(p_shape, o_shape, binp).compile()
    mem = compiled.memory_analysis()
    return {"arch": arch, "status": "ok",
            "temp_bytes": mem.temp_size_in_bytes}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", nargs=3, metavar=("ARCH", "SHAPE", "MESH"))
    ap.add_argument("--test-cell", default=None,
                    help="CI smoke: reduced config on a 2x2 mesh")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg overrides key=value (hillclimb variants)")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    if args.test_cell:
        try:
            res = run_test_cell(args.test_cell)
        except Exception as e:
            res = {"arch": args.test_cell, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-1500:]}
        print(json.dumps(res))
        return
    if args.cell:
        overrides = {}
        for kv in args.set:
            k, v = kv.split("=", 1)
            overrides[k] = (v.lower() == "true") if v.lower() in ("true", "false") else (
                int(v) if v.lstrip("-").isdigit() else v)
        try:
            res = run_cell(*args.cell, overrides=overrides or None)
        except Exception as e:  # surfaced as JSON for the sweep orchestrator
            res = {"arch": args.cell[0], "shape": args.cell[1],
                   "mesh": args.cell[2], "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-1500:]}
        print(json.dumps(res))
        return
    if args.sweep:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        sweep(args.out, args.meshes.split(","), args.arch, args.shape, args.timeout)
        return
    main_help = "use --cell ARCH SHAPE MESH or --sweep"
    print(main_help)


if __name__ == "__main__":
    main()
