"""Fault-tolerant training driver with first-class vet instrumentation.

Loop: data fetch -> jitted train_step -> (periodic) async checkpoint, with
  * every step timed as a vet "record" (unit-grouped, paper §5.2);
  * sub-phases (data / step / ckpt) timed for the Fig. 3 spill-constancy view;
  * crash-resume: restore from the newest complete checkpoint, replay the
    deterministic data stream from the step counter;
  * simulated failure injection (``fail_at_step``) for the recovery tests;
  * a VetController consuming the live profile (paper §5.5) whose decision is
    surfaced in the metrics (host-level concurrency is a deploy-side knob);
  * all vet estimation routed through one shared ``VetEngine`` (``engine=``),
    so the report and the controller use the same batched estimator.

CLI:  python -m repro.launch.train --arch mamba2-130m --steps 100 ...
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpoint import AsyncCheckpointer, latest_step, restore
from ..configs import get_config
from ..data.pipeline import SyntheticTokenPipeline
from ..engine import VetEngine, default_engine
from ..models import init_params
from ..optim.adamw import AdamWConfig, init_opt_state
from ..profiling import PhaseTimer, RecordProfiler
from ..sched.straggler import VetController
from .steps import make_train_step

__all__ = ["TrainResult", "train"]


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: list
    vet: Optional[float]
    ei: Optional[float]
    pr: Optional[float]
    phase_totals: Dict[str, float]
    resumed_from: Optional[int]
    controller_decision: Optional[Any]
    # per-worker vet snapshots from the controller's batched engine call
    worker_vets: Optional[Dict[int, float]] = None


class SimulatedFailure(RuntimeError):
    pass


def train(
    cfg_or_name,
    *,
    steps: int,
    batch: int = 8,
    seq_len: int = 128,
    lr: float = 3e-4,
    seed: int = 0,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    dtype=jnp.float32,
    mesh=None,
    n_micro: int = 1,
    record_unit: int = 5,
    fail_at_step: Optional[int] = None,
    fetch_stall_s: float = 0.0,
    q_chunk: int = 1024,
    log_every: int = 10,
    verbose: bool = True,
    engine: Optional[VetEngine] = None,
) -> TrainResult:
    cfg = get_config(cfg_or_name) if isinstance(cfg_or_name, str) else cfg_or_name

    class _Shape:
        global_batch = batch
        seq_len_ = seq_len

    pipe = SyntheticTokenPipeline(
        cfg.vocab_size, batch, seq_len, seed=seed, d_model=cfg.d_model,
        frontend=cfg.frontend, frontend_seq=max(cfg.frontend_seq, 0),
        fetch_stall_s=fetch_stall_s,
    )
    opt_cfg = AdamWConfig(lr=lr, total_steps=max(steps, 2), warmup_steps=min(20, steps // 5 + 1))
    step_fn = jax.jit(
        make_train_step(cfg, mesh, opt_cfg=opt_cfg, q_chunk=q_chunk,
                        n_micro=n_micro)
    )

    params = init_params(cfg, jax.random.PRNGKey(seed), dtype=dtype)
    opt = init_opt_state(params)

    start_step, resumed_from = 0, None
    ckpt: Optional[AsyncCheckpointer] = None
    if ckpt_dir:
        ckpt = AsyncCheckpointer(ckpt_dir)
        if latest_step(ckpt_dir) is not None:
            (params, opt), start_step = restore(ckpt_dir, (params, opt))
            start_step += 1
            resumed_from = start_step - 1
            if verbose:
                print(f"[train] resumed from step {resumed_from}")

    prof = RecordProfiler(unit=record_unit)
    phases = PhaseTimer()
    # With no explicit engine, the controller gets the shared fixed-bucket
    # default; the end-of-run report below adapts buckets to the profile
    # size (the pre-engine convention for short runs).
    controller = VetController(
        n_workers=max(n_micro, 1),
        engine=engine if engine is not None else default_engine("jax"),
    )
    losses = []

    step = start_step
    try:
        for step in range(start_step, steps):
            with phases.phase("data"):
                host_batch = pipe.batch_at(step)
                dev_batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
            with prof.record():
                with phases.phase("step"):
                    params, opt, metrics = step_fn(params, opt, dev_batch)
                    loss = float(metrics["loss"])
            losses.append(loss)
            if fail_at_step is not None and step == fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            if ckpt and step > 0 and step % ckpt_every == 0:
                with phases.phase("ckpt"):
                    ckpt.save(step, (params, opt))
            if verbose and step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
    finally:
        if ckpt:
            try:
                ckpt.wait()
            except Exception:
                pass

    # final checkpoint + vet report
    if ckpt:
        ckpt.save(step, (params, opt))
        ckpt.wait()

    vet = ei = pr = None
    decision = None
    worker_vets = None
    times = prof.unit_times()
    if times.size >= 16:
        if engine is None:
            engine = default_engine("jax", buckets=min(64, times.size // 4))
        r = engine.vet_one(times)
        vet, ei, pr = float(r.vet), float(r.ei), float(r.pr)
        controller.feed(0, times)
        decision = controller.decide()
        worker_vets = dict(decision.worker_vets) or None
        if verbose:
            print(f"[train] vet={vet:.3f} EI={ei:.3f}s PR={pr:.3f}s "
                  f"controller: {decision.reason}")
    return TrainResult(
        final_step=step, losses=losses, vet=vet, ei=ei, pr=pr,
        phase_totals=phases.totals(), resumed_from=resumed_from,
        controller_decision=decision, worker_vets=worker_vets,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--n-micro", type=int, default=1)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    res = train(cfg, steps=args.steps, batch=args.batch, seq_len=args.seq_len,
                lr=args.lr, ckpt_dir=args.ckpt_dir, n_micro=args.n_micro)
    print(f"[train] done at step {res.final_step}; "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
