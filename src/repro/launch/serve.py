"""Batched decode serving loop with per-request-step vet profiling.

prefill(prompt batch) -> decode loop; every decode step is a profiled record
(the paper's reduce-write analogue), so a serving deployment gets the same
optimality dashboard as training: vet per serving worker (estimated by the
shared ``VetEngine``), EI as the estimated ideal per-token latency, and
per-window snapshots showing vet drift over the generation.  The window
snapshots come from a ``VetStream`` registered in a ``repro.fleet.VetMux``
and ticked *inside* the decode loop — each completed unit-record is appended
in O(1) and only newly completed windows are ever vetted, through the same
coalesced dispatch path a multi-worker dashboard uses — instead of
re-slicing the full profile after the run.

The mux's live anomaly monitor (``repro.fleet.anomaly``) rides every tick:
a regime shift in the decode stream's window vets — a slow node picked up
mid-generation, contention onset — is printed the tick it is flagged and
returned on ``ServeResult.flags``, with the running count in the mux stats
line.
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..engine import BatchVetResult, VetEngine, default_engine
from ..fleet import ShardedVetMux, TransportVetMux
from ..models import decode_step, init_cache, init_params, prefill
from ..obs import LedgerReport, Tracer, format_ledger, ledger_from, write_chrome
from ..obs.trace import timed as _timed
from ..profiling import RecordProfiler

__all__ = ["ServeResult", "serve"]

_SNAPSHOT_WINDOW = 32  # unit-records per windowed vet snapshot
_SNAPSHOT_HISTORY = 64  # newest window snapshots retained for the drift view


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray  # (B, generated)
    vet: Optional[float]
    ei: Optional[float]
    pr: Optional[float]
    tokens_per_s: float
    # Windowed per-worker snapshots (newest <= _SNAPSHOT_HISTORY windows)
    # from the stream ticked during decode (None when the run produced
    # fewer than two full windows).
    windows: Optional[BatchVetResult] = None
    # Regime-shift flags raised by the mux's live anomaly monitor while the
    # decode loop ran (``repro.fleet.RegimeShift``; empty on a quiet run).
    flags: tuple = ()
    # Optimality ledger over the run's trace (None unless a tracer was
    # attached): measured-over-floor ratios per instrumented stage.
    ledger: Optional[LedgerReport] = None
    # Online tuner summary (None unless ``tune=True``): best/current knob
    # assignment, round/rollback counts (``VetTuner.report()``).
    tuner: Optional[dict] = None


def serve(
    cfg_or_name,
    *,
    batch: int = 4,
    prompt_len: int = 32,
    gen_len: int = 64,
    seed: int = 0,
    dtype=jnp.float32,
    mesh=None,
    record_unit: int = 5,
    greedy: bool = True,
    verbose: bool = True,
    engine: Optional[VetEngine] = None,
    shards: int = 1,
    transport: bool = False,
    tune: bool = False,
    tracer: Optional[Tracer] = None,
    trace_path: Optional[str] = None,
) -> ServeResult:
    cfg = get_config(cfg_or_name) if isinstance(cfg_or_name, str) else cfg_or_name
    if not cfg.supports_decode:
        raise ValueError(f"{cfg.name} is encoder-only")
    if tracer is None and trace_path is not None:
        tracer = Tracer()

    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key, dtype=dtype)
    s_max = prompt_len + gen_len
    cache = init_cache(cfg, batch, s_max, dtype=dtype)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    prefill_fn = jax.jit(lambda p, c, b: prefill(cfg, p, c, b))
    step_fn = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))

    import time

    t0 = time.perf_counter()
    logits, cache = prefill_fn(params, cache, {"tokens": prompts})
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)

    prof = RecordProfiler(unit=record_unit, name="decode", tracer=tracer)
    # Live window snapshots: this worker's stream registered in a fleet mux
    # and ticked as unit-records complete, so each tick vets only the
    # windows the last unit finished through the fleet's coalesced dispatch
    # path (a multi-worker deployment registers every decode worker in the
    # same mux; the snapshot windows are bucket-free at this size, so the
    # stream engine needs no size-adapted bucket count).  The mux is the
    # sharded fleet entry point — ``shards=1`` (one local decode worker) is
    # a single shard, and a multi-host deployment raises ``shards`` so each
    # serving process keeps its own engine while the dashboard reads the
    # shard-merged job reduction (``tick.vet_job``).
    if transport:
        # Cross-process fleet: each shard mux lives in its own worker
        # process behind retries + checkpoint/resume (repro.fleet.transport)
        # — the decode loop keeps vetting through worker crashes.
        mux = TransportVetMux(shards,
                              engine=(engine if engine is not None
                                      else default_engine("jax", buckets=64)),
                              tracer=tracer)
    else:
        mux = ShardedVetMux(shards,
                            engine=(engine if engine is not None
                                    else default_engine("jax", buckets=64)),
                            tracer=tracer)
    try:
        # The drift view keeps the newest _SNAPSHOT_HISTORY windows: plenty
        # for any one generation, bounded for a serve loop that lives
        # forever.  (Under transport the stream lives in the worker, so
        # register's return value is the shard index, not the stream.)
        stream = mux.register("decode", window=_SNAPSHOT_WINDOW,
                              stride=_SNAPSHOT_WINDOW,
                              capacity=4 * _SNAPSHOT_WINDOW,
                              history=_SNAPSHOT_HISTORY)
        fed_units = 0
        flags = []  # regime-shift flags raised live during decode
        vet_s = 0.0  # estimation overhead, excluded from the throughput wall
        tuner = None
        if tune:
            # Close the loop on the live fleet: the mux's tick_budget knob
            # driven by the online controller, with each estimation tick's
            # own measured duration as the (noisy) objective sample.  One
            # knob on one worker is the smoke-scale version of the same
            # write-back path a multi-worker deployment tunes its vet
            # stream with (repro.sched.tuner; tests/test_tuner.py locks
            # the controller against the grid oracle on the simulator).
            from ..fleet.knobs import mux_knob_hooks
            from ..sched.tuner import VetTuner
            tuner = VetTuner(mux_knob_hooks(mux), seed=seed,
                             noise_band=0.5, tracer=tracer)

        def _tick():
            # One mux tick; any regime-shift flag the live monitor raises is
            # printed the tick it fires — that's the dashboard's alert line.
            for f in mux.tick().flags:
                flags.append(f)
                if verbose:
                    print(f"[serve] REGIME SHIFT {f.stream_id}: window "
                          f"{f.onset} vet {f.pre:.2f} -> {f.post:.2f} "
                          f"(confidence {f.confidence:.2f})")

        out = [tok]
        for i in range(gen_len - 1):
            with prof.record():
                logits, cache = step_fn(params, cache, tok, jnp.asarray(prompt_len + i))
                tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
                tok.block_until_ready()
            out.append(tok)
            if prof.num_records % record_unit == 0:
                # One stopwatch for accounting and tracing (repro.obs.timed):
                # vet_s is the "serve.vet" span's own duration, measured on
                # the same clock whether or not a tracer is attached.
                sw = _timed(tracer, "serve.vet", step=i)
                with sw:
                    # O(new units) extraction + incremental tick: only the
                    # windows this unit completed are vetted.
                    new_units = prof.unit_times(start=fed_units)
                    mux.feed("decode", new_units)
                    fed_units += new_units.size
                    _tick()
                vet_s += sw.dur
                if tuner is not None:
                    # Knob write-back happens here, strictly between ticks.
                    tuner.step(sw.dur)
        wall = time.perf_counter() - t0 - vet_s
        gen = np.asarray(jnp.concatenate(out, axis=1))

        vet = ei = pr = None
        windows = None
        times = prof.unit_times()
        if times.size >= 16:
            if engine is None:
                # pre-engine call-site convention: bucket count adapts to the
                # profile size so short runs keep the bucketed estimator
                engine = default_engine("jax", buckets=min(64, times.size // 4))
            r = engine.vet_one(times)
            vet, ei, pr = float(r.vet), float(r.ei), float(r.pr)
            if verbose:
                print(f"[serve] vet={vet:.3f} EI={ei:.4f}s PR={pr:.4f}s")
            with _timed(tracer, "serve.vet", post=True):
                mux.feed("decode", times[fed_units:])  # trailing units
                _tick()
            # Transport ticks only carry newest-window rows; the retained
            # drift history comes from the bulk path either way.
            win = (mux.collect("decode") if transport
                   else mux.stream("decode").collect())
            if win is not None and win.workers >= 2:
                windows = win
                if verbose:
                    ws = " ".join(f"{v:.2f}" for v in windows.vet)
                    ms = mux.stats
                    detail = (f"{ms.respawns} respawns" if transport else
                              f"{stream.stats.vetted} vetted / "
                              f"{stream.stats.reused} reused rows")
                    print(f"[serve] window vets: {ws} "
                          f"({detail} over {ms.ticks} mux ticks / "
                          f"{ms.dispatches} dispatches / "
                          f"{ms.anomalies} anomalies)")
    finally:
        if transport:
            mux.close()
    tps = batch * gen_len / wall
    if verbose:
        print(f"[serve] {batch}x{gen_len} tokens in {wall:.2f}s = {tps:.1f} tok/s")
    tuner_report = None
    if tuner is not None:
        tuner_report = tuner.report()
        if verbose:
            knobs = " ".join(f"{k}={v}"
                             for k, v in sorted(tuner_report["best"].items()))
            print(f"[serve] tuner: best {knobs} "
                  f"(obj {tuner_report['best_y']*1e3:.2f}ms/tick over "
                  f"{tuner_report['rounds']} rounds / "
                  f"{tuner_report['rollbacks']} rollbacks"
                  f"{', converged' if tuner_report['converged'] else ''})")
    ledger = None
    if tracer is not None:
        # The live optimality dashboard: per-stage measured-over-floor
        # ratios from this run's trace (driver + any transport workers —
        # their spans were adopted tick by tick).
        ledger = ledger_from(tracer.records)
        if verbose:
            print(format_ledger(ledger, title="serve optimality ledger"))
        if trace_path is not None:
            write_chrome(trace_path, tracer)
            if verbose:
                print(f"[serve] chrome trace -> {trace_path} "
                      f"(load in Perfetto / chrome://tracing)")
    return ServeResult(tokens=gen, vet=vet, ei=ei, pr=pr, tokens_per_s=tps,
                       windows=windows, flags=tuple(flags), ledger=ledger,
                       tuner=tuner_report)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the vet fleet across N shard muxes")
    ap.add_argument("--transport", action="store_true",
                    help="run each shard mux in its own worker process "
                         "(retries + checkpoint/resume)")
    ap.add_argument("--tune", action="store_true",
                    help="close the loop: drive the mux tick_budget knob "
                         "with the online VetTuner and print its best "
                         "assignment on the dashboard")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="trace the run and write a Chrome trace-event JSON "
                         "here (Perfetto-loadable); also prints the "
                         "optimality ledger")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
          gen_len=args.gen_len, shards=args.shards, transport=args.transport,
          tune=args.tune, trace_path=args.trace)


if __name__ == "__main__":
    main()
