"""VetStream: incremental sliding-window vetting over a live record stream.

``VetEngine.vet_sliding`` answers "vet every window of this buffer" in one
batched call, and the engine's result cache makes a *repeat* of the identical
call free — but a live consumer (dashboard tick, straggler controller,
autotuner) never repeats the identical call: every tick the buffer has grown
by a chunk, so the whole buffer is re-gathered, re-hashed and re-vetted even
though only a handful of windows near the head are new.  ``VetStream`` is the
streaming path:

- **Ring buffer.**  A fixed-capacity ring of record times; ``append(chunk)``
  is O(chunk) regardless of how many records the stream has ever seen.
  Logical stream position ``p`` lives in ring slot ``p % capacity``, so a
  window's rows gather with one vectorized modular index.
- **Rolling fingerprint.**  Appends fold into a running blake2b digest —
  O(chunk), never a re-hash of the whole buffer.  The fingerprint (plus an
  epoch counter bumped by explicit invalidation) keys the engine-cache
  entries for each incremental dispatch, so replaying the same stream into
  the same engine hits the cache without hashing any matrix.
- **Incremental tick.**  ``tick()`` vets only the windows that became
  complete since the last tick — one batched engine dispatch over the delta —
  and splices the new rows into the accumulated per-window results.  Rows for
  old windows are reused from the previous tick, never re-vetted.  Each tick
  returns a ``BatchVetResult`` over all retained complete windows so far,
  equal to ``engine.vet_sliding(prefix, window, stride)`` on the same logical
  prefix (bitwise for the numpy backend; the jax/pallas backends carry their
  usual differential contracts — see ``tests/test_vet_stream.py``).
- **Invalidation-aware caching.**  Mutating history is explicit:
  ``amend(start, values)`` rewrites resident records, re-keys the fingerprint
  (epoch tag) and re-vets exactly the windows that saw the amended records on
  the next tick; ``invalidate()`` is the blanket hook ("I changed the ring
  under you") that re-vets every window still fully resident.  Either way a
  stale cache hit is impossible: pre-mutation keys are never issued again.
- **Mux primitives.**  The tick is factored into ``drain()`` (gather the
  unvetted delta matrix + its content-pure cache key, side-effect free),
  ``commit(delta, rows)`` (splice externally computed rows and advance the
  vetted watermark) and ``collect()`` (the retained-result view).  ``tick()``
  is exactly drain -> one engine dispatch -> commit -> collect; a
  ``repro.fleet.VetMux`` drains many streams, coalesces their deltas into
  shape-bucketed batched dispatches, and commits each stream's slice — the
  per-stream results are identical by construction.

The stream guarantees oracle equality only while every newly completed window
is still fully resident at tick time; if appends outrun the ring
(``capacity`` too small or ticks too rare), ``tick()`` raises instead of
silently skipping windows.  ``feed()`` is the self-managing ingest wrapper:
it sub-chunks an arbitrarily large append and ticks exactly when a further
append could overrun an unvetted window, so callers never track the budget
themselves.

Memory: the ring is O(capacity) records.  By default the accumulated result
rows are six scalars per complete window (~48 bytes) for the life of the
stream — the full prefix-oracle contract.  ``history=H`` bounds that: only
the newest ``H`` window rows are retained (oldest evicted past the cap, with
``first_retained`` naming the first surviving window), so an indefinitely
long stream holds O(capacity + H) memory while every retained row still
equals the corresponding batch-oracle row.
"""

from __future__ import annotations

import hashlib
from typing import NamedTuple, Optional

import numpy as np

from ..obs.trace import span as _span
from .engine import BatchVetResult, VetEngine, default_engine

__all__ = ["RingDelta", "StreamDelta", "StreamStats", "VetStream"]

_GROW = 64  # initial per-field result capacity (windows); grows as needed


class StreamStats(NamedTuple):
    """Counters for one stream (``VetStream.stats``)."""

    ticks: int  # tick() calls
    records: int  # records ever appended
    windows: int  # complete windows so far
    vetted: int  # window rows computed by engine dispatches
    reused: int  # window rows served from earlier ticks (sum over ticks)
    epoch: int  # invalidation epoch (amend/invalidate bumps)
    evicted: int  # window rows dropped by the bounded history cap


class StreamDelta(NamedTuple):
    """An unvetted window delta drained from a stream (``VetStream.drain``).

    ``matrix`` rows are windows ``[start, start + count)`` of the stream, in
    window order; ``key`` is the engine-cache key for this exact delta — a
    pure function of the (content-fingerprinted) append/amend history, so a
    replay of the same stream hits the cache without hashing the matrix.
    Draining is side-effect free: the delta only takes effect when passed to
    ``commit`` with its computed rows.
    """

    start: int  # first window index covered by this delta
    count: int  # number of windows in this delta
    matrix: np.ndarray  # (count, window) float64 gather of the delta windows
    key: tuple  # content-pure engine-cache key for these rows
    epoch: int  # stream epoch at drain time (commit rejects a mismatch)


class RingDelta(NamedTuple):
    """The fused-path twin of ``StreamDelta`` (``VetStream.drain_ring``).

    Instead of materializing the (count, window) gather matrix, it hands the
    engine's fused kernel the contiguous ring span covering the delta plus
    ring-relative window starts — memory O(span) <= O(ring), never
    O(windows x window).  ``commit`` accepts either delta type (it only
    reads the watermark/epoch/count fields).
    """

    start: int  # first window index covered by this delta
    count: int  # number of windows in this delta
    arena: np.ndarray  # (span,) float64 stream-order span covering the delta
    starts: np.ndarray  # (count,) int64 window starts relative to arena[0]
    window: int  # records per window
    key: tuple  # content-pure engine-cache key for these rows
    epoch: int  # stream epoch at drain time (commit rejects a mismatch)


class VetStream:
    """Incremental rolling-buffer vetting bound to one ``VetEngine``.

    Window ``k`` covers logical records ``[k*stride, k*stride + window)`` of
    the append stream — the same convention as ``vet_sliding``.  Usage::

        stream = VetStream(engine, window=512, stride=256)
        for chunk in source:
            stream.append(chunk)          # O(chunk)
            res = stream.tick()           # vets only newly complete windows
            if res is not None:
                dashboard.update(res.vet[-1], res.vet_job)

    ``capacity`` bounds resident records (default ``4 * window``); it must be
    at least ``window``, and between two ticks you may append at most
    ``capacity - window - stride + 1`` records without losing a window.
    ``history`` (optional) caps retained result rows: past the cap the oldest
    rows are evicted and ``tick()`` returns only the newest ``history``
    windows (``first_retained`` gives their absolute offset).
    """

    def __init__(self, engine: Optional[VetEngine] = None, *, window: int,
                 stride: int = 1, capacity: Optional[int] = None,
                 history: Optional[int] = None):
        window = int(window)
        stride = int(stride)
        if window < 2:
            raise ValueError(f"window must cover >= 2 records, got {window}")
        if stride < 1:
            raise ValueError(f"stride must be >= 1, got {stride}")
        capacity = int(capacity) if capacity is not None else 4 * window
        if capacity < window:
            raise ValueError(
                f"capacity ({capacity}) must hold at least one window "
                f"({window} records)")
        if history is not None:
            history = int(history)
            if history < 1:
                raise ValueError(
                    f"history must retain >= 1 window row, got {history}")
        self.engine = engine if engine is not None else default_engine("jax")
        self.window = window
        self.stride = stride
        self.capacity = capacity
        self.history = history
        self._ring = np.zeros(capacity, dtype=np.float64)
        self._total = 0  # records ever appended (logical stream length)
        self._vetted = 0  # windows whose rows are current in the result arrays
        self._epoch = 0
        self._fp = hashlib.blake2b(digest_size=16)
        self._ticks = 0
        self._vetted_rows = 0
        self._reused_rows = 0
        self._evicted_rows = 0
        self._last: Optional[BatchVetResult] = None
        # Accumulated per-window rows.  Window ``k`` lives at physical slot
        # ``k - _phys_base``; rows below ``_row_base`` are evicted (bounded
        # history) and never re-exposed.  Results are frozen *views* of these
        # arrays — O(delta) per tick, not O(windows-so-far) copies — so rows
        # already exposed to callers are never written again: a rewind
        # (amend/invalidate) below the exposed watermark, growth past the
        # physical capacity, and history compaction all reallocate fresh row
        # storage first (copy-on-write), leaving outstanding snapshots
        # aliasing the detached buffers.
        self._rows = {
            "vet": np.empty(_GROW), "ei": np.empty(_GROW),
            "oc": np.empty(_GROW), "pr": np.empty(_GROW),
            "t": np.empty(_GROW, dtype=np.int32),
            "n": np.empty(_GROW, dtype=np.int64),
        }
        self._phys_base = 0  # absolute window index stored at physical slot 0
        self._row_base = 0  # first retained (non-evicted) window index
        self._exposed = 0  # absolute window count handed out in some result
        self._dirty_low: Optional[int] = None  # lowest re-vetted exposed row

    def __repr__(self) -> str:
        return (f"VetStream(window={self.window}, stride={self.stride}, "
                f"capacity={self.capacity}, records={self._total}, "
                f"windows={self.complete_windows}, epoch={self._epoch})")

    # ------------------------------------------------------------ geometry
    @property
    def total_records(self) -> int:
        """Records ever appended (logical stream length)."""
        return self._total

    @property
    def complete_windows(self) -> int:
        """Windows fully covered by the stream so far."""
        if self._total < self.window:
            return 0
        return (self._total - self.window) // self.stride + 1

    @property
    def pending_windows(self) -> int:
        """Complete windows not yet vetted (what the next drain would take)."""
        return max(0, self.complete_windows - self._vetted)

    @property
    def headroom(self) -> int:
        """Records appendable before an unvetted window leaves the ring.

        When this reaches 0, the next append may overwrite records of a
        window that has not been vetted yet (a later ``tick`` then raises);
        ``feed`` — and ``repro.fleet.VetMux.feed`` — tick exactly when it is
        exhausted.
        """
        return self._vetted * self.stride + self.capacity - self._total

    @property
    def first_retained(self) -> int:
        """Absolute index of the oldest window still held in the result rows
        (0 unless a ``history`` cap evicted older rows)."""
        return self._row_base

    @property
    def stats(self) -> StreamStats:
        return StreamStats(ticks=self._ticks, records=self._total,
                           windows=self.complete_windows,
                           vetted=self._vetted_rows, reused=self._reused_rows,
                           epoch=self._epoch, evicted=self._evicted_rows)

    @property
    def fingerprint(self) -> str:
        """Rolling content fingerprint of the append/amend history."""
        return self._fp.hexdigest()

    def resident(self) -> np.ndarray:
        """Copy of the retained record suffix, in stream order."""
        lo = max(0, self._total - self.capacity)
        return self._ring[np.arange(lo, self._total) % self.capacity]

    def latest(self, n: int) -> np.ndarray:
        """Copy of the last ``min(n, resident)`` records, in stream order."""
        lo = max(0, self._total - min(int(n), self.capacity))
        return self._ring[np.arange(lo, self._total) % self.capacity]

    # ------------------------------------------------------------- writing
    def _write(self, arr: np.ndarray, pos0: int) -> None:
        """Write ``arr`` (len <= capacity) at logical position ``pos0``."""
        s = pos0 % self.capacity
        k = min(arr.size, self.capacity - s)
        self._ring[s:s + k] = arr[:k]
        if arr.size > k:
            self._ring[:arr.size - k] = arr[k:]

    @staticmethod
    def _coerce(times) -> np.ndarray:
        arr = np.asarray(times, dtype=np.float64)
        if arr.ndim > 1:
            raise ValueError(
                f"append expects a 1-D chunk of record times, got shape "
                f"{arr.shape}")
        return np.ascontiguousarray(np.atleast_1d(arr))

    def append(self, times) -> int:
        """Append a chunk of record times; O(chunk).  Returns records added.

        The raw primitive: no safety ticks — between two ``tick()`` calls the
        caller may append at most ``capacity - window - stride + 1`` records
        before an unvetted window falls out of the ring (``tick`` then
        raises).  Use ``feed`` to have the stream manage that budget itself.

        Args:
            times: 1-D chunk of record times (seconds).

        Returns:
            Number of records appended (the chunk size).

        Raises:
            ValueError: on a multi-dimensional chunk.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> s = VetStream(eng, window=8, stride=4, capacity=32)
            >>> s.append(np.linspace(1e-3, 2e-3, 16))
            16
            >>> s.pending_windows      # windows 0..2 complete, unvetted
            3
        """
        arr = self._coerce(times)
        if arr.size == 0:
            return 0
        self._fp.update(arr.tobytes())  # rolling: O(chunk), never the buffer
        if arr.size >= self.capacity:
            self._write(arr[-self.capacity:], self._total + arr.size
                        - self.capacity)
        else:
            self._write(arr, self._total)
        self._total += arr.size
        return arr.size

    def feed(self, times, *, on_pressure=None) -> int:
        """Append an arbitrarily large chunk, ticking only when forced.

        Splits the chunk so that no unvetted window can fall out of the ring:
        a mid-feed ``tick()`` happens exactly when the remaining append
        budget is exhausted (its result rows are retained as usual — the
        next ``tick()`` returns them without re-dispatch).  Ingest therefore
        stays O(chunk) unless overrun protection forces estimation work that
        any later ``tick()`` would have had to pay anyway.

        ``on_pressure`` replaces the forced ``self.tick()`` for consumers
        that must do more than vet when the budget runs out — the fleet mux
        ticks the *whole fleet* coalesced, ``OnlineVet`` folds each forced
        tick's rows into its EMA before eviction can drop them.  The hook
        must advance the vetted watermark (tick this stream somehow) or the
        feed cannot make progress.

        Args:
            times: 1-D chunk of record times, arbitrarily large.
            on_pressure: zero-arg hook run in place of the forced tick.

        Returns:
            Number of records appended (the chunk size).

        Raises:
            RuntimeError: when ``on_pressure`` fails to vet this stream.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> s = VetStream(eng, window=8, stride=4, capacity=16)
            >>> s.feed(np.linspace(1e-3, 2e-3, 100))   # 6x the ring
            100
            >>> s.tick().workers       # no window was ever lost
            24
        """
        on_pressure = self.tick if on_pressure is None else on_pressure
        arr = self._coerce(times)
        pos = 0
        while pos < arr.size:
            # Records we may still append before the first unvetted window's
            # start (vetted * stride) would leave the resident suffix.
            budget = self.headroom
            if budget <= 0:
                on_pressure()  # advances _vetted: budget >= capacity-window+1
                if self.headroom <= 0:
                    raise RuntimeError(
                        "feed on_pressure hook did not vet this stream; "
                        "the hook must tick it (directly or via its mux)")
                continue
            pos += self.append(arr[pos:pos + budget])
        return arr.size

    # ------------------------------------------------------------- ticking
    def _gather(self, starts: np.ndarray) -> np.ndarray:
        idx = (starts[:, None] + np.arange(self.window)[None, :]) \
            % self.capacity
        return self._ring[idx]

    def drain(self, max_windows: Optional[int] = None) -> Optional[StreamDelta]:
        """Gather the unvetted complete-window delta; side-effect free.

        Returns ``None`` when no unvetted complete window exists.  With
        ``max_windows`` only the oldest that many pending windows are taken
        (partial service under a mux tick budget); windows are always drained
        in order, so repeated partial drains cover the stream exactly once.

        Raises ``ValueError`` if the oldest unvetted window's records were
        already overwritten in the ring (appends outran ``capacity``).

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> s = VetStream(eng, window=8, stride=4, capacity=32)
            >>> _ = s.append(np.linspace(1e-3, 2e-3, 16))
            >>> delta = s.drain()
            >>> (delta.start, delta.count, delta.matrix.shape)
            (0, 3, (3, 8))
            >>> s.pending_windows      # side-effect free: still pending
            3
        """
        n_new = self.pending_windows
        if n_new <= 0:
            return None
        if max_windows is not None:
            n_new = min(n_new, int(max_windows))
            if n_new <= 0:
                return None
        first_start = self._vetted * self.stride
        if first_start < self._total - self.capacity:
            raise ValueError(
                f"stream overran the ring buffer: window "
                f"{self._vetted} starts at record {first_start} but only "
                f"records [{self._total - self.capacity}, {self._total}) "
                f"are resident; tick() more often or raise capacity "
                f"({self.capacity})")
        starts = np.arange(self._vetted, self._vetted + n_new,
                           dtype=np.int64) * self.stride
        # Keyed on the rolling fingerprint + window span + epoch — the
        # delta is a pure function of the (content-hashed) append/amend
        # history, so no per-delta matrix re-hash is needed for a replay
        # of the same stream to hit the engine cache.
        key = ("stream", self.window, self.stride, self._vetted,
               self._vetted + n_new, self._epoch, self._fp.hexdigest())
        with _span(self.engine.tracer, "stream.drain",
                   tid=self.engine.trace_tid, windows=n_new):
            matrix = self._gather(starts)
        return StreamDelta(start=self._vetted, count=n_new,
                           matrix=matrix, key=key, epoch=self._epoch)

    def drain_ring(self, max_windows: Optional[int] = None) \
            -> Optional[RingDelta]:
        """``drain`` for the fused engine path: ring-relative bounds, no
        gather matrix.

        Returns the contiguous stream-order span covering the pending
        windows plus their span-relative starts (a ``RingDelta``) — memory
        O(span), where ``drain`` materializes O(windows x window).  Same
        watermark/overrun semantics as ``drain``; the cache key differs by
        tag only (the fused kernel's rows are not bitwise the gather
        batch's, so the two paths must not share cache entries).

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> s = VetStream(eng, window=8, stride=4, capacity=32)
            >>> _ = s.append(np.linspace(1e-3, 2e-3, 16))
            >>> d = s.drain_ring()
            >>> (d.start, d.count, d.arena.shape, d.starts.tolist())
            (0, 3, (16,), [0, 4, 8])
        """
        n_new = self.pending_windows
        if n_new <= 0:
            return None
        if max_windows is not None:
            n_new = min(n_new, int(max_windows))
            if n_new <= 0:
                return None
        base = self._vetted * self.stride
        if base < self._total - self.capacity:
            raise ValueError(
                f"stream overran the ring buffer: window "
                f"{self._vetted} starts at record {base} but only "
                f"records [{self._total - self.capacity}, {self._total}) "
                f"are resident; tick() more often or raise capacity "
                f"({self.capacity})")
        end = (self._vetted + n_new - 1) * self.stride + self.window
        with _span(self.engine.tracer, "stream.drain",
                   tid=self.engine.trace_tid, windows=n_new, ring=True):
            arena = self._ring[np.arange(base, end) % self.capacity]
        starts = np.arange(n_new, dtype=np.int64) * self.stride
        key = ("fusedring", self.window, self.stride, self._vetted,
               self._vetted + n_new, self._epoch, self._fp.hexdigest())
        return RingDelta(start=self._vetted, count=n_new, arena=arena,
                         starts=starts, window=self.window, key=key,
                         epoch=self._epoch)

    def commit(self, delta: StreamDelta, rows: BatchVetResult) -> None:
        """Splice externally computed ``rows`` for ``delta`` into the stream.

        ``rows`` must be the engine's result for exactly ``delta.matrix``
        (the mux computes it inside a coalesced dispatch and hands each
        stream its slice).  Deltas commit in order: ``delta.start`` must
        equal the current vetted watermark, so a delta drained before an
        intervening ``commit``/``amend``/``invalidate`` is rejected instead
        of silently splicing stale rows.

        Args:
            delta: the ``StreamDelta`` returned by ``drain``.
            rows: the engine's ``BatchVetResult`` for ``delta.matrix``.

        Raises:
            ValueError: stale delta (watermark or epoch mismatch) or a row
                count that does not match the delta.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> s = VetStream(eng, window=8, stride=4, capacity=32)
            >>> _ = s.append(np.linspace(1e-3, 2e-3, 16))
            >>> delta = s.drain()
            >>> s.commit(delta, eng.vet_batch(delta.matrix))
            >>> s.collect().workers    # rows spliced, watermark advanced
            3
        """
        if delta.start != self._vetted:
            raise ValueError(
                f"stale delta: starts at window {delta.start} but the stream "
                f"has vetted {self._vetted} windows — drain after every "
                f"commit/amend/invalidate")
        if delta.epoch != self._epoch:
            # An amend of a *pending* window leaves the vetted watermark
            # alone, so the start check above cannot catch a delta gathered
            # before the mutation — the epoch does.
            raise ValueError(
                f"stale delta: drained at epoch {delta.epoch} but the stream "
                f"was amended/invalidated since (epoch {self._epoch}) — "
                f"re-drain to pick up the mutated records")
        if rows.workers != delta.count:
            raise ValueError(
                f"delta covers {delta.count} windows but got {rows.workers} "
                f"result rows")
        self._reused_rows += self._vetted
        self._vetted_rows += delta.count
        with _span(self.engine.tracer, "stream.commit",
                   tid=self.engine.trace_tid, windows=delta.count):
            self._splice(delta.start, rows)
            self._vetted = delta.start + delta.count
            if (self.history is not None
                    and self._vetted - self._row_base > self.history):
                evict_to = self._vetted - self.history
                self._evicted_rows += evict_to - self._row_base
                self._row_base = evict_to
            self._last = None

    def collect(self) -> Optional[BatchVetResult]:
        """Result over the retained vetted windows (frozen views), or ``None``
        while no window has been vetted.  Row ``j`` is window
        ``first_retained + j``.  Repeated calls between commits return the
        same object.
        """
        n_rows = self._vetted - self._row_base
        if n_rows <= 0:
            return None
        if self._last is not None:
            return self._last
        with _span(self.engine.tracer, "stream.collect",
                   tid=self.engine.trace_tid, windows=n_rows):
            lo = self._row_base - self._phys_base
            fields = {}
            for name in ("vet", "ei", "oc", "pr", "t", "n"):
                v = self._rows[name][lo:lo + n_rows]
                v.flags.writeable = False  # restricts the view, not the base
                fields[name] = v
            res = BatchVetResult(**fields)
            self._exposed = max(self._exposed, self._vetted)
            self._last = res
            return res

    def tick(self) -> Optional[BatchVetResult]:
        """Vet the windows that became complete since the last tick.

        Returns a ``BatchVetResult`` over all retained complete windows of
        the stream so far (row ``j`` = window ``first_retained + j``; with no
        ``history`` cap that is every window), or ``None`` while no window is
        complete yet.  Only the delta since the last tick is dispatched to
        the engine; earlier rows are reused.  A no-op tick (no new windows)
        returns the previous result object itself.

        Raises ``ValueError`` if an unvetted window's records were already
        overwritten in the ring (appends outran ``capacity`` between ticks).

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> s = VetStream(eng, window=8, stride=4, capacity=32)
            >>> _ = s.append(np.linspace(1e-3, 2e-3, 16))
            >>> res = s.tick()         # one dispatch over the 3-window delta
            >>> res.workers
            3
            >>> s.tick() is res        # no new records: zero dispatches
            True
        """
        self._ticks += 1
        if self.complete_windows == 0:
            return None
        fused = self.engine.fused_supported(self.window)
        delta = self.drain_ring() if fused else self.drain()
        if delta is None:
            if self._last is not None:
                self._reused_rows += self.complete_windows
                return self._last
            return self.collect()
        n_new = delta.count
        if fused:
            # Fused path: hand the engine ring-relative bounds — one
            # launch, staged memory O(span); row padding happens inside
            # the kernel wrapper.
            lengths = np.full(n_new, self.window, dtype=np.int64)
            rows = self.engine._memo(
                delta.key, lambda: self.engine._vet_arena_impl(
                    delta.arena, delta.starts, lengths))
        else:
            matrix, _ = self.engine.pad_rows_pow2(delta.matrix)
            rows = self.engine._memo(
                delta.key, lambda: self.engine._vet_batch_impl(matrix))
        if rows.workers > n_new:
            rows = BatchVetResult(*(a[:n_new] for a in rows))
        self.commit(delta, rows)
        return self.collect()

    def _splice(self, at: int, delta: BatchVetResult) -> None:
        """Write ``delta`` rows for windows ``[at, at + delta.workers)``."""
        need_phys = at + delta.workers - self._phys_base
        cap = self._rows["vet"].size
        # Copy-on-write: rows < _exposed alias results already handed out;
        # a rewind (amend/invalidate) about to overwrite them detaches the
        # old storage so those snapshots stay pristine.  Growth past the
        # physical capacity reallocates anyway — and compacts evicted
        # history rows away, keeping storage O(retained + delta) — which
        # detaches just the same.
        if need_phys > cap or at < self._exposed:
            new_base = min(self._row_base, at)
            new_cap = max(2 * (at + delta.workers - new_base), _GROW)
            old_lo = new_base - self._phys_base
            keep = at - new_base
            for name, arr in self._rows.items():
                grown = np.empty(new_cap, dtype=arr.dtype)
                grown[:keep] = arr[old_lo:old_lo + keep]
                self._rows[name] = grown
            self._phys_base = new_base
            self._exposed = min(self._exposed, at)
        lo = at - self._phys_base
        hi = lo + delta.workers
        for name in ("vet", "ei", "oc", "pr", "t"):
            self._rows[name][lo:hi] = getattr(delta, name)
        self._rows["n"][lo:hi] = self.window

    # -------------------------------------------------------- invalidation
    def amend(self, start: int, values) -> None:
        """Rewrite resident records ``[start, start + len(values))`` in place.

        The targeted invalidation hook: a profiler revising recently observed
        record times (clock correction, late attribution) amends them here
        instead of rebuilding the stream.  The rolling fingerprint is re-keyed
        (epoch tag), and the next ``tick()`` re-vets exactly the already-vetted
        windows from the first one covering ``start`` — never the whole
        history — so no stale cached row survives.  Rows already evicted by a
        ``history`` cap are gone and stay gone (nothing stale can be served
        from them).  Amending records that are no longer resident (or whose
        re-vettable windows already left the ring) raises.

        Args:
            start: absolute stream position of the first rewritten record.
            values: the replacement record times.

        Raises:
            ValueError: a range outside the appended stream or before the
                resident suffix, or an affected vetted window that is no
                longer fully resident.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> s = VetStream(eng, window=8, stride=8, capacity=32)
            >>> _ = s.append(np.linspace(1e-3, 2e-3, 16))
            >>> _ = s.tick()
            >>> s.amend(12, [5e-3])        # record 12 sits in window 1 only
            >>> s.pending_windows          # exactly that window re-vets
            1
            >>> s.tick().workers, s.consume_rewind()
            (2, 1)
        """
        vals = np.atleast_1d(np.asarray(values, dtype=np.float64)).ravel()
        start = int(start)
        end = start + vals.size
        if vals.size == 0:
            return
        if start < 0 or end > self._total:
            raise ValueError(
                f"amend range [{start}, {end}) outside the appended stream "
                f"[0, {self._total})")
        if start < self._total - self.capacity:
            raise ValueError(
                f"amend range [{start}, {end}) starts before the resident "
                f"suffix [{self._total - self.capacity}, {self._total})")
        # Window span that sees any amended record, clamped to the bounded
        # history's retained rows (evicted rows cannot be recomputed —
        # when every affected row is already evicted, the ring content
        # still re-keys but no retained row needs re-vetting).
        first_affected = (0 if start < self.window
                          else (start - self.window) // self.stride + 1)
        last_affected = min(self._vetted - 1, (end - 1) // self.stride)
        redo = last_affected >= self._row_base
        if redo:
            first_redo = max(first_affected, self._row_base)
            if first_redo < self._vetted:
                # Those rows must be recomputed — their windows must still
                # be fully resident.
                lo_resident = max(0, self._total - self.capacity)
                if first_redo * self.stride < lo_resident:
                    raise ValueError(
                        f"amend at record {start} affects window "
                        f"{first_redo}, which is no longer fully resident; "
                        f"raise capacity ({self.capacity}) to amend that far "
                        f"back")
        self._write(vals, start)
        self._epoch += 1
        self._fp.update(b"|amend|")
        self._fp.update(np.int64(start).tobytes())
        self._fp.update(vals.tobytes())
        if redo:
            self._mark_rewound(first_redo)

    def invalidate(self) -> int:
        """Blanket hook: the ring was mutated outside ``append``/``amend``.

        Bumps the epoch, folds the *current* resident content into the
        rolling fingerprint (so future cache keys reflect what is actually in
        the ring, not the stale append history), and marks every window still
        fully resident (and still retained by the ``history`` cap) for
        re-vetting on the next ``tick()``.  Rows for windows that already
        left the ring keep their last computed values — they cannot be
        recomputed from evicted records.  Returns the number of window rows
        scheduled for re-vetting.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> s = VetStream(eng, window=8, stride=4, capacity=32)
            >>> _ = s.append(np.linspace(1e-3, 2e-3, 16))
            >>> _ = s.tick()
            >>> s.invalidate()         # "I changed the ring under you"
            3
            >>> s.tick().workers       # every resident window re-vetted
            3
        """
        self._epoch += 1
        self._fp.update(b"|invalidate|")
        self._fp.update(self.resident().tobytes())
        lo_resident = max(0, self._total - self.capacity)
        first_resident = -(-lo_resident // self.stride)  # ceil div
        first_redo = max(first_resident, self._row_base)
        dropped = max(0, self._vetted - first_redo)
        self._mark_rewound(first_redo)
        return dropped

    def _mark_rewound(self, first_dirty: int) -> None:
        if first_dirty < self._vetted:
            self._dirty_low = (first_dirty if self._dirty_low is None
                               else min(self._dirty_low, first_dirty))
        self._vetted = min(self._vetted, first_dirty)
        self._last = None

    # ------------------------------------------------------- checkpointing
    def state_dict(self) -> dict:
        """Pickle-safe snapshot of the stream: ring, watermarks, retained
        result rows, counters, and the fingerprint *digest*.

        The transport layer (``repro.fleet.transport``) checkpoints shard
        state with this so a killed worker process resumes mid-job.  The
        rolling blake2b object itself cannot cross a process boundary (hash
        objects do not pickle); the snapshot carries its hexdigest and
        ``from_state`` chains a fresh rolling hash off it, so every
        post-restore cache key is distinct from every key the original
        stream ever issued — a restored stream can never collide with a
        stale engine-cache entry.
        """
        lo = self._row_base - self._phys_base
        n = self._vetted - self._phys_base
        return {
            "window": self.window, "stride": self.stride,
            "capacity": self.capacity, "history": self.history,
            "ring": self._ring.copy(), "total": self._total,
            "vetted": self._vetted, "epoch": self._epoch,
            "fingerprint": self.fingerprint,
            "row_base": self._row_base,
            "rows": {name: np.array(arr[lo:n])
                     for name, arr in self._rows.items()},
            "dirty_low": self._dirty_low,
            "stats": (self._ticks, self._vetted_rows, self._reused_rows,
                      self._evicted_rows),
        }

    @classmethod
    def from_state(cls, engine: Optional[VetEngine], state: dict) \
            -> "VetStream":
        """Rebuild a stream from a ``state_dict`` snapshot, bound to
        ``engine`` (typically a fresh per-process engine — caches rebuild
        on demand).

        The restored stream continues exactly where the snapshot stopped:
        same pending windows, same retained rows (``collect()`` is bitwise
        the snapshot's), same vetted watermark — so committed windows are
        never re-vetted after a resume.
        """
        s = cls(engine, window=state["window"], stride=state["stride"],
                capacity=state["capacity"], history=state["history"])
        s._ring[:] = state["ring"]
        s._total = state["total"]
        s._vetted = state["vetted"]
        s._epoch = state["epoch"]
        # Chain the fresh rolling hash off the recorded digest (see
        # state_dict): same prefix => same chain, but no raw-hash-state
        # revival is needed.
        s._fp.update(b"|resume|")
        s._fp.update(state["fingerprint"].encode())
        s._row_base = s._phys_base = state["row_base"]
        retained = s._vetted - s._row_base
        cap = max(_GROW, 2 * retained)
        for name, arr in state["rows"].items():
            grown = np.empty(cap, dtype=s._rows[name].dtype)
            grown[:retained] = arr
            s._rows[name] = grown
        # Conservative: treat every restored row as already handed out, so
        # any rewind over them copies-on-write instead of mutating storage
        # the pre-crash process may have exposed.
        s._exposed = s._vetted
        s._dirty_low = state["dirty_low"]
        (s._ticks, s._vetted_rows, s._reused_rows,
         s._evicted_rows) = state["stats"]
        return s

    def consume_rewind(self) -> Optional[int]:
        """Lowest row index re-vetted by ``amend``/``invalidate`` since the
        last call, or ``None``.  Incremental consumers that fold rows exactly
        once (e.g. ``OnlineVet``'s EMA) poll this to know which already-
        consumed rows were recomputed and re-fold from there; reading it
        clears the watermark.
        """
        low, self._dirty_low = self._dirty_low, None
        return low
