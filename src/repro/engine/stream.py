"""VetStream: incremental sliding-window vetting over a live record stream.

``VetEngine.vet_sliding`` answers "vet every window of this buffer" in one
batched call, and the engine's result cache makes a *repeat* of the identical
call free — but a live consumer (dashboard tick, straggler controller,
autotuner) never repeats the identical call: every tick the buffer has grown
by a chunk, so the whole buffer is re-gathered, re-hashed and re-vetted even
though only a handful of windows near the head are new.  ``VetStream`` is the
streaming path:

- **Ring buffer.**  A fixed-capacity ring of record times; ``append(chunk)``
  is O(chunk) regardless of how many records the stream has ever seen.
  Logical stream position ``p`` lives in ring slot ``p % capacity``, so a
  window's rows gather with one vectorized modular index.
- **Rolling fingerprint.**  Appends fold into a running blake2b digest —
  O(chunk), never a re-hash of the whole buffer.  The fingerprint (plus an
  epoch counter bumped by explicit invalidation) keys the engine-cache
  entries for each incremental dispatch, so replaying the same stream into
  the same engine hits the cache without hashing any matrix.
- **Incremental tick.**  ``tick()`` vets only the windows that became
  complete since the last tick — one batched engine dispatch over the delta —
  and splices the new rows into the accumulated per-window results.  Rows for
  old windows are reused from the previous tick, never re-vetted.  Each tick
  returns a ``BatchVetResult`` over *all* complete windows so far, equal to
  ``engine.vet_sliding(prefix, window, stride)`` on the same logical prefix
  (bitwise for the numpy backend; the jax/pallas backends carry their usual
  differential contracts — see ``tests/test_vet_stream.py``).
- **Invalidation-aware caching.**  Mutating history is explicit:
  ``amend(start, values)`` rewrites resident records, re-keys the fingerprint
  (epoch tag) and re-vets exactly the windows that saw the amended records on
  the next tick; ``invalidate()`` is the blanket hook ("I changed the ring
  under you") that re-vets every window still fully resident.  Either way a
  stale cache hit is impossible: pre-mutation keys are never issued again.

The stream guarantees oracle equality only while every newly completed window
is still fully resident at tick time; if appends outrun the ring
(``capacity`` too small or ticks too rare), ``tick()`` raises instead of
silently skipping windows.  ``feed()`` is the self-managing ingest wrapper:
it sub-chunks an arbitrarily large append and ticks exactly when a further
append could overrun an unvetted window, so callers never track the budget
themselves.

Memory: the ring is O(capacity) records, and the accumulated result rows are
six scalars per complete window (~48 bytes) — the cost of the prefix-oracle
contract (every tick returns *all* windows so far).  A consumer that only
wants the newest rows can slice them off and let the returned snapshot go;
bounding the retained history (a rolling result window) is the
donated-buffer follow-up tracked in the ROADMAP.
"""

from __future__ import annotations

import hashlib
from typing import NamedTuple, Optional

import numpy as np

from .engine import BatchVetResult, VetEngine, default_engine

__all__ = ["StreamStats", "VetStream"]

_GROW = 64  # initial per-field result capacity (windows); doubles as needed


class StreamStats(NamedTuple):
    """Counters for one stream (``VetStream.stats``)."""

    ticks: int  # tick() calls
    records: int  # records ever appended
    windows: int  # complete windows so far
    vetted: int  # window rows computed by engine dispatches
    reused: int  # window rows served from earlier ticks (sum over ticks)
    epoch: int  # invalidation epoch (amend/invalidate bumps)


class VetStream:
    """Incremental rolling-buffer vetting bound to one ``VetEngine``.

    Window ``k`` covers logical records ``[k*stride, k*stride + window)`` of
    the append stream — the same convention as ``vet_sliding``.  Usage::

        stream = VetStream(engine, window=512, stride=256)
        for chunk in source:
            stream.append(chunk)          # O(chunk)
            res = stream.tick()           # vets only newly complete windows
            if res is not None:
                dashboard.update(res.vet[-1], res.vet_job)

    ``capacity`` bounds resident records (default ``4 * window``); it must be
    at least ``window``, and between two ticks you may append at most
    ``capacity - window - stride + 1`` records without losing a window.
    """

    def __init__(self, engine: Optional[VetEngine] = None, *, window: int,
                 stride: int = 1, capacity: Optional[int] = None):
        window = int(window)
        stride = int(stride)
        if window < 2:
            raise ValueError(f"window must cover >= 2 records, got {window}")
        if stride < 1:
            raise ValueError(f"stride must be >= 1, got {stride}")
        capacity = int(capacity) if capacity is not None else 4 * window
        if capacity < window:
            raise ValueError(
                f"capacity ({capacity}) must hold at least one window "
                f"({window} records)")
        self.engine = engine if engine is not None else default_engine("jax")
        self.window = window
        self.stride = stride
        self.capacity = capacity
        self._ring = np.zeros(capacity, dtype=np.float64)
        self._total = 0  # records ever appended (logical stream length)
        self._vetted = 0  # windows whose rows are current in the result arrays
        self._epoch = 0
        self._fp = hashlib.blake2b(digest_size=16)
        self._ticks = 0
        self._vetted_rows = 0
        self._reused_rows = 0
        self._last: Optional[BatchVetResult] = None
        # Accumulated per-window rows (amortized-doubling growth).  Results
        # are frozen *views* of these arrays — O(delta) per tick, not
        # O(windows-so-far) copies — so rows already exposed to callers are
        # never written again: a rewind (amend/invalidate) below the exposed
        # watermark reallocates fresh row storage first (copy-on-write),
        # leaving outstanding snapshots aliasing the detached buffers.
        self._rows = {
            "vet": np.empty(_GROW), "ei": np.empty(_GROW),
            "oc": np.empty(_GROW), "pr": np.empty(_GROW),
            "t": np.empty(_GROW, dtype=np.int32),
            "n": np.empty(_GROW, dtype=np.int64),
        }
        self._exposed = 0  # rows handed out in some result so far
        self._dirty_low: Optional[int] = None  # lowest re-vetted exposed row

    def __repr__(self) -> str:
        return (f"VetStream(window={self.window}, stride={self.stride}, "
                f"capacity={self.capacity}, records={self._total}, "
                f"windows={self.complete_windows}, epoch={self._epoch})")

    # ------------------------------------------------------------ geometry
    @property
    def total_records(self) -> int:
        """Records ever appended (logical stream length)."""
        return self._total

    @property
    def complete_windows(self) -> int:
        """Windows fully covered by the stream so far."""
        if self._total < self.window:
            return 0
        return (self._total - self.window) // self.stride + 1

    @property
    def stats(self) -> StreamStats:
        return StreamStats(ticks=self._ticks, records=self._total,
                           windows=self.complete_windows,
                           vetted=self._vetted_rows, reused=self._reused_rows,
                           epoch=self._epoch)

    @property
    def fingerprint(self) -> str:
        """Rolling content fingerprint of the append/amend history."""
        return self._fp.hexdigest()

    def resident(self) -> np.ndarray:
        """Copy of the retained record suffix, in stream order."""
        lo = max(0, self._total - self.capacity)
        return self._ring[np.arange(lo, self._total) % self.capacity]

    def latest(self, n: int) -> np.ndarray:
        """Copy of the last ``min(n, resident)`` records, in stream order."""
        lo = max(0, self._total - min(int(n), self.capacity))
        return self._ring[np.arange(lo, self._total) % self.capacity]

    # ------------------------------------------------------------- writing
    def _write(self, arr: np.ndarray, pos0: int) -> None:
        """Write ``arr`` (len <= capacity) at logical position ``pos0``."""
        s = pos0 % self.capacity
        k = min(arr.size, self.capacity - s)
        self._ring[s:s + k] = arr[:k]
        if arr.size > k:
            self._ring[:arr.size - k] = arr[k:]

    @staticmethod
    def _coerce(times) -> np.ndarray:
        arr = np.asarray(times, dtype=np.float64)
        if arr.ndim > 1:
            raise ValueError(
                f"append expects a 1-D chunk of record times, got shape "
                f"{arr.shape}")
        return np.ascontiguousarray(np.atleast_1d(arr))

    def append(self, times) -> int:
        """Append a chunk of record times; O(chunk).  Returns records added.

        The raw primitive: no safety ticks — between two ``tick()`` calls the
        caller may append at most ``capacity - window - stride + 1`` records
        before an unvetted window falls out of the ring (``tick`` then
        raises).  Use ``feed`` to have the stream manage that budget itself.
        """
        arr = self._coerce(times)
        if arr.size == 0:
            return 0
        self._fp.update(arr.tobytes())  # rolling: O(chunk), never the buffer
        if arr.size >= self.capacity:
            self._write(arr[-self.capacity:], self._total + arr.size
                        - self.capacity)
        else:
            self._write(arr, self._total)
        self._total += arr.size
        return arr.size

    def feed(self, times) -> int:
        """Append an arbitrarily large chunk, ticking only when forced.

        Splits the chunk so that no unvetted window can fall out of the ring:
        a mid-feed ``tick()`` happens exactly when the remaining append
        budget is exhausted (its result rows are retained as usual — the
        next ``tick()`` returns them without re-dispatch).  Ingest therefore
        stays O(chunk) unless overrun protection forces estimation work that
        any later ``tick()`` would have had to pay anyway.
        """
        arr = self._coerce(times)
        pos = 0
        while pos < arr.size:
            # Records we may still append before the first unvetted window's
            # start (vetted * stride) would leave the resident suffix.
            budget = self._vetted * self.stride + self.capacity - self._total
            if budget <= 0:
                self.tick()  # advances _vetted; budget >= capacity-window+1
                continue
            pos += self.append(arr[pos:pos + budget])
        return arr.size

    # ------------------------------------------------------------- ticking
    def _gather(self, starts: np.ndarray) -> np.ndarray:
        idx = (starts[:, None] + np.arange(self.window)[None, :]) \
            % self.capacity
        return self._ring[idx]

    def tick(self) -> Optional[BatchVetResult]:
        """Vet the windows that became complete since the last tick.

        Returns a ``BatchVetResult`` over **all** complete windows of the
        stream so far (row ``k`` = window ``k``), or ``None`` while no window
        is complete yet.  Only the delta since the last tick is dispatched to
        the engine; earlier rows are reused.  A no-op tick (no new windows)
        returns the previous result object itself.

        Raises ``ValueError`` if an unvetted window's records were already
        overwritten in the ring (appends outran ``capacity`` between ticks).
        """
        self._ticks += 1
        n_complete = self.complete_windows
        if n_complete == 0:
            return None
        if n_complete > self._vetted:
            first_start = self._vetted * self.stride
            if first_start < self._total - self.capacity:
                raise ValueError(
                    f"stream overran the ring buffer: window "
                    f"{self._vetted} starts at record {first_start} but only "
                    f"records [{self._total - self.capacity}, {self._total}) "
                    f"are resident; tick() more often or raise capacity "
                    f"({self.capacity})")
            starts = np.arange(self._vetted, n_complete,
                               dtype=np.int64) * self.stride
            n_new = starts.size
            matrix = self._gather(starts)
            # Jitted backends compile one batch graph per row count; live
            # deltas vary tick to tick, so pad to the next power of two
            # (repeating the last row) and slice the result — compiles stay
            # O(log max-delta) instead of one per distinct delta size.
            if self.engine.backend != "numpy" and n_new > 1:
                pad = 1 << (n_new - 1).bit_length()
                if pad != n_new:
                    matrix = np.concatenate(
                        [matrix, np.repeat(matrix[-1:], pad - n_new, axis=0)])
            # Keyed on the rolling fingerprint + window span + epoch — the
            # delta is a pure function of the (content-hashed) append/amend
            # history, so no per-tick matrix re-hash is needed for a replay
            # of the same stream to hit the engine cache.
            key = ("stream", self.window, self.stride, self._vetted,
                   n_complete, self._epoch, self._fp.hexdigest())
            delta = self.engine._memo(
                key, lambda: self.engine._vet_batch_impl(matrix))
            if delta.workers > n_new:
                delta = BatchVetResult(*(a[:n_new] for a in delta))
            self._reused_rows += self._vetted
            self._vetted_rows += n_new
            self._splice(self._vetted, delta)
            self._vetted = n_complete
            self._last = None
        elif self._last is not None:
            self._reused_rows += n_complete
            return self._last
        w = n_complete
        fields = {}
        for name in ("vet", "ei", "oc", "pr", "t", "n"):
            v = self._rows[name][:w]
            v.flags.writeable = False  # restricts the view, not the base
            fields[name] = v
        res = BatchVetResult(**fields)
        self._exposed = max(self._exposed, w)
        self._last = res
        return res

    def _splice(self, at: int, delta: BatchVetResult) -> None:
        need = at + delta.workers
        cap = self._rows["vet"].size
        # Copy-on-write: rows < _exposed alias results already handed out;
        # a rewind (amend/invalidate) about to overwrite them detaches the
        # old storage so those snapshots stay pristine.  Growth past capacity
        # reallocates anyway, which detaches just the same.
        if need > cap or at < self._exposed:
            new_cap = max(need, 2 * cap)
            for name, arr in self._rows.items():
                grown = np.empty(new_cap, dtype=arr.dtype)
                grown[:at] = arr[:at]
                self._rows[name] = grown
            self._exposed = min(self._exposed, at)
        for name in ("vet", "ei", "oc", "pr", "t"):
            self._rows[name][at:need] = getattr(delta, name)
        self._rows["n"][at:need] = self.window

    # -------------------------------------------------------- invalidation
    def amend(self, start: int, values) -> None:
        """Rewrite resident records ``[start, start + len(values))`` in place.

        The targeted invalidation hook: a profiler revising recently observed
        record times (clock correction, late attribution) amends them here
        instead of rebuilding the stream.  The rolling fingerprint is re-keyed
        (epoch tag), and the next ``tick()`` re-vets exactly the already-vetted
        windows from the first one covering ``start`` — never the whole
        history — so no stale cached row survives.  Amending records that are
        no longer resident (or whose re-vettable windows already left the
        ring) raises.
        """
        vals = np.atleast_1d(np.asarray(values, dtype=np.float64)).ravel()
        start = int(start)
        end = start + vals.size
        if vals.size == 0:
            return
        if start < 0 or end > self._total:
            raise ValueError(
                f"amend range [{start}, {end}) outside the appended stream "
                f"[0, {self._total})")
        if start < self._total - self.capacity:
            raise ValueError(
                f"amend range [{start}, {end}) starts before the resident "
                f"suffix [{self._total - self.capacity}, {self._total})")
        # First window that sees any amended record.
        first_affected = (0 if start < self.window
                          else (start - self.window) // self.stride + 1)
        if first_affected < self._vetted:
            # Those rows must be recomputed — their windows must still be
            # fully resident.
            lo_resident = max(0, self._total - self.capacity)
            if first_affected * self.stride < lo_resident:
                raise ValueError(
                    f"amend at record {start} affects window "
                    f"{first_affected}, which is no longer fully resident; "
                    f"raise capacity ({self.capacity}) to amend that far back")
        self._write(vals, start)
        self._epoch += 1
        self._fp.update(b"|amend|")
        self._fp.update(np.int64(start).tobytes())
        self._fp.update(vals.tobytes())
        self._mark_rewound(first_affected)

    def invalidate(self) -> int:
        """Blanket hook: the ring was mutated outside ``append``/``amend``.

        Bumps the epoch, folds the *current* resident content into the
        rolling fingerprint (so future cache keys reflect what is actually in
        the ring, not the stale append history), and marks every window still
        fully resident for re-vetting on the next ``tick()``.  Rows for
        windows that already left the ring keep their last computed values —
        they cannot be recomputed from evicted records.  Returns the number
        of window rows scheduled for re-vetting.
        """
        self._epoch += 1
        self._fp.update(b"|invalidate|")
        self._fp.update(self.resident().tobytes())
        lo_resident = max(0, self._total - self.capacity)
        first_resident = -(-lo_resident // self.stride)  # ceil div
        dropped = max(0, self._vetted - first_resident)
        self._mark_rewound(first_resident)
        return dropped

    def _mark_rewound(self, first_dirty: int) -> None:
        if first_dirty < self._vetted:
            self._dirty_low = (first_dirty if self._dirty_low is None
                               else min(self._dirty_low, first_dirty))
        self._vetted = min(self._vetted, first_dirty)
        self._last = None

    def consume_rewind(self) -> Optional[int]:
        """Lowest row index re-vetted by ``amend``/``invalidate`` since the
        last call, or ``None``.  Incremental consumers that fold rows exactly
        once (e.g. ``OnlineVet``'s EMA) poll this to know which already-
        consumed rows were recomputed and re-fold from there; reading it
        clears the watermark.
        """
        low, self._dirty_low = self._dirty_low, None
        return low
