"""VetEngine: one estimation API, three interchangeable backends.

See the package docstring for the API -> paper mapping.  Implementation
notes:

- The ``jax`` and ``pallas`` backends compile ``jax.vmap`` of the *exact*
  single-profile pipeline (``repro.core.vet.vet_pipeline``) — not a parallel
  re-implementation — so cross-backend equivalence is structural, not
  coincidental.  They differ only in which two-segment-SSE scan the
  change-point step calls (jnp prefix sums vs the Pallas kernel).
- Compiled batch functions are cached per engine instance; jit's own shape
  cache handles varying (workers, window) shapes.
- Results are returned as host NumPy arrays (``BatchVetResult``): the
  consumers are control loops (schedulers, dashboards) that immediately
  branch on the values.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.vet import VetResult, vet_pipeline, vet_task
from ..kernels.changepoint.ops import auto_block, changepoint_pallas

__all__ = ["BACKENDS", "BatchVetResult", "VetEngine", "default_engine"]

BACKENDS = ("numpy", "jax", "pallas")


class BatchVetResult(NamedTuple):
    """Per-worker vet diagnostics for a batch of profiles (host arrays)."""

    vet: np.ndarray  # (W,) PR / EI per worker
    ei: np.ndarray  # (W,) estimated ideal cost (seconds)
    oc: np.ndarray  # (W,) estimated overhead cost (seconds)
    pr: np.ndarray  # (W,) profiled real cost == EI + OC
    t: np.ndarray  # (W,) change-point (1-indexed record-rank prefix size)
    n: np.ndarray  # (W,) records per profile

    @property
    def workers(self) -> int:
        return int(self.vet.shape[0])

    @property
    def vet_job(self) -> float:
        """vet_job = mean of per-task vet scores (paper §4.4)."""
        return float(self.vet.mean())

    def task(self, i: int) -> VetResult:
        """The i-th worker's result in the scalar ``VetResult`` container."""
        return VetResult(
            vet=jnp.asarray(self.vet[i]),
            ei=jnp.asarray(self.ei[i]),
            oc=jnp.asarray(self.oc[i]),
            pr=jnp.asarray(self.pr[i]),
            t=jnp.asarray(self.t[i]),
            n=int(self.n[i]),
        )


class VetEngine:
    """Batched record-times -> change-point -> extrapolation -> (EI, OC, vet).

    Parameters mirror ``vet_task``: ``omega`` (probing window), ``buckets``
    (curve bucketing; auto-disabled when a profile has < 4*buckets records)
    and ``cut_space`` ("log" framework default / "raw" paper-literal).
    ``backend`` picks the execution path, see ``repro.engine`` docstring;
    ``interpret`` keeps the Pallas kernel in interpret mode (CPU containers).
    """

    def __init__(
        self,
        backend: str = "jax",
        *,
        omega: int = 3,
        buckets: Optional[int] = 1000,
        cut_space: str = "log",
        interpret: bool = True,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        if cut_space not in ("raw", "log"):
            raise ValueError(f"cut_space must be 'raw' or 'log', got {cut_space!r}")
        self.backend = backend
        self.omega = omega
        self.buckets = buckets
        self.cut_space = cut_space
        self.interpret = interpret
        self._batch_fn = None  # compiled lazily on first vet_batch

    def __repr__(self) -> str:
        return (f"VetEngine(backend={self.backend!r}, omega={self.omega}, "
                f"buckets={self.buckets}, cut_space={self.cut_space!r})")

    # ------------------------------------------------------------- backends
    def _pallas_changepoint(self, z, omega: int = 3):
        # z's (static) trace-time shape picks the kernel block size.
        block = auto_block(z.shape[0])
        return changepoint_pallas(z, omega=omega, block=block,
                                  interpret=self.interpret)

    def _make_batch_fn(self):
        cp_fn = self._pallas_changepoint if self.backend == "pallas" else None
        single = functools.partial(
            vet_pipeline,
            omega=self.omega,
            buckets=self.buckets,
            cut_space=self.cut_space,
            changepoint_fn=cp_fn,
        )
        return jax.jit(jax.vmap(single))

    def _numpy_batch(self, matrix: np.ndarray) -> BatchVetResult:
        # The pre-engine call-site path: scalar vet_task per worker (oracle).
        results = [
            vet_task(row, omega=self.omega, buckets=self.buckets,
                     cut_space=self.cut_space)
            for row in matrix
        ]
        return BatchVetResult(
            vet=np.asarray([float(r.vet) for r in results]),
            ei=np.asarray([float(r.ei) for r in results]),
            oc=np.asarray([float(r.oc) for r in results]),
            pr=np.asarray([float(r.pr) for r in results]),
            t=np.asarray([int(r.t) for r in results], dtype=np.int32),
            n=np.asarray([r.n for r in results], dtype=np.int64),
        )

    # ------------------------------------------------------------------ API
    def vet_batch(self, times_matrix) -> BatchVetResult:
        """Vet a (workers, window) matrix of raw record times in one call.

        Rows are independent profiles; a 1-D input is treated as one worker.
        For the ``jax``/``pallas`` backends the whole batch is a single
        compiled call; ``numpy`` loops the scalar reference per row.
        """
        m = np.atleast_2d(np.asarray(times_matrix, dtype=np.float64))
        if m.ndim != 2:
            raise ValueError(f"expected (workers, window) matrix, got {m.shape}")
        if self.backend == "numpy":
            return self._numpy_batch(m)
        if self._batch_fn is None:
            self._batch_fn = self._make_batch_fn()
        vet, ei, oc, pr, t = self._batch_fn(m)
        w = m.shape[0]
        return BatchVetResult(
            vet=np.asarray(vet, dtype=np.float64),
            ei=np.asarray(ei, dtype=np.float64),
            oc=np.asarray(oc, dtype=np.float64),
            pr=np.asarray(pr, dtype=np.float64),
            t=np.asarray(t, dtype=np.int32),
            n=np.full(w, m.shape[1], dtype=np.int64),
        )

    def vet_one(self, times) -> VetResult:
        """Scalar convenience wrapper: one profile through the batched path."""
        return self.vet_batch(np.atleast_1d(np.asarray(times))[None, :]).task(0)

    def vet_many(self, profiles: Sequence) -> BatchVetResult:
        """Vet ragged profiles (different record counts per worker).

        Equal-length profiles are grouped and vetted in one batched call per
        distinct length; results come back in input order.  This is the entry
        point for controllers whose per-worker buffers fill unevenly.
        """
        arrs = [np.atleast_1d(np.asarray(p, dtype=np.float64)).ravel()
                for p in profiles]
        if not arrs:
            raise ValueError("vet_many needs at least one profile")
        w = len(arrs)
        vet = np.empty(w)
        ei = np.empty(w)
        oc = np.empty(w)
        pr = np.empty(w)
        t = np.empty(w, dtype=np.int32)
        n = np.empty(w, dtype=np.int64)
        groups: dict = {}
        for i, a in enumerate(arrs):
            groups.setdefault(a.size, []).append(i)
        for size, idxs in groups.items():
            br = self.vet_batch(np.stack([arrs[i] for i in idxs]))
            for j, i in enumerate(idxs):
                vet[i], ei[i], oc[i] = br.vet[j], br.ei[j], br.oc[j]
                pr[i], t[i], n[i] = br.pr[j], br.t[j], br.n[j]
        return BatchVetResult(vet=vet, ei=ei, oc=oc, pr=pr, t=t, n=n)

    def vet_job(self, profiles: Sequence) -> float:
        """Mean per-task vet over ragged profiles (paper §4.4)."""
        return self.vet_many(profiles).vet_job


@functools.lru_cache(maxsize=None)
def _default_engine_cached(backend: str, omega: int, buckets, cut_space: str):
    return VetEngine(backend, omega=omega, buckets=buckets, cut_space=cut_space)


def default_engine(backend: str = "jax", *, omega: int = 3,
                   buckets: Optional[int] = 64,
                   cut_space: str = "log") -> VetEngine:
    """Shared process-wide engine (so call sites reuse compiled batch fns).

    Control-loop consumers default to ``buckets=64``: their windows are a
    few hundred records, where the full-resolution scan is unnecessary and
    64 buckets matches the pre-engine call-site convention.
    """
    return _default_engine_cached(backend, omega, buckets, cut_space)
