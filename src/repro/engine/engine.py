"""VetEngine: one estimation API, three interchangeable backends.

See the package docstring for the API -> paper mapping.  Implementation
notes:

- The ``jax`` and ``pallas`` backends compile ``jax.vmap`` of the *exact*
  single-profile pipeline (``repro.core.vet.vet_pipeline``) — not a parallel
  re-implementation — so cross-backend equivalence is structural, not
  coincidental.  They differ only in which two-segment-SSE scan the
  change-point step calls (jnp prefix sums vs the Pallas kernel).
- Compiled batch functions are cached per engine instance; jit's own shape
  cache handles varying (workers, window) shapes.
- Results are returned as host NumPy arrays (``BatchVetResult``): the
  consumers are control loops (schedulers, dashboards) that immediately
  branch on the values.
- Windowed entry points (``vet_sliding`` / ``vet_windows``) materialize the
  (num_windows, window) matrix with one vectorized gather and push it through
  the same compiled ``vet_batch`` — one dispatch per distinct window length,
  never one per window.
- Every public entry point is memoized in a bounded LRU result cache keyed on
  a fingerprint of the input buffer(s) plus the call parameters; the engine
  config is fixed per instance, so a (buffer, params) hit is exact.  Cached
  result arrays are frozen (``writeable=False``) so a hit can hand back the
  stored object without defensive copies.  Control loops that re-``decide()``
  or redraw a dashboard over an unchanged window therefore pay ~a hash of the
  buffer instead of a compiled call.
"""

from __future__ import annotations

import collections
import functools
import hashlib
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.vet import VetResult, vet_pipeline, vet_task
from ..kernels.changepoint.ops import auto_block, changepoint_pallas
from ..kernels.runtime import resolve_interpret
from ..kernels.windowvet.ops import fused_window_vet, staged_bytes
from ..obs.trace import span as _span

__all__ = [
    "BACKENDS",
    "BatchVetResult",
    "CacheInfo",
    "VetEngine",
    "default_engine",
]

BACKENDS = ("numpy", "jax", "pallas")


class CacheInfo(NamedTuple):
    """Result-cache counters (``VetEngine.cache_info()``)."""

    hits: int
    misses: int
    size: int
    max_size: int


class BatchVetResult(NamedTuple):
    """Per-worker vet diagnostics for a batch of profiles (host arrays)."""

    vet: np.ndarray  # (W,) PR / EI per worker
    ei: np.ndarray  # (W,) estimated ideal cost (seconds)
    oc: np.ndarray  # (W,) estimated overhead cost (seconds)
    pr: np.ndarray  # (W,) profiled real cost == EI + OC
    t: np.ndarray  # (W,) change-point (1-indexed record-rank prefix size)
    n: np.ndarray  # (W,) records per profile

    @property
    def workers(self) -> int:
        return int(self.vet.shape[0])

    @property
    def vet_job(self) -> float:
        """vet_job = mean of per-task vet scores (paper §4.4)."""
        return float(self.vet.mean())

    def task(self, i: int) -> VetResult:
        """The i-th worker's result in the scalar ``VetResult`` container."""
        return VetResult(
            vet=jnp.asarray(self.vet[i]),
            ei=jnp.asarray(self.ei[i]),
            oc=jnp.asarray(self.oc[i]),
            pr=jnp.asarray(self.pr[i]),
            t=jnp.asarray(self.t[i]),
            n=int(self.n[i]),
        )


class VetEngine:
    """Batched record-times -> change-point -> extrapolation -> (EI, OC, vet).

    Parameters mirror ``vet_task``: ``omega`` (probing window), ``buckets``
    (curve bucketing; auto-disabled when a profile has < 4*buckets records)
    and ``cut_space`` ("log" framework default / "raw" paper-literal).
    ``backend`` picks the execution path, see ``repro.engine`` docstring;
    ``interpret`` picks the Pallas kernel mode — ``None`` (default) resolves
    the platform policy (compiled on TPU, interpret elsewhere, overridable
    via ``REPRO_PALLAS_INTERPRET`` — see ``repro.kernels.runtime``).
    ``fused`` routes windowed entry points (``vet_sliding``/``vet_windows``
    and the stream/mux tick paths) through the fused block-sparse Pallas
    kernel (``repro.kernels.windowvet``): one launch per ragged window set
    — one dispatch per tick, staged memory O(arena) — instead of one
    materialized gather dispatch per distinct window length.  ``None``
    enables it exactly for ``backend="pallas"``; the gather path stays as
    the differential oracle (and serves bucketed rows, which the fused
    non-bucketed kernel does not cover).
    ``cache_size`` bounds the memoized result cache (LRU over input
    fingerprints; 0 disables it) so repeated ticks over an unchanged buffer
    return the stored result instead of re-running the compiled batch.
    """

    def __init__(
        self,
        backend: str = "jax",
        *,
        omega: int = 3,
        buckets: Optional[int] = 1000,
        cut_space: str = "log",
        interpret: Optional[bool] = None,
        fused: Optional[bool] = None,
        cache_size: int = 128,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        if cut_space not in ("raw", "log"):
            raise ValueError(f"cut_space must be 'raw' or 'log', got {cut_space!r}")
        self.backend = backend
        self.omega = omega
        self.buckets = buckets
        self.cut_space = cut_space
        # Resolved lazily (property below): an explicit bool resolves here,
        # but the platform-policy default defers the jax backend probe to
        # the first kernel dispatch that needs it.  Constructing an engine
        # must never trigger backend discovery — transport shard workers
        # build engines right after spawn, where an eager probe would pay
        # device discovery per worker (and can deadlock a fork()ed TPU
        # child, see repro.kernels.runtime).
        self._interpret_arg = None if interpret is None else bool(interpret)
        self._interpret = self._interpret_arg
        self.fused = (backend == "pallas") if fused is None else bool(fused)
        self._batch_fn = None  # compiled lazily on first vet_batch
        # Backend dispatches ever issued (one per _vet_batch_impl /
        # _vet_arena_impl call, cache hits excluded).  The fleet
        # benchmarks/tests read this to prove coalescing: a mux tick is one
        # dispatch per shape bucket (one total on the fused path) where a
        # per-stream loop pays one per stream.
        self.dispatches = 0
        # Bytes staged for the backend across those dispatches: the
        # materialized (windows x length) gather matrices on the batch
        # path, the O(arena + rows) padded launch inputs on the fused
        # path.  The windowvet benchmarks read deltas of this to verify
        # the O(ring) memory claim.
        self.dispatch_bytes = 0
        # Memoized results: fingerprint(buffer) + params -> BatchVetResult.
        # cache_size=0 disables memoization (e.g. for honest benchmarking).
        self._cache_size = int(cache_size)
        self._cache: "collections.OrderedDict[tuple, BatchVetResult]" = (
            collections.OrderedDict()
        )
        self._cache_hits = 0
        self._cache_misses = 0
        # Observability seam (repro.obs): every backend dispatch records an
        # ``engine.dispatch`` span when a tracer is attached; ``None`` is
        # the no-op fast path.  ``trace_tid`` is the lane spans land on
        # (shard index when this engine belongs to a sharded fleet).
        # ``_seen_shapes`` marks first-seen dispatch shapes so the
        # compile-inclusive ("cold") spans are distinguishable in the
        # optimality ledger.
        self.tracer = None
        self.trace_tid = 0
        self._seen_shapes: set = set()

    def set_tracer(self, tracer, tid: int = 0) -> None:
        """Attach (or detach, with ``None``) a ``repro.obs.Tracer``; spans
        from this engine land on lane ``tid``."""
        self.tracer = tracer
        self.trace_tid = int(tid)

    def _dispatch_cold(self, kind: str, shape) -> bool:
        """First time this engine dispatches ``shape`` on a compiled
        backend — jit/pallas compilation happens inside that span."""
        key = (kind, tuple(shape))
        if key in self._seen_shapes:
            return False
        self._seen_shapes.add(key)
        return self.backend != "numpy"

    def __repr__(self) -> str:
        return (f"VetEngine(backend={self.backend!r}, omega={self.omega}, "
                f"buckets={self.buckets}, cut_space={self.cut_space!r})")

    @property
    def interpret(self) -> bool:
        """Resolved Pallas kernel mode (``repro.kernels.runtime`` policy:
        explicit argument > ``REPRO_PALLAS_INTERPRET`` > platform probe).
        The platform probe runs on first access, not at construction."""
        if self._interpret is None:
            self._interpret = resolve_interpret(None)
        return self._interpret

    def clone(self) -> "VetEngine":
        """A fresh engine with this engine's configuration and *nothing*
        else: no shared compiled functions, result cache, or counters.

        The sharded fleet replicates its template engine this way (shards
        model separate processes), and ``fleet.transport`` ships the same
        recipe across real process boundaries (``EngineSpec``).  The
        unresolved ``interpret`` argument is forwarded — not the resolved
        bool — so a clone built in another process re-resolves its own
        platform policy / environment override.
        """
        return VetEngine(self.backend, omega=self.omega, buckets=self.buckets,
                         cut_space=self.cut_space,
                         interpret=self._interpret_arg, fused=self.fused,
                         cache_size=self._cache_size)

    # ------------------------------------------------------------- backends
    def _pallas_changepoint(self, z, omega: int = 3):
        # z's (static) trace-time shape picks the kernel block size.
        block = auto_block(z.shape[0])
        return changepoint_pallas(z, omega=omega, block=block,
                                  interpret=self.interpret)

    def _make_batch_fn(self):
        cp_fn = self._pallas_changepoint if self.backend == "pallas" else None
        single = functools.partial(
            vet_pipeline,
            omega=self.omega,
            buckets=self.buckets,
            cut_space=self.cut_space,
            changepoint_fn=cp_fn,
        )
        return jax.jit(jax.vmap(single))

    def _numpy_batch(self, matrix: np.ndarray) -> BatchVetResult:
        # The pre-engine call-site path: scalar vet_task per worker (oracle).
        results = [
            vet_task(row, omega=self.omega, buckets=self.buckets,
                     cut_space=self.cut_space)
            for row in matrix
        ]
        return BatchVetResult(
            vet=np.asarray([float(r.vet) for r in results]),
            ei=np.asarray([float(r.ei) for r in results]),
            oc=np.asarray([float(r.oc) for r in results]),
            pr=np.asarray([float(r.pr) for r in results]),
            t=np.asarray([int(r.t) for r in results], dtype=np.int32),
            n=np.asarray([r.n for r in results], dtype=np.int64),
        )

    # -------------------------------------------------------------- caching
    @staticmethod
    def _digest(a: np.ndarray) -> str:
        """Content fingerprint of one buffer (shape + dtype + bytes)."""
        a = np.ascontiguousarray(a)
        h = hashlib.blake2b(digest_size=16)
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
        return h.hexdigest()

    def _key(self, tag: str, arrays: Sequence[np.ndarray], *params) -> tuple:
        """Cache key: per-buffer content fingerprints + call params.

        Each input buffer is fingerprinted *separately* (a tuple of digests,
        not one rolled-up hash) so ``invalidate(buffer)`` can find every
        cached result that was computed from a given buffer, including
        multi-buffer entries (``vet_many`` / ``vet_windows``).  The engine
        config (backend/omega/buckets/cut_space) is fixed per instance and
        the cache is per instance, so it needs no key bits.
        """
        return (tag, *params, tuple(self._digest(a) for a in arrays))

    def invalidate(self, buffer) -> int:
        """Evict every cached result computed from ``buffer``; return count.

        The cache is keyed on buffer *content*, so an in-place mutation
        already changes the key and can never serve a stale hit — but the
        stale entries for the pre-mutation content stay resident until LRU
        pressure ages them out.  ``invalidate`` drops them eagerly: call it
        with the buffer (pre- or post-mutation content both work if you hold
        the respective arrays; matching is by content) when a consumer
        explicitly mutates a profile it previously vetted.  Streams built on
        this engine (``repro.engine.stream.VetStream``) key their incremental
        dispatches on an epoch-tagged rolling fingerprint instead and expose
        their own ``invalidate()``/``amend()`` hooks.

        Args:
            buffer: the mutated array (pre- or post-mutation content).

        Returns:
            Number of cache entries evicted.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> buf = np.linspace(1e-3, 2e-3, 16)
            >>> _ = eng.vet_batch(buf)
            >>> eng.invalidate(buf)    # evicts the entry computed from buf
            1
        """
        arr = np.asarray(buffer)
        digests = {self._digest(arr)}
        # The canonical forms the public entry points hash: vet_batch's
        # atleast_2d float64 matrix, and the 1-D float64 stream/profile view
        # used by vet_many / vet_sliding / vet_windows.
        as64 = np.asarray(buffer, dtype=np.float64)
        digests.add(self._digest(np.atleast_2d(as64)))
        if as64.ndim <= 1:
            digests.add(self._digest(np.atleast_1d(as64).ravel()))
        dead = [k for k in self._cache
                if digests.intersection(k[-1] if isinstance(k[-1], tuple)
                                        else (k[-1],))]
        for k in dead:
            del self._cache[k]
        return len(dead)

    @staticmethod
    def _freeze(res: BatchVetResult) -> BatchVetResult:
        # Results are always read-only — cache hits alias the stored arrays,
        # and mutability must not depend on the engine's cache config.
        for a in res:
            if isinstance(a, np.ndarray):
                a.flags.writeable = False
        return res

    def _memo(self, key: tuple, compute: Callable[[], BatchVetResult]):
        if self._cache_size <= 0:
            return self._freeze(compute())
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self._cache_hits += 1
            return hit
        self._cache_misses += 1
        res = self._freeze(compute())
        self._cache[key] = res
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        return res

    def cache_info(self) -> CacheInfo:
        """Result-cache counters (hits/misses/size/max_size).

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> times = np.linspace(1e-3, 2e-3, 16)
            >>> _ = eng.vet_batch(times)       # miss: computes
            >>> _ = eng.vet_batch(times)       # hit: served from cache
            >>> ci = eng.cache_info()
            >>> (ci.hits, ci.misses, ci.size)
            (1, 1, 1)
        """
        return CacheInfo(hits=self._cache_hits, misses=self._cache_misses,
                         size=len(self._cache), max_size=self._cache_size)

    def cache_clear(self) -> None:
        """Drop every memoized result and reset the hit/miss counters.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> _ = eng.vet_batch(np.linspace(1e-3, 2e-3, 16))
            >>> eng.cache_clear()
            >>> eng.cache_info().size
            0
        """
        self._cache.clear()
        self._cache_hits = 0
        self._cache_misses = 0

    # ------------------------------------------------------------------ API
    def vet_batch(self, times_matrix) -> BatchVetResult:
        """Vet a (workers, window) matrix of raw record times in one call.

        Rows are independent profiles; a 1-D input is treated as one worker.
        For the ``jax``/``pallas`` backends the whole batch is a single
        compiled call; ``numpy`` loops the scalar reference per row.
        Results are memoized on the matrix fingerprint.

        Args:
            times_matrix: (workers, window) array-like of per-record times
                in seconds (coerced to float64); 1-D means one worker.

        Returns:
            ``BatchVetResult`` of (workers,) host arrays, frozen
            (read-only — cache hits alias the stored arrays).

        Raises:
            ValueError: when the input has more than two dimensions.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> m = np.linspace(1e-3, 2e-3, 32).reshape(2, 16)
            >>> res = eng.vet_batch(m)
            >>> res.workers, res.vet.shape
            (2, (2,))
            >>> bool((res.vet >= 1.0).all())   # PR/EI: 1 == nothing left
            True
        """
        m = np.atleast_2d(np.asarray(times_matrix, dtype=np.float64))
        if m.ndim != 2:
            raise ValueError(f"expected (workers, window) matrix, got {m.shape}")
        return self._memo(self._key("batch", [m]),
                          lambda: self._vet_batch_impl(m))

    def _vet_batch_impl(self, m: np.ndarray) -> BatchVetResult:
        self.dispatches += 1
        self.dispatch_bytes += m.nbytes
        with _span(self.tracer, "engine.dispatch", tid=self.trace_tid,
                   backend=self.backend, kind="batch", rows=int(m.shape[0]),
                   window=int(m.shape[1]), bytes=int(m.nbytes),
                   cold=self._dispatch_cold("batch", m.shape)):
            if self.backend == "numpy":
                return self._numpy_batch(m)
            if self._batch_fn is None:
                self._batch_fn = self._make_batch_fn()
            vet, ei, oc, pr, t = self._batch_fn(m)
            # Host conversion stays in-span: jax dispatch is async, the
            # device sync happens here.
            w = m.shape[0]
            return BatchVetResult(
                vet=np.asarray(vet, dtype=np.float64),
                ei=np.asarray(ei, dtype=np.float64),
                oc=np.asarray(oc, dtype=np.float64),
                pr=np.asarray(pr, dtype=np.float64),
                t=np.asarray(t, dtype=np.int32),
                n=np.full(w, m.shape[1], dtype=np.int64),
            )

    # ------------------------------------------------------------ fused path
    def fused_supported(self, max_len: int) -> bool:
        """Whether the fused block-sparse kernel serves windows up to
        ``max_len`` on this engine.  Requires the pallas backend with
        ``fused`` enabled, and every row non-bucketed (``vet_pipeline``
        switches to the bucketed curve at ``n >= 4 * buckets``, which the
        fused kernel does not implement — those rows keep the gather
        path)."""
        return (self.fused and self.backend == "pallas"
                and (self.buckets is None or max_len < 4 * self.buckets))

    def _vet_arena_impl(self, arena: np.ndarray, starts: np.ndarray,
                        lengths: np.ndarray) -> BatchVetResult:
        """One fused launch over ragged windows of a shared arena.

        The fused twin of ``_vet_batch_impl``: counts one dispatch, stages
        O(arena + rows) bytes (the kernel slices windows out of the arena
        in VMEM — no gather matrix is ever materialized)."""
        self.dispatches += 1
        max_len = int(lengths.max())
        nbytes = staged_bytes(arena.size, starts.size, max_len)
        self.dispatch_bytes += nbytes
        with _span(self.tracer, "engine.dispatch", tid=self.trace_tid,
                   backend=self.backend, kind="fused",
                   rows=int(starts.size), window=max_len, bytes=int(nbytes),
                   cold=self._dispatch_cold(
                       # Pow2-rounded: the fused kernel pads its launch
                       # shapes, so compile cache hits follow the rounded
                       # sizes, not the raw ones.
                       "fused", (1 << max(0, arena.size - 1).bit_length(),
                                 1 << max(0, starts.size - 1).bit_length(),
                                 1 << max(0, max_len - 1).bit_length()))):
            vet, ei, oc, pr, t, n = fused_window_vet(
                arena, starts, lengths, omega=self.omega,
                cut_space=self.cut_space, interpret=self.interpret)
            return BatchVetResult(vet=vet, ei=ei, oc=oc, pr=pr, t=t, n=n)

    def pad_rows_pow2(self, matrix: np.ndarray):
        """Pad a delta batch to the next power-of-two row count.

        Jitted backends compile one batch graph per row count; live deltas
        (stream ticks, coalesced mux buckets) vary call to call, so padding
        to the next power of two (repeating the last row — the caller
        slices its rows back out) keeps compiles O(log max-delta) instead
        of one per distinct size.  Returns ``(matrix, padding_rows)``;
        the numpy backend (no compile cache) never pads.

        Example::

            >>> padded, extra = VetEngine("jax").pad_rows_pow2(
            ...     np.ones((5, 8)))
            >>> padded.shape[0], extra
            (8, 3)
            >>> VetEngine("numpy").pad_rows_pow2(np.ones((5, 8)))[1]
            0
        """
        n = matrix.shape[0]
        if self.backend == "numpy" or n <= 1:
            return matrix, 0
        pad = 1 << (n - 1).bit_length()
        if pad == n:
            return matrix, 0
        return (np.concatenate([matrix,
                                np.repeat(matrix[-1:], pad - n, axis=0)]),
                pad - n)

    def vet_one(self, times) -> VetResult:
        """Scalar convenience wrapper: one profile through the batched path.

        Args:
            times: 1-D array-like of one profile's record times (seconds).

        Returns:
            The scalar ``repro.core.vet.VetResult`` container (0-dim
            arrays; ``float()``/``int()`` them for Python scalars).

        Example::

            >>> r = VetEngine("numpy", buckets=64).vet_one(
            ...     np.linspace(1e-3, 2e-3, 16))
            >>> float(r.vet) >= 1.0 and r.n == 16
            True
        """
        return self.vet_batch(np.atleast_1d(np.asarray(times))[None, :]).task(0)

    def vet_many(self, profiles: Sequence) -> BatchVetResult:
        """Vet ragged profiles (different record counts per worker).

        Equal-length profiles are grouped and vetted in one batched call per
        distinct length; results come back in input order.  This is the entry
        point for controllers whose per-worker buffers fill unevenly.

        Args:
            profiles: sequence of 1-D array-likes, one per worker (record
                counts may differ).

        Returns:
            ``BatchVetResult`` in input order; ``n`` carries each worker's
            record count.

        Raises:
            ValueError: on an empty profile list.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> res = eng.vet_many([np.linspace(1e-3, 2e-3, 12),
            ...                     np.linspace(1e-3, 2e-3, 20)])
            >>> res.n.tolist()       # input order, per-worker counts
            [12, 20]
        """
        arrs = [np.atleast_1d(np.asarray(p, dtype=np.float64)).ravel()
                for p in profiles]
        if not arrs:
            raise ValueError("vet_many needs at least one profile")
        return self._memo(self._key("many", arrs),
                          lambda: self._vet_many_impl(arrs))

    def _vet_many_impl(self, arrs) -> BatchVetResult:
        w = len(arrs)
        vet = np.empty(w)
        ei = np.empty(w)
        oc = np.empty(w)
        pr = np.empty(w)
        t = np.empty(w, dtype=np.int32)
        n = np.empty(w, dtype=np.int64)
        groups: dict = {}
        for i, a in enumerate(arrs):
            groups.setdefault(a.size, []).append(i)
        for size, idxs in groups.items():
            # _vet_batch_impl, not vet_batch: one cache entry per *public*
            # call, no re-hash of the materialized per-group matrices.
            br = self._vet_batch_impl(np.stack([arrs[i] for i in idxs]))
            for j, i in enumerate(idxs):
                vet[i], ei[i], oc[i] = br.vet[j], br.ei[j], br.oc[j]
                pr[i], t[i], n[i] = br.pr[j], br.t[j], br.n[j]
        return BatchVetResult(vet=vet, ei=ei, oc=oc, pr=pr, t=t, n=n)

    # ------------------------------------------------------------- windowed
    @staticmethod
    def _as_stream(times) -> np.ndarray:
        arr = np.asarray(times, dtype=np.float64)
        if arr.ndim > 1:
            raise ValueError(
                f"windowed vetting expects a 1-D stream of record times, "
                f"got shape {arr.shape}")
        return np.atleast_1d(arr)

    def vet_sliding(self, times, window: int, stride: int = 1) -> BatchVetResult:
        """Vet every sliding window of a record-time stream in one call.

        Window ``i`` covers ``times[i*stride : i*stride + window]``; the last
        (possibly partial) tail that cannot fill a window is dropped, matching
        the convention of the per-window loops this replaces.  The
        (num_windows, window) matrix is materialized with one vectorized
        gather and vetted by a single ``vet_batch`` dispatch.  Row ``k`` of
        the result is window ``k`` in stream order.

        Args:
            times: 1-D record-time stream.
            window: records per window (>= 2).
            stride: records between window starts (>= 1).

        Returns:
            ``BatchVetResult`` with one row per complete window.

        Raises:
            ValueError: empty stream, ``window < 2``, ``stride < 1``, or
                ``window`` longer than the stream.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> times = np.linspace(1e-3, 2e-3, 32)
            >>> eng.vet_sliding(times, window=16, stride=8).workers
            3
            >>> eng.vet_sliding(times[:8], window=16)
            Traceback (most recent call last):
                ...
            ValueError: window (16) exceeds the stream length (8); buffer at least one full window of records before vetting
        """
        t = self._as_stream(times)
        window = int(window)
        stride = int(stride)
        if t.size == 0:
            raise ValueError("vet_sliding needs a non-empty stream of record "
                             "times")
        if window < 2:
            raise ValueError(f"window must cover >= 2 records, got {window}")
        if stride < 1:
            raise ValueError(f"stride must be >= 1, got {stride}")
        if window > t.size:
            raise ValueError(
                f"window ({window}) exceeds the stream length ({t.size}); "
                f"buffer at least one full window of records before vetting")
        return self._memo(self._key("sliding", [t], window, stride),
                          lambda: self._vet_sliding_impl(t, window, stride))

    def _vet_sliding_impl(self, t, window, stride) -> BatchVetResult:
        starts = np.arange(0, t.size - window + 1, stride)
        if self.fused_supported(window):
            # One fused launch over the stream itself: memory O(stream),
            # not O(windows x window).
            return self._vet_arena_impl(
                t, starts, np.full(starts.size, window, dtype=np.int64))
        gather = starts[:, None] + np.arange(window)[None, :]
        return self._vet_batch_impl(t[gather])

    def vet_windows(self, times, slices: Sequence) -> BatchVetResult:
        """Vet arbitrary (possibly ragged, possibly overlapping) windows.

        ``slices`` is a sequence of ``(lo, hi)`` half-open index pairs (plain
        ``slice`` objects with step 1 also work) into the 1-D ``times``
        stream.  Windows are gathered vectorized and grouped by length — one
        ``vet_batch`` dispatch per distinct length — and results come back in
        input order.  This is the ragged-window entry point the fig6/fig8
        style "vet every sub-window of a stream" analyses route through.

        Args:
            times: 1-D record-time stream.
            slices: ``(lo, hi)`` half-open pairs (or step-1 ``slice``
                objects) into the stream, each covering >= 2 records.

        Returns:
            ``BatchVetResult`` with one row per slice, in input order.

        Raises:
            ValueError: empty slice list, out-of-bounds or too-short
                windows, or a stepped slice.

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> times = np.linspace(1e-3, 2e-3, 16)
            >>> res = eng.vet_windows(times, [(0, 12), (4, 16), (0, 16)])
            >>> res.workers, res.n.tolist()
            (3, [12, 12, 16])
        """
        t = self._as_stream(times)
        bounds = self._normalize_slices(slices, t.size)
        return self._memo(self._key("windows", [t, bounds]),
                          lambda: self._vet_windows_impl(t, bounds))

    @staticmethod
    def _normalize_slices(slices, n: int) -> np.ndarray:
        pairs = []
        for s in slices:
            if isinstance(s, slice):
                if s.step not in (None, 1):
                    raise ValueError(f"window slices must have step 1, got {s}")
                lo, hi, _ = s.indices(n)
            else:
                try:
                    lo, hi = (int(s[0]), int(s[1]))
                except (TypeError, IndexError, ValueError):
                    raise ValueError(
                        f"each window must be a (lo, hi) pair or slice, "
                        f"got {s!r}") from None
            if not 0 <= lo < hi <= n:
                raise ValueError(
                    f"window ({lo}, {hi}) out of bounds for a stream of "
                    f"{n} records (need 0 <= lo < hi <= {n})")
            if hi - lo < 2:
                raise ValueError(
                    f"window ({lo}, {hi}) must cover >= 2 records")
            pairs.append((lo, hi))
        if not pairs:
            raise ValueError("vet_windows needs at least one (lo, hi) window; "
                             "got an empty slice list")
        return np.asarray(pairs, dtype=np.int64)

    def _vet_windows_impl(self, t, bounds) -> BatchVetResult:
        lengths = bounds[:, 1] - bounds[:, 0]
        if self.fused_supported(int(lengths.max())):
            # The ragged set is a single block-sparse launch: no grouping
            # by length, no per-group gather — one dispatch total.
            return self._vet_arena_impl(t, bounds[:, 0], lengths)
        # Same group-by-length batching as ragged profiles; the slices are
        # views, so the per-group stack is the materializing gather.
        return self._vet_many_impl([t[lo:hi] for lo, hi in bounds])

    def vet_job(self, profiles: Sequence) -> float:
        """Mean per-task vet over ragged profiles (paper §4.4).

        Example::

            >>> eng = VetEngine("numpy", buckets=64)
            >>> eng.vet_job([np.linspace(1e-3, 2e-3, 12),
            ...              np.linspace(1e-3, 2e-3, 20)]) >= 1.0
            True
        """
        return self.vet_many(profiles).vet_job


@functools.lru_cache(maxsize=None)
def _default_engine_cached(backend: str, omega: int, buckets, cut_space: str):
    return VetEngine(backend, omega=omega, buckets=buckets, cut_space=cut_space)


def default_engine(backend: str = "jax", *, omega: int = 3,
                   buckets: Optional[int] = 64,
                   cut_space: str = "log") -> VetEngine:
    """Shared process-wide engine (so call sites reuse compiled batch fns).

    Control-loop consumers default to ``buckets=64``: their windows are a
    few hundred records, where the full-resolution scan is unnecessary and
    64 buckets matches the pre-engine call-site convention.
    """
    return _default_engine_cached(backend, omega, buckets, cut_space)
