"""Batched, backend-pluggable vet estimation — the production-rate engine.

The paper's pipeline (see ``repro.core``):

    record times -> order statistics -> LSE change-point ->
    monotone extrapolation g-hat -> (EI, OC) -> vet_task -> vet_job

is a post-hoc, one-profile-at-a-time measure.  Every live consumer in this
repo (the online estimator, the vet controller, the serve/train launchers,
the benchmarks) needs it *continuously* and for *many workers at once*, which
used to mean an O(workers) sequential Python loop of scalar ``vet_task``
calls.  ``VetEngine`` owns the whole pipeline behind one API instead:

    engine = VetEngine(backend="jax", buckets=64)
    batch  = engine.vet_batch(times_matrix)   # (workers, window) -> one call
    batch.vet, batch.ei, batch.oc, batch.pr, batch.t   # (workers,) arrays
    batch.vet_job                                      # mean vet (paper §4.4)

API -> paper mapping (each stage is the same code the scalar path uses):

    ``vet_batch`` row pipeline  =  sort (order statistics, §4.1)
                                -> bucketed/log curve + two-segment LSE scan
                                   (change-point t-hat, §4.3)
                                -> anchor/slope continuation (g-hat, §4.3)
                                -> EI/OC decomposition (§4.2) -> vet (§4.4)
    ``BatchVetResult.vet_job``  =  vet_job (mean of per-task vet, §4.4)

Backends (``VetEngine(backend=...)``):

- ``numpy``  — the pre-engine reference path: a host loop of jitted scalar
  ``repro.core.vet.vet_task`` calls, one per worker.  Kept as the numerical
  oracle for cross-backend equivalence tests.
- ``jax``    — ``jit(vmap(vet_pipeline))``: the whole (workers, window)
  matrix is vetted in one compiled call, including a vectorized two-segment
  SSE change-point scan.  Numerically identical to the oracle by
  construction (same traced functions, batched).
- ``pallas`` — same batched pipeline, with the SSE scan routed through the
  Pallas kernel (``repro.kernels.changepoint``), the hot path on TPU.
  Caveat: on profiles whose SSE landscape has *statistical near-ties*
  (1e-4-relative gaps between candidate cuts are common on bucketed log
  curves), its batched trace can flip the cut by one bucket on a small
  fraction of workers — EI/OC stay within ~2% of the oracle, and the
  change-point is identical on well-separated (e.g. noiseless) landscapes.
  Windowed/stream/mux entry points additionally route through the *fused*
  block-sparse kernel (``repro.kernels.windowvet``, ``fused=`` to
  override): one launch vets an entire ragged window set straight out of
  the shared buffer — one dispatch per tick instead of one per window
  length, staged memory O(ring) instead of O(windows x length) — while
  ``vet_batch`` and bucketed rows keep the gather path, which doubles as
  the fused kernel's differential oracle.

Ragged inputs (workers with different record counts) go through
``vet_many``, which groups equal-length profiles and runs one batched call
per group.  ``vet_one`` is the scalar convenience wrapper.

Windowed vetting (the downstream workloads — KS population tests, record-time
distributions, vet/time correlation, online dashboards — all evaluate vet over
*many overlapping windows* of one stream):

- ``vet_sliding(times, window, stride)`` — every stride-spaced window of a
  stream, materialized by one vectorized gather and vetted in one batched
  dispatch.
- ``vet_windows(times, slices)`` — arbitrary ragged ``(lo, hi)`` windows,
  grouped by length, one batched dispatch per distinct length.

Every public entry point is memoized in a bounded per-engine result cache
keyed on per-buffer content fingerprints + call parameters (``cache_size=``
to bound or disable; ``cache_info()``/``cache_clear()`` to inspect;
``invalidate(buffer)`` to eagerly evict every entry computed from an
explicitly mutated buffer), so repeated ``decide()``/dashboard ticks over an
unchanged window are served from the cache.

Streaming (the live-consumer path — dashboards, controllers and autotuners
that re-estimate on every tick of a growing stream):

- ``VetStream(engine, window=, stride=, capacity=, history=)`` — a
  fixed-capacity ring buffer with O(chunk) ``append`` (rolling fingerprint,
  no whole-buffer re-hash) whose ``tick()`` vets only the windows that
  became complete since the last tick, reusing all earlier rows; every
  tick's result equals ``vet_sliding`` over the same logical prefix
  (``history=`` bounds the retained result rows for indefinitely long
  streams).  ``amend``/``invalidate`` are the mutation hooks that make stale
  cache hits impossible.  The tick is factored into ``drain``/``commit``/
  ``collect`` primitives so ``repro.fleet.VetMux`` can coalesce many
  streams' deltas into shared shape-bucketed dispatches — one compiled call
  per window length per fleet tick.
"""

from .engine import (
    BACKENDS,
    BatchVetResult,
    CacheInfo,
    VetEngine,
    default_engine,
)
from .stream import RingDelta, StreamDelta, StreamStats, VetStream

__all__ = ["BACKENDS", "BatchVetResult", "CacheInfo", "RingDelta",
           "StreamDelta", "StreamStats", "VetEngine", "VetStream",
           "default_engine"]
