"""Zero-dependency span tracer — the fleet's one timing seam.

Every layer of the estimation stack (engine dispatch, stream drain/commit/
collect, mux plan/coalesce/dispatch/commit, shard fan-out, transport round
trips) times itself through this module, so "where does a tick's time go?"
has exactly one answer and one clock.  Design constraints, in order:

- **Cheap when disabled.**  Instrumented call sites never branch on a
  feature flag; they call ``span(tracer, name, ...)`` with ``tracer=None``
  and get back a shared no-op context manager (``_NULL``) — no allocation,
  no clock read.  The disabled cost per call site is a function call and a
  kwargs dict; ``benchmarks/fleet_obs.py`` prices it and the results schema
  pins the bound.
- **Injectable monotonic clock.**  ``Tracer(clock=...)`` takes any
  zero-arg float-seconds callable (default ``time.perf_counter``), so the
  deterministic suites drive span trees off a counting fake and assert
  exact timestamps.  Everything that needs a duration *even when tracing is
  off* (``ShardAccount.elapsed_s``, ``launch.serve``'s ``vet_s``) goes
  through ``timed(tracer, ...)`` — the tracer's clock when present, the
  same ``perf_counter`` otherwise — so there is one clock source, not a
  tracer clock plus ad-hoc ``perf_counter`` pairs that could disagree.
- **Cross-process reassembly.**  Spans are plain ``SpanRecord`` NamedTuples
  (pickle-safe), so a transport shard worker drains its tracer into the
  ``TickReply`` and the driver ``adopt``s the records under the worker's
  ``pid`` lane, time-shifted into the driver's round-trip window — one
  Chrome trace spanning every process (``repro.obs.export``).

Lanes: ``pid`` is the process (0 = driver, shard ``k``'s worker = ``k+1``);
``tid`` is the within-process lane (shard index for in-process shard muxes,
0 otherwise).  Nesting is tracked per ``tid`` via an explicit stack, so a
record carries its parent span id and exporters need no containment
inference.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, NamedTuple, Optional

__all__ = ["SpanRecord", "Tracer", "span", "timed"]


class SpanRecord(NamedTuple):
    """One completed span.  ``ts``/``dur`` are seconds on the tracer clock;
    ``sid`` is unique per tracer, ``parent`` the enclosing span's ``sid``
    on the same ``tid`` (``None`` at the top level); ``attrs`` is a sorted
    tuple of pickle-safe ``(key, value)`` pairs."""

    name: str
    ts: float
    dur: float
    pid: int
    tid: int
    sid: int
    parent: Optional[int]
    attrs: tuple


class _NullSpan:
    """The shared disabled-path context manager: no clock, no allocation.
    ``dur`` stays 0.0 — consumers that need a real duration with tracing
    off use ``timed`` instead."""

    __slots__ = ()
    dur = 0.0
    sid = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


_NULL = _NullSpan()


class _Stopwatch:
    """``timed``'s fallback when no tracer is wired: same ``.dur`` surface,
    same monotonic clock family, nothing recorded."""

    __slots__ = ("dur", "_t0")

    def __enter__(self) -> "_Stopwatch":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.dur = time.perf_counter() - self._t0
        return False

    def set(self, **attrs) -> "_Stopwatch":
        return self


class _Span:
    """One live span (context manager).  ``dur`` is valid after ``__exit__``
    — call sites that fold span time into their own accounting
    (``elapsed_s``, ``vet_s``) read it instead of re-timing."""

    __slots__ = ("_tracer", "name", "tid", "_attrs", "sid", "parent",
                 "_t0", "dur")

    def __init__(self, tracer: "Tracer", name: str, tid: int, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.tid = tid
        self._attrs = attrs
        self.sid = -1
        self.parent: Optional[int] = None
        self.dur = 0.0

    def set(self, **attrs) -> "_Span":
        """Attach attributes discovered mid-span (row counts, cache hits)."""
        self._attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        tr = self._tracer
        self.sid = tr._next_sid
        tr._next_sid += 1
        stack = tr._stacks.get(self.tid)
        if stack is None:
            stack = tr._stacks[self.tid] = []
        self.parent = stack[-1].sid if stack else None
        stack.append(self)
        self._t0 = tr.clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        self.dur = tr.clock() - self._t0
        stack = tr._stacks[self.tid]
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:  # tolerate out-of-order exits, never corrupt
            stack.remove(self)
        tr._record(SpanRecord(self.name, self._t0, self.dur, tr.pid,
                              self.tid, self.sid, self.parent,
                              tuple(sorted(self._attrs.items()))))
        return False


class Tracer:
    """Collects nested ``SpanRecord``s from every instrumented layer.

    Args:
        clock: zero-arg monotonic float-seconds callable (injectable for
            deterministic tests; default ``time.perf_counter``).
        pid: process lane for spans recorded *by this tracer* (adopted
            records keep the lane given to ``adopt``).
        metrics: optional ``repro.obs.MetricsRegistry``; when set, every
            completed span feeds ``span.<name>`` (duration histogram,
            seconds) and ``span.<name>.count`` automatically, so metrics
            ride the same seam as spans.

    Example::

        >>> clk = iter(range(100)).__next__
        >>> tr = Tracer(clock=lambda: float(clk()))
        >>> with tr.span("tick"):
        ...     with tr.span("dispatch", rows=3):
        ...         pass
        >>> [(r.name, r.ts, r.dur, r.parent) for r in tr.records]
        [('dispatch', 1.0, 1.0, 0), ('tick', 0.0, 3.0, None)]
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter, *,
                 pid: int = 0, metrics=None):
        self.clock = clock
        self.pid = int(pid)
        self.metrics = metrics
        self.records: List[SpanRecord] = []  # completion order
        self.process_names: Dict[int, str] = {self.pid: "driver"}
        self._stacks: Dict[int, List[_Span]] = {}
        self._next_sid = 0

    def __repr__(self) -> str:
        return (f"Tracer(pid={self.pid}, records={len(self.records)}, "
                f"open={sum(len(s) for s in self._stacks.values())})")

    def span(self, name: str, tid: int = 0, **attrs) -> _Span:
        """A new span context manager on lane ``tid`` (not yet entered)."""
        return _Span(self, name, int(tid), attrs)

    def now(self) -> float:
        """Current tracer-clock time (for aligning adopted records)."""
        return self.clock()

    def _record(self, rec: SpanRecord) -> None:
        self.records.append(rec)
        if self.metrics is not None:
            self.metrics.histogram("span." + rec.name).observe(rec.dur)

    # -------------------------------------------------------- reassembly
    def drain(self) -> List[SpanRecord]:
        """Return and clear the completed records (open spans keep running
        and will land in a later drain).  The transport worker calls this
        per tick to ship its spans back on the ``TickReply``."""
        out, self.records = self.records, []
        return out

    def clear(self) -> None:
        self.records.clear()

    def adopt(self, records: Iterable, *, pid: int,
              at: Optional[float] = None, name: Optional[str] = None) -> int:
        """Splice records drained from *another* tracer (typically another
        process) into this one under process lane ``pid``.

        Span ids are remapped into this tracer's id space (parent links
        preserved), and — because the source process's monotonic clock has
        its own origin — timestamps are uniformly shifted so the earliest
        adopted record lands at ``at`` (driver-side round-trip start;
        ``None`` keeps the source timestamps).  Relative timing within the
        adopted batch is exact; absolute alignment across processes is as
        good as the anchor.  ``name`` labels the process lane in exports.

        Returns the number of records adopted.
        """
        records = [SpanRecord(*r) for r in records]
        if not records:
            return 0
        if name is not None:
            self.process_names[int(pid)] = name
        base = self._next_sid
        self._next_sid = base + max(r.sid for r in records) + 1
        shift = 0.0 if at is None else at - min(r.ts for r in records)
        for r in records:
            self._record(r._replace(
                ts=r.ts + shift, pid=int(pid), sid=base + r.sid,
                parent=None if r.parent is None else base + r.parent))
        return len(records)


def span(tracer: Optional[Tracer], name: str, tid: int = 0, **attrs):
    """The instrumentation-seam entry point: a tracer span when tracing is
    on, the shared no-op context manager when ``tracer`` is ``None``.
    Call sites never branch themselves — the disabled path costs one call.
    """
    if tracer is None:
        return _NULL
    return tracer.span(name, tid=tid, **attrs)


def timed(tracer: Optional[Tracer], name: str, tid: int = 0, **attrs):
    """Like ``span`` but *always* measures: ``.dur`` is a real duration
    after exit even with ``tracer=None`` (a plain stopwatch on the same
    monotonic clock family).  This is the one clock source for bookkeeping
    that must exist regardless of tracing — ``ShardAccount.elapsed_s``,
    ``launch.serve``'s ``vet_s`` — so enabling tracing changes what is
    *recorded*, never what is *measured*.
    """
    if tracer is None:
        return _Stopwatch()
    return tracer.span(name, tid=tid, **attrs)
