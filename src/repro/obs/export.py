"""Trace export: Chrome trace-event JSON (Perfetto-loadable) + flamegraph.

``to_chrome`` turns a list of ``SpanRecord``s — including cross-process
records adopted from transport workers — into the Chrome trace-event
format (the ``{"traceEvents": [...]}`` object form), loadable in
``chrome://tracing`` and https://ui.perfetto.dev.  Each span becomes one
complete event (``ph: "X"``) with microsecond ``ts``/``dur``; process
lanes get ``process_name`` metadata events so the viewer labels driver
vs shard workers.

``validate_chrome`` is the schema gate CI and the benchmark artifact test
run against every exported trace: required keys and types on every event,
and well-formed nesting — within each ``(pid, tid)`` lane, spans must
strictly nest (no partial overlap), verified by a time-sorted stack sweep.

``flamegraph`` renders the same records as an indented text tree (inclusive
durations, call counts), aggregated by span-name path — the terminal-
friendly summary the serve dashboard and ``benchmarks/fleet_obs.py`` print.
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .trace import SpanRecord, Tracer

__all__ = ["to_chrome", "write_chrome", "validate_chrome", "flamegraph"]

_US = 1e6
# Float round-off tolerance for the nesting sweep, in us.  Chrome ts/dur
# come from float-seconds clocks scaled by 1e6; sibling boundaries can
# land within a rounding error of each other.
_EPS_US = 0.5


def to_chrome(records: Sequence[SpanRecord], *,
              process_names: Optional[Dict[int, str]] = None) -> dict:
    """Chrome trace-event object for ``records``.

    Timestamps are normalized so the earliest span starts at ``ts=0`` and
    scaled to integer-friendly microseconds (floats are legal in the
    format; we keep them for sub-us spans).  Span attrs land in ``args``,
    along with the tracer-side span/parent ids (``sid``/``parent``) so a
    trace can be joined back to ledger rows.
    """
    records = [SpanRecord(*r) for r in records]
    events: List[dict] = []
    names = dict(process_names or {})
    for pid in sorted({r.pid for r in records} | set(names)):
        events.append({
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": names.get(pid, f"proc{pid}")},
        })
    t0 = min((r.ts for r in records), default=0.0)
    for r in records:
        args = dict(r.attrs)
        args["sid"] = r.sid
        if r.parent is not None:
            args["parent"] = r.parent
        events.append({
            "name": r.name, "ph": "X",
            "ts": (r.ts - t0) * _US, "dur": r.dur * _US,
            "pid": r.pid, "tid": r.tid, "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome(path, source, *, indent: int = 1) -> dict:
    """Serialize ``source`` (a ``Tracer`` or a record list) to ``path``.
    Returns the written object (handy for immediate validation)."""
    if isinstance(source, Tracer):
        obj = to_chrome(source.records, process_names=source.process_names)
    else:
        obj = to_chrome(source)
    with open(path, "w") as f:
        json.dump(obj, f, indent=indent, sort_keys=True, default=float)
        f.write("\n")
    return obj


def validate_chrome(obj: dict) -> List[str]:
    """Schema-check a Chrome trace object; returns a list of problems
    (empty == valid).  Checks, per the trace-event format:

    - top level is ``{"traceEvents": [...]}``
    - every ``X`` event has ``name``/``ts``/``dur``/``pid``/``tid`` with
      the right types, ``ts >= 0`` and ``dur >= 0``
    - within each ``(pid, tid)`` lane, ``X`` events strictly nest — a
      stack sweep over ``(ts, -dur)``-sorted events finds no partial
      overlap (boundaries tolerate ``0.5us`` of float round-off)
    """
    problems: List[str] = []
    if not isinstance(obj, dict) or not isinstance(
            obj.get("traceEvents"), list):
        return ["top level must be a dict with a 'traceEvents' list"]

    lanes: Dict[Tuple[int, int], List[Tuple[float, float, str]]] = {}
    for i, ev in enumerate(obj["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph == "M":
            continue
        if ph != "X":
            problems.append(f"{where}: unsupported ph {ph!r}")
            continue
        bad = False
        for key, types in (("name", str), ("ts", (int, float)),
                           ("dur", (int, float)), ("pid", int),
                           ("tid", int)):
            if not isinstance(ev.get(key), types):
                problems.append(f"{where}: missing or mistyped {key!r} "
                                f"(got {ev.get(key)!r})")
                bad = True
        if bad:
            continue
        if ev["ts"] < 0 or ev["dur"] < 0:
            problems.append(f"{where}: negative ts/dur")
            continue
        lanes.setdefault((ev["pid"], ev["tid"]), []).append(
            (float(ev["ts"]), float(ev["dur"]), ev["name"]))

    for (pid, tid), spans in sorted(lanes.items()):
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: List[Tuple[float, float, str]] = []  # (ts, end, name)
        for ts, dur, name in spans:
            while stack and ts >= stack[-1][1] - _EPS_US:
                stack.pop()
            if stack and ts + dur > stack[-1][1] + _EPS_US:
                problems.append(
                    f"lane (pid={pid}, tid={tid}): span {name!r} "
                    f"[{ts:.3f}, {ts + dur:.3f}]us partially overlaps "
                    f"enclosing {stack[-1][2]!r} ending {stack[-1][1]:.3f}us")
                continue
            stack.append((ts, ts + dur, name))
    return problems


def flamegraph(records: Iterable[SpanRecord], *, width: int = 40) -> str:
    """Indented text flamegraph: one line per distinct span-name *path*
    (root span name down to this span's name), with inclusive total
    seconds, call count, and a proportional bar.

    Paths aggregate across processes and lanes — ``fleet.tick >
    mux.tick > engine.dispatch`` is one line whether it ran on the driver
    or on three shard workers — because the question this view answers is
    "which stage of the pipeline costs what", not "which copy of it".
    """
    records = [SpanRecord(*r) for r in records]
    by_sid = {r.sid: r for r in records}

    def path_of(r: SpanRecord) -> Tuple[str, ...]:
        parts = [r.name]
        seen = {r.sid}
        while r.parent is not None and r.parent in by_sid:
            r = by_sid[r.parent]
            if r.sid in seen:  # defensive: corrupt parent links
                break
            seen.add(r.sid)
            parts.append(r.name)
        return tuple(reversed(parts))

    totals: Dict[Tuple[str, ...], List[float]] = {}
    for r in records:
        agg = totals.setdefault(path_of(r), [0.0, 0])
        agg[0] += r.dur
        agg[1] += 1
    if not totals:
        return "(no spans)"

    roots = sum(dur for path, (dur, _) in totals.items() if len(path) == 1)
    scale = roots or max(dur for dur, _ in totals.values()) or 1.0
    children: Dict[Tuple[str, ...], List[Tuple[str, ...]]] = {}
    for path in totals:
        children.setdefault(path[:-1], []).append(path)

    lines: List[str] = []

    def emit(path: Tuple[str, ...]) -> None:
        dur, count = totals[path]
        bar = "#" * max(1, int(round(width * dur / scale)))
        pad = max(1, 34 - 2 * (len(path) - 1))
        lines.append(f"{'  ' * (len(path) - 1)}{path[-1]:<{pad}} "
                     f"{dur * 1e3:9.3f} ms  x{count:<5d} {bar}")
        for child in sorted(children.get(path, ()),
                            key=lambda p: -totals[p][0]):
            emit(child)

    for root in sorted(children.get((), ()), key=lambda p: -totals[p][0]):
        emit(root)
    return "\n".join(lines)
