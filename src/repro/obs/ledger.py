"""The optimality ledger: the paper's measure applied to the fleet itself.

The source paper judges a Hadoop optimization by the ratio of its measured
cost to an idealized lower bound ("how far from optimal"), not by raw
speedup.  This module applies the same discipline to our own estimation
stack: for every traced tick, compute a roofline-style *floor* for each
pipeline stage from what the engine actually staged (``dispatch_bytes``,
dispatch counts — the same quantities ``benchmarks/roofline.py`` prices
kernels with), and report ``measured / floor`` per stage.  A ratio near 1
means the stage runs as fast as the data movement allows; a large ratio is
unclaimed headroom, and *that ratio* — not wall time — is what later perf
PRs are judged by (ROADMAP items 2 and 3 both consume it).

Floor model (deliberately conservative, mirroring the memory-bound side of
``benchmarks/roofline.py``'s ``roofline_fraction``):

    floor_s(stage) = n_dispatches * DISPATCH_FLOOR_S
                   + staged_bytes / LEDGER_MEM_BW

- ``DISPATCH_FLOOR_S`` (1 us) is a lower bound on any dispatch: below the
  cheapest possible launch/driver round-trip on every backend we run.
- ``LEDGER_MEM_BW`` (200 GB/s) is an optimistic effective host-memory
  bandwidth — higher than any sustained host-side gather we can achieve,
  so ``bytes / LEDGER_MEM_BW`` under-estimates true staging time.

Both constants are chosen so the floor is *sound* (never above a real
measurement) rather than tight; soundness is what the benchmark artifact
and tests pin (``ratio >= 1`` on every backend).  Only spans that carry a
``bytes`` attribute (engine dispatches) get a floor; pure-orchestration
stages (plan, commit, collect) are reported measured-only, since their
floor is genuinely zero.  Cold dispatches — first time the engine sees a
shape, so jit/pallas compilation is in-span — are split into a separate
``<stage> [cold]`` row so compile time cannot masquerade as execution
headroom.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, NamedTuple, Optional

from .trace import SpanRecord

__all__ = ["DISPATCH_FLOOR_S", "LEDGER_MEM_BW", "StageLedger",
           "LedgerReport", "ledger_from", "format_ledger"]

DISPATCH_FLOOR_S = 1e-6      # s per dispatch: below any real launch path
LEDGER_MEM_BW = 200e9        # B/s: optimistic effective host bandwidth


class StageLedger(NamedTuple):
    """One span name's aggregate: measured inclusive seconds vs its floor.
    ``floor_s``/``ratio`` are ``None`` for stages with no byte-backed
    floor (orchestration)."""

    stage: str
    calls: int
    measured_s: float
    bytes: int
    floor_s: Optional[float]
    ratio: Optional[float]

    def to_json(self) -> dict:
        return {"stage": self.stage, "calls": self.calls,
                "measured_s": self.measured_s, "bytes": self.bytes,
                "floor_s": self.floor_s, "ratio": self.ratio}


class LedgerReport(NamedTuple):
    """Per-stage ledger rows plus the dispatch-stage aggregate ratio."""

    stages: tuple            # of StageLedger, dispatch stages first
    measured_s: float        # total over floor-bearing (dispatch) stages
    floor_s: float           # total floor over the same stages
    ratio: Optional[float]   # measured_s / floor_s (None if no dispatches)

    def to_json(self) -> dict:
        return {"stages": [s.to_json() for s in self.stages],
                "measured_s": self.measured_s, "floor_s": self.floor_s,
                "ratio": self.ratio}


def ledger_from(records: Iterable[SpanRecord]) -> LedgerReport:
    """Aggregate traced spans into the optimality ledger.

    Spans group by name; spans carrying a ``bytes`` attr additionally
    split on their ``cold`` attr into ``<name> [cold]`` rows (compile
    included in-span) vs warm rows, and only warm+cold dispatch rows get
    floors and feed the headline ratio.
    """
    acc: Dict[str, List] = {}  # stage -> [calls, measured, bytes, floored]
    for r in records:
        r = SpanRecord(*r)
        attrs = dict(r.attrs)
        nbytes = attrs.get("bytes")
        stage = r.name
        if nbytes is not None and attrs.get("cold"):
            stage += " [cold]"
        row = acc.setdefault(stage, [0, 0.0, 0, nbytes is not None])
        row[0] += 1
        row[1] += r.dur
        row[2] += int(nbytes or 0)

    stages: List[StageLedger] = []
    tot_meas = tot_floor = 0.0
    have_floor = False
    for stage, (calls, measured, nbytes, floored) in acc.items():
        if floored:
            floor = calls * DISPATCH_FLOOR_S + nbytes / LEDGER_MEM_BW
            ratio = measured / floor
            tot_meas += measured
            tot_floor += floor
            have_floor = True
        else:
            floor = ratio = None
        stages.append(StageLedger(stage, calls, measured, nbytes,
                                  floor, ratio))
    stages.sort(key=lambda s: (s.floor_s is None, -s.measured_s))
    return LedgerReport(tuple(stages), tot_meas, tot_floor,
                        tot_meas / tot_floor if have_floor else None)


def format_ledger(report: LedgerReport, *, title: str = "optimality ledger") -> str:
    """Fixed-width text table of the ledger (serve dashboard, benchmarks).

    ``x over floor`` is measured/floor for dispatch stages; orchestration
    stages show ``-`` (no meaningful floor).
    """
    head = f"{'stage':<28} {'calls':>6} {'measured':>11} {'floor':>11} {'x over floor':>13}"
    lines = [f"-- {title} --", head, "-" * len(head)]
    for s in report.stages:
        floor = f"{s.floor_s * 1e3:9.3f}ms" if s.floor_s is not None else f"{'-':>11}"
        ratio = f"{s.ratio:12.1f}x" if s.ratio is not None else f"{'-':>13}"
        lines.append(f"{s.stage:<28} {s.calls:>6} {s.measured_s * 1e3:9.3f}ms "
                     f"{floor} {ratio}")
    if report.ratio is not None:
        lines.append("-" * len(head))
        lines.append(f"{'all dispatch stages':<28} {'':>6} "
                     f"{report.measured_s * 1e3:9.3f}ms "
                     f"{report.floor_s * 1e3:9.3f}ms {report.ratio:12.1f}x")
    return "\n".join(lines)
