"""Zero-dependency metrics: counters, gauges, fixed-bucket histograms.

The fleet's second observability surface, next to spans (``repro.obs.trace``).
Spans answer "where did this tick's time go"; metrics answer "what has the
fleet done so far" — dispatch counts, staged bytes, retry totals, span
duration distributions — as monotonically growing state that is cheap to
update on every event and cheap to snapshot for a dashboard or benchmark
artifact.

Everything here is plain Python over dicts and lists: no client libraries,
no background threads, no global registry.  A ``MetricsRegistry`` is an
ordinary object you construct, hand to a ``Tracer`` (which then feeds
``span.<name>`` duration histograms automatically), and ``snapshot()`` into
a JSON-ready dict.

Histograms use *fixed* upper-bound buckets chosen at construction (plus an
implicit ``+inf``), so observation is O(#buckets) worst-case with no
allocation, and two snapshots are comparable bucket-for-bucket.
"""

from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_BUCKETS"]

# Log-spaced seconds, 1us .. 10s — wide enough for a null span and a cold
# pallas compile in the same histogram.
DEFAULT_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)


class Counter:
    """Monotonically increasing count (dispatches, retries, bytes)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, by: float = 1) -> None:
        if by < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc by {by})")
        self.value += by

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """A value that goes up and down (live streams, ring occupancy)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = value

    def inc(self, by: float = 1) -> None:
        self.value += by

    def dec(self, by: float = 1) -> None:
        self.value -= by

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram with running count/sum/min/max.

    ``bounds`` are inclusive upper bounds; an implicit ``+inf`` bucket
    catches the tail, so ``sum(counts) == count`` always.
    """

    __slots__ = ("name", "bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str,
                 bounds: Sequence[float] = DEFAULT_BUCKETS):
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram {name!r} bounds must be strictly "
                             f"increasing, got {bounds!r}")
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, value)] += 1
        self.count += 1
        self.sum += value
        if self.min is None or value < self.min:
            self.min = value
        if self.max is None or value > self.max:
            self.max = value

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {"type": "histogram", "count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "buckets": dict(zip([f"le_{b:g}" for b in self.bounds]
                                    + ["le_inf"], self.counts))}


class MetricsRegistry:
    """Named metric store with get-or-create accessors.

    Accessors are idempotent per name — the first call creates, later
    calls return the same object — but a name cannot change kind::

        >>> reg = MetricsRegistry()
        >>> reg.counter("engine.dispatches").inc()
        >>> reg.counter("engine.dispatches").inc(2)
        >>> reg.counter("engine.dispatches").value
        3
        >>> reg.gauge("fleet.streams").set(9)
        >>> h = reg.histogram("tick.s", bounds=(0.01, 0.1))
        >>> h.observe(0.05); h.count
        1
        >>> sorted(reg.snapshot())
        ['engine.dispatches', 'fleet.streams', 'tick.s']
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, *args, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, bounds)

    def snapshot(self) -> Dict[str, dict]:
        """JSON-ready ``{name: {type, ...}}`` dict, insertion-ordered."""
        return {name: m.snapshot() for name, m in self._metrics.items()}
