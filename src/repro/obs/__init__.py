"""repro.obs — fleet-wide tracing, metrics, and the optimality ledger.

The paper's thesis is that optimizations must be judged against an ideal
lower bound; this package is that discipline turned on the fleet stack
itself.  Three pieces, zero dependencies:

- ``Tracer`` / ``span`` / ``timed`` (``repro.obs.trace``): nested spans
  with an injectable monotonic clock, a true no-op path when disabled,
  and pickle-safe records so transport workers ship their spans back on
  ``TickReply`` for cross-process reassembly (``Tracer.adopt``).  Every
  layer — engine dispatch, stream drain/commit/collect, mux
  plan/coalesce/dispatch/commit/anomaly, shard fan-out, transport round
  trips — times itself through this one seam.
- ``MetricsRegistry`` (``repro.obs.metrics``): counters, gauges and
  fixed-bucket histograms; a tracer wired to a registry feeds
  ``span.<name>`` duration histograms automatically.
- Exports (``repro.obs.export``) and the ledger (``repro.obs.ledger``):
  Chrome trace-event JSON (Perfetto-loadable) with a schema validator CI
  runs on every export, a text flamegraph, and ``ledger_from`` — per
  stage, measured time over a roofline-style floor computed from staged
  bytes and dispatch counts, the measured-over-optimal ratio later perf
  PRs are judged by.

Wiring: ``VetMux(..., tracer=t)`` / ``mux.set_tracer(t)`` threads the
tracer down to its engine and streams; ``ShardedVetMux.set_tracer`` gives
each shard mux its own ``tid`` lane; ``TransportVetMux(..., tracer=t)``
enables worker-side tracers over the wire and adopts their spans under
per-worker ``pid`` lanes.  ``benchmarks/fleet_obs.py`` prices the
disabled-path overhead and commits the ledger artifact.
"""

from .trace import SpanRecord, Tracer, span, timed
from .metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .export import flamegraph, to_chrome, validate_chrome, write_chrome
from .ledger import (
    DISPATCH_FLOOR_S,
    LEDGER_MEM_BW,
    LedgerReport,
    StageLedger,
    format_ledger,
    ledger_from,
)

__all__ = [
    "DEFAULT_BUCKETS",
    "DISPATCH_FLOOR_S",
    "LEDGER_MEM_BW",
    "Counter",
    "Gauge",
    "Histogram",
    "LedgerReport",
    "MetricsRegistry",
    "SpanRecord",
    "StageLedger",
    "Tracer",
    "flamegraph",
    "format_ledger",
    "ledger_from",
    "span",
    "timed",
    "to_chrome",
    "validate_chrome",
    "write_chrome",
]
