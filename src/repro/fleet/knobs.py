"""Knob hooks: the write-back seam between an online tuner and a live fleet.

Every layer so far *observes* the running fleet; ``repro.sched.tuner``
closes the loop and *writes back* into it.  This module is the seam that
makes those writes safe and uniform: a ``Knob`` names one tunable quantity
and enumerates its admissible values (tuners work on the ordered index
grid, so annealed SPSA steps and bandit arms are well defined), and a
``KnobHooks`` registry binds each knob to a setter/getter pair supplied by
whoever owns the state — a mux (tick budget), a serving loop, or a
simulated workload (``repro.fleet.scenarios.TunableScenario``).

Two rules keep write-back as disciplined as the transport layer's
exactly-once ticks:

- **Applies happen between ticks.**  A setter must only mutate state a
  tick reads at its start (``VetMux.tick`` reads ``self.budget`` when it
  plans), never state a tick is mid-way through; callers (the tuner's
  ``step``) apply knobs strictly after one tick's objective sample and
  before the next tick.
- **Every apply is validated and reversible.**  ``apply`` rejects unknown
  knobs and out-of-grid values before touching any setter, and
  ``snapshot`` round-trips through the getters, so a tuner can always
  capture the pre-probe setting and restore it on rollback.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, Mapping, MutableMapping, Sequence, Tuple

__all__ = ["Knob", "KnobHooks", "mux_knob_hooks"]

KNOB_KINDS = ("spsa", "bandit")


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable quantity: a name plus its ordered admissible values.

    ``kind`` selects the tuner mechanism: ``"spsa"`` knobs are perturbed
    on their value *index* (the grid must be ordered so a +/-1 index step
    is a meaningful nudge — microbatch counts, chunk sizes); ``"bandit"``
    knobs have no useful index geometry (modes, placements, budgets whose
    response is not unimodal) and are explored as discrete arms instead.

    Example::

        >>> k = Knob("q_chunk", (16, 32, 64, 128))
        >>> k.index_of(64), k.value(2), k.clip(9)
        (2, 64, 3)
    """

    name: str
    values: Tuple
    kind: str = "spsa"

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))
        if not self.values:
            raise ValueError(f"knob {self.name!r} needs at least one value")
        if len(set(self.values)) != len(self.values):
            raise ValueError(f"knob {self.name!r} has duplicate values")
        if self.kind not in KNOB_KINDS:
            raise ValueError(f"knob kind must be one of {KNOB_KINDS}, "
                             f"got {self.kind!r}")

    def index_of(self, value) -> int:
        """Grid index of ``value``; raises ``ValueError`` off-grid."""
        try:
            return self.values.index(value)
        except ValueError:
            raise ValueError(
                f"{value!r} is not an admissible value for knob "
                f"{self.name!r} (grid: {self.values})") from None

    def value(self, index: int):
        return self.values[self.clip(index)]

    def clip(self, index: int) -> int:
        """Clamp an index onto the grid (SPSA probes near the boundary)."""
        return max(0, min(len(self.values) - 1, int(index)))


class KnobHooks:
    """Registry binding knobs to the setters/getters that own their state.

    Example::

        >>> state = {"n_micro": 1}
        >>> hooks = KnobHooks.over_state((Knob("n_micro", (1, 2, 4)),), state)
        >>> hooks.apply({"n_micro": 4}), state["n_micro"]
        ({'n_micro': 4}, 4)
        >>> hooks.snapshot()
        {'n_micro': 4}
    """

    def __init__(self):
        self._knobs: "OrderedDict[str, Knob]" = OrderedDict()
        self._setters: Dict[str, Callable] = {}
        self._getters: Dict[str, Callable] = {}

    def __repr__(self) -> str:
        return f"KnobHooks({', '.join(self._knobs)})"

    def register(self, knob: Knob, setter: Callable, getter: Callable) \
            -> "KnobHooks":
        """Bind one knob; returns ``self`` so registrations chain.

        Raises:
            ValueError: duplicate knob name.
        """
        if knob.name in self._knobs:
            raise ValueError(f"knob {knob.name!r} is already registered")
        self._knobs[knob.name] = knob
        self._setters[knob.name] = setter
        self._getters[knob.name] = getter
        return self

    @classmethod
    def over_state(cls, knobs: Sequence[Knob],
                   state: MutableMapping) -> "KnobHooks":
        """Hooks whose setters/getters are plain dict writes/reads — the
        harness for simulated workloads and for tuner unit tests."""
        hooks = cls()
        for knob in knobs:
            hooks.register(knob,
                           lambda v, _s=state, _n=knob.name: _s.__setitem__(_n, v),
                           lambda _s=state, _n=knob.name: _s[_n])
        return hooks

    @property
    def knobs(self) -> Tuple[Knob, ...]:
        return tuple(self._knobs.values())

    def knob(self, name: str) -> Knob:
        if name not in self._knobs:
            raise KeyError(f"knob {name!r} is not registered "
                           f"(have: {tuple(self._knobs)})")
        return self._knobs[name]

    def __contains__(self, name: str) -> bool:
        return name in self._knobs

    def __len__(self) -> int:
        return len(self._knobs)

    def apply(self, assignment: Mapping) -> Dict:
        """Validate the whole assignment, then write it through the setters.

        Validation is all-or-nothing: an unknown knob or an off-grid value
        raises before *any* setter runs, so a failed apply never leaves the
        fleet half-written.

        Returns:
            The applied ``{name: value}`` dict (a copy).

        Raises:
            KeyError: unknown knob name.
            ValueError: a value outside its knob's grid.
        """
        for name, value in assignment.items():
            self.knob(name).index_of(value)  # validates both name and value
        applied = {}
        for name, value in assignment.items():
            self._setters[name](value)
            applied[name] = value
        return applied

    def snapshot(self) -> Dict:
        """Current value of every registered knob, read via the getters."""
        return {name: self._getters[name]() for name in self._knobs}


def mux_knob_hooks(mux, *, budget_values: Sequence[int] = (8, 16, 32, 64),
                   hooks: KnobHooks = None) -> KnobHooks:
    """Fleet-side hooks for any mux variant (``VetMux`` / ``ShardedVetMux``
    / ``TransportVetMux``): the per-tick window-row ``tick_budget`` knob.

    The budget lives driver-side in every variant (the sharded and
    transport fleets water-fill it across shards at the top of each tick),
    so applying it between ticks is race-free even with worker processes.
    Registered as a bandit knob: the budget's latency/backlog response is
    not unimodal in general, so arms beat index gradients.

    Pass ``hooks=`` to extend an existing registry (e.g. a scenario's
    workload knobs) instead of starting a new one.
    """
    values = tuple(int(v) for v in budget_values)
    if any(v < 1 for v in values):
        raise ValueError(f"tick budgets must be >= 1 row, got {values}")
    hooks = hooks if hooks is not None else KnobHooks()

    def _set(v):
        mux.budget = int(v)

    def _get():
        # A mux built with budget=None reports the grid's largest arm
        # (unbounded behaves like the loosest admissible budget).
        return max(values) if mux.budget is None else int(mux.budget)

    return hooks.register(Knob("tick_budget", values, kind="bandit"),
                          _set, _get)
