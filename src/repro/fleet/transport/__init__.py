"""repro.fleet.transport — the fleet across real worker processes.

``TransportVetMux`` drives one long-lived worker process per shard over
duplex pipes, with the production-executor concerns a process boundary
forces: per-round-trip retries with exponential backoff under a retry
budget, periodic checkpoints plus command journals so a killed worker
resumes mid-job without re-vetting committed windows, and per-shard
accounting merged into every ``ShardTick`` / ``MuxStats``.

The in-process driver (``driver="inprocess"``) runs the identical command
stream without pipes — the differential oracle the test suite locks the
process driver against, and a fallback where multiprocessing is
unavailable.

Layering: ``proto`` (wire types) <- ``worker`` (command executor + process
loop) <- ``driver`` (channels, retries, checkpoints, the mux surface).
"""

from .driver import DRIVERS, ShardHandle, TransportVetMux
from .proto import (
    EngineSpec,
    FAULT_EXIT,
    ShardAccount,
    TickReply,
    TransportError,
    WorkerFault,
)
from .worker import ShardWorker, shard_worker_main

__all__ = [
    "DRIVERS",
    "EngineSpec",
    "FAULT_EXIT",
    "ShardAccount",
    "ShardHandle",
    "ShardWorker",
    "TickReply",
    "TransportError",
    "TransportVetMux",
    "WorkerFault",
    "shard_worker_main",
]
