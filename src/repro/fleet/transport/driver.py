"""Cross-process shard driver: pipes, retries, checkpoints, accounting.

``TransportVetMux`` is ``ShardedVetMux`` with the shards moved into real
worker processes.  Same surface (``register`` / ``deregister`` / ``feed``
/ ``tick`` / ``flush`` / ``stats``), same deterministic placement (the
shared ``ShardPlacer``), same two-level budget water-filling, same merged
``ShardTick`` — plus the production-executor concerns a process boundary
forces:

- **Bounded worker pool.**  One long-lived worker process per shard
  (started once, reused across ticks — never a process per dispatch), each
  owning a ``VetMux`` on its own engine, driven over a duplex pipe.
- **Retries with exponential backoff.**  Every round trip runs under a
  retry budget: a transport failure (dead process, broken pipe, reply
  timeout) kills the channel, sleeps ``backoff_base * backoff_factor **
  attempt``, revives the worker and re-sends.  Logical errors re-raise
  immediately as their original exception type — they are never retried.
- **Checkpoint / resume.**  After every ``checkpoint_every``-th tick the
  driver pulls each shard's full mux state (ring contents, fingerprints,
  retained rows, staleness counters — ``VetMux.state_dict``) and clears
  that shard's command journal.  Reviving a dead worker replays checkpoint
  + journal (the registers/feeds since), restoring the exact pre-failure
  state, then re-sends the failed command — so a shard killed mid-tick
  resumes without re-vetting committed windows and without skipping any
  (lifetime row/dispatch counters stay equal to the in-process oracle's).
- **Accounting.**  Per-shard round trips, retries, respawns, checkpoints
  and wall-clock (``ShardAccount``) surface on every tick
  (``ShardTick.accounts``) and merge into ``MuxStats``
  (``retries``/``respawns``).

``driver="inprocess"`` runs the identical command stream against
``ShardWorker``s in this process — no pipes, nothing to retry.  That is
the differential oracle: the suite locks the process driver to it (and
both to ``ShardedVetMux``) across the scenario bank.
"""

from __future__ import annotations

import multiprocessing as mp
import time
from typing import (
    Any,
    Callable,
    Dict,
    Hashable,
    Iterator,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import numpy as np

from ...engine import BatchVetResult, VetEngine, VetStream
from ...kernels.runtime import platform_default_hint
from ...obs.trace import span as _span, timed as _timed
from ..mux import MuxStats, MuxTick, _flush_loop
from ..schedule import split_budget
from ..shard import ShardPlacer, ShardTick
from .proto import (
    EngineSpec,
    LOGICAL_EXCEPTIONS,
    ShardAccount,
    TickReply,
    TransportError,
    WorkerFault,
)
from .worker import ShardWorker, shard_worker_main

__all__ = ["DRIVERS", "ShardHandle", "TransportVetMux"]

DRIVERS = ("process", "inprocess")


class _TransportFailure(Exception):
    """Internal: one round trip failed at the transport level (dead worker,
    broken pipe, reply timeout) — retryable, unlike logical errors."""


class _LocalChannel:
    """In-process 'transport': commands execute synchronously against a
    ``ShardWorker`` living in this process.  The differential oracle —
    identical command stream, no pipes, nothing that can die."""

    def __init__(self, factory: Callable[[], ShardWorker]):
        self._worker = factory()
        self._pending: Optional[Tuple[str, Any]] = None

    @property
    def alive(self) -> bool:
        return True

    def spawn(self) -> None:  # pragma: no cover — never dead
        pass

    def send(self, msg: Tuple[str, Any]) -> None:
        self._pending = msg

    def recv(self, timeout: float) -> tuple:
        op, payload = self._pending
        self._pending = None
        try:
            return ("ok", self._worker.handle(op, payload))
        except Exception as exc:
            return ("err", type(exc).__name__, str(exc))

    def kill(self) -> None:  # pragma: no cover — never dead
        pass

    def close(self) -> None:
        pass


class _ProcessChannel:
    """One shard worker process plus its duplex pipe.

    A transport failure tears the whole channel down (``kill``): the stale
    pipe is discarded with the dead process, so a late reply from a hung
    worker can never desynchronize a fresh command stream — every revive
    starts a new process on a new pipe.
    """

    def __init__(self, ctx, spec: EngineSpec, tenant_weights: dict,
                 urgent_headroom: int):
        self._ctx = ctx
        self._spec = spec
        self._tenant_weights = tenant_weights
        self._urgent_headroom = urgent_headroom
        self._proc = None
        self._conn = None

    @property
    def alive(self) -> bool:
        return self._proc is not None and self._proc.is_alive()

    def spawn(self) -> None:
        self.kill()
        parent, child = self._ctx.Pipe(duplex=True)
        self._proc = self._ctx.Process(
            target=shard_worker_main,
            args=(child, self._spec, self._tenant_weights,
                  self._urgent_headroom, platform_default_hint()),
            daemon=True)
        self._proc.start()
        child.close()
        self._conn = parent

    def send(self, msg: Tuple[str, Any]) -> None:
        if self._conn is None:
            raise _TransportFailure("worker not started")
        try:
            self._conn.send(msg)
        except (BrokenPipeError, EOFError, OSError) as exc:
            raise _TransportFailure(f"send failed: {exc}") from exc

    def recv(self, timeout: float) -> tuple:
        if self._conn is None:
            raise _TransportFailure("worker not started")
        try:
            if not self._conn.poll(timeout):
                raise _TransportFailure(
                    f"no reply within {timeout:.1f}s (hung worker?)")
            return self._conn.recv()
        except _TransportFailure:
            raise
        except (BrokenPipeError, EOFError, OSError) as exc:
            raise _TransportFailure(f"recv failed: {exc}") from exc

    def kill(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:  # pragma: no cover
                pass
            self._conn = None
        if self._proc is not None:
            if self._proc.is_alive():
                self._proc.terminate()
            self._proc.join(timeout=5)
            self._proc = None

    def close(self) -> None:
        if self._conn is not None and self.alive:
            try:  # graceful first: let the worker loop exit cleanly
                self._conn.send(("shutdown", None))
                self._conn.poll(1.0)
            except (BrokenPipeError, EOFError, OSError):
                pass
        self.kill()


class ShardHandle:
    """Reliable command endpoint for one shard.

    Wraps a channel with the executor concerns: retries with exponential
    backoff under a retry budget, revive (respawn + checkpoint restore +
    journal replay) when the worker died, per-shard accounting, and an
    async ``tick_async``/``finish_tick`` pair so every shard computes its
    tick concurrently instead of serially round-tripping.

    ``sleep`` is injectable so the retry/backoff unit tests assert the
    exact backoff schedule without wall-clock waits.
    """

    def __init__(self, index: int, channel, *, max_retries: int = 3,
                 backoff_base: float = 0.05, backoff_factor: float = 2.0,
                 timeout: float = 60.0, sleep: Callable[[float], None]
                 = time.sleep):
        self.index = index
        self.channel = channel
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self.backoff_factor = float(backoff_factor)
        self.timeout = float(timeout)
        self._sleep = sleep
        # Crash recovery: last checkpoint + the mutating commands since.
        self.checkpoint_blob: Optional[dict] = None
        self.journal: List[Tuple[str, Any]] = []
        self.ticks_since_checkpoint = 0
        # Accounting (ShardAccount fields).
        self.calls = 0
        self.retries = 0
        self.respawns = 0
        self.checkpoints = 0
        self.elapsed_s = 0.0
        self._async_budget: Optional[int] = None
        self._async_sent = False
        # Observability (repro.obs): when a tracer is attached, every round
        # trip is a ``transport.*`` span on lane ``index`` — and elapsed_s
        # is read off the *same* span stopwatch, so there is exactly one
        # clock source whether tracing is on or off.  ``trace_enabled``
        # mirrors the worker-side state so ``_revive`` can re-enable it
        # (the ``trace`` op is NOT journaled: journals clear at
        # checkpoints).  ``tick_sent_at`` anchors the adoption of this
        # worker's spans into the driver clock.
        self.tracer = None
        self.trace_enabled = False
        self.tick_sent_at = 0.0

    @property
    def account(self) -> ShardAccount:
        return ShardAccount(calls=self.calls, retries=self.retries,
                            respawns=self.respawns,
                            checkpoints=self.checkpoints,
                            elapsed_s=self.elapsed_s)

    # ------------------------------------------------- reliable round trip
    def call(self, op: str, payload: Any, *, journal: bool = False) -> Any:
        """One reliable round trip: send, await, retry transport failures
        with exponential backoff, revive dead workers, re-raise logical
        errors.  ``journal=True`` records the command (after success) for
        replay on a future revive — every state-mutating command between
        checkpoints must journal."""
        reply = self._reliable(op, payload)
        return self._unwrap(op, payload, reply, journal)

    def _reliable(self, op: str, payload: Any) -> tuple:
        # One stopwatch for both accounting and tracing: elapsed_s is the
        # span's own duration (``timed`` measures even with tracer=None),
        # never a second perf_counter pair that could disagree with it.
        sw = _timed(self.tracer, "transport.roundtrip", tid=self.index,
                    shard=self.index, op=op)
        try:
            with sw:
                for attempt in range(self.max_retries + 1):
                    try:
                        if not self.channel.alive:
                            self._revive()
                        self.channel.send((op, payload))
                        return self.channel.recv(self.timeout)
                    except _TransportFailure as exc:
                        self.channel.kill()
                        if attempt >= self.max_retries:
                            raise TransportError(
                                f"shard {self.index}: {op!r} failed after "
                                f"{attempt} retries: {exc}") from exc
                        self.retries += 1
                        sw.set(retries=attempt + 1)
                        self._sleep(self.backoff_base
                                    * self.backoff_factor ** attempt)
        finally:
            self.elapsed_s += sw.dur

    def _unwrap(self, op: str, payload: Any, reply: tuple,
                journal: bool) -> Any:
        if reply[0] == "err":
            _, name, msg = reply
            raise LOGICAL_EXCEPTIONS.get(name, TransportError)(msg)
        self.calls += 1
        if journal:
            self.journal.append((op, payload))
        return reply[1]

    def _revive(self) -> None:
        """Respawn a dead worker and roll it forward: restore the last
        checkpoint, then replay the journaled mutations since (register /
        deregister / feed).  The command that observed the death is
        re-sent by the retry loop after this returns, so a shard killed
        mid-tick re-ticks from exactly its pre-tick state — committed
        windows are never re-vetted, pending ones never skipped."""
        self.respawns += 1
        self.channel.spawn()
        if self.checkpoint_blob is not None:
            self._roundtrip("restore", self.checkpoint_blob)
        for op, payload in self.journal:
            self._roundtrip(op, payload)
        if self.trace_enabled:
            # Not journaled (journals clear at checkpoints), so the fresh
            # worker must be told explicitly to keep tracing.
            self._roundtrip("trace", True)

    def _roundtrip(self, op: str, payload: Any) -> Any:
        # Replay primitive: transport failures propagate to the retry loop,
        # but a *logical* error here is fatal — a command that succeeded
        # before must succeed on replay, or snapshot and journal disagree.
        self.channel.send((op, payload))
        reply = self.channel.recv(self.timeout)
        if reply[0] == "err":
            raise TransportError(
                f"shard {self.index}: resume replay of {op!r} failed: "
                f"{reply[2]}")
        return reply[1]

    # ----------------------------------------------------- parallel ticks
    def tick_async(self, budget: Optional[int]) -> None:
        """Fire a tick round trip without blocking on the reply, so all
        shards vet concurrently; ``finish_tick`` completes it.  A failure
        here just marks the fast path dead — ``finish_tick`` falls back to
        the full reliable path (revive + retry)."""
        self._async_budget = budget
        self._async_sent = False
        sw = _timed(self.tracer, "transport.send", tid=self.index,
                    shard=self.index, op="tick")
        try:
            with sw:
                if not self.channel.alive:
                    self._revive()
                if self.tracer is not None:
                    # Driver-clock anchor for adopting this tick's
                    # worker-side spans (Tracer.adopt at=).
                    self.tick_sent_at = self.tracer.now()
                self.channel.send(("tick", budget))
                self._async_sent = True
        except _TransportFailure:
            self.channel.kill()
        finally:
            self.elapsed_s += sw.dur

    def finish_tick(self) -> TickReply:
        budget = self._async_budget
        self._async_budget = None
        if self._async_sent:
            sw = _timed(self.tracer, "transport.recv", tid=self.index,
                        shard=self.index, op="tick")
            try:
                with sw:
                    reply = self.channel.recv(self.timeout)
            except _TransportFailure:
                self.channel.kill()
                self.retries += 1
                self._sleep(self.backoff_base)
            else:
                return self._unwrap("tick", budget, reply, journal=False)
            finally:
                self.elapsed_s += sw.dur
        return self.call("tick", budget)

    def close(self) -> None:
        self.channel.close()


class TransportVetMux:
    """``ShardedVetMux`` across real worker processes.

    Drop-in at the sharded-fleet call sites (same
    ``register``/``feed``/``tick``/``flush``/``stats`` surface, same merged
    ``ShardTick``), with each shard mux living in its own long-lived
    worker process behind retries, checkpoints, and accounting — see the
    module docstring.  Close it when done (``close()`` / context manager):
    worker processes are daemonic but graceful shutdown beats reaping.

    Surface deltas forced by the process boundary, all loud:

    - ``register`` returns the chosen *shard index*, not a ``VetStream``
      (the stream lives in the worker); ``stream()`` raises with guidance;
      ``collect(sid)`` fetches a stream's full retained rows on demand;
      ``deregister`` ships the stream's state back and rebuilds it
      host-side, so churn still returns a usable ``VetStream``.
    - ``tick().results`` carries each stream's *newest-window* row only
      (one row per stream — exactly what ``vet_job``/``job_reduce`` fold),
      keeping tick round trips O(streams) scalars.
    - attaching an existing ``stream=`` is rejected: a live host-side
      stream cannot be pinned to another process's engine.

    Args:
        shards / engines / engine / backend / budget / tenant_weights /
            urgent_headroom / placement: exactly ``ShardedVetMux`` (engines
            may also be ``EngineSpec``s; a template ``engine``'s config is
            shipped, never the engine object).
        driver: ``"process"`` (real workers, default) or ``"inprocess"``
            (the same command stream against in-process workers — the
            differential oracle, and a no-multiprocessing fallback).
        max_retries: transport retries per round trip before
            ``TransportError`` (the retry budget).
        backoff_base / backoff_factor: exponential backoff schedule —
            attempt ``i`` sleeps ``backoff_base * backoff_factor ** i``.
        timeout: seconds to wait for any single reply (a hung worker is a
            transport failure: killed, revived, retried).
        checkpoint_every: pull shard checkpoints every N successful ticks
            (1 = after every tick, the tightest resume window; larger
            values trade checkpoint traffic for replaying more feeds —
            and re-vetting the un-checkpointed ticks' windows — on crash).
        mp_context: multiprocessing start method (default ``"spawn"``:
            fork-safety with jax in play; see ``repro.kernels.runtime``).
        sleep: backoff sleeper, injectable for tests.
        tracer: optional ``repro.obs.Tracer``.  When set, driver-side work
            traces onto pid 0 (``fleet.*`` on lane 0, ``transport.*`` on
            lane = shard index) and every worker is told to trace too —
            its spans ride back on each ``TickReply`` and are adopted into
            this tracer under pid ``shard + 1``, yielding one cross-process
            trace.

    Example::

        >>> fleet = TransportVetMux(2, backend="numpy", driver="inprocess")
        >>> for w in range(4):
        ...     _ = fleet.register(w, window=8, stride=4)
        >>> for w in range(4):
        ...     _ = fleet.feed(w, np.linspace(1e-3, 2e-3, 16) * (w + 1))
        >>> tick = fleet.tick()
        >>> (tick.rows, len(tick.shards), tick.vet_job >= 1.0)
        (12, 2, True)
        >>> fleet.close()
    """

    def __init__(self, shards: Optional[int] = None, *,
                 engines: Optional[Sequence[Union[VetEngine, EngineSpec]]]
                 = None,
                 engine: Optional[Union[VetEngine, EngineSpec]] = None,
                 backend: str = "jax",
                 budget: Optional[int] = None,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 urgent_headroom: int = 0,
                 placement: str = "pack",
                 driver: str = "process",
                 max_retries: int = 3,
                 backoff_base: float = 0.05,
                 backoff_factor: float = 2.0,
                 timeout: float = 60.0,
                 checkpoint_every: int = 1,
                 mp_context: Union[str, Any] = "spawn",
                 sleep: Callable[[float], None] = time.sleep,
                 tracer=None):
        if driver not in DRIVERS:
            raise ValueError(
                f"driver must be one of {DRIVERS}, got {driver!r}")
        if engines is not None and engine is not None:
            raise ValueError("pass engines= (one per shard) or engine= "
                             "(a template), not both")
        if engines is not None:
            engines = list(engines)
            if not engines:
                raise ValueError("engines must name at least one shard")
            if shards is not None and shards != len(engines):
                raise ValueError(
                    f"shards={shards} but {len(engines)} engines given")
            specs = [e if isinstance(e, EngineSpec)
                     else EngineSpec.from_engine(e) for e in engines]
        else:
            shards = 1 if shards is None else int(shards)
            if shards < 1:
                raise ValueError(f"shards must be >= 1, got {shards}")
            if engine is not None:
                spec = (engine if isinstance(engine, EngineSpec)
                        else EngineSpec.from_engine(engine))
            else:
                # ShardedVetMux's default shard engine: backend, buckets=64.
                spec = EngineSpec.from_engine(VetEngine(backend, buckets=64))
            specs = [spec] * shards
        if budget is not None:
            budget = int(budget)
            if budget < 1:
                raise ValueError(
                    f"budget must be >= 1 window row, got {budget}")
        checkpoint_every = int(checkpoint_every)
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1 tick, got {checkpoint_every}")
        self.budget = budget
        self.driver = driver
        self.checkpoint_every = checkpoint_every
        self._specs = specs
        self._placer = ShardPlacer(len(specs), placement)
        self._ticks = 0
        self._host_engine: Optional[VetEngine] = None
        tw = dict(tenant_weights or {})
        uh = int(urgent_headroom)
        if driver == "process":
            ctx = (mp.get_context(mp_context) if isinstance(mp_context, str)
                   else mp_context)
            channels = [_ProcessChannel(ctx, s, tw, uh) for s in specs]
        else:
            channels = [
                _LocalChannel(lambda s=s: ShardWorker(
                    s.build(), tenant_weights=tw, urgent_headroom=uh))
                for s in specs
            ]
        self._handles = [
            ShardHandle(k, ch, max_retries=max_retries,
                        backoff_base=backoff_base,
                        backoff_factor=backoff_factor, timeout=timeout,
                        sleep=sleep)
            for k, ch in enumerate(channels)
        ]
        # The pool starts now, once — workers are reused for the fleet's
        # lifetime (the initial spawn is not a respawn).
        for ch in channels:
            if not ch.alive:
                ch.spawn()
        self.tracer = None
        if tracer is not None:
            self.set_tracer(tracer)

    def set_tracer(self, tracer) -> None:
        """Attach (or detach, with ``None``) a driver-side tracer and toggle
        worker-side tracing to match.  The ``trace`` op round-trips now so
        workers start draining spans from the very next tick."""
        self.tracer = tracer
        enabled = tracer is not None
        for h in self._handles:
            h.tracer = tracer
            if h.trace_enabled != enabled:
                h.call("trace", enabled)
                h.trace_enabled = enabled

    def __repr__(self) -> str:
        return (f"TransportVetMux(shards={self.n_shards}, "
                f"driver={self.driver!r}, streams={len(self._placer.placed)}, "
                f"budget={self.budget}, ticks={self._ticks})")

    def __enter__(self) -> "TransportVetMux":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ----------------------------------------------------------- topology
    @property
    def n_shards(self) -> int:
        return len(self._handles)

    @property
    def placement(self) -> str:
        return self._placer.policy

    @property
    def assignment(self) -> Dict[Hashable, int]:
        """stream_id -> shard index, in registration order (a copy)."""
        return {sid: p.shard for sid, p in self._placer.placed.items()}

    def shard_of(self, stream_id: Hashable) -> int:
        return self._placer.shard_of(stream_id)

    def ids(self) -> Iterator[Hashable]:
        """Stream ids in registration order (across all shards)."""
        return iter(self._placer.placed)

    def __contains__(self, stream_id: Hashable) -> bool:
        return stream_id in self._placer.placed

    def __len__(self) -> int:
        return len(self._placer.placed)

    # ------------------------------------------------------- registration
    def register(self, stream_id: Hashable, *, window: Optional[int] = None,
                 stride: int = 1, capacity: Optional[int] = None,
                 history: Optional[int] = None, priority: float = 0.0,
                 tenant: str = "default", stream=None) -> int:
        """Register a stream on a deterministically chosen shard worker.

        Same placement as ``ShardedVetMux.register`` (shared placer) —
        returns the chosen shard index instead of the worker-resident
        ``VetStream``.
        """
        if stream is not None:
            raise ValueError(
                "attached streams cannot cross the process boundary; "
                "register with window geometry and let the shard worker "
                "build the stream on its own engine")
        if stream_id in self._placer.placed:
            raise ValueError(f"stream {stream_id!r} is already registered")
        if window is None:
            raise ValueError(
                "register needs window= (the shard worker creates the "
                "stream on its own engine)")
        window = int(window)
        cap = int(capacity) if capacity is not None else 4 * window
        weight = ShardPlacer.delta_weight(window, int(stride), cap)
        k = self._placer.choose(weight, window)
        self._handles[k].call(
            "register",
            {"sid": stream_id, "window": window, "stride": int(stride),
             "capacity": capacity, "history": history,
             "priority": float(priority), "tenant": str(tenant)},
            journal=True)
        self._placer.add(stream_id, k, weight, window)
        return k

    def deregister(self, stream_id: Hashable) -> VetStream:
        """Remove a stream; its full state ships back from the worker and
        is rebuilt host-side, so churn still returns a usable standalone
        ``VetStream`` (bound to a host engine of the same spec)."""
        k = self._placer.shard_of(stream_id)
        state = self._handles[k].call("deregister", stream_id, journal=True)
        self._placer.remove(stream_id)
        if self._host_engine is None:
            self._host_engine = self._specs[k].build()
        return VetStream.from_state(self._host_engine, state)

    def stream(self, stream_id: Hashable) -> VetStream:
        self._placer.require(stream_id)
        raise TypeError(
            f"stream {stream_id!r} lives in shard worker process "
            f"{self._placer.shard_of(stream_id)}; use collect(stream_id) "
            f"for its retained rows, or deregister(stream_id) to pull the "
            f"stream back into this process")

    def collect(self, stream_id: Hashable) -> Optional[BatchVetResult]:
        """Full retained rows for one stream, fetched from its shard
        worker (``None`` while no window is vetted).  The bulk path —
        tick results only carry newest-window rows."""
        k = self._placer.shard_of(stream_id)
        return self._handles[k].call("collect", stream_id)

    # ------------------------------------------------------------- ingest
    def feed(self, stream_id: Hashable, times) -> int:
        """Append a chunk to one stream in its shard worker.

        Ring pressure ticks the *owning worker's* mux locally (unbounded,
        correctness-driven), exactly like the in-process fleet — feeds
        never block on other shards.
        """
        k = self._placer.shard_of(stream_id)
        chunk = np.ascontiguousarray(np.asarray(times, dtype=np.float64))
        return self._handles[k].call("feed", (stream_id, chunk),
                                     journal=True)

    # --------------------------------------------------------------- tick
    def tick(self) -> ShardTick:
        """Fan a tick out to every shard worker in parallel, then merge.

        Same two-level budget water-fill as ``ShardedVetMux.tick`` (each
        shard reports pending demand, ``split_budget`` slices the job
        budget), with the per-shard ticks running concurrently in their
        worker processes.  After the merge, shards due a checkpoint are
        checkpointed and their journals cleared.
        """
        self._ticks += 1
        with _span(self.tracer, "fleet.tick", shards=self.n_shards,
                   streams=len(self._placer.placed)):
            with _span(self.tracer, "fleet.plan", shards=self.n_shards):
                if self.budget is None:
                    budgets: Tuple[Optional[int], ...] \
                        = (None,) * self.n_shards
                else:
                    demands = [h.call("demand", None) for h in self._handles]
                    budgets = tuple(split_budget(self.budget, demands))
            for h, b in zip(self._handles, budgets):
                h.tick_async(b)
            replies = [h.finish_tick() for h in self._handles]
            if self.tracer is not None:
                for h, r in zip(self._handles, replies):
                    # Worker spans rode back on the reply; re-anchor them to
                    # the driver clock at the moment this tick was sent, on
                    # the worker's own process lane.
                    self.tracer.adopt(r.spans, pid=h.index + 1,
                                      at=h.tick_sent_at,
                                      name=f"shard{h.index}")
            ticks = [self._as_mux_tick(r) for r in replies]
            self._checkpoint_due()
            results: Dict[Hashable, Optional[BatchVetResult]] = {}
            serviced: Dict[Hashable, int] = {}
            deferred: Dict[Hashable, int] = {}
            with _span(self.tracer, "fleet.merge", shards=self.n_shards):
                for sid, placed in self._placer.placed.items():  # reg. order
                    t = ticks[placed.shard]
                    results[sid] = t.results[sid]
                    if sid in t.serviced:
                        serviced[sid] = t.serviced[sid]
                    if sid in t.deferred:
                        deferred[sid] = t.deferred[sid]
        return ShardTick(
            results=results, serviced=serviced, deferred=deferred,
            urgent=tuple(sid for t in ticks for sid in t.urgent),
            dispatches=sum(t.dispatches for t in ticks),
            rows=sum(t.rows for t in ticks),
            padded_rows=sum(t.padded_rows for t in ticks),
            shards=tuple(ticks), budgets=budgets, accounts=self.accounts,
            flags=tuple(f for t in ticks for f in t.flags))

    @staticmethod
    def _as_mux_tick(reply: TickReply) -> MuxTick:
        results = {
            sid: (None if row is None else BatchVetResult(
                vet=np.asarray([row[0]]), ei=np.asarray([row[1]]),
                oc=np.asarray([row[2]]), pr=np.asarray([row[3]]),
                t=np.asarray([row[4]], dtype=np.int32),
                n=np.asarray([row[5]], dtype=np.int64)))
            for sid, row in reply.newest.items()
        }
        return MuxTick(results=results, serviced=reply.serviced,
                       deferred=reply.deferred, urgent=reply.urgent,
                       dispatches=reply.dispatches, rows=reply.rows,
                       padded_rows=reply.padded_rows,
                       flags=tuple(reply.flags))

    def _checkpoint_due(self) -> None:
        for h in self._handles:
            h.ticks_since_checkpoint += 1
            if h.ticks_since_checkpoint >= self.checkpoint_every:
                h.checkpoint_blob = h.call("checkpoint", None)
                h.journal.clear()
                h.ticks_since_checkpoint = 0
                h.checkpoints += 1

    def flush(self, max_ticks: int = 1_000_000) -> ShardTick:
        """Tick until no shard has deferred work; returns the last tick.
        At most ``max_ticks`` ticks, the first included — the same shared
        boundary as ``VetMux.flush`` / ``ShardedVetMux.flush``."""
        return _flush_loop(self.tick, max_ticks)

    # -------------------------------------------------------- observation
    @property
    def stats(self) -> MuxStats:
        """Merged lifetime counters, fetched live from every shard worker;
        ``retries``/``respawns`` report this driver's transport work."""
        per = [MuxStats(*h.call("stats", None)) for h in self._handles]
        return MuxStats(ticks=self._ticks,
                        dispatches=sum(s.dispatches for s in per),
                        rows=sum(s.rows for s in per),
                        padded_rows=sum(s.padded_rows for s in per),
                        deferred=sum(s.deferred for s in per),
                        streams=len(self._placer.placed),
                        retries=sum(h.retries for h in self._handles),
                        respawns=sum(h.respawns for h in self._handles),
                        anomalies=sum(s.anomalies for s in per))

    @property
    def shard_stats(self) -> Tuple[MuxStats, ...]:
        """Per-shard worker ``MuxStats``, in shard order."""
        return tuple(MuxStats(*h.call("stats", None))
                     for h in self._handles)

    @property
    def accounts(self) -> Tuple[ShardAccount, ...]:
        """Per-shard transport accounting so far, in shard order."""
        return tuple(h.account for h in self._handles)

    # -------------------------------------------------------------- misc
    def inject_fault(self, shard: int, at_tick: int,
                     mode: str = "before") -> None:
        """Arm a test-only crash in one shard worker (``WorkerFault``):
        the worker ``os._exit``s at its ``at_tick``-th tick command.
        Process driver only — the in-process oracle has nothing to kill."""
        if self.driver != "process":
            raise ValueError(
                "fault injection needs driver='process' (the in-process "
                "oracle has no worker to kill)")
        if mode not in ("before", "mid"):
            raise ValueError(f"fault mode must be 'before' or 'mid', "
                             f"got {mode!r}")
        self._handles[shard].call("fault", WorkerFault(int(at_tick), mode))

    def close(self) -> None:
        """Shut the worker pool down (graceful, then reaped).  Idempotent;
        also runs on context-manager exit."""
        for h in self._handles:
            h.close()
