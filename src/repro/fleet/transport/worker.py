"""Shard worker: one shard's ``VetMux`` served over a command connection.

``ShardWorker`` is the command executor — a thin op table over one shard
mux.  One implementation, two placements: the child-process loop
(``shard_worker_main``) and the driver's in-process oracle channel both
route commands through ``ShardWorker.handle``, so the transport
differential suite compares *drivers* (pipes, retries, checkpoints), never
two codepaths that could drift apart.
"""

from __future__ import annotations

import os
from typing import Any, Hashable, Optional

from ...obs.trace import Tracer
from ..mux import VetMux
from .proto import FAULT_EXIT, TickReply, WorkerFault

__all__ = ["ShardWorker", "shard_worker_main"]


class ShardWorker:
    """Executes transport commands against one shard mux.

    The mux is built unbudgeted: the job-level budget is water-filled by
    the driver and arrives as each ``tick`` command's payload (mirroring
    how ``ShardedVetMux`` sets ``m.budget`` around each fan-out tick), so
    worker-side pressure ticks stay unbounded — correctness-driven ring
    drains never truncate to a stale budget.
    """

    def __init__(self, engine, *, tenant_weights=None, urgent_headroom=0):
        self.mux = VetMux(engine, tenant_weights=tenant_weights,
                          urgent_headroom=urgent_headroom)
        self.tracer: Optional[Tracer] = None

    def handle(self, op: str, payload: Any) -> Any:
        return getattr(self, "_op_" + op)(payload)

    # -------------------------------------------------------- observability
    def _op_trace(self, enabled: bool) -> None:
        """Enable/disable worker-side tracing.  Completed spans ride back on
        every ``TickReply`` (drained per tick) and get adopted into the
        driver's trace under this shard's process lane.  NOT journaled by
        the driver (the journal clears at checkpoints); ``_revive`` re-sends
        it explicitly after a respawn."""
        if enabled and self.tracer is None:
            self.tracer = Tracer()
            self.mux.set_tracer(self.tracer)
        elif not enabled and self.tracer is not None:
            self.tracer = None
            self.mux.set_tracer(None)

    # ------------------------------------------------------ mux surface
    def _op_register(self, payload: dict) -> None:
        kw = dict(payload)
        self.mux.register(kw.pop("sid"), **kw)

    def _op_deregister(self, sid: Hashable) -> dict:
        # The stream leaves this process: ship its full state back so the
        # driver can rebuild it host-side (VetStream.from_state).
        return self.mux.deregister(sid).state_dict()

    def _op_feed(self, payload) -> int:
        sid, chunk = payload
        return self.mux.feed(sid, chunk)

    def _op_demand(self, _payload) -> int:
        # Total pending window rows — this shard's input to the driver's
        # split_budget water-fill (same census ShardedVetMux.tick takes).
        return sum(self.mux.stream(sid).pending_windows
                   for sid in self.mux.ids())

    def _op_tick(self, budget: Optional[int]) -> TickReply:
        self.mux.budget = budget
        try:
            t = self.mux.tick()
        finally:
            self.mux.budget = None  # pressure ticks between fan-outs: unbounded
        newest = {}
        for sid, res in t.results.items():
            newest[sid] = (None if res is None or res.workers == 0 else
                           (float(res.vet[-1]), float(res.ei[-1]),
                            float(res.oc[-1]), float(res.pr[-1]),
                            int(res.t[-1]), int(res.n[-1])))
        return TickReply(newest=newest, serviced=dict(t.serviced),
                         deferred=dict(t.deferred), urgent=tuple(t.urgent),
                         dispatches=t.dispatches, rows=t.rows,
                         padded_rows=t.padded_rows, flags=t.flags,
                         spans=(tuple(self.tracer.drain())
                                if self.tracer is not None else ()))

    def _op_collect(self, sid: Hashable):
        # Full retained rows for one stream (BatchVetResult or None) — the
        # on-demand bulk path the differential suite uses.
        return self.mux.stream(sid).collect()

    # ------------------------------------------------- crash recovery
    def _op_checkpoint(self, _payload) -> dict:
        return self.mux.state_dict()

    def _op_restore(self, state: dict) -> None:
        self.mux.load_state_dict(state)

    def _op_stats(self, _payload):
        return self.mux.stats


def shard_worker_main(conn, spec, tenant_weights, urgent_headroom,
                      platform_hint) -> None:
    """Entry point of one shard worker process (the multiprocessing target).

    Blocks on the pipe for ``(op, payload)`` commands, executes them
    through a ``ShardWorker``, and replies ``("ok", value)`` or
    ``("err", exc_type_name, message)``.  The loop exits on ``shutdown``
    or a closed pipe (driver gone).

    ``platform_hint`` seeds ``repro.kernels.runtime`` with the parent's
    already-probed Pallas platform policy, so the worker never runs jax
    backend discovery itself (``REPRO_PALLAS_INTERPRET``, inherited via the
    environment, still overrides).

    Fault injection (tests only): a ``fault`` command arms a
    ``WorkerFault``; at the armed tick the process ``os._exit``s —
    ``"before"`` loses the tick entirely, ``"mid"`` computes and commits it
    first but dies before replying (see ``proto.WorkerFault``).
    """
    from ...kernels import runtime
    runtime.seed_platform_default(platform_hint)
    worker = ShardWorker(spec.build(), tenant_weights=tenant_weights,
                         urgent_headroom=urgent_headroom)
    armed: Optional[WorkerFault] = None
    ticks = 0
    while True:
        try:
            op, payload = conn.recv()
        except (EOFError, OSError):
            break
        if op == "shutdown":
            conn.send(("ok", None))
            break
        if op == "fault":
            armed = payload
            conn.send(("ok", None))
            continue
        try:
            if op == "tick":
                ticks += 1
                if armed is not None and ticks == armed.at_tick:
                    if armed.mode != "before":
                        worker.handle(op, payload)  # committed, reply lost
                    os._exit(FAULT_EXIT)
            value = worker.handle(op, payload)
        except Exception as exc:  # ship it; the driver re-raises by name
            conn.send(("err", type(exc).__name__, str(exc)))
        else:
            conn.send(("ok", value))
    conn.close()
