"""Wire protocol for the cross-process shard transport.

Everything that crosses a shard worker's pipe is defined here, so the
driver (``repro.fleet.transport.driver``) and the worker loop
(``repro.fleet.transport.worker``) agree by construction:

- **Commands** are ``(op, payload)`` tuples.  The ops mirror the in-process
  mux surface (``register`` / ``deregister`` / ``feed`` / ``tick`` /
  ``collect`` / ``stats``) plus the transport-only lifecycle ops
  (``demand`` for budget water-filling, ``checkpoint`` / ``restore`` for
  crash recovery, ``fault`` for test-only crash injection, ``shutdown``).
- **Replies** are ``("ok", value)`` or ``("err", exc_type_name, message)``.
  A logical error — bad stream id, ring overrun, stale delta — crosses the
  pipe *by name* and re-raises driver-side as its original exception type
  (``LOGICAL_EXCEPTIONS``); it is never retried, because re-sending a
  command the worker correctly rejected cannot succeed.  Only *transport*
  failures (dead process, broken pipe, reply timeout) are retryable.
- **Tick replies ship scalars, not row arrays.**  A shard reduces its tick
  to per-stream newest-window rows (six floats each — exactly what
  ``job_reduce`` folds into a ``JobVet`` partial) plus the service /
  deferral / dispatch counters, so a tick round trip is O(streams) small
  values no matter how many window rows the shard vetted.  Full retained
  rows stay in the worker; ``collect`` fetches them on demand (the
  differential suite does, dashboards should not).
"""

from __future__ import annotations

from typing import Dict, Hashable, NamedTuple, Optional, Tuple

__all__ = [
    "EngineSpec",
    "FAULT_EXIT",
    "LOGICAL_EXCEPTIONS",
    "NewestRow",
    "ShardAccount",
    "TickReply",
    "TransportError",
    "WorkerFault",
]

# Exit code of a fault-injected worker death (distinguishable from a real
# crash in test output).
FAULT_EXIT = 17

# Exception types a worker may raise logically; they cross the pipe by
# name and re-raise driver-side as themselves.  Anything unlisted arrives
# as TransportError (still not retried — the reply did arrive).
LOGICAL_EXCEPTIONS = {
    "ValueError": ValueError,
    "KeyError": KeyError,
    "TypeError": TypeError,
    "RuntimeError": RuntimeError,
    "IndexError": IndexError,
    "OverflowError": OverflowError,
}


class TransportError(RuntimeError):
    """A shard worker failed beyond its transport retry budget (the process
    kept dying or hanging), or a checkpoint-resume replay diverged.
    Logical errors are not transport errors — they re-raise as their
    original type and consume no retries."""


class EngineSpec(NamedTuple):
    """Pickle-safe constructor recipe for a shard worker's ``VetEngine``.

    Engines themselves never cross the pipe — compiled functions, result
    caches and dispatch counters are per-process artifacts — so the driver
    ships the configuration and each worker builds its own engine from it.
    ``interpret`` carries the *unresolved* argument (``None`` = platform
    policy): the worker re-resolves it locally, seeded with the parent's
    probed platform so it never runs backend discovery itself
    (``repro.kernels.runtime.seed_platform_default``); exporting
    ``REPRO_PALLAS_INTERPRET`` — inherited through the worker's environment
    — overrides every worker at once.
    """

    backend: str
    omega: int
    buckets: Optional[int]
    cut_space: str
    interpret: Optional[bool]
    fused: bool
    cache_size: int

    @classmethod
    def from_engine(cls, engine) -> "EngineSpec":
        return cls(backend=engine.backend, omega=engine.omega,
                   buckets=engine.buckets, cut_space=engine.cut_space,
                   interpret=engine._interpret_arg, fused=engine.fused,
                   cache_size=engine._cache_size)

    def build(self):
        from ...engine import VetEngine
        return VetEngine(self.backend, omega=self.omega, buckets=self.buckets,
                         cut_space=self.cut_space, interpret=self.interpret,
                         fused=self.fused, cache_size=self.cache_size)


# (vet, ei, oc, pr, t, n) of a stream's newest complete window — the
# scalars job_reduce needs, in BatchVetResult field order.
NewestRow = Tuple[float, float, float, float, int, int]


class TickReply(NamedTuple):
    """One shard's tick outcome as shipped back over the pipe.

    ``newest[sid]`` is the stream's newest-window row (``None`` while the
    stream has no complete window); the remaining fields are the shard
    ``MuxTick``'s counters verbatim.  The driver rebuilds a one-row
    ``MuxTick`` per shard from this, so ``ShardTick.job`` / ``vet_job``
    merge identically to the in-process fleet.
    """

    newest: Dict[Hashable, Optional[NewestRow]]
    serviced: Dict[Hashable, int]
    deferred: Dict[Hashable, int]
    urgent: Tuple[Hashable, ...]
    dispatches: int
    rows: int
    padded_rows: int
    # Regime-shift flags the worker-side anomaly monitor raised this tick
    # (repro.fleet.anomaly.RegimeShift is a top-level NamedTuple, so the
    # tuple pickles over the pipe as-is).  Appended with a default so a
    # checkpoint journal recorded before this field replays cleanly.
    flags: tuple = ()
    # Worker-side SpanRecords drained since the last reply (empty unless
    # the driver enabled tracing via the ``trace`` op).  Appended after
    # ``flags`` with a default for the same journal-replay compatibility.
    spans: tuple = ()


class ShardAccount(NamedTuple):
    """Per-shard end-of-run transport accounting
    (``TransportVetMux.accounts`` / ``ShardTick.accounts``)."""

    calls: int  # commands completed successfully (round trips)
    retries: int  # round trips re-attempted after a transport failure
    respawns: int  # worker processes restarted after a crash/hang
    checkpoints: int  # checkpoints taken
    elapsed_s: float  # wall-clock spent in round trips to this shard


class WorkerFault(NamedTuple):
    """Test-only crash injection, armed via the ``fault`` command.

    The worker ``os._exit``s at its ``at_tick``-th tick command:
    ``"before"`` dies before any work (the tick is lost entirely),
    ``"mid"`` dies after the shard mux computed *and committed* the tick
    but before any reply or checkpoint leaves the process — the torn
    dispatch that checkpoint-resume must absorb without re-vetting
    committed windows or skipping any.
    """

    at_tick: int  # 1-based count of tick commands in the worker's life
    mode: str = "before"  # "before" | "mid"
