"""repro.fleet — cross-stream vet multiplexing for live fleets.

The paper's measure only pays off operationally when it is computed
continuously for *every* task in a job (vet_job = mean over tasks, §4.4);
cluster-scale what-if analysis needs per-task profiles across hundreds of
concurrent slots.  One ``VetStream`` per consumer keeps each profile
incremental, but ticking N isolated streams in a Python loop costs O(N)
separate engine dispatches per decision — the scaling wall between "a few
dozen workers" and "as fast as the hardware allows".

This package is the layer between the streams and the engine:

- ``VetMux`` (``repro.fleet.mux``) registers many streams (heterogeneous
  window/stride/capacity/history), drains each stream's newly-complete
  window delta per tick, coalesces the deltas across *all* streams into
  shape-bucketed, pow2-padded batched dispatches — one compiled call per
  distinct window length per tick — and commits each stream's slice back, so
  every stream's rows stay equal to its own independent ``tick()`` (bitwise
  on numpy, 1e-5 on jax/pallas; ``tests/test_fleet.py``).
- ``repro.fleet.schedule`` is the tick planner: staleness-aged priority
  ordering, per-tenant weighted fairness quotas, ring-overrun urgency
  override, and budget backpressure with explicit deferral.
- ``repro.fleet.scenarios`` is the seed-stable scenario bank (uniform fleet,
  skewed stragglers, bursty arrivals, mixed window sizes, churn) that both
  the differential suites and ``benchmarks/fleet.py`` drive; the benchmark
  shows the mux cutting engine dispatches per fleet tick by the fleet size
  (>= 10x floor pinned in ``tests/test_benchmark_results_schema.py``) at
  256-1024 simulated workers.
- ``ShardedVetMux`` (``repro.fleet.shard``) partitions a fleet across K
  shard muxes — each with its own ``VetEngine``, modeling separate
  processes/hosts — behind the same register/feed/tick/flush/stats surface:
  deterministic placement (greedy bin-packing by expected delta size with
  window-length affinity, or round-robin), job-budget water-filling across
  shards (``schedule.split_budget``), and per-shard ``JobVet`` partials
  merged into the job-level ``vet_job`` exactly as a cross-process reducer
  would (``tests/test_fleet_shard.py`` locks rows to the single-mux oracle
  and the merged vet_job to 1e-9).
- ``TransportVetMux`` (``repro.fleet.transport``) moves those shards into
  real worker processes behind the same surface: one long-lived worker per
  shard driven over duplex pipes, retries with exponential backoff under a
  retry budget, periodic checkpoint + command-journal resume so a shard
  killed mid-tick recovers without re-vetting committed windows, and
  per-shard accounting on every tick (``tests/test_fleet_transport.py``
  locks the process driver to the in-process fleet across the scenario
  bank, including kill-mid-tick recovery).
- ``AnomalyMonitor`` (``repro.fleet.anomaly``) rides every mux tick and
  runs the change-point scan on each stream's own committed vet series —
  bounded log-vet ring per stream, level-ratio + consecutive-scan
  confirmation gates against the heavy tail — raising ``RegimeShift``
  flags on ``MuxTick``/``ShardTick`` and counting them in
  ``MuxStats.anomalies`` through single, sharded and transport fleets.
  The anomaly scenario bank (``ANOMALY_SCENARIOS``, after arXiv:1505.01919)
  injects contention onset, node degradation, fail+restart, diurnal load
  and hardware-tier migration with known onsets;
  ``tests/test_fleet_anomaly.py`` pins ±2-tick localization on every
  backend.

- ``repro.fleet.knobs`` is the write-back seam for the online autotuner
  (``repro.sched.tuner``): ``Knob``/``KnobHooks`` bind named, grid-valued
  fleet knobs to validated setter/getter pairs so a tuner can apply budget
  or workload changes between ticks and snapshot/rollback them safely;
  ``mux_knob_hooks`` wires the per-tick ``tick_budget`` knob of any mux
  variant, and ``scenarios.tunable()`` is the knob-sensitive simulator
  workload (known optimum) that locks the whole loop differentially.

Routed consumers: ``repro.sched.straggler.VetController`` (one mux across
all workers — ``decide()`` is one coalesced dispatch set instead of a
per-worker loop) and ``repro.launch.serve`` (dashboard window snapshots
ticked through a mux inside the decode loop).
"""

from .anomaly import AnomalyMonitor, RegimeShift
from .knobs import Knob, KnobHooks, mux_knob_hooks
from .mux import MuxStats, MuxTick, VetMux
from .scenarios import (
    ANOMALY_SCENARIOS,
    SCENARIOS,
    FleetEvent,
    FleetScenario,
    StreamSpec,
    TunableScenario,
    build,
    play,
    tunable,
)
from .schedule import StreamRequest, TickPlan, plan_tick, split_budget
from .shard import (
    JobVet,
    ShardPlacer,
    ShardTick,
    ShardedVetMux,
    job_reduce,
    merge_job,
)
from .transport import (
    EngineSpec,
    ShardAccount,
    TransportError,
    TransportVetMux,
)

__all__ = [
    "ANOMALY_SCENARIOS",
    "SCENARIOS",
    "AnomalyMonitor",
    "EngineSpec",
    "RegimeShift",
    "FleetEvent",
    "FleetScenario",
    "JobVet",
    "Knob",
    "KnobHooks",
    "MuxStats",
    "MuxTick",
    "ShardAccount",
    "ShardPlacer",
    "ShardTick",
    "ShardedVetMux",
    "StreamRequest",
    "StreamSpec",
    "TickPlan",
    "TransportError",
    "TransportVetMux",
    "TunableScenario",
    "VetMux",
    "build",
    "job_reduce",
    "merge_job",
    "mux_knob_hooks",
    "plan_tick",
    "play",
    "split_budget",
    "tunable",
]
