"""VetMux: coalesce many live streams into shared batched engine dispatches.

One live consumer = one ``VetStream``; a fleet of N consumers ticked one at a
time pays N separate engine dispatches per decision — the O(workers) Python
loop that caps a controller at a few dozen workers.  The mux replaces the
loop with a three-phase tick over every registered stream:

1. **Plan** (``repro.fleet.schedule``): pending window counts, priorities,
   staleness and ring headroom go through the tick planner, which orders the
   fleet, applies per-tenant fairness quotas, serves overrun-risk streams
   first, and defers whatever exceeds the tick ``budget``.
2. **Drain + coalesce**: each serviced stream's delta (``VetStream.drain``)
   is grouped with every other delta of the same window length into a shape
   bucket; each bucket's matrices concatenate into one (rows, window) batch,
   padded to the next power of two rows so jit compiles stay O(log fleet)
   instead of one per distinct row count.
3. **Dispatch + commit**: one ``VetEngine`` call per shape bucket — a
   homogeneous 1024-worker fleet is *one* compiled call per tick — and each
   stream commits its slice of the result (``VetStream.commit``).  Rows are
   bitwise what the stream's own ``tick()`` would have computed on the numpy
   backend (row-independent scalar loop) and within the standing 1e-5
   differential contract on jax/pallas (vmap rows are independent), so the
   per-stream oracle equality is preserved — locked by
   ``tests/test_fleet.py`` across the scenario bank.

Caching composes: each coalesced dispatch is memoized in the engine's result
cache under the tuple of its member deltas' content-pure keys, so replaying
the same fleet into the same engine serves whole mux ticks from cache without
hashing a single matrix.

``feed`` mirrors ``VetStream.feed`` but under ring pressure triggers a *mux*
tick (coalesced) instead of a per-stream one, so even overrun protection
never degenerates into scalar dispatches.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, Iterator, List, NamedTuple, Optional, Tuple

import numpy as np

from ..engine import BatchVetResult, VetEngine, VetStream, default_engine
from ..engine.stream import RingDelta, StreamDelta
from ..obs.trace import span as _span
from .anomaly import AnomalyMonitor, RegimeShift, default_monitor
from .schedule import StreamRequest, TickPlan, plan_tick

__all__ = ["MuxStats", "MuxTick", "VetMux"]


class MuxStats(NamedTuple):
    """Lifetime counters for one mux (``VetMux.stats``).

    ``retries``/``respawns`` are transport accounting
    (``repro.fleet.transport``): an in-process mux never retries or
    respawns anything, so they default to 0 and only the cross-process
    driver reports non-zero values.  ``anomalies`` counts regime-shift
    flags raised by the anomaly monitor (0 when monitoring is off).
    """

    ticks: int  # mux ticks
    dispatches: int  # coalesced engine dispatches issued
    rows: int  # window rows committed across all streams
    padded_rows: int  # pow2-padding overhead rows ever dispatched
    deferred: int  # window-row deferrals (sum over ticks)
    streams: int  # currently registered streams
    retries: int = 0  # transport round trips re-attempted after a failure
    respawns: int = 0  # shard worker processes restarted after a crash
    anomalies: int = 0  # regime-shift flags raised (repro.fleet.anomaly)


def _flush_loop(tick_fn, max_ticks: int):
    """Shared flush driver for every mux variant (``VetMux``,
    ``ShardedVetMux``, ``TransportVetMux``): tick until nothing is
    deferred, performing **at most** ``max_ticks`` ticks total — the
    initial tick included.  The variants used to decrement their own
    ``max_ticks`` argument around the loop and disagreed about whether the
    pre-loop tick counted; one helper, one boundary.

    Raises:
        ValueError: ``max_ticks < 1`` (a flush always ticks at least once).
        RuntimeError: backlog still deferred after ``max_ticks`` ticks.
    """
    max_ticks = int(max_ticks)
    if max_ticks < 1:
        raise ValueError(f"flush needs max_ticks >= 1, got {max_ticks}")
    tick = tick_fn()
    done = 1
    while tick.deferred:
        if done >= max_ticks:
            raise RuntimeError(
                f"flush did not converge within {max_ticks} ticks — is new "
                f"work arriving concurrently?")
        tick = tick_fn()
        done += 1
    return tick


class MuxTick(NamedTuple):
    """One mux tick's outcome.

    ``results[sid]`` is the stream's retained-window result (same object
    contract as ``VetStream.tick()``: ``None`` until the first window
    completes, the previous object when nothing changed).  ``flags`` holds
    the regime shifts the anomaly monitor raised *this tick* (empty when
    monitoring is off or the fleet is steady).
    """

    results: Dict[Hashable, Optional[BatchVetResult]]
    serviced: Dict[Hashable, int]  # stream -> window rows dispatched this tick
    deferred: Dict[Hashable, int]  # stream -> pending rows pushed to later ticks
    urgent: Tuple[Hashable, ...]  # streams served out-of-budget (overrun risk)
    dispatches: int  # engine dispatches this tick (== shape buckets hit)
    rows: int  # window rows committed this tick
    padded_rows: int  # pow2-padding overhead rows this tick
    flags: Tuple[RegimeShift, ...] = ()  # regime shifts raised this tick

    @property
    def vet_job(self) -> float:
        """Fleet-level vet_job: mean of every stream's newest window vet
        (paper §4.4 across the live fleet)."""
        newest = [float(r.vet[-1]) for r in self.results.values()
                  if r is not None and r.workers > 0]
        if not newest:
            raise ValueError("no stream has a complete window yet")
        return float(np.mean(newest))


class _Member:
    """Registration record for one stream."""

    __slots__ = ("stream", "priority", "tenant", "staleness")

    def __init__(self, stream: VetStream, priority: float, tenant: str):
        self.stream = stream
        self.priority = priority
        self.tenant = tenant
        self.staleness = 0


class VetMux:
    """Cross-stream vet multiplexer over one shared ``VetEngine``.

    Usage::

        mux = VetMux(engine, budget=256)
        for wid in workers:
            mux.register(wid, window=200, stride=100)
        while serving:
            for wid, chunk in arrivals:
                mux.feed(wid, chunk)
            tick = mux.tick()              # one dispatch per window-length
            dashboard.update(tick.vet_job, tick.results)

    ``budget`` caps window rows vetted per tick (``None`` = unbounded);
    ``tenant_weights`` biases the fairness split (default: equal);
    ``urgent_headroom`` is the ring headroom at or below which a stream is
    served in full regardless of budget (see ``repro.fleet.schedule``);
    ``monitor`` is the anomaly monitor — ``True`` (default) builds one
    matched to the engine backend, ``False``/``None`` disables monitoring,
    or pass a configured ``repro.fleet.AnomalyMonitor``.
    """

    def __init__(self, engine: Optional[VetEngine] = None, *,
                 budget: Optional[int] = None,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 urgent_headroom: int = 0,
                 monitor=True,
                 tracer=None):
        self.engine = engine if engine is not None else default_engine("jax")
        if budget is not None:
            budget = int(budget)
            if budget < 1:
                raise ValueError(f"budget must be >= 1 window row, got {budget}")
        self.budget = budget
        self.tenant_weights = dict(tenant_weights or {})
        self.urgent_headroom = int(urgent_headroom)
        if monitor is True:
            monitor = default_monitor(self.engine.backend)
        elif not monitor:
            monitor = None
        self.monitor: Optional[AnomalyMonitor] = monitor
        # Observability seam (repro.obs).  Only a non-None tracer is wired
        # through: attaching goes down to the engine, and the (possibly
        # process-wide default_engine) must not lose a tracer some other
        # consumer attached just because an untraced mux was built on it.
        self.tracer = None
        self.trace_tid = 0
        if tracer is not None:
            self.set_tracer(tracer)
        self._members: "OrderedDict[Hashable, _Member]" = OrderedDict()
        self._ticks = 0
        self._dispatches = 0
        self._rows = 0
        self._padded_rows = 0
        self._deferred = 0

    def __repr__(self) -> str:
        return (f"VetMux(backend={self.engine.backend!r}, "
                f"streams={len(self._members)}, budget={self.budget}, "
                f"ticks={self._ticks})")

    def set_tracer(self, tracer, tid: int = 0) -> None:
        """Attach (or detach, with ``None``) a ``repro.obs.Tracer``.  Spans
        from this mux — and from its engine and every stream it drains —
        land on lane ``tid`` (the shard index in a sharded fleet)."""
        self.tracer = tracer
        self.trace_tid = int(tid)
        self.engine.set_tracer(tracer, tid=tid)

    # -------------------------------------------------------- registration
    def register(self, stream_id: Hashable, *, window: Optional[int] = None,
                 stride: int = 1, capacity: Optional[int] = None,
                 history: Optional[int] = None, priority: float = 0.0,
                 tenant: str = "default",
                 stream: Optional[VetStream] = None) -> VetStream:
        """Add a stream to the fleet; returns the (created) ``VetStream``.

        Either pass the window geometry (``window``/``stride``/``capacity``/
        ``history``) and let the mux create the stream on its engine, or pass
        an existing ``stream`` — which must already be bound to the mux's
        engine, because coalesced dispatches run on exactly one engine.

        Args:
            stream_id: any hashable fleet-unique id.
            window / stride / capacity / history: ``VetStream`` geometry
                (used only when ``stream`` is not given).
            priority / tenant: planner inputs (see ``repro.fleet.schedule``).
            stream: an existing stream to attach instead.

        Returns:
            The registered ``VetStream``.

        Raises:
            ValueError: duplicate id, missing ``window`` and ``stream``, or
                an attached stream bound to a different engine.

        Example::

            >>> mux = VetMux(VetEngine("numpy", buckets=64))
            >>> st = mux.register("w0", window=8, stride=4)
            >>> st.window, len(mux), "w0" in mux
            (8, 1, True)
        """
        if stream_id in self._members:
            raise ValueError(f"stream {stream_id!r} is already registered")
        if stream is None:
            if window is None:
                raise ValueError(
                    "register needs window= (to create the stream) or "
                    "stream= (to attach an existing one)")
            stream = VetStream(self.engine, window=window, stride=stride,
                               capacity=capacity, history=history)
        elif stream.engine is not self.engine:
            raise ValueError(
                "attached stream must share the mux engine (coalesced "
                "dispatches run on one engine); build it with "
                "VetStream(mux.engine, ...)")
        self._members[stream_id] = _Member(stream, float(priority),
                                           str(tenant))
        return stream

    def deregister(self, stream_id: Hashable) -> VetStream:
        """Remove a stream (fleet churn); returns it for the caller to keep
        using standalone — its retained rows and vetted watermark survive.

        Raises:
            KeyError: unknown ``stream_id``.

        Example::

            >>> mux = VetMux(VetEngine("numpy", buckets=64))
            >>> st = mux.register("w0", window=8, stride=4)
            >>> mux.deregister("w0") is st and len(mux) == 0
            True
        """
        member = self._members.pop(self._require(stream_id))
        if self.monitor is not None:
            self.monitor.forget(stream_id)
        return member.stream

    def _require(self, stream_id: Hashable) -> Hashable:
        if stream_id not in self._members:
            raise KeyError(f"stream {stream_id!r} is not registered "
                           f"({len(self._members)} streams live)")
        return stream_id

    def stream(self, stream_id: Hashable) -> VetStream:
        return self._members[self._require(stream_id)].stream

    def ids(self) -> Iterator[Hashable]:
        return iter(self._members)

    def __contains__(self, stream_id: Hashable) -> bool:
        return stream_id in self._members

    def __len__(self) -> int:
        return len(self._members)

    @property
    def stats(self) -> MuxStats:
        return MuxStats(ticks=self._ticks, dispatches=self._dispatches,
                        rows=self._rows, padded_rows=self._padded_rows,
                        deferred=self._deferred, streams=len(self._members),
                        anomalies=(self.monitor.raised
                                   if self.monitor is not None else 0))

    # ------------------------------------------------------------- ingest
    def feed(self, stream_id: Hashable, times) -> int:
        """Append a chunk to one stream, mux-ticking only under ring pressure.

        The fleet analogue of ``VetStream.feed``: when the stream's append
        budget is exhausted, the *whole mux* ticks (one coalesced dispatch
        set — every stream with pending windows benefits) instead of the
        stream paying a private scalar-sized dispatch.

        Args:
            stream_id: a registered stream.
            times: 1-D chunk of record times, arbitrarily large.

        Returns:
            Number of records appended.

        Raises:
            KeyError: unknown ``stream_id``.

        Example::

            >>> mux = VetMux(VetEngine("numpy", buckets=64))
            >>> _ = mux.register("w0", window=8, stride=4, capacity=16)
            >>> mux.feed("w0", np.linspace(1e-3, 2e-3, 100))  # 6x the ring
            100
        """
        return self.stream(stream_id).feed(times, on_pressure=self.tick)

    # -------------------------------------------------------------- tick
    def tick(self) -> MuxTick:
        """Drain every stream's newly complete windows through shared
        batched dispatches; see the module docstring for the three phases.

        Returns:
            The merged ``MuxTick``: per-stream retained results, service /
            deferral maps, and this tick's dispatch/row counters.

        Example::

            >>> mux = VetMux(VetEngine("numpy", buckets=64))
            >>> for sid in ("a", "b"):
            ...     _ = mux.register(sid, window=8, stride=4)
            ...     _ = mux.feed(sid, np.linspace(1e-3, 2e-3, 16))
            >>> t = mux.tick()
            >>> (t.rows, t.dispatches)     # 2 streams, ONE shared dispatch
            (6, 1)
            >>> t.results["a"].workers, t.vet_job >= 1.0
            (3, True)
        """
        self._ticks += 1
        tick_span = _span(self.tracer, "mux.tick", tid=self.trace_tid,
                          streams=len(self._members))
        with tick_span:
            with _span(self.tracer, "mux.plan", tid=self.trace_tid):
                requests = [
                    StreamRequest(stream_id=sid,
                                  pending=m.stream.pending_windows,
                                  priority=m.priority, tenant=m.tenant,
                                  staleness=m.staleness,
                                  headroom=m.stream.headroom)
                    for sid, m in self._members.items()
                ]
                plan = plan_tick(requests, budget=self.budget,
                                 tenant_weights=self.tenant_weights,
                                 urgent_headroom=self.urgent_headroom)

            dispatches = rows = padded = 0
            serviced: Dict[Hashable, int] = {}

            # Fused path: when the engine's block-sparse kernel covers every
            # window length planned for service, the whole ragged tick is ONE
            # launch — the per-length shape buckets below collapse into a
            # single concatenated arena with a row -> (stream, window) map.
            fused = bool(plan.serve) and self.engine.fused_supported(
                max(self._members[sid].stream.window for sid in plan.serve))
            if fused:
                with _span(self.tracer, "mux.coalesce", tid=self.trace_tid,
                           fused=True) as co:
                    ring: List[Tuple[Hashable, RingDelta]] = []
                    for sid, take in plan.serve.items():
                        delta = self._members[sid].stream.drain_ring(
                            max_windows=take)
                        if delta is not None:
                            ring.append((sid, delta))
                    if ring:
                        offsets = np.cumsum(
                            [0] + [d.arena.size for _, d in ring[:-1]])
                        arena = np.concatenate([d.arena for _, d in ring])
                        starts = np.concatenate(
                            [d.starts + off
                             for (_, d), off in zip(ring, offsets)])
                        lengths = np.concatenate(
                            [np.full(d.count, d.window, dtype=np.int64)
                             for _, d in ring])
                    co.set(streams=len(ring))
                if ring:
                    key = ("muxfused", tuple(d.key for _, d in ring))
                    with _span(self.tracer, "mux.dispatch",
                               tid=self.trace_tid, fused=True,
                               rows=int(starts.size)):
                        res = self.engine._memo(
                            key, lambda: self.engine._vet_arena_impl(
                                arena, starts, lengths))
                    dispatches += 1
                    with _span(self.tracer, "mux.commit",
                               tid=self.trace_tid, streams=len(ring)):
                        off = 0
                        for sid, delta in ring:
                            seg = BatchVetResult(
                                *(a[off:off + delta.count] for a in res))
                            self._members[sid].stream.commit(delta, seg)
                            serviced[sid] = delta.count
                            off += delta.count
                            rows += delta.count

            # Drain in plan order, bucket by window length (the matrix column
            # count) — heterogeneous fleets dispatch once per distinct length.
            buckets: "OrderedDict[int, List[Tuple[Hashable, StreamDelta]]]" \
                = OrderedDict()
            if not fused:
                with _span(self.tracer, "mux.coalesce", tid=self.trace_tid,
                           fused=False):
                    for sid, take in plan.serve.items():
                        delta = self._members[sid].stream.drain(
                            max_windows=take)
                        if delta is not None:
                            buckets.setdefault(
                                delta.matrix.shape[1], []).append(
                                    (sid, delta))

            for wlen, group in buckets.items():
                big = (group[0][1].matrix if len(group) == 1
                       else np.concatenate([d.matrix for _, d in group]))
                # Same pow2 padding contract as VetStream.tick: compiled
                # batch shapes stay O(log fleet) as deltas fluctuate tick to
                # tick.
                big, pad_rows = self.engine.pad_rows_pow2(big)
                padded += pad_rows
                key = ("mux", wlen, tuple(d.key for _, d in group))
                with _span(self.tracer, "mux.dispatch", tid=self.trace_tid,
                           wlen=int(wlen), rows=int(big.shape[0])):
                    res = self.engine._memo(
                        key, lambda big=big: self.engine._vet_batch_impl(big))
                dispatches += 1
                with _span(self.tracer, "mux.commit", tid=self.trace_tid,
                           streams=len(group)):
                    off = 0
                    for sid, delta in group:
                        seg = BatchVetResult(
                            *(a[off:off + delta.count] for a in res))
                        self._members[sid].stream.commit(delta, seg)
                        serviced[sid] = delta.count
                        off += delta.count
                        rows += delta.count

            results: Dict[Hashable, Optional[BatchVetResult]] = {}
            deferred: Dict[Hashable, int] = {}
            flags: List[RegimeShift] = []
            with _span(self.tracer, "mux.collect", tid=self.trace_tid):
                for sid, m in self._members.items():
                    results[sid] = m.stream.collect()
                    left = m.stream.pending_windows
                    if left > 0:
                        deferred[sid] = left
                    # Staleness counts ticks since the stream last received
                    # *any* service while waiting; a partially served stream
                    # is not starving (fairness already gave its tenant a
                    # share), so only fully passed-over streams age.
                    if sid in serviced:
                        m.staleness = 0
                    elif left > 0:
                        m.staleness += 1
            if self.monitor is not None:
                # Same observe order as the collect loop (registration
                # order), so flags are identical to the pre-split single
                # loop — only the span boundary separates the phases.
                with _span(self.tracer, "mux.anomaly", tid=self.trace_tid):
                    for sid, m in self._members.items():
                        if results[sid] is not None:
                            flags.extend(self.monitor.observe(
                                sid, results[sid].vet,
                                first=m.stream.first_retained,
                                tenant=m.tenant))
            tick_span.set(dispatches=dispatches, rows=rows)

        self._dispatches += dispatches
        self._rows += rows
        self._padded_rows += padded
        self._deferred += sum(deferred.values())
        return MuxTick(results=results, serviced=serviced, deferred=deferred,
                       urgent=plan.urgent, dispatches=dispatches, rows=rows,
                       padded_rows=padded, flags=tuple(flags))

    def flush(self, max_ticks: int = 1_000_000) -> MuxTick:
        """Tick until no stream has deferred work (drain the backlog after a
        burst, or before reading final fleet state); returns the last tick.

        Raises:
            RuntimeError: no convergence within ``max_ticks`` (new work
                arriving concurrently).

        Example::

            >>> mux = VetMux(VetEngine("numpy", buckets=64), budget=2)
            >>> _ = mux.register("w0", window=8, stride=4, capacity=64)
            >>> _ = mux.feed("w0", np.linspace(1e-3, 2e-3, 40))
            >>> mux.tick().deferred        # budget 2 of 9 pending rows
            {'w0': 7}
            >>> mux.flush().deferred       # backlog drained, nothing lost
            {}
        """
        return _flush_loop(self.tick, max_ticks)

    # ------------------------------------------------------ checkpointing
    def state_dict(self) -> dict:
        """Pickle-safe snapshot of the whole mux: every member stream's
        state plus planner staleness and the lifetime counters.

        The transport layer (``repro.fleet.transport``) checkpoints shard
        workers with this so a killed process resumes mid-job without
        re-vetting committed windows.  Engine state is deliberately *not*
        captured: compiled functions and the result cache are per-process
        artifacts that rebuild on demand — ``load_state_dict`` binds the
        restored streams to the current mux's engine.
        """
        return {
            "members": [
                {"sid": sid, "priority": m.priority, "tenant": m.tenant,
                 "staleness": m.staleness, "stream": m.stream.state_dict()}
                for sid, m in self._members.items()
            ],
            "counters": {
                "ticks": self._ticks, "dispatches": self._dispatches,
                "rows": self._rows, "padded_rows": self._padded_rows,
                "deferred": self._deferred,
            },
            "monitor": (self.monitor.state_dict()
                        if self.monitor is not None else None),
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore a ``state_dict`` snapshot, replacing every member.

        Registration order, staleness aging, pending windows, retained
        rows and the vetted watermark all survive, so the next ``tick()``
        continues exactly where the snapshot stopped — committed windows
        are never re-vetted (the crash-recovery invariant the transport
        suite locks with lifetime row/dispatch counters).
        """
        members: "OrderedDict[Hashable, _Member]" = OrderedDict()
        for rec in state["members"]:
            member = _Member(VetStream.from_state(self.engine, rec["stream"]),
                             rec["priority"], rec["tenant"])
            member.staleness = rec["staleness"]
            members[rec["sid"]] = member
        self._members = members
        c = state["counters"]
        self._ticks = c["ticks"]
        self._dispatches = c["dispatches"]
        self._rows = c["rows"]
        self._padded_rows = c["padded_rows"]
        self._deferred = c["deferred"]
        # Monitor state rides along so restored muxes neither re-flag old
        # shifts nor lose the anomaly count (``stats`` equality after a
        # round trip).  Snapshots predating the monitor restore to a fresh
        # one.
        mon = state.get("monitor")
        if mon is not None and self.monitor is not None:
            self.monitor.load_state_dict(mon)
