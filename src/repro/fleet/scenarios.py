"""Scenario bank: seed-stable fleet workloads driving the simulator.

Each scenario compiles a fleet shape (stream specs: window geometry,
priority, tenant) plus a per-tick event script (record-time chunks from the
seed-stable ``repro.profiling.simulator``, joins, leaves) into a
``FleetScenario`` that ``play()`` can drive through any ``VetMux`` — the
differential suites replay the same scenario through the mux and through
independent per-stream ``tick()``s and require equal rows, and the fleet
benchmark scales the same shapes to 256-1024 workers.

The bank (``SCENARIOS``):

- ``uniform``            — homogeneous fleet, steady identical arrivals; the
  best case for coalescing (one shape bucket, one dispatch per tick).
- ``skewed_stragglers``  — a fraction of workers carries a much heavier
  Pareto overhead channel (the paper's straggler signature: vet outliers).
- ``bursty``             — per-tick arrivals drawn from {nothing, trickle,
  burst}; quiet workers must cost nothing, bursts must not overrun rings.
- ``mixed_windows``      — window lengths cycle through a small set, so a
  mux tick needs one dispatch per distinct length (shape buckets), not one
  per stream.
- ``churn``              — workers join mid-run and leave before the end;
  registration order, results and dispatch counts must stay deterministic.

The anomaly bank models the failure classes of "Characterization of
Performance Anomalies in Hadoop" (arXiv:1505.01919) by shaping the
simulator's *reducible-overhead channel* with a per-record multiplier
envelope — ideal times stay untouched, so the injected shift is exactly the
kind of regime change the vet measure is built to see.  Each carries its
injected ``onset_tick`` and ``affected`` stream set as ground truth for the
anomaly monitor's differential suites (windows are non-overlapping —
``window == stride == chunk`` — so window index == tick index):

- ``contention_onset``   — the whole fleet's overhead channel steps up at
  the onset (a co-tenant job lands on every node).
- ``degraded_node``      — only a slice of the fleet degrades; the rest must
  stay unflagged.
- ``fail_restart``       — overhead spikes hard at the onset and recovers
  after a fixed outage (failure + restart); the monitor should localize the
  failure edge first.
- ``diurnal``            — a smooth raised-cosine swell centered on the
  onset (daily load swing), testing localization without a sharp edge.
- ``hetero_tiers``       — statically slow/fast hardware tiers (constant
  overhead level: a *negative control* that must never flag) plus a
  migrated group whose level shifts at the onset.

The *tunable* scenario (``tunable()`` / ``TunableScenario``) is the
differential lock for the online autotuner (``repro.sched.tuner``): a
mutable workload whose reducible-overhead channel is shaped by the current
knob assignment through a known envelope with a known optimum, so a tuner
driving it through ``knob_hooks`` can be checked against exhaustive grid
search.  It is deliberately *not* in ``SCENARIOS`` — it has no fixed event
script (each tick's records depend on the knobs at that tick), so ``play``
and the replay-differential suites cannot drive it.

All randomness flows from ``numpy.random.default_rng(seed)`` / the
simulator's seeded draws, so every scenario is bitwise reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Tuple

import numpy as np

from ..profiling import simulate_records
from .knobs import Knob, KnobHooks

__all__ = ["ANOMALY_SCENARIOS", "FleetEvent", "FleetScenario", "SCENARIOS",
           "StreamSpec", "TunableScenario", "build", "play", "tunable"]


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One stream's registration parameters."""

    stream_id: str
    window: int
    stride: int
    capacity: int
    priority: float = 0.0
    tenant: str = "default"

    def register(self, mux) -> None:
        mux.register(self.stream_id, window=self.window, stride=self.stride,
                     capacity=self.capacity, priority=self.priority,
                     tenant=self.tenant)


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """One tick of fleet traffic: chunks to feed, plus churn."""

    chunks: Mapping[str, np.ndarray]  # stream_id -> record-time chunk
    joins: Tuple[StreamSpec, ...] = ()  # registered before this tick's feeds
    leaves: Tuple[str, ...] = ()  # deregistered after this tick


@dataclasses.dataclass(frozen=True)
class FleetScenario:
    """A named fleet shape + its per-tick event script.

    Anomaly-bank scenarios also carry their injected ground truth:
    ``onset_tick`` is the first tick whose records are drawn from the
    anomalous regime (``None`` for scenarios with no injected shift), and
    ``affected`` names the streams the shift touches — the differential
    suites require the anomaly monitor to localize the onset on exactly
    those streams and stay quiet on the rest.
    """

    name: str
    specs: Tuple[StreamSpec, ...]
    events: Tuple[FleetEvent, ...]
    onset_tick: int | None = None
    affected: Tuple[str, ...] = ()

    @property
    def n_streams(self) -> int:
        return len(self.specs) + sum(len(e.joins) for e in self.events)


def play(scenario: FleetScenario, mux) -> List:
    """Drive a scenario through a mux: register, feed, tick per event.

    Returns the per-event ``MuxTick`` list.  Joins are applied before the
    event's feeds, leaves after its tick — a leaver's final rows are in the
    tick that saw its last records.
    """
    for spec in scenario.specs:
        spec.register(mux)
    out = []
    for event in scenario.events:
        for spec in event.joins:
            spec.register(mux)
        for sid, chunk in event.chunks.items():
            mux.feed(sid, chunk)
        out.append(mux.tick())
        for sid in event.leaves:
            mux.deregister(sid)
    return out


# ------------------------------------------------------------------ bank
def _worker_times(n: int, seed: int, worker: int,
                  overhead_scale: float = 5e-3) -> np.ndarray:
    """One worker's whole-run record times (seed-stable simulator draw)."""
    return simulate_records(n, seed=seed * 1000 + worker,
                            overhead_scale=overhead_scale).times


def _sid(i: int) -> str:
    return f"w{i:04d}"


def uniform(*, n_workers: int = 8, n_ticks: int = 6, window: int = 32,
            stride: int = 0, chunk: int = 0, seed: int = 0) -> FleetScenario:
    """Homogeneous fleet, steady arrivals: one shape bucket per tick."""
    stride = stride or window // 2
    chunk = chunk or window // 2
    specs = tuple(StreamSpec(_sid(i), window, stride, 4 * window)
                  for i in range(n_workers))
    times = {s.stream_id: _worker_times(n_ticks * chunk, seed, i)
             for i, s in enumerate(specs)}
    events = tuple(
        FleetEvent(chunks={sid: t[k * chunk:(k + 1) * chunk]
                           for sid, t in times.items()})
        for k in range(n_ticks))
    return FleetScenario("uniform", specs, events)


def skewed_stragglers(*, n_workers: int = 8, n_ticks: int = 6,
                      window: int = 32, straggler_frac: float = 0.25,
                      straggler_boost: float = 8.0,
                      seed: int = 0) -> FleetScenario:
    """A slice of the fleet pays a much heavier reducible-overhead tail."""
    stride = window // 2
    chunk = window // 2
    n_slow = max(1, int(n_workers * straggler_frac))
    specs = tuple(StreamSpec(_sid(i), window, stride, 4 * window)
                  for i in range(n_workers))
    times = {
        s.stream_id: _worker_times(
            n_ticks * chunk, seed, i,
            overhead_scale=5e-3 * (straggler_boost if i < n_slow else 1.0))
        for i, s in enumerate(specs)
    }
    events = tuple(
        FleetEvent(chunks={sid: t[k * chunk:(k + 1) * chunk]
                           for sid, t in times.items()})
        for k in range(n_ticks))
    return FleetScenario("skewed_stragglers", specs, events)


def bursty(*, n_workers: int = 8, n_ticks: int = 8, window: int = 32,
           seed: int = 0) -> FleetScenario:
    """Arrivals per tick drawn from {0, trickle, burst} per worker."""
    stride = window // 2
    rng = np.random.default_rng(seed)
    # Ring sized for the worst burst: feed()/mux.feed() would coalesce-tick
    # under pressure anyway, but keeping bursts resident exercises pure
    # coalescing rather than overrun protection.
    burst = 3 * window
    specs = tuple(StreamSpec(_sid(i), window, stride, window + 2 * burst)
                  for i in range(n_workers))
    sizes = rng.choice([0, window // 4, burst], size=(n_ticks, n_workers),
                       p=[0.35, 0.45, 0.2])
    times = {s.stream_id: _worker_times(int(sizes[:, i].sum()) or 1, seed, i)
             for i, s in enumerate(specs)}
    cursor = {sid: 0 for sid in times}
    events = []
    for k in range(n_ticks):
        chunks: Dict[str, np.ndarray] = {}
        for i, s in enumerate(specs):
            size = int(sizes[k, i])
            if size:
                lo = cursor[s.stream_id]
                chunks[s.stream_id] = times[s.stream_id][lo:lo + size]
                cursor[s.stream_id] = lo + size
        events.append(FleetEvent(chunks=chunks))
    return FleetScenario("bursty", specs, tuple(events))


def mixed_windows(*, n_workers: int = 9, n_ticks: int = 6,
                  windows: Tuple[int, ...] = (16, 32, 64),
                  seed: int = 0,
                  strides_per_tick: int = 1) -> FleetScenario:
    """Heterogeneous window lengths: one dispatch per distinct length on the
    bucketed path, ONE total on the fused path.  ``strides_per_tick`` scales
    how many windows each stream completes per tick (capacity grows to
    hold them), for benchmark sweeps over per-tick batch depth."""
    specs = []
    for i in range(n_workers):
        w = windows[i % len(windows)]
        specs.append(StreamSpec(_sid(i), w, w // 2,
                                max(4, 2 + strides_per_tick) * w,
                                tenant=f"t{i % len(windows)}"))
    chunk = {s.stream_id: (s.window // 2) * strides_per_tick for s in specs}
    times = {s.stream_id: _worker_times(n_ticks * chunk[s.stream_id], seed, i)
             for i, s in enumerate(specs)}
    events = tuple(
        FleetEvent(chunks={
            sid: times[sid][k * c:(k + 1) * c]
            for sid, c in chunk.items()})
        for k in range(n_ticks))
    return FleetScenario("mixed_windows", tuple(specs), events)


def churn(*, n_workers: int = 8, n_ticks: int = 8, window: int = 32,
          seed: int = 0) -> FleetScenario:
    """Workers join mid-run and leave before the end (elastic fleet)."""
    stride = window // 2
    chunk = window // 2
    n_base = max(2, n_workers - n_workers // 3)
    n_join = n_workers - n_base
    join_tick = n_ticks // 3
    leave_tick = 2 * n_ticks // 3
    specs = tuple(StreamSpec(_sid(i), window, stride, 4 * window)
                  for i in range(n_base))
    joiners = tuple(StreamSpec(_sid(n_base + j), window, stride, 4 * window)
                    for j in range(n_join))
    leavers = tuple(s.stream_id for s in specs[:max(1, n_base // 4)])
    times = {_sid(i): _worker_times(n_ticks * chunk, seed, i)
             for i in range(n_base + n_join)}
    events = []
    for k in range(n_ticks):
        chunks = {
            s.stream_id: times[s.stream_id][k * chunk:(k + 1) * chunk]
            for s in specs
            if not (k > leave_tick and s.stream_id in leavers)}
        if k >= join_tick:
            # A joiner's life starts at join_tick: index its simulated run
            # by ticks-since-join so its first fed chunk is its first
            # simulated records.  (Indexing by the global tick silently
            # dropped each joiner's first join_tick*chunk records.)
            j = k - join_tick
            for s in joiners:
                chunks[s.stream_id] = \
                    times[s.stream_id][j * chunk:(j + 1) * chunk]
        events.append(FleetEvent(
            chunks=chunks,
            joins=joiners if k == join_tick else (),
            leaves=leavers if k == leave_tick else (),
        ))
    return FleetScenario("churn", specs, tuple(events))


# ------------------------------------------------------- anomaly bank
def _enveloped_times(n: int, seed: int, worker: int,
                     envelope: np.ndarray) -> np.ndarray:
    """One worker's run with the reducible-overhead channel shaped by a
    per-record multiplier envelope: ``ideal + overhead * m``.  ``m == 1``
    reproduces the simulator draw bitwise (at this scale); only the overhead
    channel moves, so the injected anomaly is pure reducible overhead
    (constant true EI).

    The anomaly bank draws its *baseline* overhead calmer than the default
    simulator (alpha=2.0 instead of 1.3, so the tail has finite variance,
    at scale 2e-3): per-window vets under the default alpha=1.3 tail swing
    1.2x-14x with no anomaly at all, which no onset detector should be
    asked to see through.  The injected multiplier envelopes then carry
    the entire anomaly signal."""
    prof = _anomaly_profile(n, seed, worker)
    return prof.ideal + prof.overhead * envelope


def _anomaly_profile(n: int, seed: int, worker: int):
    return simulate_records(n, seed=seed * 1000 + worker,
                            overhead_scale=2e-3, pareto_alpha=2.0)


def _per_tick_envelope(mt: np.ndarray, chunk: int) -> np.ndarray:
    """Expand a per-tick multiplier series to per-record (chunk records/tick)."""
    return np.repeat(np.asarray(mt, np.float64), chunk)


def _anomaly_fleet(n_workers: int, window: int,
                   tenant=None) -> Tuple[StreamSpec, ...]:
    """Non-overlapping-window fleet: window == stride, so one window
    completes per tick and window index == tick index."""
    return tuple(
        StreamSpec(_sid(i), window, window, 4 * window,
                   tenant=tenant(i) if tenant else "default")
        for i in range(n_workers))


def _chunk_events(times: Mapping[str, np.ndarray], n_ticks: int,
                  chunk: int) -> Tuple[FleetEvent, ...]:
    return tuple(
        FleetEvent(chunks={sid: t[k * chunk:(k + 1) * chunk]
                           for sid, t in times.items()})
        for k in range(n_ticks))


def contention_onset(*, n_workers: int = 8, n_ticks: int = 16,
                     window: int = 64, boost: float = 16.0,
                     seed: int = 0) -> FleetScenario:
    """Fleet-wide contention lands at the onset: every worker's overhead
    channel steps up by ``boost`` (1505.01919's co-located-job signature)."""
    onset = n_ticks // 2
    specs = _anomaly_fleet(n_workers, window)
    m = _per_tick_envelope(
        np.where(np.arange(n_ticks) >= onset, boost, 1.0), window)
    times = {s.stream_id: _enveloped_times(n_ticks * window, seed, i, m)
             for i, s in enumerate(specs)}
    return FleetScenario("contention_onset", specs,
                         _chunk_events(times, n_ticks, window),
                         onset_tick=onset,
                         affected=tuple(s.stream_id for s in specs))


def degraded_node(*, n_workers: int = 8, n_ticks: int = 16, window: int = 64,
                  degraded_frac: float = 0.25, boost: float = 16.0,
                  seed: int = 0) -> FleetScenario:
    """A slice of the fleet degrades at the onset (partial-node fault:
    failing disk, hot VM neighbour); the rest must stay unflagged."""
    onset = n_ticks // 2
    n_deg = max(1, int(n_workers * degraded_frac))
    specs = _anomaly_fleet(n_workers, window)
    step = _per_tick_envelope(
        np.where(np.arange(n_ticks) >= onset, boost, 1.0), window)
    flat = np.ones(n_ticks * window)
    times = {s.stream_id: _enveloped_times(
        n_ticks * window, seed, i, step if i < n_deg else flat)
        for i, s in enumerate(specs)}
    return FleetScenario("degraded_node", specs,
                         _chunk_events(times, n_ticks, window),
                         onset_tick=onset,
                         affected=tuple(s.stream_id
                                        for s in specs[:n_deg]))


def fail_restart(*, n_workers: int = 8, n_ticks: int = 16, window: int = 64,
                 outage_ticks: int = 5, boost: float = 20.0,
                 seed: int = 0) -> FleetScenario:
    """Hard failure at the onset, restart ``outage_ticks`` later: overhead
    spikes then recovers.  Ground truth is the *failure* edge — the monitor
    sees only normal+outage windows when it first fires, so its first flag
    should localize the onset, not the restart."""
    onset = max(2, n_ticks // 2 - 1)
    k = np.arange(n_ticks)
    m = _per_tick_envelope(
        np.where((k >= onset) & (k < onset + outage_ticks), boost, 1.0),
        window)
    specs = _anomaly_fleet(n_workers, window)
    times = {s.stream_id: _enveloped_times(n_ticks * window, seed, i, m)
             for i, s in enumerate(specs)}
    return FleetScenario("fail_restart", specs,
                         _chunk_events(times, n_ticks, window),
                         onset_tick=onset,
                         affected=tuple(s.stream_id for s in specs))


def diurnal(*, n_workers: int = 8, n_ticks: int = 16, window: int = 64,
            amplitude: float = 24.0, ramp_ticks: int = 2,
            seed: int = 0) -> FleetScenario:
    """Smooth daily-swing swell: a raised-cosine ramp of the overhead
    channel centered on the onset (no sharp edge to latch onto)."""
    onset = n_ticks // 2
    k = np.arange(n_ticks, dtype=np.float64)
    phase = np.clip((k - (onset - ramp_ticks / 2.0)) / ramp_ticks, 0.0, 1.0)
    m = _per_tick_envelope(1.0 + amplitude * 0.5 * (1.0 - np.cos(np.pi * phase)),
                           window)
    specs = _anomaly_fleet(n_workers, window)
    times = {s.stream_id: _enveloped_times(n_ticks * window, seed, i, m)
             for i, s in enumerate(specs)}
    return FleetScenario("diurnal", specs,
                         _chunk_events(times, n_ticks, window),
                         onset_tick=onset,
                         affected=tuple(s.stream_id for s in specs))


def hetero_tiers(*, n_workers: int = 9, n_ticks: int = 16, window: int = 64,
                 tiers: Tuple[float, ...] = (1.0, 4.0, 16.0),
                 boost: float = 16.0, seed: int = 0) -> FleetScenario:
    """Statically heterogeneous hardware tiers plus a migrated group.

    Two-thirds of the fleet runs on fixed hardware tiers that scale the
    *whole* runtime — ideal work and overhead alike — by a constant
    factor.  The vet measure is invariant to that scaling (slow hardware
    is not suboptimal: EI and OC grow together), so these streams are the
    negative control the monitor must never flag, no matter how slow
    their tier.  The last third gets migrated onto an oversubscribed node
    at the onset: only their reducible-overhead channel jumps (by
    ``boost``), and only those streams should flag."""
    onset = n_ticks // 2
    n_static = 2 * n_workers // 3
    specs = _anomaly_fleet(
        n_workers, window,
        tenant=lambda i: (f"tier{i % len(tiers)}" if i < n_static
                          else "migrated"))
    migrate = _per_tick_envelope(
        np.where(np.arange(n_ticks) >= onset, boost, 1.0), window)
    times = {}
    for i, s in enumerate(specs):
        if i < n_static:
            prof = _anomaly_profile(n_ticks * window, seed, i)
            times[s.stream_id] = (tiers[i % len(tiers)]
                                  * (prof.ideal + prof.overhead))
        else:
            times[s.stream_id] = _enveloped_times(n_ticks * window, seed, i,
                                                  migrate)
    return FleetScenario("hetero_tiers", specs,
                         _chunk_events(times, n_ticks, window),
                         onset_tick=onset,
                         affected=tuple(s.stream_id
                                        for s in specs[n_static:]))


ANOMALY_SCENARIOS: Dict[str, Callable[..., FleetScenario]] = {
    "contention_onset": contention_onset,
    "degraded_node": degraded_node,
    "fail_restart": fail_restart,
    "diurnal": diurnal,
    "hetero_tiers": hetero_tiers,
}

SCENARIOS: Dict[str, Callable[..., FleetScenario]] = {
    "uniform": uniform,
    "skewed_stragglers": skewed_stragglers,
    "bursty": bursty,
    "mixed_windows": mixed_windows,
    "churn": churn,
    **ANOMALY_SCENARIOS,
}


# ------------------------------------------------------- tunable scenario
class TunableScenario:
    """A knob-sensitive workload with a known optimum: the tuner's lock.

    Unlike the frozen bank scenarios, this one is *mutable*: each tick's
    record times depend on the knob assignment currently written into
    ``state`` (via the ``KnobHooks`` from :meth:`hooks`, the same seam a
    tuner uses against a live mux).  The knobs shape only the simulator's
    reducible-overhead channel through a multiplicative envelope

        ``envelope = prod_spsa (1 + curvature * |idx - idx*|) * factor[arm]``

    so the vet objective has a unique known minimum at :attr:`optimum`
    (every factor is 1 exactly there) and strictly unimodal coordinate
    slices everywhere else — exhaustive grid search provably lands on
    ``optimum``, which makes "did the online tuner find it?" a crisp
    differential test rather than a judgement call.

    Determinism contract: with ``noise == 0`` the per-worker base profile
    is drawn once and reused every tick, so a given assignment produces
    *bitwise identical* record bytes on every tick — the objective is a
    pure function of the assignment (and the engine's fingerprint cache
    turns repeat visits into hits).  With ``noise > 0`` a per-(tick,
    worker) seeded lognormal multiplier rides on the overhead channel:
    still reproducible, but the objective is noisy exactly the way
    arXiv:1611.10052 assumes.

    Windows are non-overlapping (``window == stride == chunk``): one
    window completes per stream per tick and contains only that tick's
    records, so tick ``t``'s vets reflect exactly the assignment applied
    before tick ``t``.
    """

    #: knob grids with the optimum interior on every axis; ``io_mode`` is
    #: deliberately unordered-in-effect (factors 1.55 / 1.0 / 1.3) so the
    #: index geometry is useless and only a bandit can tune it.
    DEFAULT_KNOBS = (Knob("n_micro", (1, 2, 4, 8)),
                     Knob("q_chunk", (16, 32, 64, 128)),
                     Knob("io_mode", (0, 1, 2), kind="bandit"))
    DEFAULT_OPTIMUM = {"n_micro": 4, "q_chunk": 32, "io_mode": 1}
    BANDIT_FACTORS = {"io_mode": (1.55, 1.0, 1.3)}

    def __init__(self, *, n_workers: int = 4, window: int = 48,
                 curvature: float = 0.4, noise: float = 0.0, seed: int = 0):
        self.name = "tunable"
        self.n_workers = int(n_workers)
        self.window = int(window)
        self.curvature = float(curvature)
        self.noise = float(noise)
        self.seed = int(seed)
        self.knobs = self.DEFAULT_KNOBS
        self.optimum = dict(self.DEFAULT_OPTIMUM)
        # Start at the far corner of every grid: worst n_micro/q_chunk,
        # worst bandit arm — the tuner has real distance to cover.
        self.state: Dict[str, object] = {k.name: k.values[0]
                                         for k in self.knobs}
        self._base = [_anomaly_profile(self.window, self.seed, i)
                      for i in range(self.n_workers)]

    @property
    def specs(self) -> Tuple[StreamSpec, ...]:
        return tuple(StreamSpec(_sid(i), self.window, self.window,
                                4 * self.window)
                     for i in range(self.n_workers))

    def hooks(self) -> KnobHooks:
        """The write-back seam: dict-backed hooks over :attr:`state`."""
        return KnobHooks.over_state(self.knobs, self.state)

    def envelope(self, assignment: Mapping | None = None) -> float:
        """Overhead multiplier for an assignment (current state if None)."""
        a = dict(self.state if assignment is None else assignment)
        m = 1.0
        for knob in self.knobs:
            idx = knob.index_of(a[knob.name])
            opt = knob.index_of(self.optimum[knob.name])
            if knob.kind == "spsa":
                m *= 1.0 + self.curvature * abs(idx - opt)
            else:
                m *= self.BANDIT_FACTORS[knob.name][idx]
        return m

    def chunks(self, tick: int) -> Dict[str, np.ndarray]:
        """One tick's record chunks under the *current* knob state."""
        m = self.envelope()
        out = {}
        for i, prof in enumerate(self._base):
            mult = m
            if self.noise:
                rng = np.random.default_rng([self.seed, 7919, tick, i])
                mult = m * float(np.exp(self.noise * rng.standard_normal()))
            out[_sid(i)] = prof.ideal + prof.overhead * mult
        return out

    def reset(self) -> None:
        """Back to the starting corner (for reuse across harness runs)."""
        for k in self.knobs:
            self.state[k.name] = k.values[0]


def tunable(**overrides) -> TunableScenario:
    """Build the tuner-lock scenario (factory mirroring the bank callables)."""
    return TunableScenario(**overrides)


def build(name: str, **overrides) -> FleetScenario:
    """Build a bank scenario by name (sizes overridable for tests/benchmarks)."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; choose from "
                         f"{sorted(SCENARIOS)}")
    return SCENARIOS[name](**overrides)
