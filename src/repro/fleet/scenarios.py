"""Scenario bank: seed-stable fleet workloads driving the simulator.

Each scenario compiles a fleet shape (stream specs: window geometry,
priority, tenant) plus a per-tick event script (record-time chunks from the
seed-stable ``repro.profiling.simulator``, joins, leaves) into a
``FleetScenario`` that ``play()`` can drive through any ``VetMux`` — the
differential suites replay the same scenario through the mux and through
independent per-stream ``tick()``s and require equal rows, and the fleet
benchmark scales the same shapes to 256-1024 workers.

The bank (``SCENARIOS``):

- ``uniform``            — homogeneous fleet, steady identical arrivals; the
  best case for coalescing (one shape bucket, one dispatch per tick).
- ``skewed_stragglers``  — a fraction of workers carries a much heavier
  Pareto overhead channel (the paper's straggler signature: vet outliers).
- ``bursty``             — per-tick arrivals drawn from {nothing, trickle,
  burst}; quiet workers must cost nothing, bursts must not overrun rings.
- ``mixed_windows``      — window lengths cycle through a small set, so a
  mux tick needs one dispatch per distinct length (shape buckets), not one
  per stream.
- ``churn``              — workers join mid-run and leave before the end;
  registration order, results and dispatch counts must stay deterministic.

All randomness flows from ``numpy.random.default_rng(seed)`` / the
simulator's seeded draws, so every scenario is bitwise reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Tuple

import numpy as np

from ..profiling import simulate_records

__all__ = ["FleetEvent", "FleetScenario", "SCENARIOS", "StreamSpec",
           "build", "play"]


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One stream's registration parameters."""

    stream_id: str
    window: int
    stride: int
    capacity: int
    priority: float = 0.0
    tenant: str = "default"

    def register(self, mux) -> None:
        mux.register(self.stream_id, window=self.window, stride=self.stride,
                     capacity=self.capacity, priority=self.priority,
                     tenant=self.tenant)


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """One tick of fleet traffic: chunks to feed, plus churn."""

    chunks: Mapping[str, np.ndarray]  # stream_id -> record-time chunk
    joins: Tuple[StreamSpec, ...] = ()  # registered before this tick's feeds
    leaves: Tuple[str, ...] = ()  # deregistered after this tick


@dataclasses.dataclass(frozen=True)
class FleetScenario:
    """A named fleet shape + its per-tick event script."""

    name: str
    specs: Tuple[StreamSpec, ...]
    events: Tuple[FleetEvent, ...]

    @property
    def n_streams(self) -> int:
        return len(self.specs) + sum(len(e.joins) for e in self.events)


def play(scenario: FleetScenario, mux) -> List:
    """Drive a scenario through a mux: register, feed, tick per event.

    Returns the per-event ``MuxTick`` list.  Joins are applied before the
    event's feeds, leaves after its tick — a leaver's final rows are in the
    tick that saw its last records.
    """
    for spec in scenario.specs:
        spec.register(mux)
    out = []
    for event in scenario.events:
        for spec in event.joins:
            spec.register(mux)
        for sid, chunk in event.chunks.items():
            mux.feed(sid, chunk)
        out.append(mux.tick())
        for sid in event.leaves:
            mux.deregister(sid)
    return out


# ------------------------------------------------------------------ bank
def _worker_times(n: int, seed: int, worker: int,
                  overhead_scale: float = 5e-3) -> np.ndarray:
    """One worker's whole-run record times (seed-stable simulator draw)."""
    return simulate_records(n, seed=seed * 1000 + worker,
                            overhead_scale=overhead_scale).times


def _sid(i: int) -> str:
    return f"w{i:04d}"


def uniform(*, n_workers: int = 8, n_ticks: int = 6, window: int = 32,
            stride: int = 0, chunk: int = 0, seed: int = 0) -> FleetScenario:
    """Homogeneous fleet, steady arrivals: one shape bucket per tick."""
    stride = stride or window // 2
    chunk = chunk or window // 2
    specs = tuple(StreamSpec(_sid(i), window, stride, 4 * window)
                  for i in range(n_workers))
    times = {s.stream_id: _worker_times(n_ticks * chunk, seed, i)
             for i, s in enumerate(specs)}
    events = tuple(
        FleetEvent(chunks={sid: t[k * chunk:(k + 1) * chunk]
                           for sid, t in times.items()})
        for k in range(n_ticks))
    return FleetScenario("uniform", specs, events)


def skewed_stragglers(*, n_workers: int = 8, n_ticks: int = 6,
                      window: int = 32, straggler_frac: float = 0.25,
                      straggler_boost: float = 8.0,
                      seed: int = 0) -> FleetScenario:
    """A slice of the fleet pays a much heavier reducible-overhead tail."""
    stride = window // 2
    chunk = window // 2
    n_slow = max(1, int(n_workers * straggler_frac))
    specs = tuple(StreamSpec(_sid(i), window, stride, 4 * window)
                  for i in range(n_workers))
    times = {
        s.stream_id: _worker_times(
            n_ticks * chunk, seed, i,
            overhead_scale=5e-3 * (straggler_boost if i < n_slow else 1.0))
        for i, s in enumerate(specs)
    }
    events = tuple(
        FleetEvent(chunks={sid: t[k * chunk:(k + 1) * chunk]
                           for sid, t in times.items()})
        for k in range(n_ticks))
    return FleetScenario("skewed_stragglers", specs, events)


def bursty(*, n_workers: int = 8, n_ticks: int = 8, window: int = 32,
           seed: int = 0) -> FleetScenario:
    """Arrivals per tick drawn from {0, trickle, burst} per worker."""
    stride = window // 2
    rng = np.random.default_rng(seed)
    # Ring sized for the worst burst: feed()/mux.feed() would coalesce-tick
    # under pressure anyway, but keeping bursts resident exercises pure
    # coalescing rather than overrun protection.
    burst = 3 * window
    specs = tuple(StreamSpec(_sid(i), window, stride, window + 2 * burst)
                  for i in range(n_workers))
    sizes = rng.choice([0, window // 4, burst], size=(n_ticks, n_workers),
                       p=[0.35, 0.45, 0.2])
    times = {s.stream_id: _worker_times(int(sizes[:, i].sum()) or 1, seed, i)
             for i, s in enumerate(specs)}
    cursor = {sid: 0 for sid in times}
    events = []
    for k in range(n_ticks):
        chunks: Dict[str, np.ndarray] = {}
        for i, s in enumerate(specs):
            size = int(sizes[k, i])
            if size:
                lo = cursor[s.stream_id]
                chunks[s.stream_id] = times[s.stream_id][lo:lo + size]
                cursor[s.stream_id] = lo + size
        events.append(FleetEvent(chunks=chunks))
    return FleetScenario("bursty", specs, tuple(events))


def mixed_windows(*, n_workers: int = 9, n_ticks: int = 6,
                  windows: Tuple[int, ...] = (16, 32, 64),
                  seed: int = 0,
                  strides_per_tick: int = 1) -> FleetScenario:
    """Heterogeneous window lengths: one dispatch per distinct length on the
    bucketed path, ONE total on the fused path.  ``strides_per_tick`` scales
    how many windows each stream completes per tick (capacity grows to
    hold them), for benchmark sweeps over per-tick batch depth."""
    specs = []
    for i in range(n_workers):
        w = windows[i % len(windows)]
        specs.append(StreamSpec(_sid(i), w, w // 2,
                                max(4, 2 + strides_per_tick) * w,
                                tenant=f"t{i % len(windows)}"))
    chunk = {s.stream_id: (s.window // 2) * strides_per_tick for s in specs}
    times = {s.stream_id: _worker_times(n_ticks * chunk[s.stream_id], seed, i)
             for i, s in enumerate(specs)}
    events = tuple(
        FleetEvent(chunks={
            sid: times[sid][k * c:(k + 1) * c]
            for sid, c in chunk.items()})
        for k in range(n_ticks))
    return FleetScenario("mixed_windows", tuple(specs), events)


def churn(*, n_workers: int = 8, n_ticks: int = 8, window: int = 32,
          seed: int = 0) -> FleetScenario:
    """Workers join mid-run and leave before the end (elastic fleet)."""
    stride = window // 2
    chunk = window // 2
    n_base = max(2, n_workers - n_workers // 3)
    n_join = n_workers - n_base
    join_tick = n_ticks // 3
    leave_tick = 2 * n_ticks // 3
    specs = tuple(StreamSpec(_sid(i), window, stride, 4 * window)
                  for i in range(n_base))
    joiners = tuple(StreamSpec(_sid(n_base + j), window, stride, 4 * window)
                    for j in range(n_join))
    leavers = tuple(s.stream_id for s in specs[:max(1, n_base // 4)])
    times = {_sid(i): _worker_times(n_ticks * chunk, seed, i)
             for i in range(n_base + n_join)}
    events = []
    for k in range(n_ticks):
        live = [s.stream_id for s in specs
                if not (k > leave_tick and s.stream_id in leavers)]
        if k >= join_tick:
            live += [s.stream_id for s in joiners]
        events.append(FleetEvent(
            chunks={sid: times[sid][k * chunk:(k + 1) * chunk]
                    for sid in live},
            joins=joiners if k == join_tick else (),
            leaves=leavers if k == leave_tick else (),
        ))
    return FleetScenario("churn", specs, tuple(events))


SCENARIOS: Dict[str, Callable[..., FleetScenario]] = {
    "uniform": uniform,
    "skewed_stragglers": skewed_stragglers,
    "bursty": bursty,
    "mixed_windows": mixed_windows,
    "churn": churn,
}


def build(name: str, **overrides) -> FleetScenario:
    """Build a bank scenario by name (sizes overridable for tests/benchmarks)."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; choose from "
                         f"{sorted(SCENARIOS)}")
    return SCENARIOS[name](**overrides)
