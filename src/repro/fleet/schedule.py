"""Tick planning for the vet mux: who gets vetted this tick, and how much.

A ``VetMux`` tick has a fixed amount of estimation work it is willing to do
(the ``budget``, in window rows).  The planner turns the fleet's pending
state into a deterministic service order:

- **Urgency first.**  A stream whose ring headroom is exhausted *must* be
  drained now — deferring it means the next append overruns the ring and a
  later tick raises.  Urgent streams are served in full, even past the
  budget (correctness beats smoothing; the overshoot is visible in the
  plan).
- **Aging, not starvation.**  Within a tenant, streams are ordered by
  ``priority + staleness``: staleness counts consecutive mux ticks a stream
  sat with pending work unserviced, so any fixed priority gap is eventually
  out-aged and every stream is served in bounded time.
- **Tenant fairness.**  The remaining budget is split across tenants with
  pending demand by weighted water-filling (default weight 1): each round
  every active tenant gets its weighted integer share, unused share flows
  back into the pool, and rounds repeat until the budget or the demand is
  exhausted.  A tenant with one hot stream cannot crowd out the rest of the
  fleet.
- **Backpressure.**  Whatever the budget cannot cover is *deferred*, not
  dropped: the plan names the leftover per stream, the mux bumps their
  staleness, and the rows are picked up by later ticks (windows are always
  drained in order, so deferral never skips or reorders results).

Everything is deterministic: ties break on registration order, tenants
iterate in sorted name order, and no randomness is involved — the same fleet
state always yields the same plan (the scenario differential suites depend
on this).

Sharded fleets (``repro.fleet.shard.ShardedVetMux``) reuse the same
machinery one level up: the *job-level* budget is first split across shards
by the identical weighted water-filling (``split_budget`` — each shard's
demand is its streams' total pending rows, unused share flows to shards
that still have demand), and then each shard runs its own ``plan_tick``
over its local streams with its allocated slice — so fairness applies
twice, across shards and within each shard, and both levels stay
deterministic.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, List, Mapping, NamedTuple, Optional, Sequence, Tuple

__all__ = ["StreamRequest", "TickPlan", "plan_tick", "split_budget"]


class StreamRequest(NamedTuple):
    """One stream's pending state as seen by the planner."""

    stream_id: Hashable
    pending: int  # complete-but-unvetted windows
    priority: float  # larger = served earlier (subject to aging/fairness)
    tenant: str  # fairness-quota group
    staleness: int  # consecutive mux ticks left unserviced with pending > 0
    headroom: int  # appendable records before the ring overruns


class TickPlan(NamedTuple):
    """The planner's verdict for one mux tick."""

    serve: "OrderedDict[Hashable, int]"  # stream -> windows to drain, in order
    deferred: Dict[Hashable, int]  # stream -> pending windows pushed out
    urgent: Tuple[Hashable, ...]  # streams served out-of-budget (overrun risk)

    @property
    def total_rows(self) -> int:
        return sum(self.serve.values())


def plan_tick(
    requests: Sequence[StreamRequest],
    *,
    budget: Optional[int] = None,
    tenant_weights: Optional[Mapping[str, float]] = None,
    urgent_headroom: int = 0,
) -> TickPlan:
    """Order and bound this tick's estimation work; see the module docstring.

    ``budget`` is the window-row cap for the tick (``None`` = unbounded:
    serve everything, still in urgency/priority order).  ``urgent_headroom``
    is the headroom at or below which a stream is treated as
    must-serve-in-full.
    """
    order = {r.stream_id: i for i, r in enumerate(requests)}
    if len(order) != len(requests):
        raise ValueError("duplicate stream_id in plan_tick requests")
    live = [r for r in requests if r.pending > 0]

    def rank(r: StreamRequest):
        # Aging: staleness adds to priority, so deferral is self-correcting.
        return (-(r.priority + r.staleness), order[r.stream_id])

    urgent = sorted((r for r in live if r.headroom <= urgent_headroom),
                    key=rank)
    rest = sorted((r for r in live if r.headroom > urgent_headroom), key=rank)

    serve: "OrderedDict[Hashable, int]" = OrderedDict()
    for r in urgent:
        serve[r.stream_id] = r.pending

    if budget is None:
        for r in rest:
            serve[r.stream_id] = r.pending
        return TickPlan(serve=serve, deferred={},
                        urgent=tuple(r.stream_id for r in urgent))

    weights = dict(tenant_weights or {})
    for t, w in weights.items():
        if w <= 0:
            raise ValueError(f"tenant weight must be > 0, got {t!r}: {w}")

    # Weighted water-filling over the non-urgent demand: per-tenant totals
    # from the shared core, then each tenant's total fills its streams in
    # rank order (same greedy order as the rounds it replaces).
    pool = max(0, int(budget) - sum(r.pending for r in urgent))
    alloc: Dict[Hashable, int] = {r.stream_id: 0 for r in rest}
    queues: Dict[str, List[StreamRequest]] = {}
    for r in rest:  # rest is already rank-sorted; queues inherit the order
        queues.setdefault(r.tenant, []).append(r)
    tenants = sorted(queues)
    totals = _waterfill(pool,
                        [sum(r.pending for r in queues[t]) for t in tenants],
                        [weights.get(t, 1.0) for t in tenants])
    for t, total in zip(tenants, totals):
        for r in queues[t]:
            if total <= 0:
                break
            take = min(r.pending, total)
            alloc[r.stream_id] = take
            total -= take

    for r in rest:  # global rank order, after the urgent block
        if alloc[r.stream_id] > 0:
            serve[r.stream_id] = alloc[r.stream_id]
    deferred = {r.stream_id: r.pending - alloc[r.stream_id]
                for r in rest if r.pending - alloc[r.stream_id] > 0}
    return TickPlan(serve=serve, deferred=deferred,
                    urgent=tuple(r.stream_id for r in urgent))


def _waterfill(pool: int, demands: Sequence[int],
               weights: Sequence[float]) -> List[int]:
    """The shared integer water-filling core (both fairness levels use it:
    ``plan_tick`` across tenants, ``split_budget`` across shards).

    Rounds of demand-capped proportional shares: each round every index
    with unmet demand gets ``pool * w_i / sum(active w)`` (integer floor,
    remainder round-robin in index order), grants are capped at remaining
    demand, unused share flows back into the pool, and rounds repeat until
    the pool or the demand is exhausted.  Deterministic; ties break on
    index order (callers pass keys pre-sorted).
    """
    alloc = [0] * len(demands)
    while pool > 0:
        active = [i for i in range(len(demands)) if demands[i] > alloc[i]]
        if not active:
            break
        total_w = sum(weights[i] for i in active)
        # Floors of the proportional shares, clamped cumulatively to the
        # pool: once pool * w_i / total_w is large enough that a float ulp
        # exceeds 1, the floors alone can sum *above* the pool (the
        # remainder below would go negative — an empty range() — and the
        # round would silently over-allocate past the budget).
        left = pool
        shares = {}
        for i in active:
            s = min(int(pool * weights[i] / total_w), left)
            shares[i] = s
            left -= s
        for j in range(left):  # remainder, round-robin
            shares[active[j % len(active)]] += 1
        granted = 0
        for i in active:
            take = min(demands[i] - alloc[i], shares[i])
            alloc[i] += take
            granted += take
        if granted == 0:
            break
        pool -= granted
    return alloc


def split_budget(
    budget: int,
    demands: Sequence[int],
    *,
    weights: Optional[Sequence[float]] = None,
) -> List[int]:
    """Water-fill an integer row ``budget`` across shards.

    The shard-level half of the two-level fairness scheme (see the module
    docstring): ``demands[k]`` is shard ``k``'s total pending window rows and
    the returned ``alloc[k]`` is its slice of the job budget, never above its
    demand.  Same rules as the per-tenant split inside ``plan_tick``: each
    round every shard with unmet demand gets its weighted proportional share
    (integer floor, remainder round-robin in shard order), unused share flows
    back into the pool, and rounds repeat until the budget or the demand is
    exhausted.  Deterministic: no randomness, ties break on shard index.

    Args:
        budget: job-level window-row cap for one tick (values < 0 clamp
            to 0).
        demands: per-shard pending window rows.
        weights: optional per-shard bias (default: equal).  Must be > 0 and
            match ``len(demands)``.

    Returns:
        Per-shard integer allocations, ``0 <= alloc[k] <= demands[k]`` and
        ``sum(alloc) == min(budget, sum(demands))``.

    Raises:
        ValueError: on a non-positive weight or a weight/demand length
            mismatch.

    Example::

        >>> split_budget(8, [10, 10])
        [4, 4]
        >>> split_budget(8, [2, 10])       # unused share flows to demand
        [2, 6]
        >>> split_budget(9, [12, 12], weights=[2.0, 1.0])
        [6, 3]
        >>> split_budget(100, [3, 0, 1])   # never above demand
        [3, 0, 1]
    """
    k = len(demands)
    if weights is None:
        weights = [1.0] * k
    if len(weights) != k:
        raise ValueError(
            f"weights length {len(weights)} != demands length {k}")
    for i, w in enumerate(weights):
        if w <= 0:
            raise ValueError(f"shard weight must be > 0, got shard {i}: {w}")
    return _waterfill(max(0, int(budget)), demands, weights)
