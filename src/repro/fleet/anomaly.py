"""Online regime-shift monitoring on the fleet's vet stream.

The vet measure turns a profile into a scalar "how far from optimal" score;
this module turns the *time series* of those scores into an anomaly monitor
by running the repo's own change-point machinery (``core.changepoint`` /
``kernels.changepoint``) one level up the stack: per stream, the newest
window vets accumulate in a bounded history ring, and every mux tick the
two-segment least-squares scan asks whether the ring splits into two vet
regimes.  A confident split with a material level shift is flagged as a
:class:`RegimeShift` — onset window index, pre/post vet level, confidence —
which ``VetMux``/``ShardedVetMux``/``TransportVetMux`` surface through
``MuxTick.flags`` / ``ShardTick.flags`` and count in ``MuxStats.anomalies``.

Why a change-point and not a threshold: "Performance Tuning of Hadoop
MapReduce: A Noisy Gradient Approach" (arXiv:1611.10052) consumes exactly
this kind of signal as a noisy objective — a regime shift averaged into a
running mean poisons every gradient estimate after the onset, while a
*flagged* shift lets the consumer restart its baseline.  The failure classes
themselves (contention onset, partial-node degradation, failure/restart,
diurnal swings, tier migration) follow "Characterization of Performance
Anomalies in Hadoop" (arXiv:1505.01919) and are modeled one-to-one in
``fleet.scenarios``'s anomaly bank.

Detection ladder: the monitor accepts the same three backends as the engine
(``method="numpy" | "jax" | "pallas"``).  The numpy method is the f64
oracle scan; jax runs ``core.changepoint.estimate_changepoint``; pallas
runs ``kernels.changepoint.changepoint_pallas``.  Confidence and the
pre/post levels are always computed host-side in f64 (rings are <= a few
dozen points — the backend choice only moves the argmin search), so the
differential suites can require onset agreement across all three within
the scenario bank's +/-2-tick tolerance.

Heavy-tail hardening — window vets inherit the overhead channel's Pareto
tail, so a naive mean-shift test on raw vets flags every lucky straggler
window.  Three defenses, all cheap:

- the scan runs on **log vets**: a regime shift multiplies the overhead,
  so it is additive in log space, while a single spiky window is
  compressed instead of dominating the SSE;
- the level gate is a **ratio** (``post/pre >= min_ratio`` or the
  inverse), i.e. a shift in *level*, not in variance — statically slow
  hardware (heterogeneous tiers) sits at a constant ratio of 1 and never
  flags;
- a candidate onset must be **stable across ``confirm`` consecutive
  scans** (within one window) before it is raised — a transient spike's
  apparent shift decays as more windows arrive and fails the gates
  before confirmation, while a true onset's cut locks in, at the cost
  of ``confirm - 1`` ticks of flag latency.

    >>> import numpy as np
    >>> mon = AnomalyMonitor(method="numpy", min_points=8)
    >>> pre, post = np.full(6, 1.2), np.full(6, 3.0)
    >>> series = np.concatenate([pre, post])
    >>> mon.observe("w0", series[:10], first=0)  # candidate, 1st sighting
    ()
    >>> mon.observe("w0", series[:11], first=0)  # agrees, 2nd sighting
    ()
    >>> (flag,) = mon.observe("w0", series, first=0)  # confirmed -> raised
    >>> flag.stream_id, flag.onset, flag.pre < flag.post
    ('w0', 6, True)
    >>> mon.raised
    1
"""

from __future__ import annotations

from typing import Dict, Hashable, List, NamedTuple, Optional, Tuple

import numpy as np

__all__ = ["AnomalyMonitor", "RegimeShift"]

_TINY = 1e-12

_METHODS = ("numpy", "jax", "pallas")


class RegimeShift(NamedTuple):
    """One detected vet-regime shift on one stream.

    ``onset`` is the absolute window index of the first post-shift window
    (for non-overlapping windows — the anomaly bank's geometry — window
    index == mux tick index).  ``confidence`` is the two-segment SSE gap
    ``1 - SSE_two_segment / SSE_single_segment`` in [0, 1]: how much better
    two vet regimes explain the ring than one.
    """

    stream_id: Hashable
    tenant: str
    onset: int
    pre: float  # vet level (geometric mean) before the onset
    post: float  # vet level (geometric mean) from the onset on
    confidence: float


def _closed_form_scan_f64(y: np.ndarray, omega: int) -> np.ndarray:
    """f64 numpy mirror of ``core.changepoint.two_segment_sse``: the SSE of
    the best two-segment linear fit for every candidate prefix length k
    (+inf outside the probing window)."""
    n = y.size
    k = np.arange(1, n + 1, dtype=np.float64)
    cy = np.cumsum(y)
    cyy = np.cumsum(y * y)
    cxy = np.cumsum(k * y)
    sx1 = k * (k + 1.0) / 2.0
    sxx1 = k * (k + 1.0) * (2.0 * k + 1.0) / 6.0
    nf = float(n)
    sx_tot = nf * (nf + 1.0) / 2.0
    sxx_tot = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 6.0

    def seg(m, sx, sy, sxx, sxy, syy):
        m = np.maximum(m, 1.0)
        sxx_c = sxx - sx * sx / m
        sxy_c = sxy - sx * sy / m
        syy_c = syy - sy * sy / m
        safe = sxx_c > 0.0
        sse = syy_c - np.where(safe, sxy_c * sxy_c / np.where(safe, sxx_c, 1.0),
                               0.0)
        return np.maximum(sse, 0.0)

    sse = (seg(k, sx1, cy, sxx1, cxy, cyy)
           + seg(nf - k, sx_tot - sx1, cy[-1] - cy, sxx_tot - sxx1,
                 cxy[-1] - cxy, cyy[-1] - cyy))
    valid = (k >= omega) & (k <= nf - omega)
    return np.where(valid, sse, np.inf)


def _single_segment_sse_f64(y: np.ndarray) -> float:
    """SSE of one linear fit over the whole ring (the null model)."""
    n = y.size
    k = np.arange(1, n + 1, dtype=np.float64)
    sy, syy, sxy = y.sum(), (y * y).sum(), (k * y).sum()
    nf = float(n)
    sx = nf * (nf + 1.0) / 2.0
    sxx = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 6.0
    sxx_c = sxx - sx * sx / nf
    syy_c = syy - sy * sy / nf
    if sxx_c <= 0.0:
        return max(float(syy_c), 0.0)
    sxy_c = sxy - sx * sy / nf
    return max(float(syy_c - sxy_c * sxy_c / sxx_c), 0.0)


class _StreamState:
    """Per-stream ring + watermark + flags already raised."""

    __slots__ = ("ring", "base", "seen", "onsets", "candidate", "hits")

    def __init__(self):
        self.ring: List[float] = []  # newest window vets, oldest first
        self.base = 0  # absolute window index of ring[0]
        self.seen = 0  # vetted-window watermark already consumed
        self.onsets: List[int] = []  # onsets already flagged
        self.candidate: Optional[int] = None  # onset awaiting confirmation
        self.hits = 0  # consecutive scans agreeing on the candidate

    def reset(self, base: int = 0, seen: int = 0) -> None:
        self.ring.clear()
        self.base, self.seen = base, seen
        self.onsets.clear()
        self.candidate = None
        self.hits = 0


class AnomalyMonitor:
    """Bounded-history change-point monitor over per-stream vet series.

    Args:
        method: argmin backend — ``"numpy"`` (f64 oracle scan), ``"jax"``
            (``core.changepoint.estimate_changepoint``) or ``"pallas"``
            (``kernels.changepoint.changepoint_pallas``).
        ring: newest window vets retained per stream (bounded memory for
            serve loops that live forever).
        omega: probing-window margin, as in ``core.changepoint``.
        min_points: scans only run once a ring holds this many points
            (never below ``2 * omega`` — shorter rings have no valid split).
        min_confidence: two-segment SSE gap (on log vets) required to flag.
            Deliberately permissive (the null model is a *sloped* line, which
            already absorbs much of a step, and Pareto within-segment noise
            inflates the two-segment SSE) — the ratio and confirmation gates
            carry the false-positive budget.
        min_ratio: multiplicative level shift ``max(post,pre)/min(post,pre)``
            required to flag (keeps statically slow-but-steady streams —
            heterogeneous tiers — from flagging on fit noise).
        confirm: consecutive scans (on fresh data) that must agree on the
            candidate onset, within one window, before it is raised.  A
            transient spike's apparent shift decays as more windows arrive
            and fails the gates before confirmation; a true shift's cut
            locks in.

    Each onset is flagged once: re-detections within ``omega`` ticks of an
    already-raised onset are suppressed, while a genuinely new shift on the
    same stream (e.g. the restart edge after a failure) flags again.
    """

    def __init__(self, method: str = "numpy", *, ring: int = 64,
                 omega: int = 3, min_points: int = 0,
                 min_confidence: float = 0.25, min_ratio: float = 2.0,
                 confirm: int = 3):
        if method not in _METHODS:
            raise ValueError(f"method must be one of {_METHODS}, "
                             f"got {method!r}")
        if ring < 2 * omega:
            raise ValueError(f"ring must hold >= 2*omega={2 * omega} points, "
                             f"got {ring}")
        self.method = method
        self.ring = int(ring)
        self.omega = int(omega)
        self.min_points = max(int(min_points), 2 * self.omega)
        self.min_confidence = float(min_confidence)
        self.min_ratio = float(min_ratio)
        self.confirm = max(int(confirm), 1)
        self._streams: Dict[Hashable, _StreamState] = {}
        self._raised = 0

    def __repr__(self) -> str:
        return (f"AnomalyMonitor(method={self.method!r}, ring={self.ring}, "
                f"streams={len(self._streams)}, raised={self._raised})")

    @property
    def raised(self) -> int:
        """Lifetime count of flags raised (``MuxStats.anomalies``)."""
        return self._raised

    # ------------------------------------------------------------ observe
    def observe(self, stream_id: Hashable, vets, *, first: int,
                tenant: str = "default") -> Tuple[RegimeShift, ...]:
        """Consume a stream's retained window vets; return newly raised flags.

        Args:
            stream_id: the stream the series belongs to.
            vets: the retained window vets, oldest first (``BatchVetResult
                .vet`` as the mux collects it; ``None``/empty is a no-op).
            first: absolute window index of ``vets[0]`` (the stream's
                ``first_retained`` watermark) — lets the monitor take only
                windows it has not seen and survive ring eviction.
            tenant: fairness tenant, echoed into the flag.

        Returns:
            Tuple of flags raised by this observation (usually empty).
        """
        if vets is None:
            return ()
        v = np.asarray(vets, np.float64).ravel()
        if v.size == 0:
            return ()
        st = self._streams.setdefault(stream_id, _StreamState())
        vetted = first + v.size  # stream's vetted-window watermark
        if vetted < st.seen or first > st.seen:
            # Rewind (stream reset / checkpoint restore) or a gap (windows
            # evicted before we saw them): restart the ring at this span.
            st.reset(base=first, seen=first)
        new = v[st.seen - first:]
        if not new.size:
            # No fresh windows: rescanning the same ring would let a noise
            # cut "confirm" itself without new evidence.
            return ()
        st.ring.extend(float(x) for x in new)
        st.seen = vetted
        drop = len(st.ring) - self.ring
        if drop > 0:
            del st.ring[:drop]
            st.base += drop
        return self._scan(stream_id, tenant, st)

    def _scan(self, stream_id: Hashable, tenant: str,
              st: _StreamState) -> Tuple[RegimeShift, ...]:
        m = len(st.ring)
        if m < self.min_points:
            return ()
        # Log vets: a regime shift multiplies the overhead channel, so it
        # is additive here, and a single Pareto-tail spike no longer
        # dominates the SSE.  Levels are reported back as geometric means.
        z = np.log(np.maximum(np.asarray(st.ring, np.float64), _TINY))
        t = self._argmin(z)  # 1-indexed prefix length within the ring
        pre = float(np.exp(z[:t].mean()))
        post = float(np.exp(z[t:].mean()))
        sse0 = _single_segment_sse_f64(z)
        sse2 = float(_closed_form_scan_f64(z, self.omega)[t - 1])
        confidence = 0.0 if sse0 <= _TINY else \
            float(np.clip(1.0 - sse2 / sse0, 0.0, 1.0))
        ratio = max(post, pre) / max(min(post, pre), _TINY)
        if confidence < self.min_confidence or ratio < self.min_ratio:
            st.candidate, st.hits = None, 0
            return ()
        onset = st.base + t  # absolute index of the first post-shift window
        if any(abs(onset - prev) <= self.omega for prev in st.onsets):
            return ()
        if st.candidate is None or abs(onset - st.candidate) > 1:
            # First sighting (or the cut moved): restart confirmation.
            st.candidate, st.hits = onset, 1
            return ()
        st.hits += 1
        if st.hits < self.confirm:
            return ()
        st.candidate, st.hits = None, 0
        st.onsets.append(onset)
        self._raised += 1
        return (RegimeShift(stream_id=stream_id, tenant=tenant, onset=onset,
                            pre=pre, post=post, confidence=confidence),)

    def _argmin(self, y: np.ndarray) -> int:
        if self.method == "numpy":
            return int(np.argmin(_closed_form_scan_f64(y, self.omega))) + 1
        if self.method == "jax":
            from ..core.changepoint import estimate_changepoint
            return int(estimate_changepoint(
                np.asarray(y, np.float32), omega=self.omega))
        from ..kernels.changepoint.ops import auto_block, changepoint_pallas
        return int(changepoint_pallas(np.asarray(y, np.float32),
                                      omega=self.omega,
                                      block=auto_block(y.size)))

    # ------------------------------------------------------------- churn
    def forget(self, stream_id: Hashable) -> None:
        """Drop a deregistered stream's state (its raised count survives)."""
        self._streams.pop(stream_id, None)

    # ---------------------------------------------------- checkpointing
    def state_dict(self) -> dict:
        """Pickle-safe snapshot (rings, watermarks, raised-flag dedup)."""
        return {
            "method": self.method,
            "raised": self._raised,
            "streams": [
                {"sid": sid, "ring": list(st.ring), "base": st.base,
                 "seen": st.seen, "onsets": list(st.onsets),
                 "candidate": st.candidate, "hits": st.hits}
                for sid, st in self._streams.items()
            ],
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore a snapshot: detection continues without re-flagging
        shifts the snapshot already raised (the transport crash-recovery
        invariant, same as the mux's committed-window watermark)."""
        self._raised = int(state["raised"])
        self._streams = {}
        for rec in state["streams"]:
            st = _StreamState()
            st.ring = [float(x) for x in rec["ring"]]
            st.base = int(rec["base"])
            st.seen = int(rec["seen"])
            st.onsets = [int(x) for x in rec["onsets"]]
            cand = rec.get("candidate")
            st.candidate = None if cand is None else int(cand)
            st.hits = int(rec.get("hits", 0))
            self._streams[rec["sid"]] = st


def default_monitor(backend: str) -> AnomalyMonitor:
    """Monitor matched to an engine backend (``VetMux(monitor=True)``)."""
    return AnomalyMonitor(method=backend if backend in _METHODS else "numpy")
