"""ShardedVetMux: partition a fleet mux across shards, merge job-level vets.

A single ``VetMux`` coalesces thousands of live streams into per-tick batched
dispatches — but it is one object on one engine, i.e. one process.  The
paper's measure only means something at *job* scale: ``vet_job`` is the mean
over every task in the job (§4.4), so once the fleet no longer fits one
process the estimator has to become a set of per-process estimators whose
partial reductions merge into the same job-level numbers.  This module is
that layer:

- ``ShardedVetMux`` partitions registered streams across ``K`` shard muxes.
  Each shard owns its *own* ``VetEngine`` — shards model separate
  processes/hosts, so nothing (compiled functions, result caches, dispatch
  counters) is shared between them.  The public surface is the single-mux
  surface: ``register`` / ``deregister`` / ``feed`` / ``tick`` / ``flush`` /
  ``stats``, so every ``VetMux`` consumer can opt in by swapping the
  constructor.
- **Placement** is deterministic (no RNG): ``"pack"`` (default) greedy
  bin-packs by each stream's expected per-tick delta size with window-length
  affinity — same-length streams co-locate so a shard tick stays one
  dispatch per *locally present* length, and a length only spills to a new
  shard when load imbalance exceeds one stream's expected delta.
  ``"round_robin"`` is the trivial alternative.  Either way the same
  registration/deregistration history always yields the same assignment
  (same seed => same placement — the churn suites depend on it).
- **A tick fans out, then merges.**  The job-level ``budget`` is first
  water-filled across shards by pending demand (``schedule.split_budget``),
  each shard plans and coalesces its own tick under its slice (fairness
  applies per shard, then per tenant within the shard), and the per-shard
  ``MuxTick``s merge into one ``ShardTick``: union of per-stream results
  (rows bitwise equal to a single mux over the same feeds on numpy, 1e-5 on
  jax/pallas — ``tests/test_fleet_shard.py``), summed dispatch/row counters,
  and the job-level reduction below.
- **Job-level merge.**  Each shard reduces its tick to a ``JobVet`` partial
  (stream-count-weighted newest-window vet/EI/OC means); ``merge_job``
  combines partials exactly the way a cross-process reducer would — weighted
  by stream counts, so the merged ``vet_job`` equals the single-mux mean to
  float-sum reassociation (<= 1e-9 in the differential suite).

What sharding buys (``benchmarks/fleet_shard.py``): the *per-shard* maximum
dispatch count and row load per tick fall as shards are added — each model
process does strictly less estimation work — while the fleet-total dispatch
count stays within ``single-mux + K`` per tick (placement keeps shape
buckets intact instead of shattering them).
"""

from __future__ import annotations

from typing import (
    Dict,
    Hashable,
    Iterable,
    Iterator,
    List,
    NamedTuple,
    Optional,
    Sequence,
    Tuple,
)

import numpy as np

from ..engine import VetEngine, VetStream
from ..obs.trace import span as _span
from .anomaly import RegimeShift
from .mux import BatchVetResult, MuxStats, MuxTick, VetMux, _flush_loop
from .schedule import split_budget

__all__ = ["JobVet", "ShardPlacer", "ShardTick", "ShardedVetMux",
           "job_reduce", "merge_job"]

PLACEMENTS = ("pack", "round_robin")


class JobVet(NamedTuple):
    """One job-level (or per-shard partial) vet reduction.

    ``vet_job`` is the paper's §4.4 mean of per-task vets over the newest
    complete window of every stream that has one; ``ei``/``oc`` are the
    matching stream-count-weighted means of the newest-window EI/OC (the
    job's estimated ideal and overhead cost per task).  ``streams`` is the
    weight — the number of streams folded in — which is what makes partials
    mergeable across shards/processes (``merge_job``).
    """

    vet_job: float
    ei: float  # mean newest-window estimated ideal cost (seconds)
    oc: float  # mean newest-window estimated overhead cost (seconds)
    streams: int  # streams with a complete window (the merge weight)


def job_reduce(tick: MuxTick) -> Optional[JobVet]:
    """Reduce one mux tick to its ``JobVet`` partial.

    Returns ``None`` when no stream in the tick has a complete window yet
    (an empty partial carries no weight).  This is the per-process half of
    the job-level reduction: a shard (or a remote host) computes it locally
    and ships four numbers instead of its per-stream rows.

    Example::

        >>> from repro.engine import VetEngine
        >>> from repro.fleet import VetMux
        >>> mux = VetMux(VetEngine("numpy", buckets=64))
        >>> _ = mux.register("w0", window=8, stride=4)
        >>> _ = mux.feed("w0", np.linspace(1e-3, 2e-3, 16))
        >>> part = job_reduce(mux.tick())
        >>> part.streams
        1
        >>> part.vet_job >= 1.0
        True
    """
    newest_vet: List[float] = []
    newest_ei: List[float] = []
    newest_oc: List[float] = []
    for res in tick.results.values():
        if res is not None and res.workers > 0:
            newest_vet.append(float(res.vet[-1]))
            newest_ei.append(float(res.ei[-1]))
            newest_oc.append(float(res.oc[-1]))
    if not newest_vet:
        return None
    n = len(newest_vet)
    return JobVet(vet_job=float(np.mean(newest_vet)),
                  ei=float(np.mean(newest_ei)),
                  oc=float(np.mean(newest_oc)), streams=n)


def merge_job(parts: Iterable[Optional[JobVet]]) -> JobVet:
    """Merge per-shard ``JobVet`` partials into the job-level reduction.

    Stream-count-weighted: ``merge([p1, p2]).vet_job`` equals the mean over
    the union of both shards' streams, exactly as one mux over the whole
    fleet would compute it (up to float-sum reassociation).  ``None``
    partials (shards with no complete window yet) carry no weight.

    Raises:
        ValueError: when every partial is ``None``/absent — there is no
            window anywhere to reduce over (same contract as
            ``MuxTick.vet_job``).

    Example::

        >>> a = JobVet(vet_job=2.0, ei=1.0, oc=1.0, streams=2)
        >>> b = JobVet(vet_job=5.0, ei=1.0, oc=4.0, streams=1)
        >>> merge_job([a, None, b])
        JobVet(vet_job=3.0, ei=1.0, oc=2.0, streams=3)
    """
    live = [p for p in parts if p is not None and p.streams > 0]
    if not live:
        raise ValueError("no stream has a complete window yet")
    n = sum(p.streams for p in live)
    return JobVet(
        vet_job=sum(p.vet_job * p.streams for p in live) / n,
        ei=sum(p.ei * p.streams for p in live) / n,
        oc=sum(p.oc * p.streams for p in live) / n,
        streams=n,
    )


class ShardTick(NamedTuple):
    """One sharded tick's merged outcome.

    Field-compatible with ``MuxTick`` (``results`` / ``serviced`` /
    ``deferred`` / ``urgent`` / ``dispatches`` / ``rows`` / ``padded_rows``
    mean the same things, merged over all shards), plus the per-shard
    breakdown: ``shards[k]`` is shard ``k``'s own ``MuxTick`` and
    ``budgets[k]`` the row budget it was water-filled for this tick
    (``None`` = unbounded).  ``accounts`` is per-shard transport accounting
    (round trips / retries / respawns / checkpoints / wall-clock) — empty
    for the in-process fleet, populated by
    ``fleet.transport.TransportVetMux``.
    """

    results: Dict[Hashable, Optional[BatchVetResult]]
    serviced: Dict[Hashable, int]  # stream -> window rows dispatched
    deferred: Dict[Hashable, int]  # stream -> rows pushed to later ticks
    urgent: Tuple[Hashable, ...]  # streams served out-of-budget, shard order
    dispatches: int  # engine dispatches across all shards this tick
    rows: int  # window rows committed across all shards
    padded_rows: int  # pow2 padding overhead rows across all shards
    shards: Tuple[MuxTick, ...]  # per-shard ticks, in shard order
    budgets: Tuple[Optional[int], ...]  # per-shard water-filled budgets
    accounts: tuple = ()  # per-shard ShardAccount, transport driver only
    flags: Tuple[RegimeShift, ...] = ()  # regime shifts raised, shard order

    @property
    def job(self) -> JobVet:
        """The merged job-level reduction over every shard's partial."""
        return merge_job(job_reduce(t) for t in self.shards)

    @property
    def vet_job(self) -> float:
        """Job-level vet (paper §4.4) merged across shards; equals the
        single-mux ``MuxTick.vet_job`` over the same feeds to <= 1e-9."""
        return self.job.vet_job


class _Placement(NamedTuple):
    """One stream's placement record (for deterministic rebalancing)."""

    shard: int
    weight: int  # expected per-tick delta rows (bin-packing load unit)
    length: int  # window length (dispatch shape-bucket key)


class ShardPlacer:
    """Deterministic stream -> shard placement, shared by every fleet
    driver.

    Owns the registration census (placement records, per-shard load, and
    per-shard window-length counts) that the ``"pack"`` policy packs
    against.  ``ShardedVetMux`` (in-process shards) and
    ``repro.fleet.transport.TransportVetMux`` (real worker processes) both
    place through this class, so moving a fleet across the process boundary
    reproduces the identical assignment — which is what lets the transport
    differential suite compare the two drivers shard by shard.
    """

    def __init__(self, n_shards: int, policy: str = "pack"):
        if policy not in PLACEMENTS:
            raise ValueError(
                f"placement must be one of {PLACEMENTS}, got {policy!r}")
        self.n_shards = int(n_shards)
        self.policy = policy
        # sid -> (shard, weight, length), in registration order (the order
        # ids()/tick results iterate in, mirroring a single mux).
        self.placed: Dict[Hashable, _Placement] = {}
        self.loads = [0] * self.n_shards  # sum of member weights per shard
        # per shard: window length -> member count (dispatch bucket census)
        self.lengths: List[Dict[int, int]] = [{} for _ in range(self.n_shards)]
        self._rr = 0  # round_robin cursor (never rewound: deterministic)

    @staticmethod
    def delta_weight(window: int, stride: int, capacity: int) -> int:
        """Expected per-tick delta rows, bounded by what the ring can hold
        pending at once — the bin-packing load unit.  Identical geometry
        => identical weight, so placement is a pure function of the
        registration history."""
        return max(1, (capacity - window) // stride + 1)

    def choose(self, weight: int, length: int) -> int:
        """Deterministic shard choice for a new stream; see the module
        docstring for the two policies.  Pure: call ``add`` to record it."""
        if self.policy == "round_robin":
            k = self._rr % self.n_shards
            self._rr += 1
            return k
        # "pack": greedy bin-pack by load, with window-length affinity — a
        # shard already hosting this length is preferred unless it is more
        # than one expected-delta heavier than the best alternative (then
        # the length spills: balance beats bucket purity, but only just).
        best, best_key = 0, None
        for k in range(self.n_shards):
            hosts = length in self.lengths[k]
            cost = self.loads[k] + (0 if hosts else weight)
            key = (cost, 0 if hosts else 1, k)
            if best_key is None or key < best_key:
                best, best_key = k, key
        return best

    def add(self, stream_id: Hashable, shard: int, weight: int,
            length: int) -> None:
        self.placed[stream_id] = _Placement(shard, weight, length)
        self.loads[shard] += weight
        self.lengths[shard][length] = self.lengths[shard].get(length, 0) + 1

    def remove(self, stream_id: Hashable) -> _Placement:
        placed = self.placed.pop(self.require(stream_id))
        self.loads[placed.shard] -= placed.weight
        census = self.lengths[placed.shard]
        census[placed.length] -= 1
        if census[placed.length] <= 0:
            del census[placed.length]
        return placed

    def require(self, stream_id: Hashable) -> Hashable:
        if stream_id not in self.placed:
            raise KeyError(f"stream {stream_id!r} is not registered "
                           f"({len(self.placed)} streams live)")
        return stream_id

    def shard_of(self, stream_id: Hashable) -> int:
        return self.placed[self.require(stream_id)].shard


class ShardedVetMux:
    """K-shard fleet mux with a merged job-level vet.

    Drop-in for ``VetMux`` at the call sites that opt in (the constructor
    differs; ``register``/``feed``/``tick``/``flush``/``stats`` do not)::

        fleet = ShardedVetMux(4, backend="jax", budget=1024)
        for wid in workers:
            fleet.register(wid, window=200, stride=100)
        while serving:
            for wid, chunk in arrivals:
                fleet.feed(wid, chunk)
            tick = fleet.tick()           # fans out K shard ticks, merges
            dashboard.update(tick.vet_job, tick.results)

    Args:
        shards: number of shard muxes (>= 1).  Ignored when ``engines`` is
            given (one shard per engine).
        engines: explicit per-shard engines (each shard models one
            process/host, so engines are never shared between shards).
        engine: a template engine; shard 0 uses it directly and shards
            1..K-1 get fresh engines with the same configuration.  Mutually
            exclusive with ``engines``.
        backend: backend for the default per-shard engines (``buckets=64``,
            the fleet control-loop convention) when neither ``engines`` nor
            ``engine`` is given.
        budget: job-level window-row cap per tick, water-filled across
            shards by pending demand (``None`` = unbounded).
        tenant_weights / urgent_headroom: forwarded to every shard's
            planner (fairness applies within each shard's slice).
        placement: ``"pack"`` (default — deterministic greedy bin-packing
            by expected delta size with window-length affinity) or
            ``"round_robin"``.

    Raises:
        ValueError: on ``shards < 1``, an unknown ``placement``, both
            ``engines`` and ``engine`` given, or a ``shards``/``engines``
            length mismatch.

    Example::

        >>> fleet = ShardedVetMux(2, backend="numpy")
        >>> for i in range(4):
        ...     _ = fleet.register(i, window=8, stride=4)
        >>> sorted(fleet.assignment.values())   # balanced across 2 shards
        [0, 0, 1, 1]
        >>> for i in range(4):
        ...     _ = fleet.feed(i, np.linspace(1e-3, 2e-3, 16) * (i + 1))
        >>> tick = fleet.tick()
        >>> tick.rows, len(tick.shards)
        (12, 2)
        >>> tick.vet_job >= 1.0                 # merged job-level measure
        True
    """

    def __init__(self, shards: Optional[int] = None, *,
                 engines: Optional[Sequence[VetEngine]] = None,
                 engine: Optional[VetEngine] = None,
                 backend: str = "jax",
                 budget: Optional[int] = None,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 urgent_headroom: int = 0,
                 placement: str = "pack",
                 tracer=None):
        if engines is not None and engine is not None:
            raise ValueError("pass engines= (one per shard) or engine= "
                             "(a template), not both")
        if engines is not None:
            engines = list(engines)
            if not engines:
                raise ValueError("engines must name at least one shard")
            if shards is not None and shards != len(engines):
                raise ValueError(
                    f"shards={shards} but {len(engines)} engines given")
        else:
            shards = 1 if shards is None else int(shards)
            if shards < 1:
                raise ValueError(f"shards must be >= 1, got {shards}")
            if engine is not None:
                engines = [engine] + [engine.clone()
                                      for _ in range(shards - 1)]
            else:
                engines = [VetEngine(backend, buckets=64)
                           for _ in range(shards)]
        if budget is not None:
            budget = int(budget)
            if budget < 1:
                raise ValueError(
                    f"budget must be >= 1 window row, got {budget}")
        self.budget = budget
        self._placer = ShardPlacer(len(engines), placement)
        self._muxes = [VetMux(e, tenant_weights=tenant_weights,
                              urgent_headroom=urgent_headroom)
                       for e in engines]
        self._ticks = 0
        self.tracer = None
        if tracer is not None:
            self.set_tracer(tracer)

    def set_tracer(self, tracer) -> None:
        """Attach (or detach, with ``None``) a ``repro.obs.Tracer``.  Each
        shard mux gets its own ``tid`` lane (the shard index), so one trace
        shows the K in-process shards side by side; the fan-out/merge spans
        land on lane 0."""
        self.tracer = tracer
        for k, m in enumerate(self._muxes):
            m.set_tracer(tracer, tid=k)

    @property
    def placement(self) -> str:
        return self._placer.policy

    @property
    def _placed(self) -> Dict[Hashable, _Placement]:
        # Registration-order placement records (the placer owns them).
        return self._placer.placed

    def __repr__(self) -> str:
        return (f"ShardedVetMux(shards={self.n_shards}, "
                f"streams={len(self._placed)}, budget={self.budget}, "
                f"placement={self.placement!r}, ticks={self._ticks})")

    # ----------------------------------------------------------- topology
    @property
    def n_shards(self) -> int:
        return len(self._muxes)

    def shard(self, k: int) -> VetMux:
        """The k-th shard mux (its engine, stats, and streams are local to
        the shard — the per-process view)."""
        return self._muxes[k]

    @property
    def engines(self) -> Tuple[VetEngine, ...]:
        return tuple(m.engine for m in self._muxes)

    @property
    def assignment(self) -> Dict[Hashable, int]:
        """stream_id -> shard index, in registration order (a copy)."""
        return {sid: p.shard for sid, p in self._placed.items()}

    def shard_of(self, stream_id: Hashable) -> int:
        return self._placer.shard_of(stream_id)

    def _require(self, stream_id: Hashable) -> Hashable:
        return self._placer.require(stream_id)

    # ------------------------------------------------------- registration
    def register(self, stream_id: Hashable, *, window: Optional[int] = None,
                 stride: int = 1, capacity: Optional[int] = None,
                 history: Optional[int] = None, priority: float = 0.0,
                 tenant: str = "default",
                 stream: Optional[VetStream] = None) -> VetStream:
        """Add a stream to the fleet on a deterministically chosen shard.

        Same contract as ``VetMux.register``: pass the window geometry and
        the chosen shard's mux creates the stream on *its* engine, or pass
        an existing ``stream`` — which pins placement to the shard owning
        its engine (coalesced dispatches run on one engine per shard).

        Returns:
            The (created or attached) ``VetStream``.

        Raises:
            ValueError: duplicate ``stream_id``; neither ``window`` nor
                ``stream`` given; an attached stream bound to none of the
                shard engines.
        """
        if stream_id in self._placed:
            raise ValueError(f"stream {stream_id!r} is already registered")
        if stream is not None:
            for k, m in enumerate(self._muxes):
                if stream.engine is m.engine:
                    shard = k
                    break
            else:
                raise ValueError(
                    "attached stream must be bound to one of the shard "
                    "engines (coalesced dispatches run on one engine per "
                    "shard); build it with VetStream(fleet.shard(k).engine, "
                    "...) or let register() create it")
            weight = ShardPlacer.delta_weight(stream.window, stream.stride,
                                              stream.capacity)
            length = stream.window
        else:
            if window is None:
                raise ValueError(
                    "register needs window= (to create the stream) or "
                    "stream= (to attach an existing one)")
            window = int(window)
            cap = int(capacity) if capacity is not None else 4 * window
            weight = ShardPlacer.delta_weight(window, int(stride), cap)
            length = window
            shard = self._placer.choose(weight, length)
        out = self._muxes[shard].register(
            stream_id, window=window, stride=stride, capacity=capacity,
            history=history, priority=priority, tenant=tenant, stream=stream)
        self._placer.add(stream_id, shard, weight, length)
        return out

    def deregister(self, stream_id: Hashable) -> VetStream:
        """Remove a stream (fleet churn); returns it for standalone use.

        The shard's load/length census shrinks deterministically, so the
        next ``register`` re-balances toward the vacated shard — the same
        churn history always reproduces the same assignment.
        """
        placed = self._placer.remove(stream_id)
        return self._muxes[placed.shard].deregister(stream_id)

    def stream(self, stream_id: Hashable) -> VetStream:
        return self._muxes[self._placed[self._require(stream_id)].shard] \
            .stream(stream_id)

    def ids(self) -> Iterator[Hashable]:
        """Stream ids in registration order (across all shards)."""
        return iter(self._placed)

    def __contains__(self, stream_id: Hashable) -> bool:
        return stream_id in self._placed

    def __len__(self) -> int:
        return len(self._placed)

    @property
    def stats(self) -> MuxStats:
        """Merged lifetime counters (``ticks`` counts *fan-out* ticks; the
        dispatch/row/deferral sums are fleet totals over all shards)."""
        per = [m.stats for m in self._muxes]
        return MuxStats(ticks=self._ticks,
                        dispatches=sum(s.dispatches for s in per),
                        rows=sum(s.rows for s in per),
                        padded_rows=sum(s.padded_rows for s in per),
                        deferred=sum(s.deferred for s in per),
                        streams=len(self._placed),
                        anomalies=sum(s.anomalies for s in per))

    @property
    def shard_stats(self) -> Tuple[MuxStats, ...]:
        """Per-shard ``MuxStats``, in shard order (the per-process view)."""
        return tuple(m.stats for m in self._muxes)

    # ------------------------------------------------------------- ingest
    def feed(self, stream_id: Hashable, times) -> int:
        """Append a chunk to one stream via its shard's mux.

        Under ring pressure the *owning shard* ticks coalesced (the
        per-process overrun protection — a shard never reaches across
        process boundaries mid-feed); a job-level ``budget`` never applies
        to pressure ticks, which are correctness-driven.
        """
        return self._muxes[self._placed[self._require(stream_id)].shard] \
            .feed(stream_id, times)

    # --------------------------------------------------------------- tick
    def tick(self) -> ShardTick:
        """Fan a tick out to every shard, then merge (see module docstring).

        With a job-level ``budget``, per-shard slices are water-filled by
        pending demand first (``schedule.split_budget``); each shard's own
        planner then applies priority/staleness/tenant fairness within its
        slice.  Ring-overrun-urgent streams are always served in full by
        their shard regardless of the slice.
        """
        self._ticks += 1
        with _span(self.tracer, "fleet.tick", shards=self.n_shards,
                   streams=len(self._placed)):
            with _span(self.tracer, "fleet.plan"):
                if self.budget is None:
                    budgets: Tuple[Optional[int], ...] = \
                        (None,) * self.n_shards
                else:
                    demands = [0] * self.n_shards
                    for sid, placed in self._placed.items():
                        demands[placed.shard] += self._muxes[placed.shard] \
                            .stream(sid).pending_windows
                    budgets = tuple(split_budget(self.budget, demands))
            ticks: List[MuxTick] = []
            for m, b in zip(self._muxes, budgets):
                m.budget = b
                try:
                    ticks.append(m.tick())
                finally:
                    # pressure ticks between fan-outs: unbounded
                    m.budget = None
            with _span(self.tracer, "fleet.merge"):
                results: Dict[Hashable, Optional[BatchVetResult]] = {}
                serviced: Dict[Hashable, int] = {}
                deferred: Dict[Hashable, int] = {}
                for sid, placed in self._placed.items():  # registration order
                    t = ticks[placed.shard]
                    results[sid] = t.results[sid]
                    if sid in t.serviced:
                        serviced[sid] = t.serviced[sid]
                    if sid in t.deferred:
                        deferred[sid] = t.deferred[sid]
        return ShardTick(
            results=results, serviced=serviced, deferred=deferred,
            urgent=tuple(sid for t in ticks for sid in t.urgent),
            dispatches=sum(t.dispatches for t in ticks),
            rows=sum(t.rows for t in ticks),
            padded_rows=sum(t.padded_rows for t in ticks),
            shards=tuple(ticks), budgets=budgets,
            flags=tuple(f for t in ticks for f in t.flags))

    def flush(self, max_ticks: int = 1_000_000) -> ShardTick:
        """Tick until no shard has deferred work; returns the last tick.

        Performs at most ``max_ticks`` ticks, the first one included —
        the same boundary as ``VetMux.flush`` (shared loop).

        Raises:
            ValueError: ``max_ticks < 1``.
            RuntimeError: when the backlog does not converge within
                ``max_ticks`` ticks (new work arriving concurrently).
        """
        return _flush_loop(self.tick, max_ticks)

    def close(self) -> None:
        """Release fleet resources — a no-op here, where every shard lives
        in this process.  Surface symmetry with
        ``fleet.transport.TransportVetMux.close()`` (which terminates its
        worker processes), so consumers can hold either mux and always
        close it."""
