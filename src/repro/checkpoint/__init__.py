from .checkpoint import AsyncCheckpointer, cleanup_keep_n, latest_step, restore, save

__all__ = ["AsyncCheckpointer", "cleanup_keep_n", "latest_step", "restore", "save"]
