"""Fault-tolerant checkpointing: atomic, async, keep-N, mesh-shape-agnostic.

Layout (one directory per step):

  <root>/step_000123/
     manifest.json         # step, leaf paths, shapes, dtypes
     arr_000.npy ...       # one .npy per leaf (host-local full arrays;
                           # in a true multi-host run each host writes its
                           # shard files - same manifest format)
  <root>/LATEST            # atomic pointer (written last via rename)

Atomicity: the step directory is staged as .tmp-<step> and renamed only after
all leaves + manifest are fsynced; LATEST is updated by writing LATEST.tmp +
rename.  A crash mid-write leaves a .tmp dir that restore() ignores.
Async: save() can hand the (host-copied) state to a background thread.
Elastic restore: arrays are loaded whole and re-sharded by the caller's
current mesh (specs are logical, not device-bound).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer", "cleanup_keep_n"]


def _leaf_paths(tree) -> List[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for pp in path:
            if isinstance(pp, jax.tree_util.DictKey):
                parts.append(str(pp.key))
            elif isinstance(pp, jax.tree_util.SequenceKey):
                parts.append(str(pp.idx))
            else:
                parts.append(str(pp))
        paths.append("/".join(parts))
    return paths


def save(root: str, step: int, state, *, keep_n: int = 3) -> str:
    """Blocking atomic save of a pytree of arrays."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:09d}")
    tmp = os.path.join(root, f".tmp-{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = jax.tree.leaves(state)
    names = _leaf_paths(state)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(leaf)
        fn = f"arr_{i:04d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    latest_tmp = os.path.join(root, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest_tmp, os.path.join(root, "LATEST"))
    cleanup_keep_n(root, keep_n)
    return final


def latest_step(root: str) -> Optional[int]:
    try:
        with open(os.path.join(root, "LATEST")) as f:
            step = int(f.read().strip())
    except (FileNotFoundError, ValueError):
        return None
    if os.path.isdir(os.path.join(root, f"step_{step:09d}")):
        return step
    # pointer ahead of a crashed write: fall back to newest complete dir
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(root)
        if d.startswith("step_") and os.path.isdir(os.path.join(root, d))
    )
    return steps[-1] if steps else None


def restore(root: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of ``tree_like`` (validates shapes/dtypes).

    Returns (state, step).  Raises FileNotFoundError if no checkpoint.
    """
    if step is None:
        step = latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree.flatten(tree_like)
    if len(flat_like) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"expected {len(flat_like)}"
        )
    arrs = []
    for want, entry in zip(flat_like, manifest["leaves"]):
        arr = np.load(os.path.join(d, entry["file"]))
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"leaf {entry['name']}: shape {arr.shape} != {want.shape}"
            )
        arrs.append(arr.astype(want.dtype))
    return treedef.unflatten(arrs), step


def cleanup_keep_n(root: str, keep_n: int) -> None:
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(root)
        if d.startswith("step_") and os.path.isdir(os.path.join(root, d))
    )
    for s in steps[:-keep_n] if keep_n > 0 else []:
        shutil.rmtree(os.path.join(root, f"step_{s:09d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with at-most-one in flight.

    save() snapshots the state to host memory synchronously (cheap vs a
    device->disk stall in the step loop) and writes in the background.
    """

    def __init__(self, root: str, keep_n: int = 3):
        self.root = root
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state) -> None:
        self.wait()
        host_state = jax.tree.map(lambda a: np.asarray(a), state)

        def run():
            try:
                save(self.root, step, host_state, keep_n=self.keep_n)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
