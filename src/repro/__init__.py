"""repro: the vet optimality measure (Kim/Baek/Lee 2013) as a first-class
feature of a multi-pod JAX training/serving framework."""

__version__ = "1.0.0"
