"""Starfish-analogue config tuner, audited by vet (paper §5.5 context).

Starfish searches Hadoop parameter space against a cost model; the analogue
here grid-searches launcher knobs (microbatch count, record unit, q_chunk)
against measured step time — then vet answers the paper's question: *how far
from ideal is the tuned configuration still?*  (Paper Table 3: Starfish-tuned
jobs still show vet 3.3-4.2.)

This is the *offline* half of the tuning layer: candidate scoring is shared
with the online controller (``repro.sched.tuner.evaluate_candidate``), and
all step timing routes through the ``repro.obs`` tracer clock — pass
``tracer=`` and every candidate shows up in the Chrome trace as a
``tuner.candidate`` span over its ``tune.step`` samples.
"""

from __future__ import annotations

import itertools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from ..data.pipeline import SyntheticTokenPipeline
from ..engine import VetEngine, default_engine
from ..models import init_params
from ..optim.adamw import AdamWConfig, init_opt_state
from ..profiling import RecordProfiler
from .tuner import TuneCandidate, evaluate_candidate

__all__ = ["TuneCandidate", "tune"]


def tune(
    cfg,
    *,
    batch: int = 8,
    seq_len: int = 64,
    steps_per_candidate: int = 30,
    n_micro_options: Sequence[int] = (1, 2),
    q_chunk_options: Sequence[int] = (32, 64),
    seed: int = 0,
    verbose: bool = True,
    engine: Optional[VetEngine] = None,
    tracer=None,
) -> List[TuneCandidate]:
    """Measure every knob combination; return candidates sorted by step time,
    each annotated with its vet score (the optimality audit)."""
    from ..launch.steps import make_train_step

    pipe = SyntheticTokenPipeline(cfg.vocab_size, batch, seq_len, seed=seed,
                                  d_model=cfg.d_model, frontend=cfg.frontend,
                                  frontend_seq=max(cfg.frontend_seq, 0))
    results = []
    for n_micro, q_chunk in itertools.product(n_micro_options, q_chunk_options):
        if batch % n_micro:
            continue
        params = init_params(cfg, jax.random.PRNGKey(seed), dtype=jnp.float32)
        opt = init_opt_state(params)
        step_fn = jax.jit(make_train_step(
            cfg, None, opt_cfg=AdamWConfig(total_steps=steps_per_candidate),
            q_chunk=q_chunk, n_micro=n_micro,
        ))
        prof = RecordProfiler(unit=1, name="tune.step", tracer=tracer)
        for s in range(steps_per_candidate):
            b = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            with prof.record():
                params, opt, m = step_fn(params, opt, b)
                jax.block_until_ready(m["loss"])
        times = prof.record_times()[2:]  # drop compile steps
        eng = engine if engine is not None else default_engine(
            "jax", buckets=min(64, max(8, times.size // 4)))
        cand = evaluate_candidate({"n_micro": n_micro, "q_chunk": q_chunk},
                                  times, engine=eng, tracer=tracer)
        results.append(cand)
        if verbose:
            print(f"[tune] {cand.knobs}: step {cand.mean_step_s*1e3:.1f}ms "
                  f"vet {cand.vet:.2f}")
    results.sort(key=lambda c: c.mean_step_s)
    return results
