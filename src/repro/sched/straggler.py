"""Resource-aware scheduling driven by the vet measure (paper §5.5).

The paper's rule: "given the number of tasks calculated as W, if the
vet_task of the tasks is higher than W, the scheduler should reduce the
number of tasks."  Generalized here into a controller that consumes live
per-worker record profiles and emits concurrency / straggler decisions:

  * vet_job >> 1 with EI stable   -> host is oversubscribed: lower worker
    count (or microbatch concurrency) until vet approaches the knee.
  * one worker's vet an outlier   -> straggler: flag for re-shard/eviction
    (KS test against the pooled population confirms it is not noise).

Estimation routes through a ``repro.engine.VetEngine``: ``decide()`` vets
all workers in one batched call (grouped by profile length when buffers fill
unevenly) instead of a per-worker Python loop, and that call is memoized in
the engine's result cache — a control loop that re-``decide()``s between feeds
(dashboard ticks, idle polls) over unchanged buffers pays a buffer hash, not
a compiled batch.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core import ks_2samp
from ..engine import VetEngine, default_engine

__all__ = ["SchedulerDecision", "VetController"]


@dataclass
class SchedulerDecision:
    target_workers: int
    stragglers: List[int] = field(default_factory=list)
    vet_job: float = 1.0
    reason: str = ""
    worker_vets: Dict[int, float] = field(default_factory=dict)


class VetController:
    """Windowed vet-based concurrency controller.

    feed() per-worker record times; decide() returns the recommended worker
    count and straggler set.  Hysteresis: only moves one step per decision,
    and only when the vet signal clears the deadband.
    """

    def __init__(
        self,
        n_workers: int,
        *,
        min_workers: int = 1,
        max_workers: Optional[int] = None,
        window_records: int = 200,
        vet_high: float = 1.5,  # above the paper's W-rule knee => shrink
        vet_low: float = 1.1,  # near-ideal => can grow
        straggler_pvalue: float = 0.01,
        straggler_ratio: float = 1.5,
        engine: Optional[VetEngine] = None,
    ):
        self.n_workers = n_workers
        self.min_workers = min_workers
        self.max_workers = max_workers or n_workers
        self.window = window_records
        self.vet_high = vet_high
        self.vet_low = vet_low
        self.straggler_pvalue = straggler_pvalue
        self.straggler_ratio = straggler_ratio
        self.engine = engine if engine is not None else default_engine("jax")
        self._buffers: Dict[int, List[float]] = {i: [] for i in range(n_workers)}

    def feed(self, worker_id: int, record_times: Sequence[float]) -> None:
        buf = self._buffers.setdefault(worker_id, [])
        buf.extend(float(t) for t in record_times)
        if len(buf) > self.window:
            del buf[: len(buf) - self.window]

    def ready(self) -> bool:
        return all(len(b) >= 32 for b in self._buffers.values() if b is not None)

    def decide(self) -> SchedulerDecision:
        ids = [i for i, b in self._buffers.items() if len(b) >= 32]
        if not ids:
            return SchedulerDecision(self.n_workers, reason="insufficient data")
        profiles = {i: np.asarray(self._buffers[i]) for i in ids}

        # One batched engine call vets every worker (grouped by length).
        batch = self.engine.vet_many([profiles[i] for i in ids])
        vj = batch.vet_job
        vets = {i: float(v) for i, v in zip(ids, batch.vet)}

        # --- straggler detection: per-worker vet outliers confirmed by KS ---
        med = float(np.median(list(vets.values())))
        stragglers = []
        pooled = np.concatenate(list(profiles.values()))
        for i, v in vets.items():
            if v > self.straggler_ratio * med and len(profiles) > 2:
                ks = ks_2samp(profiles[i], pooled)
                if ks.pvalue < self.straggler_pvalue:
                    stragglers.append(i)

        # --- paper's W-rule with hysteresis ---
        target = self.n_workers
        reason = "steady"
        if vj > max(self.vet_high, float(self.n_workers)):
            # vet above the worker count: hopelessly oversubscribed
            target = max(self.min_workers, self.n_workers - 1)
            reason = f"vet_job {vj:.2f} > workers {self.n_workers} (paper W-rule)"
        elif vj > self.vet_high:
            target = max(self.min_workers, self.n_workers - 1)
            reason = f"vet_job {vj:.2f} > {self.vet_high}: shrink"
        elif vj < self.vet_low and self.n_workers < self.max_workers:
            target = self.n_workers + 1
            reason = f"vet_job {vj:.2f} < {self.vet_low}: headroom, grow"

        return SchedulerDecision(
            target_workers=target, stragglers=stragglers, vet_job=vj,
            reason=reason, worker_vets=vets,
        )

    def apply(self, decision: SchedulerDecision) -> None:
        self.n_workers = decision.target_workers
