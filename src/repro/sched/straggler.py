"""Resource-aware scheduling driven by the vet measure (paper §5.5).

The paper's rule: "given the number of tasks calculated as W, if the
vet_task of the tasks is higher than W, the scheduler should reduce the
number of tasks."  Generalized here into a controller that consumes live
per-worker record profiles and emits concurrency / straggler decisions:

  * vet_job >> 1 with EI stable   -> host is oversubscribed: lower worker
    count (or microbatch concurrency) until vet approaches the knee.
  * one worker's vet an outlier   -> straggler: flag for re-shard/eviction
    (KS test against the pooled population confirms it is not noise).

Estimation routes through one ``repro.fleet.VetMux`` holding a per-worker
``VetStream``: ``feed`` appends chunks into a worker's ring buffer in
O(chunk), and ``decide()`` is a single mux tick — every worker's newly
complete windows are drained and coalesced into one batched engine dispatch
per window-length bucket (all workers share one geometry here, so one
dispatch covers the whole fleet) instead of the former one-stream-at-a-time
loop of O(workers) dispatches.  Workers that received no records between
decisions reuse their previous rows outright (no re-gather, no buffer
re-hash), so an idle poll pays nothing per quiet worker.  Workers still
warming up (fewer than a full window of records) are vetted over their
resident buffers in one batched, memoized ``vet_many`` call.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core import ks_2samp
from ..engine import VetEngine, default_engine
from ..fleet import ShardedVetMux, VetMux

__all__ = ["SchedulerDecision", "VetController"]


@dataclass
class SchedulerDecision:
    target_workers: int
    stragglers: List[int] = field(default_factory=list)
    vet_job: float = 1.0
    reason: str = ""
    worker_vets: Dict[int, float] = field(default_factory=dict)


class VetController:
    """Windowed vet-based concurrency controller.

    feed() per-worker record times; decide() returns the recommended worker
    count and straggler set.  Hysteresis: only moves one step per decision,
    and only when the vet signal clears the deadband.

    Args:
        n_workers: initial worker count (one stream per worker).
        min_workers / max_workers: clamp for the W-rule recommendation.
        window_records: records per vetting window.
        vet_high / vet_low: shrink/grow hysteresis deadband on ``vet_job``.
        straggler_pvalue / straggler_ratio: KS confirmation threshold and
            the vet-outlier multiple that nominates a straggler candidate.
        engine: backing ``VetEngine`` (shared default when omitted).
        shards: opt-in fleet sharding — with ``shards > 1`` estimation
            routes through a ``ShardedVetMux`` (``engine`` is the template
            for the per-shard engines, each shard modeling one process) and
            ``decide()`` reads the shard-merged job reduction; with the
            default ``1`` a plain single ``VetMux`` is used.

    Example::

        >>> import numpy as np
        >>> ctl = VetController(4, engine=VetEngine("numpy", buckets=64),
        ...                     shards=2)
        >>> for w in range(4):
        ...     ctl.feed(w, np.linspace(1e-3, 2e-3, 64))
        >>> d = ctl.decide()
        >>> d.target_workers <= 4 and len(d.worker_vets) == 4
        True
    """

    def __init__(
        self,
        n_workers: int,
        *,
        min_workers: int = 1,
        max_workers: Optional[int] = None,
        window_records: int = 200,
        vet_high: float = 1.5,  # above the paper's W-rule knee => shrink
        vet_low: float = 1.1,  # near-ideal => can grow
        straggler_pvalue: float = 0.01,
        straggler_ratio: float = 1.5,
        engine: Optional[VetEngine] = None,
        shards: int = 1,
    ):
        self.n_workers = n_workers
        self.min_workers = min_workers
        self.max_workers = max_workers or n_workers
        self.window = window_records
        self.vet_high = vet_high
        self.vet_low = vet_low
        self.straggler_pvalue = straggler_pvalue
        self.straggler_ratio = straggler_ratio
        self.engine = engine if engine is not None else default_engine("jax")
        # One mux across the whole worker fleet: decide() drains every
        # worker's newly complete windows in one coalesced dispatch set.
        # With shards > 1 the fleet is partitioned across shard muxes (one
        # engine each — the cross-process scaling path) and decide() merges
        # the per-shard reductions; the decision logic is identical.
        if int(shards) > 1:
            self.mux = ShardedVetMux(int(shards), engine=self.engine)
        else:
            self.mux = VetMux(self.engine)
        for i in range(n_workers):
            self._register(i)

    def _register(self, worker_id: int) -> None:
        # Half-window stride: a worker's vet refreshes every window/2 records;
        # 4x capacity bounds the per-feed sub-chunks and keeps the latest full
        # window resident for the KS straggler test.  decide() only reads the
        # newest row per worker, so a small bounded history keeps a long-lived
        # fleet's memory O(workers), not O(records ever seen).
        self.mux.register(worker_id, window=self.window,
                          stride=max(1, self.window // 2),
                          capacity=4 * self.window, history=8)

    def feed(self, worker_id: int, record_times: Sequence[float]) -> None:
        """Append one worker's newly observed record times (seconds).

        O(chunk) ingest: the mux only ticks mid-feed if overrun protection
        forces it (coalesced even then); estimation otherwise waits for
        ``decide()``.  Unknown workers are auto-registered (elastic fleets).

        Example::

            >>> ctl = VetController(1, engine=VetEngine("numpy", buckets=64))
            >>> ctl.feed(0, np.linspace(1e-3, 2e-3, 16))
            >>> ctl.feed(7, [1e-3])          # a brand-new worker joins
            >>> len(ctl.mux)
            2
        """
        if worker_id not in self.mux:
            self._register(worker_id)
        self.mux.feed(worker_id,
                      np.asarray(record_times, dtype=np.float64).ravel())

    def ready(self) -> bool:
        """True once every worker has the 32 records ``decide`` needs.

        Example::

            >>> ctl = VetController(1, engine=VetEngine("numpy", buckets=64))
            >>> ctl.ready()
            False
            >>> ctl.feed(0, np.linspace(1e-3, 2e-3, 32))
            >>> ctl.ready()
            True
        """
        return all(self.mux.stream(i).total_records >= 32
                   for i in self.mux.ids())

    def decide(self) -> SchedulerDecision:
        """One coalesced estimation pass -> a concurrency recommendation.

        Ticks the fleet mux (only workers with newly complete windows cost
        anything; warmup workers fall back to one memoized ``vet_many``),
        flags KS-confirmed vet outliers as stragglers, and applies the
        paper's W-rule with hysteresis to ``vet_job``.

        Returns:
            ``SchedulerDecision`` with ``target_workers``, ``stragglers``,
            ``vet_job``, per-worker vets and a human-readable ``reason``
            (``"insufficient data"`` until some worker has 32 records).
        """
        ids = [i for i in self.mux.ids()
               if self.mux.stream(i).total_records >= 32]
        if not ids:
            return SchedulerDecision(self.n_workers, reason="insufficient data")
        # Buffer copies are gathered lazily: an idle poll (no new windows, no
        # outlier candidates) never materializes a single profile.
        profiles: Dict[int, np.ndarray] = {}

        def profile(i: int) -> np.ndarray:
            if i not in profiles:
                profiles[i] = self.mux.stream(i).latest(self.window)
            return profiles[i]

        # One mux tick for the whole fleet: only workers that completed new
        # windows since the last decision contribute rows, and all of them
        # share one batched dispatch per window-length bucket.  Workers still
        # short of their first full window are vetted over their resident
        # buffers in one batched vet_many (grouped by length, memoized — an
        # unchanged warmup fleet is a single cache hit).
        tick = self.mux.tick()
        vets: Dict[int, float] = {}
        warmup: List[int] = []
        for i in ids:
            res = tick.results[i]
            if res is not None:
                vets[i] = float(res.vet[-1])
            else:
                warmup.append(i)
        if warmup:
            # Group by backing engine: with shards= each shard's warmup
            # profiles are vetted on that shard's own engine (one memoized
            # vet_many per shard), preserving the per-process model —
            # fleet-wide warmup never funnels through a single engine.
            by_engine: Dict[int, tuple] = {}
            for i in warmup:
                eng = self.mux.stream(i).engine
                by_engine.setdefault(id(eng), (eng, []))[1].append(i)
            for eng, ids_ in by_engine.values():
                batch = eng.vet_many([profile(i) for i in ids_])
                vets.update((i, float(v)) for i, v in zip(ids_, batch.vet))
        vj = float(np.mean(list(vets.values())))

        # --- straggler detection: per-worker vet outliers confirmed by KS ---
        med = float(np.median(list(vets.values())))
        stragglers = []
        candidates = [i for i, v in vets.items()
                      if v > self.straggler_ratio * med] if len(ids) > 2 else []
        if candidates:
            pooled = np.concatenate([profile(i) for i in ids])
            for i in candidates:
                ks = ks_2samp(profile(i), pooled)
                if ks.pvalue < self.straggler_pvalue:
                    stragglers.append(i)

        # --- paper's W-rule with hysteresis ---
        target = self.n_workers
        reason = "steady"
        if vj > max(self.vet_high, float(self.n_workers)):
            # vet above the worker count: hopelessly oversubscribed
            target = max(self.min_workers, self.n_workers - 1)
            reason = f"vet_job {vj:.2f} > workers {self.n_workers} (paper W-rule)"
        elif vj > self.vet_high:
            target = max(self.min_workers, self.n_workers - 1)
            reason = f"vet_job {vj:.2f} > {self.vet_high}: shrink"
        elif vj < self.vet_low and self.n_workers < self.max_workers:
            target = self.n_workers + 1
            reason = f"vet_job {vj:.2f} < {self.vet_low}: headroom, grow"

        return SchedulerDecision(
            target_workers=target, stragglers=stragglers, vet_job=vj,
            reason=reason, worker_vets=vets,
        )

    def apply(self, decision: SchedulerDecision) -> None:
        """Adopt a decision's worker count (the caller resizes the pool)."""
        self.n_workers = decision.target_workers
