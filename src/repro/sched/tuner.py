"""Online vet-driven autotuning: the loop that *uses* the measure.

The paper measures how far a job sits from its lower bound; every layer so
far reports that number.  This module closes the loop: ``VetTuner`` treats
the fleet's per-tenant vet stream — read off ``MuxTick``/``ShardTick`` via
:func:`objective_from_tick` — as a noisy objective and walks the fleet's
knob grids online, writing each move back through the
``repro.fleet.knobs.KnobHooks`` seam between ticks.

Mechanics, after "Performance Tuning of Hadoop MapReduce: A Noisy Gradient
Approach" (arXiv:1611.10052):

- **SPSA probing** for ordered integer knobs: a Rademacher ±1 delta on the
  knob's *index* grid, two probe evaluations (plus/minus), the noisy
  gradient estimate :func:`spsa_gradient`, and a sign step whose integer
  magnitude anneals with the classic ``a0/(k+1+A)**alpha`` gain sequence.
  On these few-knob grids the delta is masked to one prior-selected
  coordinate per round ("coordinate SPSA"): the estimator is unchanged,
  the noiseless walk becomes provably exact (each round moves the probed
  knob one step toward its optimum or dead-bands exactly on it), and the
  PR 9 optimality ledger slots in as the prior on *which* knob to perturb
  (:meth:`VetTuner.update_prior`).
- **Discounted UCB1 arms** for knobs with no useful index geometry
  (modes, budgets): the objective context drifts while the SPSA knobs
  move, so arm statistics decay (non-stationary bandit) and the knob's
  operating value is the discounted-best arm, re-applied after every
  exploration play.
- **Rollback guard**: every round re-measures the operating point; if it
  regresses beyond ``noise_band`` of the best assignment seen, the tuner
  reverts to that best point through the hooks (and counts the rollback).
  Probes are transient by construction — the guard ensures the *operating*
  point never silently walks off a cliff.
- **Cost-vs-perf frontier**: :func:`elbow_walk` is nes-spark's
  ``extract_opt_conf`` stopping rule (accept a candidate while
  ``perf_inc > cost_inc``, updating the reference) over
  :class:`FrontierPoint` rows, for picking an operating point when knobs
  trade runtime against resource units.

``tune_scenario`` / ``grid_scenario`` drive the loop against
``repro.fleet.scenarios.tunable()`` — the simulator workload with a known
optimum — so "the tuner found the optimum" is a differential test against
exhaustive grid search, not a judgement call (``tests/test_tuner.py``).
:func:`evaluate_candidate` is the one candidate-scoring path shared with
the offline ``sched.autotune.tune`` grid sorter.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..fleet.knobs import Knob, KnobHooks
from ..obs import timed

__all__ = [
    "ElbowResult",
    "FrontierPoint",
    "GridResult",
    "SPSAConfig",
    "TuneCandidate",
    "TuneReport",
    "VetTuner",
    "elbow_walk",
    "evaluate_candidate",
    "grid_scenario",
    "grid_search",
    "objective_from_tick",
    "spsa_gradient",
    "tune_scenario",
]


# --------------------------------------------------- shared candidate scoring
@dataclasses.dataclass
class TuneCandidate:
    """One knob assignment scored on measured times + its vet audit."""

    knobs: Dict
    mean_step_s: float
    vet: float
    ei: float


def evaluate_candidate(knobs: Mapping, times: np.ndarray, *, engine,
                       tracer=None) -> TuneCandidate:
    """Score one assignment from its measured record times.

    The single candidate-scoring path shared by the offline grid sorter
    (``sched.autotune.tune``), the online harnesses here, and the
    ``autotune_online`` benchmark: mean step time plus the vet/EI audit
    from one engine dispatch, under a ``tuner.candidate`` span so every
    evaluation lands on the one tracer clock.
    """
    times = np.asarray(times, np.float64)
    with timed(tracer, "tuner.candidate", n=int(times.size),
               **{f"knob.{k}": v for k, v in knobs.items()}):
        r = engine.vet_one(times)
    return TuneCandidate(knobs=dict(knobs), mean_step_s=float(times.mean()),
                         vet=float(r.vet), ei=float(r.ei))


# -------------------------------------------------------------- SPSA pieces
@dataclasses.dataclass(frozen=True)
class SPSAConfig:
    """Gain sequences for the annealed sign step (1611.10052 defaults).

    ``a0/(k+1+A)**alpha`` is the step magnitude before integer rounding
    (floored at one grid step while a move is warranted); ``c0/(k+1)**gamma``
    is the probe radius, rounded to a whole grid step (>= 1).
    """

    a0: float = 2.0
    c0: float = 1.0
    alpha: float = 0.602
    gamma: float = 0.101
    A: float = 5.0

    def step_size(self, k: int) -> int:
        return max(1, int(round(self.a0 / (k + 1 + self.A) ** self.alpha)))

    def probe_radius(self, k: int) -> int:
        return max(1, int(round(self.c0 / (k + 1) ** self.gamma)))


def spsa_gradient(y_plus: float, y_minus: float,
                  plus_idx: Sequence[int],
                  minus_idx: Sequence[int]) -> Tuple[float, ...]:
    """Simultaneous-perturbation gradient estimate on the index grid.

    ``ghat_i = (y+ - y-) / (idx+_i - idx-_i)`` with the *applied* (clipped)
    index span in the denominator, so boundary-clipped probes do not
    inflate the estimate; a component whose span collapsed to zero
    contributes a zero gradient (no information).  On a separable
    quadratic, ``ghat = <grad, delta> * delta`` (elementwise over a ±1
    delta), hence ``<ghat, grad> = <grad, delta>**2 >= 0`` — the descent
    property the hypothesis suite pins.
    """
    plus = np.asarray(plus_idx, np.float64)
    minus = np.asarray(minus_idx, np.float64)
    if plus.shape != minus.shape:
        raise ValueError(f"probe shapes differ: {plus.shape} vs {minus.shape}")
    dy = float(y_plus) - float(y_minus)
    span = plus - minus
    out = np.zeros_like(span)
    np.divide(dy, span, out=out, where=span != 0)
    return tuple(float(g) for g in out)


# ------------------------------------------------------------ tick objective
def objective_from_tick(tick, kind: str = "vet",
                        include: Optional[Sequence] = None) -> float:
    """One scalar objective sample from a ``MuxTick``/``ShardTick``.

    Mean over each stream's *newest* complete window of ``kind``:
    ``"vet"`` (the optimality measure — lower is closer to ideal),
    ``"pr"`` (measured runtime) or ``"ei"`` (estimated ideal).
    ``include`` restricts to those stream ids (per-tenant tuning: pass the
    tenant's streams).  Raises if no included stream has a window yet.
    """
    if kind not in ("vet", "pr", "ei"):
        raise ValueError(f"objective kind must be vet|pr|ei, got {kind!r}")
    newest = [float(getattr(r, kind)[-1]) for sid, r in tick.results.items()
              if r is not None and r.workers > 0
              and (include is None or sid in include)]
    if not newest:
        raise ValueError("no included stream has a complete window yet")
    return float(np.mean(newest))


# ----------------------------------------------------------------- VetTuner
@dataclasses.dataclass(frozen=True)
class PhaseRecord:
    """One completed tuner phase: what was applied, what it measured."""

    round: int
    phase: str  # base | plus | minus | arm
    knob: Optional[str]  # the knob this round perturbs (None before select)
    assignment: Dict
    y: float
    action: str = ""  # "", "move", "hold", "rollback", "arm:<value>"


class _ArmStats:
    """Discounted UCB1 over one bandit knob's arms (non-stationary)."""

    def __init__(self, knob: Knob, discount: float, ucb_c: float):
        self.knob = knob
        self.discount = float(discount)
        self.ucb_c = float(ucb_c)
        self.count = {v: 0.0 for v in knob.values}  # discounted play counts
        self.mean_y = {v: 0.0 for v in knob.values}  # discounted mean obj
        self.plays = 0

    def choose(self):
        """Next arm to play: unseen arms first (grid order), else max UCB
        on the reward ``-y`` with a discounted exploration bonus."""
        for v in self.knob.values:
            if self.count[v] == 0.0:
                return v
        total = sum(self.count.values())
        return max(self.knob.values,
                   key=lambda v: (-self.mean_y[v]
                                  + self.ucb_c * math.sqrt(
                                      math.log(max(total, math.e))
                                      / self.count[v])))

    def record(self, value, y: float) -> None:
        """Decay every arm, then credit this play (discounted running mean)."""
        for v in self.knob.values:
            self.count[v] *= self.discount
        c, m = self.count[value], self.mean_y[value]
        self.count[value] = c + 1.0
        self.mean_y[value] = (m * c + float(y)) / (c + 1.0)
        self.plays += 1

    def best(self):
        """Operating arm: discounted-best mean among played arms (grid-order
        tie-break); first arm before any play."""
        played = [v for v in self.knob.values if self.count[v] > 0.0]
        if not played:
            return self.knob.values[0]
        return min(played, key=lambda v: (self.mean_y[v],
                                          self.knob.index_of(v)))


class VetTuner:
    """Online knob controller over a live vet objective.

    Drive it sample-by-sample: measure the objective at the currently
    applied assignment (one fleet tick — ``objective_from_tick``), call
    :meth:`step` with it, and the tuner advances its phase machine,
    writing the next assignment through ``hooks`` before returning it.
    Each round is:

    1. **base** — ``settle`` samples at the operating point; the rollback
       guard fires here (revert to the best-seen assignment if the base
       regressed beyond ``noise_band``), then the round's knob is selected
       (round-robin, or weighted by the ledger prior).
    2. **plus / minus** — SPSA probes at ``idx ± delta`` for an ordered
       knob, then the annealed sign step (dead-band on an exactly
       symmetric response, which is what the probes return when the knob
       sits on its optimum under a deterministic objective)...
    3. **arm** — ...or one discounted-UCB1 exploration play for a bandit
       knob, after which the operating value snaps back to the
       discounted-best arm.

    ``best`` is the assignment with the lowest *mean* objective over every
    evaluation that touched it (probes included — probing is how the
    optimum is first visited); ``converged`` turns True once the operating
    assignment has been stable for ``patience`` full rounds.
    """

    def __init__(self, hooks: KnobHooks, *, seed: int = 0, settle: int = 1,
                 spsa: Optional[SPSAConfig] = None, noise_band: float = 0.25,
                 dead_band: float = 0.0, patience: int = 3,
                 arm_discount: float = 0.6, ucb_c: float = 0.5,
                 tracer=None):
        if settle < 1:
            raise ValueError(f"settle must be >= 1, got {settle}")
        if not len(hooks):
            raise ValueError("hooks has no knobs registered")
        self.hooks = hooks
        self.spsa = spsa if spsa is not None else SPSAConfig()
        self.settle = int(settle)
        self.noise_band = float(noise_band)
        self.dead_band = float(dead_band)
        self.patience = int(patience)
        self.tracer = tracer
        self._rng = np.random.default_rng(seed)
        self.current: Dict = dict(hooks.snapshot())
        self.weights: Dict[str, float] = {k.name: 1.0 for k in hooks.knobs}
        self._k: Dict[str, int] = {k.name: 0 for k in hooks.knobs}
        self._arms: Dict[str, _ArmStats] = {
            k.name: _ArmStats(k, arm_discount, ucb_c)
            for k in hooks.knobs if k.kind == "bandit"}
        self._stats: Dict[Tuple, Tuple[int, float]] = {}  # key -> (n, mean)
        self._rr = 0  # round-robin cursor (uniform-prior knob selection)
        self._phase = "base"
        self._probe: Dict = {}  # in-flight round scratch
        self._buf: List[float] = []
        self._stable = 0
        self.rounds = 0
        self.rollbacks = 0
        self.history: List[PhaseRecord] = []
        self._apply(self.current)

    # ------------------------------------------------------------- plumbing
    @staticmethod
    def _key(assignment: Mapping) -> Tuple:
        return tuple(sorted(assignment.items()))

    def _record(self, assignment: Mapping, y: float) -> None:
        key = self._key(assignment)
        n, mean = self._stats.get(key, (0, 0.0))
        self._stats[key] = (n + 1, (mean * n + y) / (n + 1))

    @property
    def best(self) -> Tuple[Dict, float]:
        """(assignment, mean objective) with the lowest mean seen so far."""
        if not self._stats:
            return dict(self.current), float("nan")
        key = min(self._stats, key=lambda k: self._stats[k][1])
        return dict(key), self._stats[key][1]

    @property
    def converged(self) -> bool:
        return self._stable >= self.patience

    def update_prior(self, ledger, stage_knobs: Mapping[str, Sequence[str]]
                     ) -> Dict[str, float]:
        """Weight knob selection by the optimality ledger's per-stage
        measured-over-floor ratios (PR 9): a stage far off its floor votes
        for the knobs mapped to it, so probing effort goes where the
        reducible overhead actually sits.  ``stage_knobs`` maps ledger
        stage names (substring match) to knob names; unmapped knobs keep
        weight 1 so nothing starves.  Returns the new weights."""
        for stage in ledger.stages:
            for pattern, names in stage_knobs.items():
                if pattern in stage.stage:
                    for name in names:
                        if name in self.hooks:
                            self.weights[name] = max(
                                self.weights.get(name, 1.0),
                                float(stage.ratio))
        return dict(self.weights)

    def _select_knob(self) -> Knob:
        """Round's knob: deterministic round-robin under a uniform prior
        (the exactness-proof path), weighted draw once a ledger prior has
        skewed the weights."""
        knobs = self.hooks.knobs
        w = np.array([self.weights[k.name] for k in knobs], np.float64)
        if np.allclose(w, w[0]):
            knob = knobs[self._rr % len(knobs)]
            self._rr += 1
            return knob
        return knobs[int(self._rng.choice(len(knobs), p=w / w.sum()))]

    def _apply(self, assignment: Mapping) -> Dict:
        self._applied = self.hooks.apply(dict(assignment))
        return self._applied

    def _log(self, phase: str, assignment: Mapping, y: float,
             action: str = "") -> None:
        knob = self._probe.get("knob")
        self.history.append(PhaseRecord(
            round=self.rounds, phase=phase,
            knob=knob.name if knob is not None else None,
            assignment=dict(assignment), y=float(y), action=action))

    # ----------------------------------------------------------- the loop
    def step(self, y: float) -> Dict:
        """Feed one objective sample measured at the applied assignment;
        returns the assignment the *next* sample should be measured under.
        """
        self._buf.append(float(y))
        if len(self._buf) < self.settle:
            return dict(self._applied)
        y_bar = float(np.mean(self._buf))
        self._buf = []
        with timed(self.tracer, "tuner.phase", phase=self._phase,
                   round=self.rounds):
            getattr(self, f"_finish_{self._phase}")(y_bar)
        return dict(self._applied)

    def _finish_base(self, y: float) -> None:
        self._record(self.current, y)
        best_knobs, best_y = self.best
        action = ""
        if (self._key(best_knobs) != self._key(self.current)
                and y > best_y * (1.0 + self.noise_band)):
            # Rollback guard: the operating point regressed beyond the
            # noise band — snap back to the best-seen assignment.
            moved = dict(self.current)
            self.current = dict(best_knobs)
            self._apply(self.current)
            self.rollbacks += 1
            self._stable = 0
            action = "rollback"
            self._log("base", moved, y, action)
        else:
            self._log("base", self.current, y, action)
        knob = self._select_knob()
        self._probe = {"knob": knob}
        if knob.kind == "bandit":
            arm = self._arms[knob.name].choose()
            self._probe["arm"] = arm
            self._apply({**self.current, knob.name: arm})
            self._phase = "arm"
            return
        idx = knob.index_of(self.current[knob.name])
        delta = int(self._rng.choice((-1, 1)))
        c = self.spsa.probe_radius(self._k[knob.name])
        plus, minus = knob.clip(idx + c * delta), knob.clip(idx - c * delta)
        if plus == minus:  # single-value grid: nothing to probe
            self._finish_round(moved=False)
            return
        self._probe.update(idx=idx, plus=plus, minus=minus)
        self._apply({**self.current, knob.name: knob.value(plus)})
        self._phase = "plus"

    def _finish_plus(self, y: float) -> None:
        knob = self._probe["knob"]
        probe = {**self.current, knob.name: knob.value(self._probe["plus"])}
        self._record(probe, y)
        self._log("plus", probe, y)
        self._probe["y_plus"] = y
        self._apply({**self.current, knob.name: knob.value(self._probe["minus"])})
        self._phase = "minus"

    def _finish_minus(self, y: float) -> None:
        knob = self._probe["knob"]
        probe = {**self.current, knob.name: knob.value(self._probe["minus"])}
        self._record(probe, y)
        y_plus, y_minus = self._probe["y_plus"], y
        (ghat,) = spsa_gradient(y_plus, y_minus,
                                (self._probe["plus"],), (self._probe["minus"],))
        scale = max(abs(y_plus), abs(y_minus), 1e-30)
        moved = False
        if ghat != 0.0 and abs(y_plus - y_minus) > self.dead_band * scale:
            m = self.spsa.step_size(self._k[knob.name])
            nxt = knob.clip(self._probe["idx"] - m * int(np.sign(ghat)))
            moved = nxt != self._probe["idx"]
            if moved:
                self.current[knob.name] = knob.value(nxt)
        self._k[knob.name] += 1
        self._log("minus", probe, y, "move" if moved else "hold")
        self._finish_round(moved=moved)

    def _finish_arm(self, y: float) -> None:
        knob, arm = self._probe["knob"], self._probe["arm"]
        probe = {**self.current, knob.name: arm}
        self._record(probe, y)
        stats = self._arms[knob.name]
        stats.record(arm, y)
        best_arm = stats.best()
        moved = best_arm != self.current[knob.name]
        self.current[knob.name] = best_arm
        self._k[knob.name] += 1
        self._log("arm", probe, y, f"arm:{arm}")
        self._finish_round(moved=moved)

    def _finish_round(self, *, moved: bool) -> None:
        self._stable = 0 if moved else self._stable + 1
        self.rounds += 1
        self._probe = {"knob": self._probe.get("knob")}
        self._apply(self.current)
        self._phase = "base"

    def report(self) -> Dict:
        """Summary dict (dashboards, benchmarks): best/current assignment,
        round + rollback counts, convergence."""
        best_knobs, best_y = self.best
        return {
            "best": best_knobs, "best_y": best_y,
            "current": dict(self.current), "rounds": self.rounds,
            "rollbacks": self.rollbacks, "converged": self.converged,
            "samples": int(sum(n for n, _ in self._stats.values())),
        }


# --------------------------------------------------------- grid search oracle
@dataclasses.dataclass(frozen=True)
class GridResult:
    """Exhaustive sweep outcome: (assignment, objective) rows, best first."""

    table: Tuple[Tuple[Dict, float], ...]

    @property
    def best(self) -> Tuple[Dict, float]:
        return self.table[0]


def grid_search(hooks: KnobHooks, sample: Callable[[], float],
                *, tracer=None) -> GridResult:
    """Exhaustive oracle: apply every assignment in the knob-grid product,
    measure ``sample()`` under it, return all rows sorted ascending.

    This is what the online tuner is tested *against*: same hooks, same
    objective, every point measured.
    """
    knobs = hooks.knobs
    table = []
    for combo in itertools.product(*(k.values for k in knobs)):
        assignment = {k.name: v for k, v in zip(knobs, combo)}
        hooks.apply(assignment)
        with timed(tracer, "tuner.grid_point",
                   **{f"knob.{k}": v for k, v in assignment.items()}):
            y = float(sample())
        table.append((assignment, y))
    table.sort(key=lambda row: row[1])
    return GridResult(table=tuple(table))


# ------------------------------------------------------- cost-vs-perf elbow
@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    """One frontier candidate: runtime at a knob setting costing ``units``
    resource units (cost = runtime * units, nes-spark's pricing)."""

    knobs: Dict
    runtime: float
    units: float

    @property
    def cost(self) -> float:
        return self.runtime * self.units


@dataclasses.dataclass(frozen=True)
class ElbowResult:
    """Elbow-walk outcome: chosen index/point plus the accepted trail."""

    index: int
    point: FrontierPoint
    trail: Tuple[int, ...]


def elbow_walk(points: Sequence[FrontierPoint]) -> ElbowResult:
    """nes-spark's ``extract_opt_conf`` walk over a candidate frontier.

    Starting from the first point as the reference, scan in candidate
    order and accept a point while its perf gain beats its cost growth —
    ``perf_inc = ref_runtime / runtime`` vs ``cost_inc = cost / ref_cost``
    — updating the reference at each accept (rejected points are skipped,
    not terminal, exactly like the original).  The accepted ``trail`` is
    strictly increasing by construction, and both ratios are invariant to
    uniformly rescaling every runtime (or every cost), so the stopping
    point only depends on the frontier's *shape* — the two invariants the
    property suite pins.  A single candidate is its own elbow.
    """
    if not points:
        raise ValueError("empty frontier")
    ref = points[0]
    trail = [0]
    for i, p in enumerate(points[1:], start=1):
        perf_inc = ref.runtime / p.runtime
        cost_inc = p.cost / ref.cost
        if perf_inc > cost_inc:
            trail.append(i)
            ref = p
    return ElbowResult(index=trail[-1], point=points[trail[-1]],
                       trail=tuple(trail))


# ------------------------------------------------------- scenario harnesses
@dataclasses.dataclass(frozen=True)
class TuneReport:
    """Closed-loop run outcome over a tunable scenario."""

    best: Dict
    best_y: float
    current: Dict
    ticks: int
    rounds: int
    rollbacks: int
    converged: bool
    history: Tuple[PhaseRecord, ...]


def _scenario_mux(scenario, *, engine=None, backend: str = "numpy",
                  tracer=None):
    from ..engine import default_engine
    from ..fleet.mux import VetMux

    eng = engine if engine is not None else default_engine(backend, buckets=64)
    # monitor=False: the tuner's own probes are deliberate regime shifts;
    # the anomaly monitor would flag every one of them.
    mux = VetMux(eng, monitor=False, tracer=tracer)
    for spec in scenario.specs:
        spec.register(mux)
    return mux


def tune_scenario(scenario, *, engine=None, backend: str = "numpy",
                  max_ticks: int = 96, objective: str = "vet",
                  tracer=None, **tuner_kw) -> TuneReport:
    """Run the full closed loop against a ``TunableScenario``: feed one
    chunk set per tick, measure the objective off the ``MuxTick``, and let
    a ``VetTuner`` write knob moves back through the scenario's hooks."""
    mux = _scenario_mux(scenario, engine=engine, backend=backend,
                        tracer=tracer)
    tuner = VetTuner(scenario.hooks(), tracer=tracer, **tuner_kw)
    ticks = 0
    for t in range(max_ticks):
        for sid, chunk in scenario.chunks(t).items():
            mux.feed(sid, chunk)
        y = objective_from_tick(mux.tick(), kind=objective)
        tuner.step(y)
        ticks = t + 1
    best_knobs, best_y = tuner.best
    return TuneReport(best=best_knobs, best_y=best_y,
                      current=dict(tuner.current), ticks=ticks,
                      rounds=tuner.rounds, rollbacks=tuner.rollbacks,
                      converged=tuner.converged,
                      history=tuple(tuner.history))


def grid_scenario(scenario, *, engine=None, backend: str = "numpy",
                  objective: str = "vet", tracer=None) -> GridResult:
    """Exhaustive oracle over a ``TunableScenario``: one tick per grid
    point, same mux/objective path as :func:`tune_scenario`."""
    mux = _scenario_mux(scenario, engine=engine, backend=backend,
                        tracer=tracer)
    hooks = scenario.hooks()
    tick = itertools.count()

    def sample() -> float:
        t = next(tick)
        for sid, chunk in scenario.chunks(t).items():
            mux.feed(sid, chunk)
        return objective_from_tick(mux.tick(), kind=objective)

    return grid_search(hooks, sample, tracer=tracer)
