from .straggler import SchedulerDecision, VetController

__all__ = ["SchedulerDecision", "VetController"]
