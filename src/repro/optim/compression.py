"""Gradient compression for the slow cross-pod axis, with error feedback.

At 1000+ nodes the pod-to-pod (DCN) reduction is the scarce bandwidth; int8
block-quantized all-reduce with error feedback cuts it 4x vs f32 / 2x vs bf16
with negligible convergence impact when the residual is carried:

    q = quantize(g + e);  all_reduce(q);  e' = (g + e) - dequantize(q)

Pure-jnp, shard_map-compatible (the reduce happens outside; this module only
provides the codec + the error-feedback state).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["QuantState", "quantize_int8", "dequantize_int8", "init_error_feedback",
           "compress_with_feedback", "decompress_and_update"]

BLOCK = 256


class QuantState(NamedTuple):
    q: jax.Array  # int8 payload
    scale: jax.Array  # f32 per-block scales


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def quantize_int8(g: jax.Array) -> QuantState:
    flat, _ = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QuantState(q=q, scale=scale[:, 0])


def dequantize_int8(qs: QuantState, shape) -> jax.Array:
    flat = (qs.q.astype(jnp.float32) * qs.scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, errors):
    """Returns (quantized pytree, new candidate errors pytree-of-f32)."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        qs = quantize_int8(target)
        deq = dequantize_int8(qs, g.shape)
        return qs, target - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def decompress_and_update(qtree, shapes_like):
    def one(qs, like):
        return dequantize_int8(qs, like.shape).astype(like.dtype)

    flat_q = jax.tree.leaves(
        qtree, is_leaf=lambda x: isinstance(x, QuantState)
    )
    flat_like, treedef = jax.tree.flatten(shapes_like)
    return treedef.unflatten([one(q, l) for q, l in zip(flat_q, flat_like)])
