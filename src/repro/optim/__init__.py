from .adamw import AdamWConfig, OptState, adamw_update, init_opt_state, lr_at

__all__ = ["AdamWConfig", "OptState", "adamw_update", "init_opt_state", "lr_at"]
