"""AdamW with global-norm clipping and warmup+cosine schedule.

Moments are float32 pytrees mirroring the params; their sharding is the ZeRO
rule in ``distributed.sharding.opt_state_specs`` (applied at jit boundaries) —
the update math here is sharding-oblivious.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update", "lr_at"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # moments can be stored bf16 (halves optimizer HBM; math stays f32) —
    # the dry-run auto-tuner enables this when microbatching alone cannot
    # fit the 16 GiB budget.
    moment_dtype: Any = jnp.float32


class OptState(NamedTuple):
    step: jax.Array  # int32 scalar
    mu: Any  # first moment (f32 pytree)
    nu: Any  # second moment (f32 pytree)


def init_opt_state(params, moment_dtype=jnp.float32) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1t
        vhat = v2 / b2t
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m2.astype(m.dtype), v2.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v), {
        "grad_norm": gnorm,
        "lr": lr,
    }
