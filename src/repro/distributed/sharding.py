"""Sharding rules: logical parameter/activation/cache layouts -> mesh axes.

Scheme (DESIGN.md §4):
  * batch/tokens sharded over the DP axes ("pod", "data");
  * TP over "model": attention by heads (replicating KV projections when
    kv_heads doesn't divide the axis), MLP by d_ff, vocab by "model";
  * FSDP: the non-TP matrix dim of each weight sharded over "data";
  * ZeRO: optimizer moments additionally sharded over "data" on the largest
    still-replicated dim;
  * decode KV caches sharded over "model" on the *sequence* axis
    (flash-decoding style) and over DP on batch when divisible.

All rules operate on pytree paths + leaf shapes, so they apply uniformly to
stacked (leading layer-dim) parameters.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "MeshAxes",
    "param_specs",
    "opt_state_specs",
    "batch_specs",
    "cache_specs",
    "named",
]


class MeshAxes:
    """Axis-name bundle; dp includes 'pod' when present in the mesh."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        names = mesh.axis_names
        self.tp = "model" if "model" in names else None
        dp = tuple(a for a in ("pod", "data") if a in names)
        self.dp: Tuple[str, ...] = dp
        self.dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        self.tp_size = mesh.shape[self.tp] if self.tp else 1

    def dp_spec(self):
        return self.dp if len(self.dp) > 1 else (self.dp[0] if self.dp else None)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ------------------------------------------------------------- parameter rules
def _leaf_spec(path: str, shape, ax: MeshAxes, cfg) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""
    tp, dp = ax.tp, ax.dp_spec()
    r = len(shape)
    stacked = path.startswith("seg") and r >= 2  # leading layer dim
    L = (None,) if stacked else ()
    s = shape[1:] if stacked else shape

    def fsdp(dim_size):
        if not getattr(cfg, "weights_fsdp", True):
            return None
        return dp if _div(dim_size, ax.dp_size) else None

    def tpd(dim_size):
        return tp if _div(dim_size, ax.tp_size) else None

    if "embed" in path or path.endswith("head"):
        # (V, D) or (D, V): vocab over tp, other dim over dp
        big = int(np.argmax(s))
        spec = [None, None]
        spec[big] = tpd(s[big])
        spec[1 - big] = fsdp(s[1 - big])
        return P(*spec)

    # Attention (flat projections: plain matrix rules)
    if "attn" in path:
        if path.endswith(("wq", "wk", "wv")):  # (D, H*Dh)
            return P(*L, fsdp(s[0]), tpd(s[1]))
        if path.endswith("wo"):  # (H*Dh, D)
            return P(*L, tpd(s[0]), fsdp(s[1]))
        if path.endswith(("bq", "bk", "bv")):  # (H*Dh,)
            return P(*L, tpd(s[0]))
        if path.endswith("wkv_a"):  # (D, lora+rope)
            return P(*L, fsdp(s[0]), None)
        if path.endswith("wkv_b"):  # (lora, H*(nope+v))
            return P(*L, None, tpd(s[1]))
    # MLP
    if path.endswith(("gate", "up")):  # (D, F)
        return P(*L, fsdp(s[0]), tpd(s[1]))
    if path.endswith("down"):  # (F, D)
        return P(*L, tpd(s[0]), fsdp(s[1]))
    # MoE
    if path.endswith("router"):
        return P(*L, None, None)
    if path.endswith(("wg", "wu", "wd")):  # (E, D, F) / (E, F, D)
        return P(*L, tpd(s[0]), None, None)
    # Mamba (fused baseline): in_proj boundaries don't align with shards ->
    # FSDP only; see EXPERIMENTS.md §Perf for the split-projection variant.
    if path.endswith("in_proj"):  # (D, 2di+2n+h)
        return P(*L, fsdp(s[0]), None)
    # Mamba (split projections): inner/head dims shard over TP
    if path.endswith(("wz", "wx")):  # (D, di)
        return P(*L, fsdp(s[0]), tpd(s[1]))
    if path.endswith("wdt"):  # (D, H)
        return P(*L, fsdp(s[0]), tpd(s[1]))
    if path.endswith(("wb", "wc")):  # (D, N) tiny
        return P(*L, fsdp(s[0]), None)
    if path.endswith("conv_wx"):  # (K, di)
        return P(*L, None, tpd(s[1]))
    if path.endswith("conv_bx"):  # (di,)
        return P(*L, tpd(s[0]))
    if path.endswith("out_proj"):  # (di, D)
        if getattr(cfg, "ssm_split_proj", False):
            return P(*L, tpd(s[0]), fsdp(s[1]))
        return P(*L, None, fsdp(s[1]))
    if path.endswith(("conv_w", "conv_b", "conv_wbc", "conv_bbc",
                      "A_log", "D", "dt_bias")):
        return P(*L, *([None] * len(s)))
    # norms and everything else: replicated (tiny)
    return P(*L, *([None] * len(s)))


def _path_str(path) -> str:
    parts = []
    for pp in path:
        if isinstance(pp, jax.tree_util.DictKey):
            parts.append(str(pp.key))
        elif isinstance(pp, jax.tree_util.SequenceKey):
            parts.append(str(pp.idx))
    return "/".join(parts)


def param_specs(params_shape, ax: MeshAxes, cfg):
    """Pytree of PartitionSpec matching a params pytree (of arrays or
    ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(_path_str(path), leaf.shape, ax, cfg),
        params_shape,
    )


def opt_state_specs(params_shape, ax: MeshAxes, cfg):
    """ZeRO: moments take the param spec, then shard the largest
    still-replicated dim over dp (if divisible)."""

    def zero(path, leaf):
        spec = _leaf_spec(_path_str(path), leaf.shape, ax, cfg)
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        # an axis may appear at most once per spec: skip leaves already
        # dp-sharded by the FSDP rule
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None:
                    used.add(a)
        if any(a in used for a in ax.dp):
            return P(*entries)
        # skip leading stacked-layer dim (index 0) when searching
        best, best_dim = -1, -1
        start = 1 if _path_str(path).startswith("seg") and len(leaf.shape) >= 2 else 0
        for i in range(start, len(leaf.shape)):
            if entries[i] is None and _div(leaf.shape[i], ax.dp_size):
                if leaf.shape[i] > best:
                    best, best_dim = leaf.shape[i], i
        if best_dim >= 0 and ax.dp:
            entries[best_dim] = ax.dp_spec()
        return P(*entries)

    return jax.tree_util.tree_map_with_path(zero, params_shape)


# ------------------------------------------------------------ batch/activation
def batch_specs(cfg, ax: MeshAxes, batch_shape):
    """Input batch: leading (global batch) dim over dp when divisible."""

    def spec(leaf):
        b = leaf.shape[0]
        first = ax.dp_spec() if _div(b, ax.dp_size) else None
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map(spec, batch_shape)


# ------------------------------------------------------------------ decode kv
def cache_specs(cache_shape, ax: MeshAxes, cfg):
    """Stacked caches: (count, B, S, ...) KV -> batch over dp, seq over tp
    (sequence-sharded decode); mamba states -> batch over dp, heads over tp."""

    def spec(path, leaf):
        p = _path_str(path)
        s = leaf.shape
        dp = ax.dp_spec()
        tp = ax.tp
        if p.endswith(("k_scale", "v_scale")) and len(s) == 4:  # (L,B,S,KH)
            return P(
                None,
                dp if _div(s[1], ax.dp_size) else None,
                tp if _div(s[2], ax.tp_size) else None,
                None,
            )
        if p.endswith(("k", "v")) and len(s) == 5:  # (L, B, S, KH, Dh)
            return P(
                None,
                dp if _div(s[1], ax.dp_size) else None,
                tp if _div(s[2], ax.tp_size) else None,
                None,
                None,
            )
        if p.endswith(("ckv", "krope")) and len(s) == 4:  # (L, B, S, dim)
            return P(
                None,
                dp if _div(s[1], ax.dp_size) else None,
                tp if _div(s[2], ax.tp_size) else None,
                None,
            )
        if p.endswith("h") and len(s) == 5:  # (L, B, H, P, N) f32 ssm state
            return P(
                None,
                dp if _div(s[1], ax.dp_size) else None,
                tp if _div(s[2], ax.tp_size) else None,
                None,
                None,
            )
        if p.endswith("conv") and len(s) == 4:  # (L, B, K-1, C)
            return P(None, dp if _div(s[1], ax.dp_size) else None, None, None)
        return P(*([None] * len(s)))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)
