from .sharding import MeshAxes, batch_specs, cache_specs, opt_state_specs, param_specs

__all__ = ["MeshAxes", "batch_specs", "cache_specs", "opt_state_specs", "param_specs"]
