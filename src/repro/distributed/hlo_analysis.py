"""Collective-traffic analysis from compiled HLO text.

``cost_analysis()`` has no collective-bytes entry, so we parse the (post-SPMD)
HLO: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute contributes its *output* operand bytes.  Ring cost model
per chip:

    all-gather        bytes * (n-1)/n   ~ bytes
    reduce-scatter    bytes * (n-1)/n   ~ bytes   (input bytes ~ output*n; we
                                                   count the transferred share)
    all-reduce        2 * bytes * (n-1)/n ~ 2*bytes   (RS + AG)
    all-to-all        bytes * (n-1)/n   ~ bytes
    collective-permute  bytes

We fold the factor into ``ici_bytes`` (the per-chip traffic estimate) and also
report the raw per-kind byte sums.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

__all__ = ["collective_bytes", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"=\s*(?P<out>.*?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_FACTOR = {
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-reduce": 2.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum collective output bytes per op kind + ring-model per-chip traffic.

    Works on post-SPMD HLO (the per-device program): shapes in the text are
    already per-shard, so sums are per-chip.
    """
    by_kind: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    ici = 0.0
    for m in _OP_RE.finditer(hlo_text):
        out = m.group("out")
        op = m.group("op")
        b = _shape_bytes(out)
        by_kind[op] += b
        counts[op] += 1
        ici += b * _FACTOR[op]
    return {
        "ici_bytes": ici,
        "bytes_by_kind": dict(by_kind),
        "counts": dict(counts),
        "total_output_bytes": float(sum(by_kind.values())),
    }
