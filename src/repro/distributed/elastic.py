"""Elastic rescale: re-derive shardings for a changed device set and reshard
a checkpointed state.

Checkpoints are logical (full arrays + logical axis rules), so scaling from
mesh (d1, m1) to (d2, m2) is: load -> rebuild specs for the new mesh ->
device_put with the new NamedShardings.  Failure handling in launch/train.py
uses this to resume on fewer (or more) healthy chips without conversion
tooling.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding

from .sharding import MeshAxes, opt_state_specs, param_specs

__all__ = ["reshard_state", "choose_mesh_shape"]


def choose_mesh_shape(n_devices: int, *, model_axis: Optional[int] = None):
    """Largest (data, model) grid for the healthy device count.

    Keeps the model axis if it still divides; otherwise picks the biggest
    power-of-two model axis that fits (TP must divide attention/ffn dims).
    """
    if model_axis and n_devices % model_axis == 0:
        return (n_devices // model_axis, model_axis)
    m = 1
    while m * 2 <= n_devices and (n_devices % (m * 2) == 0) and m * 2 <= 16:
        m *= 2
    return (n_devices // m, m)


def reshard_state(cfg, mesh, params, opt_state=None):
    """device_put params (and optimizer state) onto a (new) mesh using the
    logical sharding rules.  Works from host (numpy) or device arrays."""
    ax = MeshAxes(mesh)
    pspec = param_specs(params, ax, cfg)
    params = jax.device_put(
        params, jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
    )
    if opt_state is None:
        return params
    from ..optim.adamw import OptState
    from jax.sharding import PartitionSpec as P

    ospec = opt_state_specs(opt_state.mu, ax, cfg)
    opt = OptState(
        step=jax.device_put(opt_state.step, NamedSharding(mesh, P())),
        mu=jax.device_put(
            opt_state.mu, jax.tree.map(lambda s: NamedSharding(mesh, s), ospec)
        ),
        nu=jax.device_put(
            opt_state.nu, jax.tree.map(lambda s: NamedSharding(mesh, s), ospec)
        ),
    )
    return params, opt
