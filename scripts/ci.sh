#!/usr/bin/env bash
# Tier-1 CI: install test extras, run the full pytest suite, then a fast
# VetEngine smoke benchmark (numpy/jax/pallas backend agreement + timing).
#
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Test extras: hypothesis powers the property suite; without it those tests
# skip (importorskip), so an offline container still runs tier-1 green.
if ! python -c "import hypothesis" >/dev/null 2>&1; then
  echo "[ci] installing test extras (hypothesis)"
  python -m pip install --quiet hypothesis \
    || echo "[ci] WARNING: hypothesis unavailable (offline?); property tests will skip"
fi

# Full run (no -x) so the report covers every module, and the engine smoke
# below still executes when a test fails; exit status reflects the tests.
echo "[ci] tier-1: pytest"
status=0
python -m pytest -q "$@" || status=$?

echo "[ci] smoke: VetEngine backend benchmark"
smoke_status=0
python -m benchmarks.run --only vet_engine || smoke_status=$?

if [ "$status" -ne 0 ]; then
  echo "[ci] FAIL: pytest exited $status"
  exit "$status"
fi
if [ "$smoke_status" -ne 0 ]; then
  echo "[ci] FAIL: vet_engine smoke benchmark exited $smoke_status"
  exit "$smoke_status"
fi
echo "[ci] OK"
