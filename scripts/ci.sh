#!/usr/bin/env bash
# Tier-1 CI: docs gate (README/ARCHITECTURE present, public-surface doctests,
# quickstart's sharded stanza), install test extras, run the streaming +
# fleet + sharded-fleet + transport + anomaly-monitor + observability +
# windowed vetting differential suites explicitly
# (with JUnit XML reports), then the full pytest suite, then a fast
# VetEngine smoke benchmark (batch + windowed + streaming sections: backend
# agreement, batched-vs-scalar speedup, cached-tick cost,
# incremental-tick-vs-regather speedup).
#
# Usage: scripts/ci.sh [extra pytest args...]
# JUnit XML lands in ${CI_REPORTS_DIR:-reports}/ for CI systems that ingest it.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
REPORTS_DIR="${CI_REPORTS_DIR:-reports}"
mkdir -p "$REPORTS_DIR"

# Docs gate: the repo ships its own map.  README.md and docs/ARCHITECTURE.md
# must exist, every docstring example on the public estimation surface must
# run (doctests on engine/ + fleet/ + the routed OnlineVet/VetController),
# and the quickstart's sharded-fleet stanza must work end to end.
echo "[ci] docs gate: README + ARCHITECTURE + doctests + quickstart stanza 6"
for doc in README.md docs/ARCHITECTURE.md; do
  if [ ! -f "$doc" ]; then
    echo "[ci] FAIL: $doc is missing (the docs gate requires it)"
    exit 1
  fi
done
docs_status=0
python -m pytest -q --doctest-modules \
  --junitxml="$REPORTS_DIR/doctest.xml" \
  src/repro/engine src/repro/fleet \
  src/repro/core/online.py src/repro/sched/straggler.py \
  || docs_status=$?
if [ "$docs_status" -ne 0 ]; then
  echo "[ci] FAIL: public-surface doctests exited $docs_status"
  exit "$docs_status"
fi
python examples/quickstart.py --stanza 6 || {
  echo "[ci] FAIL: quickstart stanza 6 (sharded fleet) did not run"
  exit 1
}

# Test extras: hypothesis powers the property suites; without it those tests
# skip (importorskip), so an offline container still runs tier-1 green.
if ! python -c "import hypothesis" >/dev/null 2>&1; then
  echo "[ci] installing test extras (hypothesis)"
  python -m pip install --quiet hypothesis \
    || echo "[ci] WARNING: hypothesis unavailable (offline?); property tests will skip"
fi

# Streaming vetting first and explicitly (-x): the streaming differential
# suite locks every incremental tick to the batch oracle, and the simulator
# determinism suite pins the ground truth every oracle is built from — if
# these break, the full-suite report below is noise.
echo "[ci] streaming vetting: differential + simulator-determinism suites"
streaming_status=0
python -m pytest -q -x \
  --junitxml="$REPORTS_DIR/streaming.xml" \
  tests/test_vet_stream.py \
  tests/test_simulator_determinism.py \
  || streaming_status=$?

# Fleet multiplexing next: the mux differential suite locks every coalesced
# dispatch to the per-stream oracle across the scenario bank, and the smoke
# suite is the fast (<= 64 workers, numpy) tier-1 path.
echo "[ci] fleet vetting: mux differential + scheduler + smoke suites"
fleet_status=0
python -m pytest -q -x \
  --junitxml="$REPORTS_DIR/fleet.xml" \
  tests/test_fleet.py \
  tests/test_fleet_smoke.py \
  || fleet_status=$?

# Sharded fleets: per-stream rows vs the single-mux oracle across the bank
# and all backends, merged job-level vets, deterministic placement, the
# scenario-bank edge cases, and the <= 64-worker / 2-shard numpy smoke.
echo "[ci] sharded fleet: shard differential + scenario + smoke suites"
shard_status=0
python -m pytest -q -x \
  --junitxml="$REPORTS_DIR/shard.xml" \
  tests/test_fleet_shard.py \
  tests/test_fleet_shard_smoke.py \
  tests/test_fleet_scenarios.py \
  || shard_status=$?

# Cross-process transport: the process-driver differential + kill-mid-tick
# recovery suites, under a hard timeout so a hung worker pool (a dead pipe
# that never times out, a respawn loop) fails the stage fast instead of
# wedging CI.  `timeout` sends TERM, then KILL 30s later if ignored.
echo "[ci] transport: process-driver differential + crash-recovery suites"
transport_status=0
timeout -k 30 600 python -m pytest -q -x \
  --junitxml="$REPORTS_DIR/transport.xml" \
  tests/test_fleet_transport.py \
  || transport_status=$?
if [ "$transport_status" -eq 124 ]; then
  echo "[ci] transport suite timed out (hung worker pool?)"
fi

# Anomaly monitoring: the live change-point monitor against the anomaly
# scenario bank (onset localization within +/-2 ticks on every backend,
# sharded/transport flag plumbing, checkpoint/resume), plus the change-point
# edge-case regressions (short-input guards, f64 index-sum precision) and
# the hypothesis property suite (skips offline).
echo "[ci] anomaly monitor: detection differential + change-point edge suites"
anomaly_status=0
python -m pytest -q -x \
  --junitxml="$REPORTS_DIR/anomaly.xml" \
  tests/test_fleet_anomaly.py \
  tests/test_changepoint_edges.py \
  tests/test_changepoint_properties.py \
  || anomaly_status=$?

# Observability: tracer/metrics/export/ledger semantics plus the
# instrumented fleet seam (traced-vs-untraced differential, cross-process
# span adoption, respawn re-enable), then a live trace-export-and-validate:
# quickstart stanza 7 dumps a Chrome trace and validate_chrome must pass it.
echo "[ci] observability: tracer + export + ledger suites, trace validate"
obs_status=0
python -m pytest -q -x \
  --junitxml="$REPORTS_DIR/obs.xml" \
  tests/test_obs.py \
  || obs_status=$?
if [ "$obs_status" -eq 0 ]; then
  python examples/quickstart.py --stanza 7 \
    --trace "$REPORTS_DIR/quickstart_trace.json" >/dev/null \
    || obs_status=$?
fi
if [ "$obs_status" -eq 0 ]; then
  python - "$REPORTS_DIR/quickstart_trace.json" <<'PY' || obs_status=$?
import json, sys
from repro.obs import validate_chrome
problems = validate_chrome(json.load(open(sys.argv[1])))
if problems:
    print("[ci] trace validation problems:", *problems, sep="\n  ")
    sys.exit(1)
print(f"[ci] quickstart trace validated ({sys.argv[1]})")
PY
fi

# Online autotuner: the simulator-recoverability lock (online VetTuner ==
# grid oracle exactly with noise off, within one knob step under seeded
# noise, all backends), the knob_hooks seam, and the elbow/SPSA/rollback
# property suite (skips offline).
echo "[ci] autotuner: recoverability differential + property suites"
tuner_status=0
python -m pytest -q -x \
  --junitxml="$REPORTS_DIR/tuner.xml" \
  tests/test_tuner.py \
  tests/test_tuner_properties.py \
  || tuner_status=$?

# Windowed vetting next (same reasoning for the batched sliding/ragged path).
echo "[ci] windowed vetting: differential + property + benchmark-smoke suites"
windowed_status=0
python -m pytest -q -x \
  --junitxml="$REPORTS_DIR/windowed.xml" \
  tests/test_vet_windows.py \
  tests/test_vet_windows_properties.py \
  tests/test_benchmarks_smoke.py \
  || windowed_status=$?

# Fused window-vet kernel: the one-launch ragged path against its ladder
# (gather rung bitwise on the cut, f64 scalar root), including the ring-wrap
# seam and the one-dispatch fused mux tick.
echo "[ci] fused window-vet: kernel differential + property suites"
windowvet_status=0
python -m pytest -q -x \
  --junitxml="$REPORTS_DIR/windowvet.xml" \
  tests/test_windowvet.py \
  tests/test_windowvet_properties.py \
  || windowvet_status=$?

# Full run (no -x) so the report covers every module, and the engine smoke
# below still executes when a test fails; exit status reflects the tests.
# The streaming/windowed suites already ran above, so they are not run twice.
echo "[ci] tier-1: pytest"
status=0
python -m pytest -q \
  --junitxml="$REPORTS_DIR/tier1.xml" \
  --ignore=tests/test_vet_stream.py \
  --ignore=tests/test_simulator_determinism.py \
  --ignore=tests/test_fleet.py \
  --ignore=tests/test_fleet_smoke.py \
  --ignore=tests/test_fleet_shard.py \
  --ignore=tests/test_fleet_shard_smoke.py \
  --ignore=tests/test_fleet_scenarios.py \
  --ignore=tests/test_fleet_transport.py \
  --ignore=tests/test_fleet_anomaly.py \
  --ignore=tests/test_changepoint_edges.py \
  --ignore=tests/test_changepoint_properties.py \
  --ignore=tests/test_obs.py \
  --ignore=tests/test_tuner.py \
  --ignore=tests/test_tuner_properties.py \
  --ignore=tests/test_vet_windows.py \
  --ignore=tests/test_vet_windows_properties.py \
  --ignore=tests/test_benchmarks_smoke.py \
  --ignore=tests/test_windowvet.py \
  --ignore=tests/test_windowvet_properties.py \
  "$@" || status=$?

echo "[ci] smoke: VetEngine backend benchmark (batch + windowed + streaming)"
smoke_status=0
python -m benchmarks.run --only vet_engine || smoke_status=$?

if [ "$streaming_status" -ne 0 ]; then
  echo "[ci] FAIL: streaming vetting suites exited $streaming_status"
  exit "$streaming_status"
fi
if [ "$fleet_status" -ne 0 ]; then
  echo "[ci] FAIL: fleet vetting suites exited $fleet_status"
  exit "$fleet_status"
fi
if [ "$shard_status" -ne 0 ]; then
  echo "[ci] FAIL: sharded fleet suites exited $shard_status"
  exit "$shard_status"
fi
if [ "$transport_status" -ne 0 ]; then
  echo "[ci] FAIL: transport suites exited $transport_status"
  exit "$transport_status"
fi
if [ "$anomaly_status" -ne 0 ]; then
  echo "[ci] FAIL: anomaly-monitor suites exited $anomaly_status"
  exit "$anomaly_status"
fi
if [ "$obs_status" -ne 0 ]; then
  echo "[ci] FAIL: observability suites / trace validation exited $obs_status"
  exit "$obs_status"
fi
if [ "$tuner_status" -ne 0 ]; then
  echo "[ci] FAIL: autotuner suites exited $tuner_status"
  exit "$tuner_status"
fi
if [ "$windowed_status" -ne 0 ]; then
  echo "[ci] FAIL: windowed vetting suites exited $windowed_status"
  exit "$windowed_status"
fi
if [ "$windowvet_status" -ne 0 ]; then
  echo "[ci] FAIL: fused window-vet suites exited $windowvet_status"
  exit "$windowvet_status"
fi
if [ "$status" -ne 0 ]; then
  echo "[ci] FAIL: pytest exited $status"
  exit "$status"
fi
if [ "$smoke_status" -ne 0 ]; then
  echo "[ci] FAIL: vet_engine smoke benchmark exited $smoke_status"
  exit "$smoke_status"
fi
echo "[ci] OK"
