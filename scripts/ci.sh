#!/usr/bin/env bash
# Tier-1 CI: install test extras, run the windowed-vetting differential suite
# explicitly, then the full pytest suite, then a fast VetEngine smoke
# benchmark (batch + windowed sections: backend agreement, batched-vs-scalar
# speedup, cached-tick cost).
#
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Test extras: hypothesis powers the property suites; without it those tests
# skip (importorskip), so an offline container still runs tier-1 green.
if ! python -c "import hypothesis" >/dev/null 2>&1; then
  echo "[ci] installing test extras (hypothesis)"
  python -m pip install --quiet hypothesis \
    || echo "[ci] WARNING: hypothesis unavailable (offline?); property tests will skip"
fi

# Windowed vetting first and explicitly (-x): these lock the batched
# sliding/ragged path to the scalar oracle — if they break, the full-suite
# report below is noise.
echo "[ci] windowed vetting: differential + property + benchmark-smoke suites"
windowed_status=0
python -m pytest -q -x \
  tests/test_vet_windows.py \
  tests/test_vet_windows_properties.py \
  tests/test_benchmarks_smoke.py \
  || windowed_status=$?

# Full run (no -x) so the report covers every module, and the engine smoke
# below still executes when a test fails; exit status reflects the tests.
# The windowed suites already ran above, so they are not run twice.
echo "[ci] tier-1: pytest"
status=0
python -m pytest -q \
  --ignore=tests/test_vet_windows.py \
  --ignore=tests/test_vet_windows_properties.py \
  --ignore=tests/test_benchmarks_smoke.py \
  "$@" || status=$?

echo "[ci] smoke: VetEngine backend benchmark (batch + windowed sections)"
smoke_status=0
python -m benchmarks.run --only vet_engine || smoke_status=$?

if [ "$windowed_status" -ne 0 ]; then
  echo "[ci] FAIL: windowed vetting suites exited $windowed_status"
  exit "$windowed_status"
fi
if [ "$status" -ne 0 ]; then
  echo "[ci] FAIL: pytest exited $status"
  exit "$status"
fi
if [ "$smoke_status" -ne 0 ]; then
  echo "[ci] FAIL: vet_engine smoke benchmark exited $smoke_status"
  exit "$smoke_status"
fi
echo "[ci] OK"
