"""Inject generated §Dry-run/§Roofline tables into EXPERIMENTS.md."""

import io
import sys
from contextlib import redirect_stdout

sys.path.insert(0, "scripts")
from gen_roofline_md import main as gen

buf = io.StringIO()
with redirect_stdout(buf):
    gen()
tables = buf.getvalue()

path = "EXPERIMENTS.md"
text = open(path).read()
marker = "<!-- ROOFLINE_TABLES -->"
assert marker in text
text = text.replace(marker, marker + "\n\n" + tables)
open(path, "w").write(text)
print(f"injected {len(tables.splitlines())} table lines")
