"""Render EXPERIMENTS.md §Roofline + §Dry-run tables from dryrun.json."""

import json
import sys

GiB = 2 ** 30


def main(path="benchmarks/results/dryrun.json"):
    d = json.load(open(path))

    print("### Single-pod roofline table (16x16 = 256 chips, TPU v5e terms)\n")
    print("| cell | n_micro | T_compute (s) | T_memory (s) | T_collective (s) |"
          " dominant | MODEL/HLO flops | peak GiB (tpu-est) | fits 16GiB |")
    print("|---|---|---|---|---|---|---|---|---|")
    for k in sorted(d):
        v = d[k]
        if v.get("mesh") != "single":
            continue
        cell = f"{v['arch']} × {v['shape']}"
        if v.get("status") == "skipped":
            print(f"| {cell} | — | — | — | — | *skipped* | — | — | {v['reason'][:48]} |")
            continue
        if v.get("status") != "ok" or "t_compute_s" not in v:
            print(f"| {cell} | — | — | — | — | *{v.get('status')}* | — | — | — |")
            continue
        peak = (v.get("peak_tpu_estimate_bytes") or
                v["memory"]["peak_device_bytes"]) / GiB
        md = v.get("moment_dtype", "f32")
        nm = f"{v.get('n_micro', 1)}" + ("/bf16-mom" if md == "bfloat16" else "")
        print(f"| {cell} | {nm} | {v['t_compute_s']:.3f} | {v['t_memory_s']:.3f} "
              f"| {v['t_collective_s']:.3f} | **{v['dominant']}** "
              f"| {v['useful_flop_ratio']:.3f} | {peak:.1f} | "
              f"{'yes' if v.get('fits_hbm') else 'NO'} |")

    print("\n### Multi-pod (2 x 16 x 16 = 512 chips) coherence gate\n")
    print("| cell | status | peak GiB (tpu-est) | fits |")
    print("|---|---|---|---|")
    for k in sorted(d):
        v = d[k]
        if v.get("mesh") != "multi":
            continue
        cell = f"{v['arch']} × {v['shape']}"
        if v.get("status") == "skipped":
            print(f"| {cell} | skipped ({v['reason'][:40]}) | — | — |")
            continue
        if v.get("status") != "ok":
            print(f"| {cell} | {v.get('status')} | — | — |")
            continue
        peak = (v.get("peak_tpu_estimate_bytes") or
                v["memory"]["peak_device_bytes"]) / GiB
        print(f"| {cell} | ok | {peak:.1f} | {'yes' if v.get('fits_hbm') else 'NO'} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
