"""Paper Fig. 8: distribution of record processing times (1000-bucket view).

A real contended run shows the heavy tail: a few records carry the majority
of total time; ~85% of records take near-identical time.
"""

from __future__ import annotations

import numpy as np

from repro.core import bucketize, vet_task
from repro.profiling import run_contended_job

from .common import emit, save_json


def run(records: int = 2000):
    tasks = run_contended_job(2, records, unit=1)
    times = np.concatenate(tasks)
    buckets = np.asarray(bucketize(times, 200))
    total = times.sum()
    top1 = np.sort(times)[-max(1, times.size // 100):].sum()
    flat = np.sort(times)[: int(times.size * 0.85)]
    spread = float(flat.std() / flat.mean())
    r = vet_task(times, buckets=200)
    emit("fig8/record_times", float(times.mean() * 1e6),
         f"top1pct_share={top1/total:.1%};base85_cv={spread:.2f};"
         f"vet={float(r.vet):.2f}")
    save_json("fig8_distribution", {
        "bucket_sums": buckets.tolist(),
        "top1pct_share": float(top1 / total),
        "base85_cv": spread,
    })
    return buckets
