"""Paper Fig. 8: distribution of record processing times (1000-bucket view).

A real contended run shows the heavy tail: a few records carry the majority
of total time; ~85% of records take near-identical time.  Vet estimation
(full-profile and the sliding per-window distribution) routes through the
batched ``VetEngine`` path.
"""

from __future__ import annotations

import numpy as np

from repro.core import bucketize
from repro.engine import default_engine
from repro.profiling import run_contended_job

from .common import emit, save_json


def run(records: int = 2000, window: int = 256, stride: int = 128):
    engine = default_engine("jax", buckets=200)
    tasks = run_contended_job(2, records, unit=1)
    times = np.concatenate(tasks)
    buckets = np.asarray(bucketize(times, 200))
    total = times.sum()
    top1 = np.sort(times)[-max(1, times.size // 100):].sum()
    flat = np.sort(times)[: int(times.size * 0.85)]
    spread = float(flat.std() / flat.mean())
    r = engine.vet_one(times)
    # Windowed view: how the vet of the stream itself is distributed — every
    # sliding window in one batched call.
    win = engine.vet_sliding(times, window=min(window, times.size),
                             stride=stride)
    emit("fig8/record_times", float(times.mean() * 1e6),
         f"top1pct_share={top1/total:.1%};base85_cv={spread:.2f};"
         f"vet={float(r.vet):.2f}")
    emit("fig8/windowed_vet", 0.0,
         f"windows={win.workers};vet_p50={float(np.median(win.vet)):.2f};"
         f"vet_max={float(win.vet.max()):.2f}")
    save_json("fig8_distribution", {
        "bucket_sums": buckets.tolist(),
        "top1pct_share": float(top1 / total),
        "base85_cv": spread,
        "windowed_vet_p50": float(np.median(win.vet)),
        "windowed_vet_max": float(win.vet.max()),
    })
    return buckets
