"""Paper Table 3: even auto-tuned ("Starfish-optimized") configurations keep a
consistent EI and vet >> 1 — the tuner minimizes step time within its knob
space, vet shows how much reducible overhead remains.
"""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.sched.autotune import tune

from .common import emit, save_json


def run():
    cfg = get_config("qwen3-14b").reduced()
    candidates = tune(cfg, batch=8, seq_len=64, steps_per_candidate=24,
                      n_micro_options=(1, 2), q_chunk_options=(32, 64),
                      verbose=False)
    eis = np.asarray([c.ei for c in candidates])
    out = []
    for i, c in enumerate(candidates):
        emit(f"table3/cand{i}", c.mean_step_s * 1e6,
             f"knobs={c.knobs};vet={c.vet:.2f};EI={c.ei:.4f}s")
        out.append({"knobs": c.knobs, "step_s": c.mean_step_s,
                    "vet": c.vet, "ei": c.ei})
    drift = float((eis.max() - eis.min()) / eis.min()) if eis.size else 0.0

    # The paper's cluster was *shared*: its Starfish-tuned jobs still showed
    # vet 3.3-4.2 because tuning can't remove contention overhead.  Re-audit
    # the best tuned config under host contention: vet must rise while EI
    # stays at the tuned-job level.
    import threading

    from repro.profiling.contention import make_record_work

    stop = threading.Event()
    spin_work = make_record_work()

    def spin():
        while not stop.is_set():
            spin_work()

    th = threading.Thread(target=spin, daemon=True)
    th.start()
    try:
        contended = tune(cfg, batch=8, seq_len=64, steps_per_candidate=24,
                         n_micro_options=(candidates[0].knobs["n_micro"],),
                         q_chunk_options=(candidates[0].knobs["q_chunk"],),
                         verbose=False)[0]
    finally:
        stop.set()
        th.join()
    emit("table3/best_contended", contended.mean_step_s * 1e6,
         f"vet={contended.vet:.2f};EI={contended.ei:.4f}s;"
         f"ei_vs_idle={contended.ei / candidates[0].ei:.2f}x")
    emit("table3/summary", 0.0,
         f"ei_consistency_drift={drift:.1%};best={candidates[0].knobs};"
         f"vet_idle={candidates[0].vet:.2f};vet_contended={contended.vet:.2f}")
    save_json("table3_tuned", {
        "candidates": out, "ei_drift": drift,
        "contended": {"vet": contended.vet, "ei": contended.ei,
                      "step_s": contended.mean_step_s},
    })
    return candidates
