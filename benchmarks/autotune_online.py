"""Online autotuner accounting: recovery quality + the cost-perf elbow.

Three questions priced here:

- **Does the closed loop find the optimum?**  The full ``VetTuner`` loop
  runs against the ``tunable`` scenario on every backend, noiseless and
  under seeded noise, and the committed artifact records the recovered
  assignment's per-knob index error against the exhaustive grid oracle
  (and the oracle's own agreement with the designed optimum).
- **Where is the operating point?**  A diminishing-returns parallelism
  sweep (runtime ~ 1 + beta/v on a doubling unit grid, the nes-spark
  executor-count shape) is priced through the shared candidate evaluator
  and walked with the elbow rule — the artifact commits the frontier and
  the chosen elbow.
- **What does tuning cost?**  Mean wall time per closed-loop tick vs the
  same fleet ticked without a tuner attached.

Wall-clock numbers are environment-dependent and not pinned; the recovery
and frontier fields are pinned by ``tests/test_benchmark_results_schema.py``
(error == 0 noiseless on every backend, <= 1 step noisy, frontier runtimes
strictly decreasing with an interior, strictly-increasing elbow trail).
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.engine import BACKENDS, VetEngine
from repro.fleet import tunable
from repro.profiling import simulate_records
from repro.sched.tuner import (
    FrontierPoint,
    elbow_walk,
    evaluate_candidate,
    grid_scenario,
    tune_scenario,
)

from .common import emit, save_json

SEED = 0
NOISE = 0.15
NOISELESS_TICKS = 96
NOISY_TICKS = 160
FRONTIER_UNITS = (1, 2, 4, 8, 16)
FRONTIER_BETA = 8.0


def _error_steps(a, b, scenario) -> int:
    return max(abs(k.index_of(a[k.name]) - k.index_of(b[k.name]))
               for k in scenario.knobs)


def _recover(backend: str, *, noise: float, max_ticks: int,
             settle: int) -> Dict:
    sc = tunable(seed=SEED, noise=noise)
    grid = grid_scenario(tunable(seed=SEED), engine=VetEngine(backend,
                                                              buckets=64))
    t0 = time.perf_counter()
    rep = tune_scenario(sc, engine=VetEngine(backend, buckets=64),
                        max_ticks=max_ticks, settle=settle, seed=SEED)
    wall = time.perf_counter() - t0
    return {
        "best": rep.best,
        "grid_best": grid.best[0],
        "designed_optimum": dict(sc.optimum),
        "error_steps": _error_steps(rep.best, grid.best[0], sc),
        "rounds": rep.rounds,
        "rollbacks": rep.rollbacks,
        "converged": rep.converged,
        "ticks": rep.ticks,
        "tick_us": wall / rep.ticks * 1e6,
    }


def _frontier() -> Dict:
    """Diminishing-returns sweep: each parallelism step v scales the
    reducible-overhead channel by (1 + beta/v); runtime is the summed
    profile, cost is runtime * v."""
    prof = simulate_records(512, seed=SEED, overhead_scale=2e-3,
                            pareto_alpha=2.0)
    eng = VetEngine("numpy", buckets=64)
    points, vets = [], []
    for v in FRONTIER_UNITS:
        times = prof.ideal + prof.overhead * (1.0 + FRONTIER_BETA / v)
        cand = evaluate_candidate({"parallelism": v}, times, engine=eng)
        points.append(FrontierPoint(cand.knobs, float(times.sum()), float(v)))
        vets.append(cand.vet)
    res = elbow_walk(points)
    return {
        "units": list(FRONTIER_UNITS),
        "beta": FRONTIER_BETA,
        "runtime_s": [p.runtime for p in points],
        "cost": [p.cost for p in points],
        "vet": vets,
        "elbow_index": res.index,
        "elbow_units": res.point.units,
        "trail": list(res.trail),
    }


def _overhead() -> Dict:
    """Closed-loop tick price vs the same fleet ticked without a tuner."""
    from repro.fleet.mux import VetMux
    from repro.sched.tuner import VetTuner, objective_from_tick

    def loop(tuned: bool) -> float:
        sc = tunable(seed=SEED)
        mux = VetMux(VetEngine("numpy", buckets=64), monitor=False)
        for spec in sc.specs:
            spec.register(mux)
        tuner = VetTuner(sc.hooks(), seed=SEED) if tuned else None
        n = 64
        t0 = time.perf_counter()
        for t in range(n):
            for sid, chunk in sc.chunks(t).items():
                mux.feed(sid, chunk)
            y = objective_from_tick(mux.tick())
            if tuner is not None:
                tuner.step(y)
        return (time.perf_counter() - t0) / n * 1e6

    plain_us = loop(False)
    tuned_us = loop(True)
    return {"plain_tick_us": plain_us, "tuned_tick_us": tuned_us,
            "overhead_pct": (tuned_us / plain_us - 1.0) * 100.0}


def run() -> None:
    recovery: Dict[str, Dict] = {}
    for backend in BACKENDS:
        noiseless = _recover(backend, noise=0.0, max_ticks=NOISELESS_TICKS,
                             settle=1)
        noisy = _recover(backend, noise=NOISE, max_ticks=NOISY_TICKS,
                         settle=2)
        recovery[backend] = {"noiseless": noiseless, "noisy": noisy}
        emit(f"autotune_online_{backend}", noiseless["tick_us"],
             f"err={noiseless['error_steps']} "
             f"noisy_err={noisy['error_steps']} "
             f"rounds={noiseless['rounds']} "
             f"converged={noiseless['converged']}")

    frontier = _frontier()
    emit("autotune_online_elbow", 0.0,
         f"units={frontier['elbow_units']:.0f} "
         f"trail={'>'.join(str(i) for i in frontier['trail'])}")

    overhead = _overhead()
    emit("autotune_online_overhead", overhead["tuned_tick_us"],
         f"plain={overhead['plain_tick_us']:.1f}us "
         f"({overhead['overhead_pct']:+.1f}%)")

    save_json("autotune_online", {
        "seed": SEED,
        "noise": NOISE,
        "noisy_ticks": NOISY_TICKS,
        "recovery": recovery,
        "frontier": frontier,
        "overhead": overhead,
    })


if __name__ == "__main__":
    run()
