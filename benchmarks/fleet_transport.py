"""Cross-process transport overhead + crash recovery accounting.

The sharded benchmark (``benchmarks/fleet_shard.py``) shows per-shard work
falling as a fleet is partitioned — but in one process.  This benchmark
prices the actual process boundary (``repro.fleet.transport``) and proves
the recovery path on a real killed worker:

- **Per-tick overhead.**  The same 64-worker / 2-shard fleet is driven
  through the in-process ``ShardedVetMux``, the ``inprocess`` transport
  driver (command protocol, no pipes), and the ``process`` driver (real
  worker processes + pipes + per-tick checkpoints).  The deltas separate
  protocol cost from transport cost.  Numpy backend: the point is the
  boundary, not the kernels, and worker spawn stays cheap.
- **Crash recovery.**  One shard worker is killed mid-job (``mid`` fault:
  the tick is committed worker-side but the reply is lost — the torn
  dispatch).  The driver retries, respawns from checkpoint + journal, and
  the run's merged ``vet_job`` is compared against the in-process oracle
  on identical feeds; the committed artifact pins the error at 1e-9 and
  exactly one respawn with no dispatch/row drift (no window vetted twice),
  via ``tests/test_benchmark_results_schema.py``.

Timing numbers are environment-dependent (process spawn, pipe latency) and
are *not* pinned by the schema test — only the correctness and accounting
fields are.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.fleet import ShardedVetMux, TransportVetMux

from .common import emit, save_json

WORKERS = 64
SHARDS = 2
STEPS = 6
CHUNK = 12  # records per worker per step: 2 new windows/tick at w=8 s=4


def _drive(mux, *, fault_at=None, seed=3):
    """Deterministic register/feed/tick loop shared by every variant."""
    rng = np.random.default_rng(seed)
    for w in range(WORKERS):
        mux.register(f"w{w}", window=8, stride=4, capacity=64)
    walls, last = [], None
    for step in range(STEPS):
        for w in range(WORKERS):
            mux.feed(f"w{w}", rng.standard_normal(CHUNK) ** 2 + 1e-3)
        if fault_at is not None and step == fault_at:
            mux.inject_fault(0, at_tick=fault_at + 1, mode="mid")
        t0 = time.perf_counter()
        last = mux.tick()
        walls.append(time.perf_counter() - t0)
    steady = walls[1:]  # first tick pays ring/row growth
    return sum(steady) / len(steady) * 1e6, last


def run() -> Dict:
    tick_us, oracle_last = _drive(ShardedVetMux(SHARDS, backend="numpy"))
    oracle_job = oracle_last.job.vet_job
    out: Dict = {
        "workers": WORKERS,
        "shards": SHARDS,
        "steps": STEPS,
        "backend": "numpy",
        "inprocess_sharded_tick_us": tick_us,
    }
    emit(f"fleet_transport/sharded_{WORKERS}w", tick_us, "oracle")

    for driver in ("inprocess", "process"):
        with TransportVetMux(SHARDS, backend="numpy", driver=driver) as fl:
            tick_us, last = _drive(fl)
            stats = fl.stats
            out[f"{driver}_driver"] = {
                "tick_us": tick_us,
                "vet_job_abs_err": abs(last.job.vet_job - oracle_job),
                "dispatches": stats.dispatches,
                "rows": stats.rows,
                "retries": stats.retries,
                "respawns": stats.respawns,
            }
            emit(f"fleet_transport/{driver}_{WORKERS}w", tick_us,
                 f"disp={stats.dispatches};retries={stats.retries}")

    # Crash recovery: kill shard 0 mid-tick, resume, stay equal to the
    # oracle with every window vetted exactly once.
    with TransportVetMux(SHARDS, backend="numpy", driver="process",
                         backoff_base=0.01) as fl:
        t0 = time.perf_counter()
        _, last = _drive(fl, fault_at=2)
        wall_s = time.perf_counter() - t0
        stats = fl.stats
        acc = fl.accounts[0]
        out["kill_resume"] = {
            "fault": "mid-tick exit on shard 0, step 2",
            "vet_job_abs_err": abs(last.job.vet_job - oracle_job),
            "dispatches": stats.dispatches,
            "rows": stats.rows,
            "retries": stats.retries,
            "respawns": stats.respawns,
            "shard0_checkpoints": acc.checkpoints,
            "shard0_elapsed_s": acc.elapsed_s,
            "run_wall_s": wall_s,
        }
        emit("fleet_transport/kill_resume", wall_s * 1e6,
             f"respawns={stats.respawns};retries={stats.retries};"
             f"abs_err={out['kill_resume']['vet_job_abs_err']:.2e}")

    # The oracle counters every variant above must match (re-driven fresh
    # so its stats cover exactly the same feeds).
    o = ShardedVetMux(SHARDS, backend="numpy")
    _drive(o)
    out["oracle"] = {"dispatches": o.stats.dispatches, "rows": o.stats.rows,
                     "vet_job": oracle_job}
    save_json("fleet_transport", out)
    return out
