"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; detailed payloads land in
benchmarks/results/*.json.  ``python -m benchmarks.run [--only NAME]``.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("table2_slots", "Paper Table 2: PR/EI/vet vs worker count"),
    ("table3_tuned", "Paper Table 3: vet audit of auto-tuned configs"),
    ("fig1_gap", "Paper Fig 1: tuned time vs estimated ideal"),
    ("fig3_spill", "Paper Fig 3: aux-phase constancy"),
    ("fig6_ks", "Paper Fig 6: vet stability across same-config jobs (KS)"),
    ("fig8_distribution", "Paper Fig 8: record-time distribution"),
    ("fig9_tail", "Paper Fig 9: Hill plot / emplot heavy tail"),
    ("fig13_io", "Paper Fig 13: fast vs slow input device"),
    ("fig14_correlation", "Paper Fig 14: vet vs task-time correlation"),
    ("roofline", "Framework: roofline table from dry-run"),
    ("kernels_bench", "Framework: Pallas kernel micro-benchmarks"),
    ("windowvet", "Framework: fused window-vet launch vs bucketed gather"),
    ("vet_engine", "Framework: VetEngine backend comparison (numpy/jax/pallas)"),
    ("fleet", "Framework: VetMux coalesced fleet ticks vs per-stream loop"),
    ("fleet_shard", "Framework: ShardedVetMux shard-scaling vs one mux"),
    ("fleet_transport", "Framework: cross-process transport driver vs "
     "in-process fleet, with kill+resume recovery"),
    ("fleet_anomaly", "Framework: anomaly-monitor tick overhead + "
     "detection quality over the scenario bank"),
    ("fleet_obs", "Framework: tracer overhead gate + cross-process trace "
     "+ self-applied optimality ledger"),
    ("autotune_online", "Framework: online VetTuner recovery vs the grid "
     "oracle + cost-perf elbow + closed-loop tick overhead"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single suite")
    args = ap.parse_args()
    if args.only and args.only not in {name for name, _ in SUITES}:
        ap.error(f"unknown suite {args.only!r}; choose from "
                 f"{', '.join(name for name, _ in SUITES)}")

    print("name,us_per_call,derived")
    failures = []
    for mod_name, desc in SUITES:
        if args.only and args.only != mod_name:
            continue
        print(f"# === {mod_name}: {desc}", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run()
            print(f"# --- {mod_name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(mod_name)
            print(f"# !!! {mod_name} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}", flush=True)
        sys.exit(1)
    print("# all suites passed", flush=True)


if __name__ == "__main__":
    main()
