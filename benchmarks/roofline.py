"""Roofline table from the dry-run JSON (§Roofline of EXPERIMENTS.md).

Per (arch x shape) single-pod cell: the three terms (compute / memory /
collective) in seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and
the implied roofline fraction.  Multi-pod rows report the coherence/memory
gate only.
"""

from __future__ import annotations

import json
import os

from .common import emit, save_json

DRYRUN_JSON = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


def load():
    with open(DRYRUN_JSON) as f:
        return json.load(f)


def run():
    try:
        results = load()
    except FileNotFoundError:
        emit("roofline/missing", 0.0, "run launch/dryrun.py --sweep first")
        return None

    rows = []
    for key in sorted(results):
        r = results[key]
        if r.get("mesh") != "single":
            continue
        if r.get("status") == "skipped":
            rows.append({"cell": key, "status": "skipped", "reason": r["reason"]})
            continue
        if r.get("status") != "ok" or "t_compute_s" not in r:
            rows.append({"cell": key, "status": r.get("status", "?")})
            continue
        # Roofline fraction: for compute-shaped cells, the share of the bound
        # spent on useful model flops; for decode (memory-shaped), how close
        # HLO traffic is to the mandatory params+cache streaming floor.
        if r.get("shape") in ("decode_32k", "long_500k"):
            floor = r.get("mandatory_bytes_per_chip")
            frac = (floor / (r["t_memory_s"] * 819e9)) if floor else (
                r["useful_flop_ratio"] * r["t_compute_s"] / r["roofline_bound_s"])
        else:
            frac = r["useful_flop_ratio"] * r["t_compute_s"] / r["roofline_bound_s"]
        row = {
            "cell": key,
            "t_compute_s": r["t_compute_s"],
            "t_memory_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"],
            "dominant": r["dominant"],
            "useful_flop_ratio": r["useful_flop_ratio"],
            "roofline_fraction": frac,
            "n_micro": r.get("n_micro"),
            "fits_hbm": r.get("fits_hbm"),
        }
        rows.append(row)
        emit(f"roofline/{key}", r["roofline_bound_s"] * 1e6,
             f"dom={r['dominant']};frac={frac:.3f};"
             f"useful={r['useful_flop_ratio']:.2f};fits={r.get('fits_hbm')}")
    ok = [x for x in rows if "roofline_fraction" in x]
    if ok:
        worst = min(ok, key=lambda x: x["roofline_fraction"])
        coll = [x for x in ok if x["dominant"] == "collective"]
        emit("roofline/summary", 0.0,
             f"cells={len(ok)};worst={worst['cell']}"
             f"({worst['roofline_fraction']:.3f});collective_bound={len(coll)}")
    save_json("roofline_table", {"rows": rows})
    return rows
