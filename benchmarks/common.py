"""Shared benchmark helpers: CSV emission + result persistence."""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

_rows: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def rows() -> List[str]:
    return list(_rows)


def save_json(name: str, payload: Dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True, default=float)
    return path


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Mean wall seconds per call (blocking fn)."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters
