"""Paper Fig. 1: actual (tuned) processing time vs the estimated ideal.

The best auto-tuned candidate still sits above EI — the optimization headroom
the paper's measure exposes.
"""

from __future__ import annotations

from repro.configs import get_config
from repro.sched.autotune import tune

from .common import emit, save_json


def run():
    cfg = get_config("mamba2-130m").reduced()
    candidates = tune(cfg, batch=8, seq_len=64, steps_per_candidate=20,
                      n_micro_options=(1,), q_chunk_options=(64,),
                      verbose=False)
    best = candidates[0]
    gap = best.vet - 1.0
    ei_per_step = best.mean_step_s / best.vet  # EI/PR ratio applied per step
    emit("fig1/tuned_vs_ideal", best.mean_step_s * 1e6,
         f"PR_per_step={best.mean_step_s:.4f}s;"
         f"EI_per_step={ei_per_step:.4f}s;vet={best.vet:.2f};"
         f"headroom={gap:.0%}")
    save_json("fig1_gap", {"best": {"knobs": best.knobs, "vet": best.vet,
                                    "step_s": best.mean_step_s}})
    return best
