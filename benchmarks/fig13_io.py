"""Paper Fig. 13: fast storage (SSD) vs slow (HDD) — vet moves toward 1.

Analogue: per-record input stalls injected (slow device) vs none (fast).
The slow-device job's vet is materially higher; the fast job approaches the
paper's SSD observation (vet clustered near ~1.3).
"""

from __future__ import annotations

import time

from repro.engine import default_engine
from repro.profiling import run_contended_job

from .common import emit, save_json


def run(records: int = 300):
    from repro.profiling.contention import make_record_work

    base_work = make_record_work()

    state = {"i": 0}

    def slow_work():
        state["i"] += 1
        if state["i"] % 8 == 0:
            time.sleep(0.004)  # disk-access-scale stall inside the record
        return base_work()

    fast = run_contended_job(2, records, unit=5)
    slow = run_contended_job(2, records, unit=5, work=slow_work)
    engine = default_engine("jax")
    vf, vs = engine.vet_many(fast), engine.vet_many(slow)
    emit("fig13/fast_vs_slow", 0.0,
         f"vet_fast={vf.vet_job:.2f};vet_slow={vs.vet_job:.2f};"
         f"ei_fast={vf.ei.mean():.4f}s;ei_slow={vs.ei.mean():.4f}s")
    save_json("fig13_io", {
        "vet_fast": vf.vet_job, "vet_slow": vs.vet_job,
        "ei_fast": float(vf.ei.mean()), "ei_slow": float(vs.ei.mean()),
    })
    return vf, vs
