"""Fleet multiplexing: per-stream tick loop vs ``VetMux`` coalesced dispatch.

The workload is the straggler-controller / fleet-dashboard shape: N live
workers, each with its own ``VetStream``, every tick appends a chunk per
worker and re-estimates.  The baseline is the pre-fleet path — tick every
stream in a Python loop, one engine dispatch per stream — against the mux,
which drains all N deltas and coalesces them into one shape-bucketed batched
dispatch per tick.  Both paths compute identical rows (the differential
contract in ``tests/test_fleet.py``); the contrast is pure dispatch count
and wall clock, reported per backend at 256 workers plus a jax scaling point
at 1024.

A heterogeneous section replays the scenario bank's ``mixed_windows`` shape
at fleet scale: the mux pays one dispatch per *distinct window length*
(3 here) per tick, not one per stream.

Engines run with the result cache disabled so every tick pays its real
compute; dispatch counts come from ``VetEngine.dispatches`` and are exact,
not timed (the >= 10x reduction floor pinned by
``tests/test_benchmark_results_schema.py`` is deterministic).
"""

from __future__ import annotations

import time

import numpy as np

from repro.engine import BACKENDS, VetEngine, VetStream
from repro.fleet import VetMux, build, play

from .common import emit, save_json, time_fn


def _fleet_times(workers: int, n_records: int, seed: int = 0):
    from repro.profiling import simulate_records

    return [simulate_records(n_records, seed=seed * 1000 + i).times
            for i in range(workers)]


def bench_fleet_tick(workers: int = 256, *, window: int = 64,
                     stride: int = 32, chunk: int = 32, n_ticks: int = 4,
                     backend: str = "jax", seed: int = 0) -> dict:
    """One backend's loop-vs-mux contrast at a given fleet size.

    Feeds are identical on both paths and excluded from the timed region —
    the measured cost is the per-tick estimation sweep (the controller's
    ``decide()`` hot path): N stream ticks vs one mux tick.
    """
    # A full window up front: the warmup tick below must complete (and so
    # compile) the same per-tick delta shape the timed ticks replay —
    # window records for the first window, then one stride-sized window
    # per chunk per tick.
    times = _fleet_times(workers, window + n_ticks * chunk, seed=seed)
    cap = 4 * window

    def tick_slice(i, k):
        return times[i][window + (k - 1) * chunk:window + k * chunk]

    # --- baseline: the pre-fleet per-stream tick loop -------------------
    eng_loop = VetEngine(backend, buckets=64, cache_size=0)
    streams = [VetStream(eng_loop, window=window, stride=stride, capacity=cap)
               for _ in range(workers)]
    for i, st in enumerate(streams):  # warmup: compile the delta shape
        st.append(times[i][:window])
        st.tick()
    loop_s = 0.0
    d0 = eng_loop.dispatches
    for k in range(1, n_ticks + 1):
        for i, st in enumerate(streams):
            st.append(tick_slice(i, k))
        t0 = time.perf_counter()
        for st in streams:
            st.tick()
        loop_s += time.perf_counter() - t0
    loop_dispatches = (eng_loop.dispatches - d0) / n_ticks
    loop_us = loop_s / n_ticks * 1e6

    # --- the mux: one coalesced dispatch per window-length bucket -------
    eng_mux = VetEngine(backend, buckets=64, cache_size=0)
    mux = VetMux(eng_mux)
    for i in range(workers):
        mux.register(i, window=window, stride=stride, capacity=cap)
    for i in range(workers):
        mux.feed(i, times[i][:window])
    mux.tick()  # warmup: compile the coalesced pow2 batch shape
    mux_s = 0.0
    d0 = eng_mux.dispatches
    for k in range(1, n_ticks + 1):
        for i in range(workers):
            mux.feed(i, tick_slice(i, k))
        t0 = time.perf_counter()
        mux.tick()
        mux_s += time.perf_counter() - t0
    mux_dispatches = (eng_mux.dispatches - d0) / n_ticks
    mux_us = mux_s / n_ticks * 1e6

    out = {
        "workers": workers,
        "loop_tick_us": loop_us,
        "mux_tick_us": mux_us,
        "tick_speedup": loop_us / mux_us,
        "loop_dispatches_per_tick": loop_dispatches,
        "mux_dispatches_per_tick": mux_dispatches,
        "dispatch_reduction": loop_dispatches / mux_dispatches,
    }
    emit(f"fleet/{backend}_{workers}w", mux_us,
         f"loop_us={loop_us:.1f};speedup={out['tick_speedup']:.1f}x;"
         f"dispatches={loop_dispatches:.0f}->{mux_dispatches:.0f}")
    return out


def bench_mixed_windows(workers: int = 255, *, n_ticks: int = 4,
                        backend: str = "jax", seed: int = 1) -> dict:
    """Heterogeneous fleet: dispatches collapse to the window-length count."""
    sc = build("mixed_windows", n_workers=workers, n_ticks=n_ticks, seed=seed)
    n_lengths = len({s.window for s in sc.specs})
    eng = VetEngine(backend, buckets=64, cache_size=0)
    mux = VetMux(eng)
    t0 = time.perf_counter()
    ticks = play(sc, mux)
    wall = time.perf_counter() - t0
    dispatching = [t.dispatches for t in ticks if t.rows]
    out = {
        "workers": workers,
        "window_lengths": n_lengths,
        "n_ticks": n_ticks,
        "max_dispatches_per_tick": max(dispatching),
        "rows": mux.stats.rows,
        "wall_s": wall,
    }
    emit(f"fleet/mixed_{backend}_{workers}w", wall / len(ticks) * 1e6,
         f"buckets={out['max_dispatches_per_tick']};"
         f"streams={workers};rows={out['rows']}")
    return out


def bench_mixed_fused(workers: int = 256, *, n_ticks: int = 4, seed: int = 1,
                      strides_per_tick: int = 1) -> dict:
    """mixed_windows on pallas: the fused one-launch tick vs the bucketed
    gather path (``fused=False``), same scenario, same rows.

    Two numbers matter: dispatches/tick (window-length count on the bucketed
    path, 1 fused) and peak per-tick staged bytes (the bucketed path
    materializes O(windows x length) gather matrices; the fused launch
    stages the O(ring) arena + per-row metadata).
    """
    sc = build("mixed_windows", n_workers=workers, n_ticks=n_ticks, seed=seed,
               strides_per_tick=strides_per_tick)
    n_lengths = len({s.window for s in sc.specs})
    out = {"workers": workers, "window_lengths": n_lengths,
           "n_ticks": n_ticks, "strides_per_tick": strides_per_tick}
    for label, fused in (("fused", True), ("bucketed", False)):
        eng = VetEngine("pallas", buckets=64, cache_size=0, fused=fused)
        mux = VetMux(eng)
        for spec in sc.specs:
            spec.register(mux)
        ticks, peak_bytes, wall = [], 0, 0.0
        for event in sc.events:
            for sid, chunk in event.chunks.items():
                mux.feed(sid, chunk)
            b0 = eng.dispatch_bytes
            t0 = time.perf_counter()
            ticks.append(mux.tick())
            wall += time.perf_counter() - t0
            peak_bytes = max(peak_bytes, eng.dispatch_bytes - b0)
        out[label] = {
            "max_dispatches_per_tick": max(t.dispatches for t in ticks
                                           if t.rows),
            "peak_tick_bytes": peak_bytes,
            "rows": mux.stats.rows,
            "wall_s": wall,
        }
    out["dispatch_reduction"] = (out["bucketed"]["max_dispatches_per_tick"]
                                 / out["fused"]["max_dispatches_per_tick"])
    out["bytes_ratio"] = (out["bucketed"]["peak_tick_bytes"]
                          / out["fused"]["peak_tick_bytes"])
    emit(f"fleet/mixed_fused_{workers}w",
         out["fused"]["wall_s"] / n_ticks * 1e6,
         f"dispatches={out['bucketed']['max_dispatches_per_tick']}->"
         f"{out['fused']['max_dispatches_per_tick']};"
         f"bytes_ratio={out['bytes_ratio']:.2f}x")
    return out


def run():
    out = {"window": 64, "stride": 32, "chunk": 32, "workers": 256}
    for backend in BACKENDS:
        out[backend] = bench_fleet_tick(
            256, backend=backend, n_ticks=(2 if backend == "numpy" else 4))
    # The schema floor reads the jax number (the production path); each
    # backend section carries its own reduction too.
    out["dispatch_reduction"] = out["jax"]["dispatch_reduction"]
    out["scaling_1024"] = bench_fleet_tick(1024, backend="jax", n_ticks=2)
    out["mixed_windows"] = bench_mixed_windows(255, backend="jax")
    emit("fleet/summary_256w", 0.0,
         f"dispatch_reduction={out['dispatch_reduction']:.0f}x;"
         f"jax_speedup={out['jax']['tick_speedup']:.1f}x")
    save_json("fleet", out)
    return out
