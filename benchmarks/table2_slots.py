"""Paper Table 2: PR/EI statistics with varying worker ("map slot") count.

Real measurement on this host: W in {1,2,3,4} concurrent workers contend for
the core; PR grows ~linearly with W while EI stays consistent and vet_job
rises — the paper's central result (theirs: PR 3.2s->10.3s, EI 1.26s->1.45s,
vet 2.4->7.2 for slots 1->4).
"""

from __future__ import annotations

from repro.engine import default_engine
from repro.profiling import run_contended_job

from .common import emit, save_json


def run(records_per_task: int = 400, unit: int = 5):
    engine = default_engine("jax")
    table = {}
    for w in (1, 2, 3, 4):
        tasks = run_contended_job(w, records_per_task, unit=unit)
        jr = engine.vet_many(tasks)  # all tasks in one batched call
        table[w] = {
            "pr_mean": float(jr.pr.mean()), "pr_std": float(jr.pr.std()),
            "ei_mean": float(jr.ei.mean()), "ei_std": float(jr.ei.std()),
            "vet_job": jr.vet_job,
        }
        emit(
            f"table2/slots={w}",
            table[w]["pr_mean"] * 1e6 / max(records_per_task // unit, 1),
            f"vet={table[w]['vet_job']:.2f};EI={table[w]['ei_mean']:.4f}s;"
            f"PR={table[w]['pr_mean']:.4f}s",
        )
    # headline checks (reported, not asserted): PR grows, EI consistent
    pr_growth = table[4]["pr_mean"] / table[1]["pr_mean"]
    ei_drift = abs(table[4]["ei_mean"] - table[1]["ei_mean"]) / table[1]["ei_mean"]
    vet_growth = table[4]["vet_job"] / table[1]["vet_job"]
    emit("table2/summary", 0.0,
         f"pr_growth={pr_growth:.2f}x;ei_drift={ei_drift:.1%};"
         f"vet_growth={vet_growth:.2f}x")
    save_json("table2_slots", {"table": table, "pr_growth": pr_growth,
                               "ei_drift": ei_drift})
    return table
