"""Fused window-vet kernel: one-launch ragged fleets vs bucketed gather.

Three sections, all on the pallas backend (interpret mode on this CPU
container — the dispatch and byte counts are exact and platform-independent;
the wall clocks are CPU correctness/latency tracking, not TPU numbers):

- ``w256`` / ``w1024`` — the ``mixed_windows`` fleet scenario (window
  lengths 16/32/64 cycling across workers) with the fused engine vs the
  same engine forced onto the bucketed gather path.  Fused ticks issue ONE
  launch regardless of how many window lengths are live; bucketed ticks
  issue one per distinct length.  Peak per-tick staged bytes contrast the
  O(ring) fused arena against the O(windows x length) gather matrices.
- ``sliding`` — kernel-level micro: ``fused_window_vet`` over a dense
  sliding window set vs the engine's materialize-and-batch gather path on
  the same stream, plus the staged-vs-materialized byte ledger.

The committed ``windowvet.json`` is schema-pinned by
``tests/test_benchmark_results_schema.py``: fused dispatches/tick == 1 and
fused staged bytes strictly below the bucketed path are acceptance floors,
not advisory numbers.
"""

from __future__ import annotations

import numpy as np

from repro.engine import VetEngine
from repro.kernels.windowvet import fused_window_vet
from repro.kernels.windowvet.ops import staged_bytes

from .common import emit, save_json, time_fn
from .fleet import bench_mixed_fused


def bench_sliding(n_records: int = 4096, *, window: int = 64,
                  stride: int = 16, seed: int = 0, iters: int = 3) -> dict:
    """One stream, every stride-spaced window: fused kernel vs gather path."""
    from repro.profiling import simulate_records

    times = simulate_records(n_records, seed=seed).times
    starts = np.arange(0, n_records - window + 1, stride, dtype=np.int64)
    lengths = np.full(starts.size, window, dtype=np.int64)

    t_fused = time_fn(
        lambda: fused_window_vet(times, starts, lengths), iters=iters)
    gather = VetEngine("pallas", buckets=64, cache_size=0, fused=False)
    t_gather = time_fn(
        lambda: gather.vet_sliding(times, window=window, stride=stride),
        iters=iters)

    rows_p = max(8, 1 << (int(starts.size) - 1).bit_length())
    materialized = rows_p * window * 8  # the gather path's padded f64 matrix
    staged = staged_bytes(n_records, starts.size, window)
    out = {
        "n_records": n_records,
        "window": window,
        "stride": stride,
        "num_windows": int(starts.size),
        "fused_us": t_fused * 1e6,
        "gather_us": t_gather * 1e6,
        "staged_bytes": staged,
        "materialized_bytes": materialized,
        "bytes_ratio": materialized / staged,
    }
    emit("windowvet/sliding", out["fused_us"],
         f"gather_us={out['gather_us']:.1f};"
         f"bytes_ratio={out['bytes_ratio']:.2f}x")
    return out


def run():
    out = {
        "sliding": bench_sliding(),
        "w256": bench_mixed_fused(256, strides_per_tick=2),
        "w1024": bench_mixed_fused(1024, n_ticks=3, strides_per_tick=2),
    }
    emit("windowvet/summary", 0.0,
         f"w256_dispatches={out['w256']['bucketed']['max_dispatches_per_tick']}"
         f"->{out['w256']['fused']['max_dispatches_per_tick']};"
         f"w1024_bytes_ratio={out['w1024']['bytes_ratio']:.2f}x")
    save_json("windowvet", out)
    return out
