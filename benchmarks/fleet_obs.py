"""Observability cost + the self-applied optimality ledger.

Three sections, one committed artifact (``results/fleet_obs.json``):

- **Disabled-tracer overhead gate.**  The instrumentation seam stays in
  the hot path even when no tracer is attached, so its no-op cost is the
  one number that must be provably negligible.  We price a null span
  (``span(None, ...)`` enter/exit) directly, count the spans one traced
  256-worker mux tick emits, and bound the disabled-path overhead as
  ``spans_per_tick * null_span_ns`` against the measured untraced tick —
  the committed ``disabled_overhead_frac`` must stay under 5%
  (``tests/test_benchmark_results_schema.py`` pins it).  The traced-mode
  delta is also reported, unpinned: tracing is opt-in and allowed to cost.
- **Optimality ledger per backend.**  The paper's measure applied to our
  own stack: drive the ``mixed_windows`` scenario through a traced
  ``VetMux`` on every backend and report measured-over-floor ratios per
  stage (``repro.obs.ledger``).  Soundness — every ratio >= 1.0 — is
  pinned by the schema test on all three backends; the ratios themselves
  are the headroom numbers later perf PRs are judged by.
- **Cross-process trace.**  A 2-shard ``TransportVetMux`` on the process
  driver, traced end to end; worker spans ride back on tick replies and
  are adopted under their shard's pid.  The exported Chrome trace
  (``results/fleet_obs_trace.json``, Perfetto-loadable) must validate
  (well-formed nesting per lane) and span all three processes.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.engine import VetEngine
from repro.fleet import VetMux, TransportVetMux, build, play
from repro.obs import Tracer, ledger_from, to_chrome, validate_chrome
from repro.obs.trace import span as _span

from .common import emit, save_json

WORKERS = 256
TICKS = 6
BACKENDS = ("numpy", "jax", "pallas")


def _null_span_ns(iters: int = 200_000) -> float:
    """Per-call cost of the disabled-tracer no-op path (enter + exit)."""
    with _span(None, "warmup"):
        pass
    t0 = time.perf_counter()
    for _ in range(iters):
        with _span(None, "x"):
            pass
    return (time.perf_counter() - t0) / iters * 1e9


def _drive(mux, *, workers=WORKERS, ticks=TICKS, seed=7):
    """Deterministic register/feed/tick loop; returns steady-state tick us."""
    rng = np.random.default_rng(seed)
    for w in range(workers):
        mux.register(f"w{w}", window=64, stride=32, capacity=256)
    walls = []
    for _ in range(ticks):
        for w in range(workers):
            mux.feed(f"w{w}", rng.standard_normal(64) ** 2 + 1e-3)
        t0 = time.perf_counter()
        mux.tick()
        walls.append(time.perf_counter() - t0)
    steady = walls[1:]  # first tick pays compile + ring growth
    return sum(steady) / len(steady) * 1e6


def _overhead_section() -> Dict:
    null_ns = _null_span_ns()
    emit("fleet_obs/null_span", null_ns * 1e-3, "disabled-tracer no-op")

    # Throwaway drive so jax's process-wide jit cache is warm before either
    # measured run — otherwise the first variant pays all compiles and the
    # off/on comparison is meaningless.
    _drive(VetMux(VetEngine("jax", buckets=64)))
    tick_off_us = _drive(VetMux(VetEngine("jax", buckets=64)))

    tracer = Tracer()
    mux_on = VetMux(VetEngine("jax", buckets=64), tracer=tracer)
    tick_on_us = _drive(mux_on)
    spans_per_tick = len(tracer.drain()) / TICKS

    # Upper bound on what the seam costs when no tracer is attached: every
    # span site collapses to one null-span call.
    disabled_frac = spans_per_tick * null_ns * 1e-3 / tick_off_us
    traced_frac = (tick_on_us - tick_off_us) / tick_off_us
    emit(f"fleet_obs/tick_off_{WORKERS}w", tick_off_us,
         f"disabled_overhead_frac={disabled_frac:.4f}")
    emit(f"fleet_obs/tick_on_{WORKERS}w", tick_on_us,
         f"spans_per_tick={spans_per_tick:.0f}")
    return {
        "backend": "jax",
        "workers": WORKERS,
        "ticks": TICKS,
        "null_span_ns": null_ns,
        "tick_off_us": tick_off_us,
        "tick_on_us": tick_on_us,
        "spans_per_tick": spans_per_tick,
        "disabled_overhead_frac": disabled_frac,
        "traced_overhead_frac": traced_frac,
    }


def _ledger_section() -> Dict:
    out: Dict = {}
    for backend in BACKENDS:
        tracer = Tracer()
        mux = VetMux(VetEngine(backend, buckets=64), tracer=tracer)
        scenario = build("mixed_windows", n_workers=48, n_ticks=5, seed=0)
        play(scenario, mux)
        report = ledger_from(tracer.records)
        out[backend] = report.to_json()
        emit(f"fleet_obs/ledger_{backend}", report.measured_s * 1e6,
             f"x_over_floor={report.ratio:.1f}")
    return out


def _trace_section() -> Dict:
    tracer = Tracer()
    with TransportVetMux(2, backend="numpy", driver="process",
                         tracer=tracer) as fleet:
        _drive(fleet, workers=16, ticks=3)
    obj = to_chrome(tracer.records, process_names=tracer.process_names)
    problems = validate_chrome(obj)
    pids = sorted({e["pid"] for e in obj["traceEvents"]})
    path = save_json("fleet_obs_trace", obj)
    emit("fleet_obs/process_trace", len(obj["traceEvents"]),
         f"pids={len(pids)};problems={len(problems)}")
    return {
        "events": len(obj["traceEvents"]),
        "pids": pids,
        "validate_problems": problems,
        "path": "benchmarks/results/fleet_obs_trace.json",
    }


def run() -> Dict:
    out = {
        "overhead": _overhead_section(),
        "ledger": _ledger_section(),
        "trace": _trace_section(),
    }
    save_json("fleet_obs", out)
    return out
