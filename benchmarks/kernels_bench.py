"""Kernel micro-benchmarks: µs/call for the Pallas kernels (interpret mode on
this CPU container — correctness/latency tracking, not TPU numbers) and their
pure-jnp references."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.changepoint.ops import changepoint_pallas
from repro.kernels.changepoint.ref import changepoint_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref

from .common import emit, save_json, time_fn

KEY = jax.random.PRNGKey(0)


def run():
    out = {}
    # changepoint on 64k records
    import numpy as np

    y = jnp.asarray(np.sort(np.random.default_rng(0).pareto(1.3, 65536) + 1))
    t_k = time_fn(lambda: jax.block_until_ready(changepoint_pallas(y)), iters=5)
    t_r = time_fn(lambda: jax.block_until_ready(changepoint_ref(y)), iters=5)
    emit("kernels/changepoint_64k", t_k * 1e6, f"ref_us={t_r*1e6:.1f}")
    out["changepoint"] = {"kernel_us": t_k * 1e6, "ref_us": t_r * 1e6}

    # vet engine: batched numpy/jax/pallas backend comparison (small shapes
    # here; the full 64x512 / 64-window sweeps are the standalone vet_engine
    # suite)
    from .vet_engine import bench_backends, bench_streaming, bench_windowed

    out["vet_engine"] = bench_backends(workers=16, window=256, iters=3)
    out["vet_engine_windowed"] = bench_windowed(n_records=568, window=64,
                                                stride=8, iters=3)
    out["vet_engine_streaming"] = bench_streaming(n_records=8192, window=256,
                                                  stride=256, chunk=1024)

    # fused window-vet: dense sliding windows, one launch vs gather batch
    from .windowvet import bench_sliding

    out["windowvet"] = bench_sliding(n_records=2048, window=64, stride=16,
                                     iters=3)

    # flash attention 512 x 8h x 64d
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 512, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 2, 64), jnp.float32)
    t_k = time_fn(lambda: jax.block_until_ready(flash_attention(q, k, v)), iters=3)
    t_r = time_fn(lambda: jax.block_until_ready(attention_ref(q, k, v)), iters=3)
    emit("kernels/flash_512", t_k * 1e6, f"ref_us={t_r*1e6:.1f}")
    out["flash"] = {"kernel_us": t_k * 1e6, "ref_us": t_r * 1e6}

    # ssd 512 x 4h x 64p x 64n
    x = jax.random.normal(ks[0], (1, 512, 4, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 512, 4), jnp.float32))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, 4))
    bb = jax.random.normal(ks[2], (1, 512, 64), jnp.float32)
    d = jnp.ones((4,))
    t_k = time_fn(lambda: jax.block_until_ready(ssd(x, dt, a_log, bb, bb, d)), iters=3)
    t_r = time_fn(lambda: jax.block_until_ready(ssd_ref(x, dt, a_log, bb, bb, d)), iters=3)
    emit("kernels/ssd_512", t_k * 1e6, f"ref_us={t_r*1e6:.1f}")
    out["ssd"] = {"kernel_us": t_k * 1e6, "ref_us": t_r * 1e6}

    save_json("kernels_bench", out)
    return out
