"""Paper Fig. 9: Hill plot + emplot — the record-time tail is heavy.

The paper measures alpha ~ 1.3 on Hadoop read-map records.  We report the
Hill estimate and emplot slope for (a) real contended records and (b) the
simulator calibrated to the paper's profile (pareto_alpha=1.3), which must
recover alpha in [1.1, 1.5].
"""

from __future__ import annotations

import numpy as np

from repro.core import tail_report
from repro.profiling import run_contended_job, simulate_records

from .common import emit, save_json


def run():
    # (a) real contention
    tasks = run_contended_job(3, 1200, unit=1)
    times = np.concatenate(tasks)
    rep_real = tail_report(times - times.min() * 0.999)
    emit("fig9/real", 0.0,
         f"alpha={rep_real.alpha:.2f};emplot_slope={rep_real.emplot_slope:.2f};"
         f"heavy={rep_real.heavy}")

    # (b) paper-calibrated simulator
    p = simulate_records(300_000, base=1e-6, base_jitter=0.1, io_frac=0.1,
                         io_cost=2e-6, overhead_frac=0.05, overhead_scale=2e-5,
                         pareto_alpha=1.3, seed=3)
    rep_sim = tail_report(p.overhead[p.overhead > 0])
    emit("fig9/simulated", 0.0,
         f"alpha={rep_sim.alpha:.2f};band={rep_sim.alpha_stable_band};"
         f"paper_alpha=1.3")
    save_json("fig9_tail", {
        "real": rep_real._asdict(), "sim": rep_sim._asdict(),
    })
    return rep_real, rep_sim
