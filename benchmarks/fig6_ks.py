"""Paper Fig. 6 + KS test: vet_task samples from same-config jobs come from
the same population (the paper's KS p-value for jobs 1,2 was 0.61)."""

from __future__ import annotations

import numpy as np

from repro.core import ks_2samp, vet_task
from repro.profiling import run_contended_job

from .common import emit, save_json


def run():
    # two identically-configured "jobs" on this host
    job_a = run_contended_job(2, 350, unit=5)
    job_b = run_contended_job(2, 350, unit=5)
    # per-unit vet over sliding sub-windows => a vet_task sample per job
    def vets(job):
        out = []
        for task in job:
            n = task.size
            for lo in range(0, n - 32, 16):
                out.append(float(vet_task(task[lo:lo + 32], buckets=None,
                                          cut_space="log").vet))
        return np.asarray(out)

    va, vb = vets(job_a), vets(job_b)
    ks = ks_2samp(va, vb)
    emit("fig6/ks_same_config", 0.0,
         f"mean_a={va.mean():.2f};mean_b={vb.mean():.2f};"
         f"ks_p={ks.pvalue:.3f};same_pop={ks.pvalue > 0.05}")
    save_json("fig6_ks", {"p": ks.pvalue, "d": ks.statistic,
                          "mean_a": float(va.mean()), "mean_b": float(vb.mean())})
    return ks
