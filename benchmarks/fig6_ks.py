"""Paper Fig. 6 + KS test: vet_task samples from same-config jobs come from
the same population (the paper's KS p-value for jobs 1,2 was 0.61).

The per-job vet sample is every sliding sub-window of every task, vetted in
one batched ``VetEngine.vet_sliding`` call per task (the pre-engine version
ran one scalar ``vet_task`` per window).
"""

from __future__ import annotations

import numpy as np

from repro.core import ks_2samp
from repro.engine import default_engine
from repro.profiling import run_contended_job

from .common import emit, save_json


def run(records: int = 350, window: int = 32, stride: int = 16):
    engine = default_engine("jax", buckets=None)
    # two identically-configured "jobs" on this host
    job_a = run_contended_job(2, records, unit=5)
    job_b = run_contended_job(2, records, unit=5)

    # per-unit vet over sliding sub-windows => a vet_task sample per job
    def vets(job):
        return np.concatenate([
            engine.vet_sliding(task, window=min(window, task.size),
                               stride=stride).vet
            for task in job
        ])

    va, vb = vets(job_a), vets(job_b)
    ks = ks_2samp(va, vb)
    emit("fig6/ks_same_config", 0.0,
         f"mean_a={va.mean():.2f};mean_b={vb.mean():.2f};"
         f"ks_p={ks.pvalue:.3f};same_pop={ks.pvalue > 0.05}")
    save_json("fig6_ks", {"p": ks.pvalue, "d": ks.statistic,
                          "mean_a": float(va.mean()), "mean_b": float(vb.mean())})
    return ks
