"""VetEngine backend comparison: numpy scalar loop vs jit+vmap jax vs Pallas.

Vets a (workers, window) batch of simulator ground-truth profiles through all
three backends, reports µs/call and cross-backend agreement against the numpy
oracle.  The headline number is the batched speedup: the jax/pallas backends
vet the whole worker fleet in one compiled call where the numpy reference
pays one scalar ``vet_task`` dispatch per worker.
"""

from __future__ import annotations

import numpy as np

from repro.engine import BACKENDS, VetEngine

from .common import emit, save_json, time_fn


def make_batch(workers: int, window: int, seed: int = 0) -> np.ndarray:
    from repro.profiling import simulate_records

    return np.stack(
        [simulate_records(window, seed=seed + i).times for i in range(workers)]
    )


def bench_backends(workers: int = 64, window: int = 512, iters: int = 5) -> dict:
    """Time every backend on the same batch; return the comparison payload."""
    m = make_batch(workers, window)
    out = {"workers": workers, "window": window}
    oracle = None
    for backend in BACKENDS:
        eng = VetEngine(backend, buckets=64)
        res = eng.vet_batch(m)  # warmup / compile
        t = time_fn(lambda: eng.vet_batch(m), warmup=1,
                    iters=max(2, iters if backend != "numpy" else 2))
        stats = {"us_per_call": t * 1e6, "vet_job": res.vet_job}
        if oracle is None:
            oracle = res
        else:
            stats["max_rel_ei_vs_numpy"] = float(
                np.max(np.abs(res.ei - oracle.ei) / oracle.ei)
            )
            stats["t_mismatches_vs_numpy"] = int(np.sum(res.t != oracle.t))
        out[backend] = stats
        emit(
            f"vet_engine/{backend}_{workers}x{window}",
            t * 1e6,
            f"vet_job={res.vet_job:.3f}"
            + (f";ei_rel={stats['max_rel_ei_vs_numpy']:.1e}"
               if "max_rel_ei_vs_numpy" in stats else ";oracle"),
        )
    speedup = out["numpy"]["us_per_call"] / out["jax"]["us_per_call"]
    out["jax_speedup_vs_numpy"] = speedup
    emit(f"vet_engine/summary_{workers}x{window}", 0.0,
         f"jax_speedup={speedup:.1f}x")
    return out


def run():
    out = bench_backends(workers=64, window=512)
    save_json("vet_engine", out)
    return out
