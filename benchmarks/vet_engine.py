"""VetEngine backend comparison: numpy scalar loop vs jit+vmap jax vs Pallas.

Vets a (workers, window) batch of simulator ground-truth profiles through all
three backends, reports µs/call and cross-backend agreement against the numpy
oracle.  The headline number is the batched speedup: the jax/pallas backends
vet the whole worker fleet in one compiled call where the numpy reference
pays one scalar ``vet_task`` dispatch per worker.

The windowed section times the same contrast on the *sliding-window* workload
(the fig6/fig8/online-dashboard shape): ``vet_sliding`` over a 64-window
stream as one gather + one batched dispatch, against the numpy backend's
per-window scalar loop, plus the cached-tick cost (same buffer re-vetted
through the engine's result cache).

The streaming section times the *live* workload (dashboard / controller /
autotuner ticks on a growing stream): the amortized per-tick cost of a
``VetStream`` (append a chunk, vet only the newly complete windows) against
what a naive consumer pays per tick — a full ``vet_sliding`` re-gather over
the whole buffer (batched backends) or the per-window scalar loop (numpy
backend) — across all three backends.
"""

from __future__ import annotations

import time

import numpy as np

from repro.engine import BACKENDS, VetEngine, VetStream

from .common import emit, save_json, time_fn


def make_batch(workers: int, window: int, seed: int = 0) -> np.ndarray:
    from repro.profiling import simulate_records

    return np.stack(
        [simulate_records(window, seed=seed + i).times for i in range(workers)]
    )


def bench_backends(workers: int = 64, window: int = 512, iters: int = 5) -> dict:
    """Time every backend on the same batch; return the comparison payload."""
    m = make_batch(workers, window)
    out = {"workers": workers, "window": window}
    oracle = None
    for backend in BACKENDS:
        # cache_size=0: time the compute, not the engine's result cache
        eng = VetEngine(backend, buckets=64, cache_size=0)
        res = eng.vet_batch(m)  # warmup / compile
        t = time_fn(lambda: eng.vet_batch(m), warmup=1,
                    iters=max(2, iters if backend != "numpy" else 2))
        stats = {"us_per_call": t * 1e6, "vet_job": res.vet_job}
        if oracle is None:
            oracle = res
        else:
            stats["max_rel_ei_vs_numpy"] = float(
                np.max(np.abs(res.ei - oracle.ei) / oracle.ei)
            )
            stats["t_mismatches_vs_numpy"] = int(np.sum(res.t != oracle.t))
        out[backend] = stats
        emit(
            f"vet_engine/{backend}_{workers}x{window}",
            t * 1e6,
            f"vet_job={res.vet_job:.3f}"
            + (f";ei_rel={stats['max_rel_ei_vs_numpy']:.1e}"
               if "max_rel_ei_vs_numpy" in stats else ";oracle"),
        )
    speedup = out["numpy"]["us_per_call"] / out["jax"]["us_per_call"]
    out["jax_speedup_vs_numpy"] = speedup
    emit(f"vet_engine/summary_{workers}x{window}", 0.0,
         f"jax_speedup={speedup:.1f}x")
    return out


def bench_windowed(n_records: int = 1264, window: int = 256,
                   stride: int = 16, iters: int = 5) -> dict:
    """Sliding-window vetting: batched gather+dispatch vs per-window loop.

    Engines run with the result cache disabled so every iteration pays the
    real compute; the cached-tick number is measured separately on a
    cache-enabled engine (the dashboard steady state).
    """
    from repro.profiling import simulate_records

    times = simulate_records(n_records, seed=7).times
    num_windows = (times.size - window) // stride + 1
    out = {"n_records": n_records, "window": window, "stride": stride,
           "num_windows": num_windows}
    for backend in BACKENDS:
        eng = VetEngine(backend, buckets=64, cache_size=0)
        res = eng.vet_sliding(times, window=window, stride=stride)  # warmup
        t = time_fn(lambda: eng.vet_sliding(times, window=window,
                                            stride=stride),
                    warmup=1, iters=(2 if backend == "numpy" else iters))
        out[backend] = {"us_per_call": t * 1e6,
                        "vet_p50": float(np.median(res.vet))}
        emit(f"vet_engine/windowed_{backend}_{num_windows}w{window}",
             t * 1e6, f"vet_p50={out[backend]['vet_p50']:.3f}")
    # dashboard steady state: unchanged buffer served from the result cache
    cached_eng = VetEngine("jax", buckets=64)
    cached_eng.vet_sliding(times, window=window, stride=stride)
    t_cached = time_fn(lambda: cached_eng.vet_sliding(times, window=window,
                                                      stride=stride),
                       warmup=2, iters=20)
    out["cached_tick_us"] = t_cached * 1e6
    speedup = out["numpy"]["us_per_call"] / out["jax"]["us_per_call"]
    out["batched_speedup_vs_scalar_loop"] = speedup
    emit(f"vet_engine/windowed_summary_{num_windows}w{window}", 0.0,
         f"batched_speedup={speedup:.1f}x;cached_tick_us={t_cached*1e6:.1f}")
    return out


def bench_streaming(n_records: int = 65536, window: int = 512,
                    stride: int = 512, chunk: int = 2048) -> dict:
    """Streaming tick: incremental ``VetStream`` vs full per-tick re-gather.

    Feeds an ``n_records`` stream chunk-by-chunk; the stream's amortized
    per-tick cost (append + vet only the delta windows) is contrasted with
    the naive dashboard tick — a full ``vet_sliding`` over the final stream,
    which is what a consumer that re-slices its whole buffer pays *every*
    tick at steady state.  Engines run cache-disabled so every tick pays its
    real compute.
    """
    from repro.profiling import simulate_records

    times = simulate_records(n_records, seed=13).times
    n_ticks = -(-n_records // chunk)
    num_windows = (n_records - window) // stride + 1
    out = {"n_records": n_records, "window": window, "stride": stride,
           "chunk": chunk, "n_ticks": n_ticks, "num_windows": num_windows}
    for backend in BACKENDS:
        eng = VetEngine(backend, buckets=64, cache_size=0)
        cap = max(4 * window, window + 2 * chunk)

        def feed_stream():
            st = VetStream(eng, window=window, stride=stride, capacity=cap)
            for lo in range(0, n_records, chunk):
                st.append(times[lo:lo + chunk])
                st.tick()
            return st

        feed_stream()  # warmup: compile the delta-batch shapes
        t0 = time.perf_counter()
        st = feed_stream()
        stream_us = (time.perf_counter() - t0) / n_ticks * 1e6
        # steady-state naive tick: one full re-gather over the whole stream
        eng.vet_sliding(times, window=window, stride=stride)  # warmup
        regather_us = time_fn(
            lambda: eng.vet_sliding(times, window=window, stride=stride),
            warmup=0, iters=(1 if backend == "numpy" else 3)) * 1e6
        out[backend] = {
            "stream_tick_us": stream_us,
            "regather_tick_us": regather_us,
            "tick_speedup": regather_us / stream_us,
            "vetted_rows": st.stats.vetted,
        }
        emit(f"vet_engine/stream_{backend}_{num_windows}w{window}",
             stream_us,
             f"regather_us={regather_us:.1f};"
             f"speedup={regather_us / stream_us:.1f}x")
    out["stream_speedup_vs_regather"] = out["jax"]["tick_speedup"]
    emit(f"vet_engine/stream_summary_{num_windows}w{window}", 0.0,
         f"jax_stream_speedup={out['stream_speedup_vs_regather']:.1f}x")
    return out


def run():
    out = bench_backends(workers=64, window=512)
    out["windowed"] = bench_windowed()
    out["streaming"] = bench_streaming()
    save_json("vet_engine", out)
    return out
