"""Anomaly-monitor overhead + detection quality accounting.

Two questions priced here:

- **What does live monitoring cost?**  The same 256-worker fleet tick is
  driven with the monitor on and off; the delta is the per-tick price of
  scanning every stream's vet ring with the change-point machinery.  Numpy
  backend and method: the point is the monitor loop, not the kernels.
- **How fast and how accurately does it flag?**  Every scenario in the
  anomaly bank is played through a monitored mux; the committed artifact
  records, per scenario, how many affected streams were detected, the
  localization error of each first flag against the injected onset, the
  flag latency (ticks from injected onset to the tick the flag was
  raised — confirmation costs a couple of ticks by design), and how many
  unaffected streams ever flagged.

Wall-clock numbers are environment-dependent and not pinned; the detection
quality fields are pinned by ``tests/test_benchmark_results_schema.py``
(every affected stream detected, onset error within the bank's +/-2-tick
tolerance, zero false flags).
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.engine import VetEngine
from repro.fleet import VetMux, build
from repro.fleet.scenarios import ANOMALY_SCENARIOS

from .common import emit, save_json

SEED = 1  # the bank's differential seed (see tests/test_fleet_anomaly.py)
OVERHEAD_WORKERS = 256
OVERHEAD_TICKS = 8
OVERHEAD_CHUNK = 64  # one complete window per worker per tick


def _detection_quality(name: str) -> Dict:
    sc = build(name, seed=SEED)
    mux = VetMux(VetEngine("numpy", buckets=64))
    for s in sc.specs:
        s.register(mux)
    firsts: Dict = {}  # sid -> (flag, tick index raised)
    for k, ev in enumerate(sc.events):
        for sid, chunk in ev.chunks.items():
            mux.feed(sid, chunk)
        for f in mux.tick().flags:
            firsts.setdefault(f.stream_id, (f, k))
    affected = set(sc.affected)
    errs = [abs(f.onset - sc.onset_tick)
            for sid, (f, _) in firsts.items() if sid in affected]
    lats = [k - sc.onset_tick
            for sid, (f, k) in firsts.items() if sid in affected]
    return {
        "onset_tick": sc.onset_tick,
        "n_affected": len(affected),
        "detected": len(errs),
        "false_flags": len(set(firsts) - affected),
        "mean_onset_err_ticks": float(np.mean(errs)) if errs else None,
        "max_onset_err_ticks": int(max(errs)) if errs else None,
        "mean_flag_latency_ticks": float(np.mean(lats)) if lats else None,
        "max_flag_latency_ticks": int(max(lats)) if lats else None,
    }


def _overhead_tick_us(monitor: bool) -> float:
    """Steady-state per-tick wall microseconds for a 256-worker fleet."""
    rng = np.random.default_rng(7)
    mux = VetMux(VetEngine("numpy", buckets=64), monitor=monitor)
    for w in range(OVERHEAD_WORKERS):
        mux.register(f"w{w:04d}", window=OVERHEAD_CHUNK,
                     stride=OVERHEAD_CHUNK, capacity=4 * OVERHEAD_CHUNK)
    chunks = rng.standard_normal(
        (OVERHEAD_WORKERS, OVERHEAD_TICKS, OVERHEAD_CHUNK)) ** 2 + 1e-3
    walls = []
    for k in range(OVERHEAD_TICKS):
        for w in range(OVERHEAD_WORKERS):
            mux.feed(f"w{w:04d}", chunks[w, k])
        t0 = time.perf_counter()
        mux.tick()
        walls.append(time.perf_counter() - t0)
    steady = walls[1:]  # first tick pays ring/row growth
    return sum(steady) / len(steady) * 1e6


def run() -> Dict:
    out: Dict = {
        "seed": SEED,
        "backend": "numpy",
        "method": "numpy",
        "tolerance_ticks": 2,
        "scenarios": {},
    }
    for name in sorted(ANOMALY_SCENARIOS):
        q = _detection_quality(name)
        out["scenarios"][name] = q
        emit(f"fleet_anomaly/{name}",
             0.0 if q["mean_flag_latency_ticks"] is None
             else q["mean_flag_latency_ticks"],
             f"detected={q['detected']}/{q['n_affected']};"
             f"max_err={q['max_onset_err_ticks']};"
             f"false={q['false_flags']}")

    on_us = _overhead_tick_us(True)
    off_us = _overhead_tick_us(False)
    out["overhead_256w"] = {
        "workers": OVERHEAD_WORKERS,
        "ticks": OVERHEAD_TICKS,
        "monitor_on_tick_us": on_us,
        "monitor_off_tick_us": off_us,
        "overhead_us": on_us - off_us,
        "overhead_pct": 100.0 * (on_us - off_us) / off_us,
    }
    emit(f"fleet_anomaly/overhead_{OVERHEAD_WORKERS}w", on_us - off_us,
         f"on={on_us:.0f}us;off={off_us:.0f}us;"
         f"pct={out['overhead_256w']['overhead_pct']:.1f}")
    save_json("fleet_anomaly", out)
    return out
