"""Paper Fig. 3: "spill" sub-phase times are small and constant across tasks.

Analogue: the host-side data-fetch phase per training step vs the step
("read-map") phase.  The fetch time must be (a) much smaller than the step
and (b) near-constant across steps — justifying the paper's decision to
estimate ideal time from the dominant phase only.
"""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.launch.train import train

from .common import emit, save_json


def run():
    cfg = get_config("qwen3-14b").reduced()
    res = train(cfg, steps=24, batch=4, seq_len=32, verbose=False, q_chunk=32)
    totals = res.phase_totals
    ratio = totals.get("data", 0.0) / max(totals.get("step", 1e-9), 1e-9)
    emit("fig3/phase_ratio", totals.get("step", 0.0) / 24 * 1e6,
         f"data_total={totals.get('data', 0):.3f}s;"
         f"step_total={totals.get('step', 0):.3f}s;data/step={ratio:.1%}")
    save_json("fig3_spill", {"phase_totals": totals, "data_step_ratio": ratio})
    return totals
