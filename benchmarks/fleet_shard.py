"""Sharded fleet estimation: one mux vs a ``ShardedVetMux`` at 1/2/4/8 shards.

The single-mux fleet benchmark (``benchmarks/fleet.py``) proves coalescing:
N per-stream dispatches collapse to one per window-length bucket per tick.
This benchmark measures the next axis — *partitioning* that coalesced work
across shards, each modeling one process/host with its own ``VetEngine``:

- The workload is a heterogeneous fleet with 8 distinct window lengths
  (the ``mixed_windows`` scenario shape at 256 and 1024 workers), where a
  single mux pays 8 dispatches per tick.
- The interesting numbers are the *per-shard maxima*: the most dispatches
  and the most window rows any one shard (process) handles in a tick.  The
  length-affine "pack" placement keeps same-length streams co-located, so
  per-shard max dispatches fall as shards are added (8 -> 4 -> 2 -> 1 from
  1 to 8 shards) and per-shard max rows fall with the worker split — each
  model process does strictly less estimation work.
- The guard rail is the fleet-total dispatch count: placement must not
  shatter shape buckets, so the total stays within ``single-mux + K`` per
  tick (here it stays exactly at the single-mux count).  Both bounds are
  pinned on the committed artifact by
  ``tests/test_benchmark_results_schema.py``.

Engines run with the result cache disabled so every tick pays real compute;
dispatch counts come from ``VetEngine.dispatches``/``MuxTick.dispatches``
and are exact, not timed.  The first (compile) tick is excluded from the
timed region.  In-process wall clock does not improve with shards — the
win is the per-shard work distribution, which is what a multi-process
deployment scales on.
"""

from __future__ import annotations

import time
from typing import Dict, Sequence, Tuple

from repro.engine import VetEngine
from repro.fleet import ShardedVetMux, VetMux, build

from .common import emit, save_json

# 8 distinct window lengths: a single mux pays one dispatch per length per
# tick, so per-shard dispatch maxima can halve all the way down to 1 at 8
# shards.
WINDOW_LENGTHS: Tuple[int, ...] = (8, 12, 16, 24, 32, 48, 64, 96)


def _drive(mux, scenario):
    """Register + feed + tick a scenario, timing each tick individually."""
    for spec in scenario.specs:
        spec.register(mux)
    ticks, walls = [], []
    for event in scenario.events:
        for spec in event.joins:
            spec.register(mux)
        for sid, chunk in event.chunks.items():
            mux.feed(sid, chunk)
        t0 = time.perf_counter()
        ticks.append(mux.tick())
        walls.append(time.perf_counter() - t0)
        for sid in event.leaves:
            mux.deregister(sid)
    return ticks, walls


def _tick_us(walls) -> float:
    # First tick pays the jit compiles; report the steady-state mean.
    steady = walls[1:] if len(walls) > 1 else walls
    return sum(steady) / len(steady) * 1e6


def bench_shard_scaling(workers: int, *,
                        shards_list: Sequence[int] = (1, 2, 4, 8),
                        n_lengths: int = 8, n_ticks: int = 3,
                        backend: str = "jax", seed: int = 2) -> Dict:
    """One worker-count's shard-scaling sweep (see module docstring)."""
    windows = WINDOW_LENGTHS[:n_lengths]
    scenario = build("mixed_windows", n_workers=workers, n_ticks=n_ticks,
                     windows=windows, seed=seed)

    # --- single-mux baseline: every length's bucket on one engine --------
    single = VetMux(VetEngine(backend, buckets=64, cache_size=0))
    ticks, walls = _drive(single, scenario)
    moving = [t for t in ticks if t.rows]
    out: Dict = {
        "workers": workers,
        "window_lengths": len(set(windows)),
        "n_ticks": n_ticks,
        "single_mux_dispatches_per_tick": max(t.dispatches for t in moving),
        "single_mux_tick_us": _tick_us(walls),
        "shards": {},
    }

    for k in shards_list:
        smux = ShardedVetMux(
            k, engines=[VetEngine(backend, buckets=64, cache_size=0)
                        for _ in range(k)])
        ticks, walls = _drive(smux, scenario)
        moving = [t for t in ticks if t.rows]
        entry = {
            "shards": k,
            "total_dispatches_per_tick": max(t.dispatches for t in moving),
            "per_shard_max_dispatches_per_tick": max(
                max(st.dispatches for st in t.shards) for t in moving),
            "per_shard_max_rows_per_tick": max(
                max(st.rows for st in t.shards) for t in moving),
            "tick_us": _tick_us(walls),
            "vet_job": moving[-1].vet_job,
        }
        out["shards"][str(k)] = entry
        emit(f"fleet_shard/{backend}_{workers}w_k{k}", entry["tick_us"],
             f"total_disp={entry['total_dispatches_per_tick']};"
             f"shard_max_disp={entry['per_shard_max_dispatches_per_tick']};"
             f"shard_max_rows={entry['per_shard_max_rows_per_tick']}")
    return out


def run():
    out = {
        "backend": "jax",
        "n_lengths": len(WINDOW_LENGTHS),
        "shards_list": [1, 2, 4, 8],
        "w256": bench_shard_scaling(256, n_ticks=3),
        "w1024": bench_shard_scaling(1024, n_ticks=2),
    }
    k1 = out["w1024"]["shards"]["1"]["per_shard_max_dispatches_per_tick"]
    k4 = out["w1024"]["shards"]["4"]["per_shard_max_dispatches_per_tick"]
    emit("fleet_shard/summary_1024w", 0.0,
         f"per_shard_max_dispatches {k1}->{k4} from 1->4 shards;"
         f"single={out['w1024']['single_mux_dispatches_per_tick']}")
    save_json("fleet_shard", out)
    return out
