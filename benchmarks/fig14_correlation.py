"""Paper Fig. 14: vet_task strongly correlates with task processing time
(paper Pearson 0.93-0.96): tasks that took longer did so because of
reducible overhead, not because their ideal work differs."""

from __future__ import annotations

import numpy as np

from repro.core import pearson, vet_task
from repro.profiling import run_contended_job

from .common import emit, save_json


def run():
    vets, times = [], []
    # many short tasks across varying contention levels
    for w in (1, 2, 3, 4):
        for rep in range(2):
            tasks = run_contended_job(w, 150, unit=5)
            for t in tasks:
                r = vet_task(t, buckets=None, cut_space="log")
                vets.append(float(r.vet))
                times.append(float(r.pr))
    rho = pearson(np.asarray(vets), np.asarray(times))
    emit("fig14/pearson", 0.0,
         f"rho={rho:.3f};n_tasks={len(vets)};paper=0.93-0.96")
    save_json("fig14_correlation", {"pearson": rho, "vets": vets, "times": times})
    return rho
