"""Paper Fig. 14: vet_task strongly correlates with task processing time
(paper Pearson 0.93-0.96): tasks that took longer did so because of
reducible overhead, not because their ideal work differs.

Each job's tasks are vetted in one batched ``VetEngine.vet_many`` call (the
pre-engine version looped scalar ``vet_task`` per task)."""

from __future__ import annotations

import numpy as np

from repro.core import pearson
from repro.engine import default_engine
from repro.profiling import run_contended_job

from .common import emit, save_json


def run(records: int = 150, reps: int = 2, workers=(1, 2, 3, 4)):
    engine = default_engine("jax", buckets=None)
    vets, times = [], []
    # many short tasks across varying contention levels
    for w in workers:
        for rep in range(reps):
            tasks = run_contended_job(w, records, unit=5)
            batch = engine.vet_many(tasks)
            vets.extend(float(v) for v in batch.vet)
            times.extend(float(p) for p in batch.pr)
    rho = pearson(np.asarray(vets), np.asarray(times))
    emit("fig14/pearson", 0.0,
         f"rho={rho:.3f};n_tasks={len(vets)};paper=0.93-0.96")
    save_json("fig14_correlation", {"pearson": rho, "vets": vets, "times": times})
    return rho
