"""Property-based (hypothesis) tests for the change-point scan.

The O(n^2) f64 naive scan is the oracle; properties drive the closed-form
prefix-sum paths across short, degenerate, tied and heavy-tailed inputs and
across omega boundaries.  Skipped wholesale when ``hypothesis`` is not
installed, like the other ``*_properties`` suites.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core.changepoint import (  # noqa: E402
    estimate_changepoint,
    estimate_changepoint_naive,
    two_segment_sse,
)


@st.composite
def sorted_curves(draw):
    """Sorted profiles spanning flat, tied, stepped and spiky shapes."""
    n = draw(st.integers(min_value=6, max_value=96))
    kind = draw(st.sampled_from(["flat", "tied", "step", "spiky"]))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    if kind == "flat":
        y = np.full(n, draw(st.floats(1e-3, 10.0)))
    elif kind == "tied":
        # Few distinct values, long runs of exact ties.
        vals = np.sort(rng.uniform(0.5, 5.0, size=3))
        y = np.sort(rng.choice(vals, size=n))
    elif kind == "step":
        k = draw(st.integers(1, n - 1))
        lo = draw(st.floats(0.1, 1.0))
        hi = lo * draw(st.floats(1.5, 20.0))
        y = np.concatenate([np.full(k, lo), np.full(n - k, hi)])
    else:
        y = np.sort(rng.normal(1.0, 0.05, n) + rng.pareto(1.5, n) * 0.5)
    return np.sort(y)


@settings(max_examples=40, deadline=None)
@given(sorted_curves(), st.integers(min_value=1, max_value=6))
def test_prop_matches_naive_oracle_or_raises(y, omega):
    """Valid inputs: the batch path's split is SSE-equivalent to the
    oracle's (argmin ties under f32 may pick a different index, but never a
    worse landscape value).  Invalid inputs: ValueError vs the oracle's -1."""
    n = y.size
    if n < 2 * omega:
        assert estimate_changepoint_naive(y, omega=omega) == -1
        with pytest.raises(ValueError):
            estimate_changepoint(jnp.asarray(y, jnp.float32), omega=omega)
        return
    t_naive = estimate_changepoint_naive(y, omega=omega)
    t = int(estimate_changepoint(jnp.asarray(y, jnp.float32), omega=omega))
    assert omega <= t <= n - omega
    assert t_naive != -1
    # Compare landscape values at the two argmins in f64: the batch pick
    # must be as good as the oracle's up to f32 round-off of the inputs.
    sse = np.asarray(two_segment_sse(jnp.asarray(y, jnp.float32),
                                     omega=omega), np.float64)
    span = max(float(np.ptp(y)) ** 2 * n, 1e-9)
    assert sse[t - 1] <= sse[t_naive - 1] + 1e-4 * span


@settings(max_examples=25, deadline=None)
@given(sorted_curves())
def test_prop_omega_widening_never_escapes_window(y):
    """Every omega yields a split inside its own probing window, and the
    landscape outside the window is +inf."""
    n = y.size
    for omega in range(1, n // 2 + 1):
        sse = np.asarray(two_segment_sse(jnp.asarray(y, jnp.float32),
                                         omega=omega))
        k = np.arange(1, n + 1)
        outside = (k < omega) | (k > n - omega)
        assert np.all(np.isinf(sse[outside]))
        t = int(estimate_changepoint(jnp.asarray(y, jnp.float32),
                                     omega=omega))
        assert omega <= t <= n - omega


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=30), st.floats(1.5, 30.0),
       st.integers(min_value=1, max_value=30))
def test_prop_clean_step_localized_exactly(k, jump, tail):
    """A noiseless two-level step is localized exactly by both the oracle
    and the batch path whenever the step is inside the probing window."""
    omega = 3
    n = k + tail
    if n < 2 * omega or not (omega <= k <= n - omega):
        return
    y = np.concatenate([np.ones(k), np.full(tail, jump)])
    assert estimate_changepoint_naive(y, omega=omega) == k
    assert int(estimate_changepoint(jnp.asarray(y, jnp.float32),
                                    omega=omega)) == k
