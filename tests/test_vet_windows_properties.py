"""Property-based (hypothesis) tests for windowed vetting.

Mirrors ``test_core_vet_properties.py``: skipped wholesale when
``hypothesis`` is not installed (``scripts/ci.sh`` installs it as a test
extra).  Deterministic twins of the cache properties also live in
``test_vet_windows.py`` so the contract stays covered on offline containers.

Window/stride are held fixed per property so jit compiles one batched shape
per stream length instead of one per example.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.engine import VetEngine  # noqa: E402

WINDOW = 64
STRIDE = 32

# Module-level engines: one compiled batch fn (and one result cache) shared
# by every example, mirroring how call sites hold a long-lived engine.
ENGINE = VetEngine("jax", buckets=64)
RAW_ENGINE = VetEngine("jax", buckets=64, cut_space="raw")


@st.composite
def record_streams(draw):
    # A couple of fixed lengths (not st.integers) to bound jit recompiles.
    n = draw(st.sampled_from((128, 192)))
    base = draw(st.floats(min_value=1e-6, max_value=1.0))
    vals = draw(
        st.lists(
            st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
            min_size=n, max_size=n,
        )
    )
    return base + np.asarray(vals)


@settings(max_examples=25, deadline=None)
@given(record_streams())
def test_prop_ei_plus_oc_equals_pr_per_window(times):
    """The decomposition holds in every window, not just in aggregate."""
    res = ENGINE.vet_sliding(times, window=WINDOW, stride=STRIDE)
    assert np.all(res.ei > 0)
    np.testing.assert_allclose(res.ei + res.oc, res.pr, rtol=1e-4, atol=1e-6)
    # the ideal is a per-window lower bound
    assert np.all(res.ei <= res.pr * (1 + 1e-5) + 1e-6)


@settings(max_examples=25, deadline=None)
@given(record_streams(), st.integers(min_value=-3, max_value=9))
def test_prop_windowed_scale_equivariance_exact(times, log2_c):
    """times -> c*times with c a power of two is *exactly* equivariant in the
    raw cut space: the scaling commutes with every float op (the mantissas
    are untouched), so the cut is identical and vet is bitwise unchanged."""
    c = float(2.0 ** log2_c)
    r1 = RAW_ENGINE.vet_sliding(times, window=WINDOW, stride=STRIDE)
    r2 = RAW_ENGINE.vet_sliding(c * times, window=WINDOW, stride=STRIDE)
    np.testing.assert_array_equal(r2.t, r1.t)
    np.testing.assert_allclose(r2.vet, r1.vet, rtol=1e-6)
    np.testing.assert_allclose(r2.ei, c * r1.ei, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(record_streams(), st.floats(min_value=0.1, max_value=1000.0))
def test_prop_windowed_scale_equivariance_log_default(times, c):
    """General c on the framework-default log cut space: PR scales exactly,
    and vet is scale-invariant on every window whose change-point survived
    the rescale.  (A general c perturbs the float32 log curve by ~ulp, which
    can flip the argmin between documented statistical near-ties — the cut
    itself is only equivariant up to those ties, so flipped windows are
    excluded rather than asserted at a fake-loose tolerance.)"""
    r1 = ENGINE.vet_sliding(times, window=WINDOW, stride=STRIDE)
    r2 = ENGINE.vet_sliding(c * times, window=WINDOW, stride=STRIDE)
    np.testing.assert_allclose(r2.pr, c * r1.pr, rtol=1e-4)
    same_cut = r2.t == r1.t
    np.testing.assert_allclose(r2.vet[same_cut], r1.vet[same_cut],
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(r2.ei[same_cut], c * r1.ei[same_cut],
                               rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(record_streams())
def test_prop_repeat_call_on_unchanged_buffer_is_bitwise_identical(times):
    """The cache contract: an unchanged buffer returns the stored result."""
    r1 = ENGINE.vet_sliding(times, window=WINDOW, stride=STRIDE)
    r2 = ENGINE.vet_sliding(times, window=WINDOW, stride=STRIDE)
    assert r2 is r1
    for a, b in zip(r1, r2):
        assert a.tobytes() == b.tobytes()
