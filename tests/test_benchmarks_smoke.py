"""Smoke tests for the windowed-vetting benchmark reroute (fig6/fig8/fig14).

These benchmarks used to carry their own per-window scalar ``vet_task``
loops; they now flow through ``VetEngine.vet_sliding`` / ``vet_many``.  Each
``run()`` is exercised on tiny record counts with ``run_contended_job``
monkeypatched to a *seeded* simulator double (real contention timing is
nondeterministic and slow), asserting the emitted vet values are finite —
guarding the reroute end to end without timing noise.
"""

import inspect

import numpy as np
import pytest

import benchmarks.fig6_ks as fig6
import benchmarks.fig8_distribution as fig8
import benchmarks.fig14_correlation as fig14
from repro.profiling import simulate_records


def fake_contended_job_factory(calls):
    """A seeded, deterministic stand-in for ``run_contended_job``.

    Matches the real signature/shape contract: ``n_tasks`` arrays of
    ``records_per_task // unit`` unit-grouped times.  Each task gets a fresh
    simulator profile; the running call counter keeps draws distinct but
    reproducible across the whole test.
    """

    def fake(n_tasks, records_per_task, *, unit=5, **kwargs):
        out = []
        for _ in range(n_tasks):
            calls.append((n_tasks, records_per_task, unit))
            n_units = max(8, records_per_task // max(1, unit))
            out.append(simulate_records(n_units, seed=1000 + len(calls)).times)
        return out

    return fake


@pytest.fixture
def seeded_job(monkeypatch):
    calls = []
    fake = fake_contended_job_factory(calls)
    for mod in (fig6, fig8, fig14):
        monkeypatch.setattr(mod, "run_contended_job", fake)
    return calls


@pytest.fixture
def captured(monkeypatch):
    """Capture emit/save_json payloads instead of touching results/."""
    rows, payloads = [], {}
    for mod in (fig6, fig8, fig14):
        monkeypatch.setattr(
            mod, "emit",
            lambda name, us, derived="", _r=rows: _r.append((name, us, derived)))
        monkeypatch.setattr(
            mod, "save_json",
            lambda name, payload, _p=payloads: _p.setdefault(name, payload))
    return rows, payloads


def test_fig6_tiny_run_emits_finite_vets(seeded_job, captured):
    rows, payloads = captured
    ks = fig6.run(records=320, window=32, stride=16)
    assert np.isfinite(ks.pvalue) and np.isfinite(ks.statistic)
    assert 0.0 <= ks.pvalue <= 1.0
    p = payloads["fig6_ks"]
    assert np.isfinite(p["mean_a"]) and p["mean_a"] >= 1.0
    assert np.isfinite(p["mean_b"]) and p["mean_b"] >= 1.0
    assert len(seeded_job) == 4  # 2 jobs x 2 tasks, no real contention run


def test_fig6_degenerate_single_window_per_task(seeded_job, captured):
    """Tasks exactly one window long still flow through vet_sliding."""
    rows, payloads = captured
    ks = fig6.run(records=160, window=32, stride=16)
    assert np.isfinite(ks.pvalue)


def test_fig8_tiny_run_emits_finite_windowed_vets(seeded_job, captured):
    rows, payloads = captured
    fig8.run(records=150, window=64, stride=32)
    p = payloads["fig8_distribution"]
    assert np.isfinite(p["windowed_vet_p50"]) and p["windowed_vet_p50"] >= 1.0
    assert np.isfinite(p["windowed_vet_max"])
    assert p["windowed_vet_max"] >= p["windowed_vet_p50"]
    windowed_rows = [r for r in rows if r[0] == "fig8/windowed_vet"]
    assert len(windowed_rows) == 1


def test_fig14_tiny_run_correlation_is_finite(seeded_job, captured):
    rows, payloads = captured
    rho = fig14.run(records=160, reps=1, workers=(1, 2))
    assert np.isfinite(rho)
    assert -1.0 <= rho <= 1.0
    p = payloads["fig14_correlation"]
    assert len(p["vets"]) == 3  # 1 + 2 tasks
    assert all(np.isfinite(v) and v >= 1.0 - 1e-6 for v in p["vets"])
    assert all(np.isfinite(t) and t > 0 for t in p["times"])


def test_no_direct_per_window_vet_task_loops_remain():
    """The acceptance guard: fig6/fig8/fig14 and OnlineVet must not call the
    scalar ``vet_task`` directly — all windowed estimation goes through the
    engine's batched path."""
    import repro.core.online as online

    for mod in (fig6, fig8, fig14, online):
        src = inspect.getsource(mod)
        # prose may cite the paper's vet_task *measure*; code must not call it
        assert "vet_task(" not in src, f"{mod.__name__} still calls vet_task"
        assert not hasattr(mod, "vet_task"), \
            f"{mod.__name__} still imports vet_task"
