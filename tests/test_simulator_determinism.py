"""Seeded-determinism regression tests for ``repro.profiling.simulator``.

Every differential suite in this repo (engine backends, windowed vetting,
streaming ticks, benchmark smoke tests) builds its ground-truth profiles from
``simulate_records``/``simulate_job`` with fixed seeds and silently assumes
the draws are bitwise-stable.  Nothing pinned that until now: a refactor that
reorders the RNG consumption (or a silent change to the profile's identities)
would shift every oracle at once and mask real regressions.  These tests make
the assumption explicit:

- same seed => bitwise-identical profiles, call after call and across
  interleavings;
- a golden content hash pins the exact draw sequence (NumPy guarantees
  ``default_rng`` stream stability for a fixed bit generator, so this only
  moves if *our* simulator changes what it asks the RNG for);
- the ``SimProfile`` identities hold exactly: ``times == ideal + overhead``,
  ``true_ei == ideal.sum()``, ``true_oc == overhead.sum()``, and ``true_vet``
  is their ratio.
"""

import hashlib

import numpy as np
import pytest

from repro.profiling import SimProfile, simulate_job, simulate_records


def content_hash(a: np.ndarray) -> str:
    return hashlib.blake2b(np.ascontiguousarray(a).tobytes(),
                           digest_size=16).hexdigest()


class TestSimulateRecordsDeterminism:
    @pytest.mark.parametrize("seed", (0, 3, 1234))
    def test_same_seed_is_bitwise_stable(self, seed):
        a = simulate_records(500, seed=seed)
        b = simulate_records(500, seed=seed)
        np.testing.assert_array_equal(a.times, b.times)
        np.testing.assert_array_equal(a.ideal, b.ideal)
        np.testing.assert_array_equal(a.overhead, b.overhead)
        assert a.true_ei == b.true_ei and a.true_oc == b.true_oc

    def test_stability_across_interleaved_calls(self):
        """Module-level RNG state must not leak between calls."""
        a = simulate_records(200, seed=5)
        simulate_records(999, seed=17)  # unrelated draw in between
        b = simulate_records(200, seed=5)
        np.testing.assert_array_equal(a.times, b.times)

    def test_golden_hash_pins_the_draw_sequence(self):
        """The exact bytes of the seed-0 profile, pinned.  If this moves, the
        simulator's RNG consumption changed and every differential oracle in
        the repo moved with it — bump deliberately, never incidentally."""
        p = simulate_records(256, seed=0)
        assert content_hash(p.times) == "bc4c4806fb945c8b5823f6a152d304f3"
        assert content_hash(p.ideal) == "615e083c5071d8f3ac7fa5cb171d0316"

    def test_different_seeds_differ(self):
        a = simulate_records(300, seed=0)
        b = simulate_records(300, seed=1)
        assert not np.array_equal(a.times, b.times)

    def test_profile_identities_exact(self):
        p = simulate_records(400, seed=7)
        assert isinstance(p, SimProfile)
        np.testing.assert_array_equal(p.times, p.ideal + p.overhead)
        assert p.true_ei == float(p.ideal.sum())
        assert p.true_oc == float(p.overhead.sum())
        assert p.true_vet == (p.true_ei + p.true_oc) / p.true_ei
        assert p.true_vet >= 1.0
        assert np.all(p.times > 0) and np.all(p.overhead >= 0)


class TestSimulateJobDeterminism:
    def test_same_seed_job_is_bitwise_stable(self):
        a = simulate_job(3, 400, utilization_factor=2.0, seed=2)
        b = simulate_job(3, 400, utilization_factor=2.0, seed=2)
        assert len(a) == len(b) == 3
        for pa, pb in zip(a, b):
            np.testing.assert_array_equal(pa.times, pb.times)

    def test_tasks_within_a_job_are_distinct_draws(self):
        job = simulate_job(3, 400, seed=4)
        assert not np.array_equal(job[0].times, job[1].times)
        assert not np.array_equal(job[1].times, job[2].times)

    def test_true_vet_consistent_and_utilization_scales_overhead(self):
        """The Table 2 mechanism, deterministically: a higher utilization
        factor inflates only the overhead channel (ideal unchanged)."""
        lo = simulate_job(2, 2000, utilization_factor=1.0, seed=9)
        hi = simulate_job(2, 2000, utilization_factor=4.0, seed=9)
        for p_lo, p_hi in zip(lo, hi):
            assert p_hi.true_oc > p_lo.true_oc
            assert p_hi.true_vet > p_lo.true_vet
            assert p_hi.true_vet >= 1.0
