"""Fast tier-1 smoke for the fleet path: <= 64 workers, numpy backend only.

The full differential suite (``tests/test_fleet.py``) sweeps every scenario
and backend; this file is the quick guard that keeps tier-1 cheap while
still proving the three load-bearing properties end to end at a realistic
fleet width: oracle equality, dispatch coalescing, and a working benchmark
harness (tiny sizes, no artifacts written).
"""

import numpy as np

import benchmarks.fleet as fleet_bench
from repro.engine import VetEngine
from repro.fleet import VetMux, build, play


def test_64_worker_fleet_matches_batch_oracle_bitwise():
    """One 64-stream uniform fleet: final mux rows == vet_sliding oracle."""
    scenario = build("uniform", n_workers=64, n_ticks=3, window=16, seed=21)
    eng = VetEngine("numpy", buckets=64)
    last = play(scenario, VetMux(eng))[-1]
    oracle = VetEngine("numpy", buckets=64)
    for spec in scenario.specs:
        fed = np.concatenate([e.chunks[spec.stream_id]
                              for e in scenario.events])
        ref = oracle.vet_sliding(fed, window=spec.window, stride=spec.stride)
        got = last.results[spec.stream_id]
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a, b)


def test_64_worker_fleet_is_one_dispatch_per_tick():
    eng = VetEngine("numpy", buckets=64)
    mux = VetMux(eng)
    ticks = play(build("uniform", n_workers=64, n_ticks=3, window=16,
                       seed=22), mux)
    moving = [t for t in ticks if t.rows]
    assert moving and all(t.dispatches == 1 for t in moving)
    assert eng.dispatches == len(moving)  # never one per stream


def test_benchmark_harness_smoke_tiny():
    """The benchmark's measurement loop at toy size (8 workers, numpy):
    payload complete, dispatch reduction == fleet width."""
    out = fleet_bench.bench_fleet_tick(8, window=16, stride=8, chunk=8,
                                       n_ticks=2, backend="numpy", seed=5)
    assert out["loop_dispatches_per_tick"] == 8
    assert out["mux_dispatches_per_tick"] == 1
    assert out["dispatch_reduction"] == 8
    assert np.isfinite(out["loop_tick_us"]) and np.isfinite(out["mux_tick_us"])


def test_benchmark_mixed_windows_smoke_tiny():
    out = fleet_bench.bench_mixed_windows(9, n_ticks=2, backend="numpy",
                                          seed=6)
    assert out["max_dispatches_per_tick"] <= out["window_lengths"] == 3
    assert out["rows"] > 0
