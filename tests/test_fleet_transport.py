"""Transport suite for ``repro.fleet.transport`` (the cross-process fleet).

The tentpole contract: ``TransportVetMux`` drives the same shard muxes as
``ShardedVetMux`` through real worker processes, and the fleet survives a
shard dying mid-tick — after retry + checkpoint resume the merged
``vet_job`` still equals the in-process oracle at 1e-9, with no window
vetted twice (lifetime dispatch/row counters stay equal to the oracle's,
which vetted every window exactly once by construction).

Three rungs of the differential ladder live here:

1. **inprocess driver vs ``ShardedVetMux``** across the whole scenario
   bank — locks the command protocol (register/feed/demand/tick/collect)
   to the in-process fleet with no pipes in play;
2. **process driver vs the oracle** — adds real pipes, spawn, and
   serialization (bounded to two scenarios: each worker spawn imports the
   full stack);
3. **process driver under injected worker crashes** — the acceptance
   scenario: kill one shard mid-tick, recover via checkpoint + journal
   replay, stay equal to the oracle.

Also locked here: retry/backoff semantics against a fault-injecting fake
channel (exact exponential schedule, retry-budget exhaustion, logical
errors never retried), checkpoint/resume state roundtrips at the mux and
stream level, the fork-safe lazy platform probe (engine construction never
triggers backend discovery; workers inherit the parent's policy), and the
transport surface's loud deltas (attached streams rejected, ``stream()``
redirects to ``collect``).
"""

import numpy as np
import pytest

from repro.engine import VetEngine, VetStream
from repro.fleet import (
    SCENARIOS,
    EngineSpec,
    ShardedVetMux,
    TransportError,
    TransportVetMux,
    VetMux,
    build,
)
from repro.fleet.transport import ShardWorker
from repro.fleet.transport.driver import ShardHandle, _TransportFailure
from repro.kernels import runtime

PROCESS_KW = dict(driver="process", timeout=30.0, backoff_base=0.01)


def job_or_none(tick):
    try:
        return tick.job
    except ValueError:  # no stream has a complete window yet
        return None


def assert_rows_equal(got, ref, context=""):
    assert (got is None) == (ref is None), context
    if ref is None:
        return
    assert got.workers == ref.workers, context
    for name in ("vet", "ei", "oc", "pr", "t", "n"):
        np.testing.assert_array_equal(getattr(got, name), getattr(ref, name),
                                      err_msg=context)


def lockstep(name, fleet, oracle, **overrides):
    """Drive a scenario through a transport fleet and the in-process oracle
    in lockstep, comparing every tick: schedule decisions (serviced /
    deferred / urgent), dispatch and row counters, the newest-window row of
    every stream, and the merged job reduction."""
    scenario = build(name, **overrides)
    for spec in scenario.specs:
        spec.register(fleet)
        spec.register(oracle)
    for k, event in enumerate(scenario.events):
        for spec in event.joins:
            spec.register(fleet)
            spec.register(oracle)
        for sid, chunk in event.chunks.items():
            fleet.feed(sid, chunk)
            oracle.feed(sid, chunk)
        tick = fleet.tick()
        ref = oracle.tick()
        ctx = f"{name} tick {k}"
        assert tick.serviced == ref.serviced, ctx
        assert tick.deferred == ref.deferred, ctx
        assert sorted(tick.urgent) == sorted(ref.urgent), ctx
        assert tick.dispatches == ref.dispatches, ctx
        assert tick.rows == ref.rows, ctx
        assert tick.padded_rows == ref.padded_rows, ctx
        assert set(tick.results) == set(ref.results), ctx
        for sid, rr in ref.results.items():
            got = tick.results[sid]
            if rr is None or rr.workers == 0:
                assert got is None or got.workers == 0, f"{ctx} stream {sid}"
                continue
            # Transport ticks carry each stream's newest-window row only.
            assert got.workers == 1, f"{ctx} stream {sid}"
            for field in ("vet", "ei", "oc", "pr", "t", "n"):
                np.testing.assert_array_equal(
                    getattr(got, field)[-1:], getattr(rr, field)[-1:],
                    err_msg=f"{ctx} stream {sid} {field}")
        tj, rj = job_or_none(tick), job_or_none(ref)
        assert (tj is None) == (rj is None), ctx
        if rj is not None:
            assert tj.streams == rj.streams, ctx
            assert abs(tj.vet_job - rj.vet_job) <= 1e-9, ctx
        for sid in event.leaves:
            fleet.deregister(sid)
            oracle.deregister(sid)
    # Lifetime counters: every window vetted exactly once on both sides.
    fs, os_ = fleet.stats, oracle.stats
    assert (fs.dispatches, fs.rows, fs.padded_rows, fs.deferred) == \
           (os_.dispatches, os_.rows, os_.padded_rows, os_.deferred)
    # Retained rows of every surviving stream, bitwise (numpy backend).
    for sid in list(fleet.ids()):
        assert_rows_equal(fleet.collect(sid), oracle.stream(sid).collect(),
                          context=f"{name} collect {sid}")


# ---------------------------------------------------------- differential
class TestInprocessDifferential:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_every_tick_matches_the_sharded_oracle(self, name):
        with TransportVetMux(2, backend="numpy", driver="inprocess") as fleet:
            lockstep(name, fleet, ShardedVetMux(2, backend="numpy"),
                     n_workers=6, n_ticks=5, seed=11)

    def test_budgeted_fleet_converges_to_oracle_after_flush(self):
        sc = build("uniform", n_workers=6, n_ticks=4, window=16, seed=5)
        with TransportVetMux(2, backend="numpy", driver="inprocess",
                             budget=4) as fleet:
            oracle = ShardedVetMux(2, backend="numpy", budget=4)
            for spec in sc.specs:
                spec.register(fleet)
                spec.register(oracle)
            for event in sc.events:
                for sid, chunk in event.chunks.items():
                    fleet.feed(sid, chunk)
                    oracle.feed(sid, chunk)
                t, r = fleet.tick(), oracle.tick()
                assert t.budgets == r.budgets  # same water-fill both sides
            assert fleet.stats.deferred > 0  # the budget actually bit
            last = fleet.flush()
            ref = oracle.flush()
            assert abs(last.vet_job - ref.vet_job) <= 1e-9
            for sid in fleet.ids():
                assert_rows_equal(fleet.collect(sid),
                                  oracle.stream(sid).collect(), context=sid)


class TestProcessDifferential:
    @pytest.mark.parametrize("name", ["churn", "mixed_windows"])
    def test_real_worker_processes_match_the_oracle(self, name):
        with TransportVetMux(2, backend="numpy", **PROCESS_KW) as fleet:
            lockstep(name, fleet, ShardedVetMux(2, backend="numpy"),
                     n_workers=5, n_ticks=4, seed=11)
            assert fleet.stats.retries == 0  # healthy run: no transport work
            assert fleet.stats.respawns == 0


# -------------------------------------------------------- crash recovery
def drive_steps(mux, *, steps=5, workers=6, seed=7, fault_at=None,
                fault_mode="mid"):
    """Deterministic feed/tick loop (same draws for fleet and oracle);
    optionally arms a worker crash on shard 0 before step ``fault_at``."""
    rng = np.random.default_rng(seed)
    for w in range(workers):
        mux.register(f"w{w}", window=8, stride=4, capacity=64)
    ticks = []
    for step in range(steps):
        for w in range(workers):
            mux.feed(f"w{w}", rng.standard_normal(12) ** 2 + 1e-3)
        if fault_at is not None and step == fault_at:
            # One worker lineage dies at its next tick command.
            mux.inject_fault(0, at_tick=fault_at + 1, mode=fault_mode)
        ticks.append(mux.tick())
    return ticks


class TestKillOneShardMidTick:
    @pytest.mark.parametrize("mode", ["mid", "before"])
    def test_checkpoint_resume_matches_the_oracle_exactly_once(self, mode):
        """The acceptance scenario: shard 0's worker is killed mid-job
        (``mid`` = after committing its tick but before replying — the torn
        dispatch), the driver respawns it from checkpoint + journal, and
        the run stays equal to the in-process oracle: per-tick vet_job at
        1e-9, lifetime dispatch/row counters equal (every window vetted
        exactly once — a re-vet or a skip would show as a counter drift),
        retained rows bitwise."""
        oracle = ShardedVetMux(2, backend="numpy")
        o_ticks = drive_steps(oracle)
        with TransportVetMux(2, backend="numpy", **PROCESS_KW) as fleet:
            t_ticks = drive_steps(fleet, fault_at=2, fault_mode=mode)
            for ot, tt in zip(o_ticks, t_ticks):
                oj, tj = job_or_none(ot), job_or_none(tt)
                assert (oj is None) == (tj is None)
                if oj is not None:
                    assert abs(oj.vet_job - tj.vet_job) <= 1e-9
            os_, ts = oracle.stats, fleet.stats
            assert (os_.dispatches, os_.rows) == (ts.dispatches, ts.rows)
            assert ts.retries >= 1 and ts.respawns == 1
            acc = fleet.accounts[0]
            assert acc.respawns == 1 and acc.retries >= 1
            assert acc.checkpoints >= 1 and acc.elapsed_s > 0
            assert fleet.accounts[1].respawns == 0  # shard 1 never died
            # Tick-level accounting surfaces the recovery in ShardTick.
            assert t_ticks[-1].accounts[0].respawns == 1
            for w in range(6):
                assert_rows_equal(fleet.collect(f"w{w}"),
                                  oracle.stream(f"w{w}").collect(),
                                  context=f"w{w}")

    def test_coarse_checkpoint_cadence_still_recovers(self):
        """checkpoint_every > 1 widens the journal-replay window (feeds
        since the last checkpoint) but recovery must still be exact."""
        oracle = ShardedVetMux(2, backend="numpy")
        o_ticks = drive_steps(oracle)
        with TransportVetMux(2, backend="numpy", checkpoint_every=3,
                             **PROCESS_KW) as fleet:
            t_ticks = drive_steps(fleet, fault_at=3)
            oj, tj = o_ticks[-1].job, t_ticks[-1].job
            assert abs(oj.vet_job - tj.vet_job) <= 1e-9
            assert fleet.stats.respawns == 1
            assert (oracle.stats.dispatches, oracle.stats.rows) == \
                   (fleet.stats.dispatches, fleet.stats.rows)


# -------------------------------------------------------- retry/backoff
class FlakyChannel:
    """Fault-injecting channel double: the next ``fail`` receives raise a
    transport failure, later ones return ``reply``.  Records everything."""

    def __init__(self, fail=0, reply=("ok", 42)):
        self.fail = fail
        self.reply = reply
        self.alive = False
        self.spawns = 0
        self.sent = []

    def spawn(self):
        self.spawns += 1
        self.alive = True

    def send(self, msg):
        if not self.alive:
            raise _TransportFailure("send on a dead channel")
        self.sent.append(msg)

    def recv(self, timeout):
        if self.fail > 0:
            self.fail -= 1
            raise _TransportFailure("injected")
        return self.reply

    def kill(self):
        self.alive = False

    def close(self):
        self.alive = False


def handle_with(channel, **kw):
    sleeps = []
    kw.setdefault("max_retries", 3)
    kw.setdefault("backoff_base", 0.05)
    kw.setdefault("backoff_factor", 2.0)
    h = ShardHandle(0, channel, sleep=sleeps.append, **kw)
    channel.spawn()  # the driver spawns eagerly; initial spawn != respawn
    return h, sleeps


class TestRetryBackoff:
    def test_transient_failures_retry_with_exponential_backoff(self):
        ch = FlakyChannel(fail=3)
        h, sleeps = handle_with(ch)
        assert h.call("stats", None) == 42
        assert sleeps == [0.05, 0.1, 0.2]  # base * factor**attempt
        assert h.retries == 3 and h.respawns == 3  # dead channel revived
        assert h.calls == 1  # one *successful* round trip

    def test_retry_budget_exhaustion_is_a_transport_error(self):
        ch = FlakyChannel(fail=99)
        h, sleeps = handle_with(ch, max_retries=2)
        with pytest.raises(TransportError, match="after 2 retries"):
            h.call("tick", None)
        assert sleeps == [0.05, 0.1]
        assert h.retries == 2 and h.calls == 0

    def test_logical_errors_reraise_by_name_and_never_retry(self):
        ch = FlakyChannel(reply=("err", "KeyError", "'nope'"))
        h, sleeps = handle_with(ch)
        with pytest.raises(KeyError, match="nope"):
            h.call("feed", ("nope", None))
        assert sleeps == [] and h.retries == 0 and h.calls == 0

    def test_unknown_error_types_arrive_as_transport_error_unretried(self):
        ch = FlakyChannel(reply=("err", "SomethingExotic", "boom"))
        h, _ = handle_with(ch)
        with pytest.raises(TransportError, match="boom"):
            h.call("tick", None)
        assert h.retries == 0

    def test_revive_replays_checkpoint_then_journal_in_order(self):
        ch = FlakyChannel()
        h, _ = handle_with(ch)
        h.checkpoint_blob = {"mock": "checkpoint"}
        h.journal.extend([("register", {"sid": "a"}), ("feed", ("a", 1))])
        h._revive()
        assert ch.sent == [("restore", {"mock": "checkpoint"}),
                           ("register", {"sid": "a"}), ("feed", ("a", 1))]
        assert h.respawns == 1

    def test_journaled_commands_accumulate_until_checkpoint(self):
        ch = FlakyChannel(reply=("ok", None))
        h, _ = handle_with(ch)
        h.call("register", {"sid": "a"}, journal=True)
        h.call("feed", ("a", 1), journal=True)
        h.call("stats", None)  # read-only: not journaled
        assert h.journal == [("register", {"sid": "a"}), ("feed", ("a", 1))]

    def test_finish_tick_falls_back_to_the_reliable_path(self):
        ch = FlakyChannel(fail=1)  # async reply lost; reliable retry wins
        h, sleeps = handle_with(ch)
        h.tick_async(None)
        out = h.finish_tick()
        assert out == 42
        assert h.retries == 1 and sleeps == [0.05]


# --------------------------------------------------- checkpoint roundtrip
class TestCheckpointRoundtrip:
    def feed_some(self, mux):
        mux.register("a", window=8, stride=4, capacity=64)
        mux.register("b", window=16, stride=8, capacity=64)
        mux.feed("a", np.linspace(1e-3, 2e-3, 20))
        mux.feed("b", np.linspace(1e-3, 3e-3, 24))
        mux.tick()

    def test_mux_state_dict_roundtrip_continues_identically(self):
        """checkpoint -> restore into a fresh mux -> both sides fed the same
        tail produce bitwise-identical rows and identical counters: exactly
        what a respawned worker does."""
        a = VetMux(VetEngine("numpy", buckets=64))
        self.feed_some(a)
        state = a.state_dict()
        b = VetMux(VetEngine("numpy", buckets=64))
        b.load_state_dict(state)
        tail = np.linspace(2e-3, 4e-3, 16)
        for mux in (a, b):
            mux.feed("a", tail)
            mux.feed("b", tail)
            mux.tick()
        for sid in ("a", "b"):
            assert_rows_equal(b.stream(sid).collect(),
                              a.stream(sid).collect(), context=sid)
        assert b.stats == a.stats

    def test_checkpoint_survives_pickle(self):
        import pickle
        a = VetMux(VetEngine("numpy", buckets=64))
        self.feed_some(a)
        blob = pickle.loads(pickle.dumps(a.state_dict()))
        b = VetMux(VetEngine("numpy", buckets=64))
        b.load_state_dict(blob)
        for sid in ("a", "b"):
            assert_rows_equal(b.stream(sid).collect(),
                              a.stream(sid).collect(), context=sid)

    def test_restored_stream_fingerprint_diverges_from_the_dead_lineage(self):
        """A restored stream chains its fingerprint off the checkpoint
        digest, so post-resume engine-cache keys can never collide with the
        dead lineage's keys for different future data."""
        eng = VetEngine("numpy", buckets=64)
        st = VetStream(eng, window=8, stride=4, capacity=64)
        st.feed(np.linspace(1e-3, 2e-3, 20))
        st.tick()
        restored = VetStream.from_state(eng, st.state_dict())
        assert restored.fingerprint != st.fingerprint
        # but the data and rows are the originals, bitwise
        assert_rows_equal(restored.collect(), st.collect())

    def test_deregister_pulls_the_stream_back_across_the_boundary(self):
        with TransportVetMux(2, backend="numpy", driver="inprocess") as fleet:
            fleet.register("a", window=8, stride=4, capacity=64)
            times = np.linspace(1e-3, 2e-3, 20)
            fleet.feed("a", times)
            fleet.tick()
            stream = fleet.deregister("a")
            assert isinstance(stream, VetStream)
            ref = VetEngine("numpy", buckets=64).vet_sliding(
                times, window=8, stride=4)
            np.testing.assert_array_equal(stream.collect().vet, ref.vet)
            assert "a" not in fleet


# ------------------------------------------------- fork-safe lazy probe
class TestRuntimePolicy:
    def test_engine_construction_never_probes_the_backend(self, monkeypatch):
        """Building an engine (as every spawning worker does) must not
        trigger jax backend discovery — the probe deadlock-bait the lazy
        policy exists to avoid."""
        monkeypatch.setattr(runtime, "_PLATFORM", None)
        def boom():
            raise AssertionError("backend discovery ran at construction")
        monkeypatch.setattr(runtime.jax, "default_backend", boom)
        eng = VetEngine("numpy", buckets=64)
        assert eng._interpret is None  # unresolved, not probed
        clone = eng.clone()
        assert clone._interpret is None

    def test_interpret_resolves_lazily_on_first_access(self, monkeypatch):
        monkeypatch.setattr(runtime, "_PLATFORM", None)
        monkeypatch.delenv(runtime.ENV_VAR, raising=False)
        monkeypatch.setattr(runtime.jax, "default_backend", lambda: "cpu")
        eng = VetEngine("numpy", buckets=64)
        assert eng.interpret is True  # cpu probes to interpret mode
        assert runtime.platform_default_hint() is True  # memoized

    def test_seed_installs_the_parent_policy_without_probing(self, monkeypatch):
        monkeypatch.setattr(runtime, "_PLATFORM", None)
        def boom():
            raise AssertionError("seeded worker must not probe")
        monkeypatch.setattr(runtime.jax, "default_backend", boom)
        runtime.seed_platform_default(False)  # parent probed: TPU/compiled
        assert runtime.platform_default_hint() is False
        assert runtime.resolve_interpret(None) is False

    def test_env_override_beats_the_seed(self, monkeypatch):
        monkeypatch.setattr(runtime, "_PLATFORM", None)
        runtime.seed_platform_default(False)
        monkeypatch.setenv(runtime.ENV_VAR, "1")
        assert runtime.resolve_interpret(None) is True

    def test_seed_none_leaves_the_lazy_probe_armed(self, monkeypatch):
        monkeypatch.setattr(runtime, "_PLATFORM", None)
        runtime.seed_platform_default(None)
        assert runtime.platform_default_hint() is None

    def test_clone_forwards_the_unresolved_interpret_argument(self):
        explicit = VetEngine("numpy", buckets=64, interpret=True)
        assert explicit.clone()._interpret_arg is True
        lazy = VetEngine("numpy", buckets=64)
        assert lazy.clone()._interpret_arg is None

    def test_engine_spec_carries_the_unresolved_argument(self):
        spec = EngineSpec.from_engine(VetEngine("numpy", buckets=64))
        assert spec.interpret is None
        built = spec.build()
        assert built._interpret is None


# ------------------------------------------------------------- lifecycle
class TestTransportLifecycle:
    def test_driver_validation(self):
        with pytest.raises(ValueError, match="driver"):
            TransportVetMux(2, backend="numpy", driver="carrier-pigeon")

    def test_checkpoint_cadence_validation(self):
        with pytest.raises(ValueError, match="checkpoint_every"):
            TransportVetMux(2, backend="numpy", driver="inprocess",
                            checkpoint_every=0)

    def test_budget_validation(self):
        with pytest.raises(ValueError, match="budget"):
            TransportVetMux(2, backend="numpy", driver="inprocess", budget=0)

    def test_engines_and_engine_are_mutually_exclusive(self):
        with pytest.raises(ValueError, match="not both"):
            TransportVetMux(engines=[EngineSpec.from_engine(
                VetEngine("numpy", buckets=64))],
                engine=VetEngine("numpy", buckets=64), driver="inprocess")

    def test_attached_streams_cannot_cross_the_boundary(self):
        eng = VetEngine("numpy", buckets=64)
        with TransportVetMux(2, backend="numpy", driver="inprocess") as fleet:
            with pytest.raises(ValueError, match="process boundary"):
                fleet.register("a", stream=VetStream(eng, window=8, stride=4))

    def test_register_needs_window_geometry(self):
        with TransportVetMux(2, backend="numpy", driver="inprocess") as fleet:
            with pytest.raises(ValueError, match="window"):
                fleet.register("a")

    def test_register_duplicate_rejected(self):
        with TransportVetMux(2, backend="numpy", driver="inprocess") as fleet:
            fleet.register("a", window=8)
            with pytest.raises(ValueError, match="already registered"):
                fleet.register("a", window=8)

    def test_stream_access_redirects_to_collect(self):
        with TransportVetMux(2, backend="numpy", driver="inprocess") as fleet:
            fleet.register("a", window=8)
            with pytest.raises(TypeError, match="collect"):
                fleet.stream("a")
            with pytest.raises(KeyError, match="not registered"):
                fleet.stream("ghost")

    def test_fault_injection_needs_the_process_driver(self):
        with TransportVetMux(2, backend="numpy", driver="inprocess") as fleet:
            with pytest.raises(ValueError, match="process"):
                fleet.inject_fault(0, at_tick=1)

    def test_logical_worker_errors_reraise_without_retries(self):
        with TransportVetMux(2, backend="numpy", driver="inprocess") as fleet:
            with pytest.raises(KeyError, match="not registered"):
                fleet.feed("ghost", np.ones(4))
            assert fleet.stats.retries == 0

    def test_placement_mirrors_the_sharded_fleet(self):
        smux = ShardedVetMux(3, backend="numpy", placement="pack")
        with TransportVetMux(3, backend="numpy", driver="inprocess",
                             placement="pack") as fleet:
            for i, w in enumerate((8, 16, 8, 32, 16, 8)):
                smux.register(i, window=w, stride=w // 2, capacity=4 * w)
                fleet.register(i, window=w, stride=w // 2, capacity=4 * w)
            assert fleet.assignment == {
                sid: smux.shard_of(sid) for sid in smux.ids()}
            assert list(fleet.ids()) == list(smux.ids())
            assert len(fleet) == len(smux) == 6

    def test_flush_boundary_is_pinned(self):
        def backlog():
            fleet = TransportVetMux(2, backend="numpy", driver="inprocess",
                                    budget=2)
            fleet.register("a", window=8, stride=4, capacity=256)
            fleet.feed("a", np.linspace(1e-3, 2e-3, 40))  # 9 windows
            return fleet
        with backlog() as fleet:
            assert not fleet.flush(max_ticks=5).deferred
        with backlog() as fleet:
            with pytest.raises(RuntimeError, match="did not converge"):
                fleet.flush(max_ticks=4)
        with backlog() as fleet:
            with pytest.raises(ValueError, match="max_ticks"):
                fleet.flush(max_ticks=0)

    def test_close_is_idempotent_and_context_managed(self):
        fleet = TransportVetMux(2, backend="numpy", driver="inprocess")
        fleet.close()
        fleet.close()
