"""Online autotuner suite: the simulator-recoverability lock.

The tentpole contract: on the ``tunable`` scenario — whose reducible
overhead is shaped by the knob assignment through an envelope with a
known optimum — the online ``VetTuner`` driving the fleet through the
``knob_hooks`` seam must land where exhaustive grid search lands:

- **noiseless**: exactly the grid oracle's best assignment (the objective
  is then a pure function of the assignment, so this is a differential
  test, not a tolerance call), on all three engine backends;
- **seeded noise**: within one knob step of the optimum in at most
  ``NOISY_TICKS`` ticks.

Also locked here: the knob_hooks seam itself (all-or-nothing validation,
snapshot round-trip, the ``tick_budget`` knob writing the live budget of
every mux variant), the tick objective reader, the ledger prior, and the
PR 9 trace seam — tuner spans must appear in a validated Chrome export.
"""

import json

import numpy as np
import pytest

from repro.engine import BACKENDS, VetEngine
from repro.fleet import (
    Knob,
    KnobHooks,
    ShardedVetMux,
    VetMux,
    mux_knob_hooks,
    tunable,
)
from repro.obs import Tracer, validate_chrome, write_chrome
from repro.obs.ledger import LedgerReport, StageLedger
from repro.sched.tuner import (
    VetTuner,
    evaluate_candidate,
    grid_scenario,
    objective_from_tick,
    tune_scenario,
)

SEED = 0
NOISE = 0.15
NOISY_TICKS = 160  # the "<= N ticks" bound for the noisy lock


def _engine(backend):
    return VetEngine(backend, buckets=64)


def _error_steps(a, b, scenario):
    """Max per-knob index distance between two assignments."""
    return max(abs(k.index_of(a[k.name]) - k.index_of(b[k.name]))
               for k in scenario.knobs)


# ------------------------------------------------------- recoverability lock
@pytest.mark.parametrize("backend", BACKENDS)
def test_noiseless_recovers_grid_optimum(backend):
    """Differential lock: online tuner == exhaustive grid oracle, exactly."""
    grid = grid_scenario(tunable(seed=SEED), engine=_engine(backend))
    rep = tune_scenario(tunable(seed=SEED), engine=_engine(backend),
                        max_ticks=96, seed=SEED)
    assert rep.best == grid.best[0]
    # Same assignment measured through the same backend: identical bytes per
    # evaluation; the tuner's running mean over repeat visits may drift in
    # the last ulp, nothing more.
    assert rep.best_y == pytest.approx(grid.best[1], rel=1e-12)
    assert rep.converged
    # The walk also *settles* on the optimum, not just visits it.
    assert rep.current == grid.best[0]


@pytest.mark.parametrize("backend", BACKENDS)
def test_noiseless_optimum_is_designed_optimum(backend):
    """The grid oracle itself lands on the scenario's designed optimum
    (envelope == 1 exactly there), so the lock above is anchored to known
    ground truth rather than to whatever the oracle happens to like."""
    sc = tunable(seed=SEED)
    grid = grid_scenario(sc, engine=_engine(backend))
    assert grid.best[0] == sc.optimum


@pytest.mark.parametrize("backend", BACKENDS)
def test_noisy_recovers_within_one_step(backend):
    """Seeded lognormal noise on the overhead channel: the tuner must land
    within one knob step of the optimum inside the tick budget."""
    sc = tunable(seed=SEED, noise=NOISE)
    rep = tune_scenario(sc, engine=_engine(backend), max_ticks=NOISY_TICKS,
                        settle=2, seed=SEED)
    assert _error_steps(rep.best, sc.optimum, sc) <= 1


def test_noisy_recovery_across_seeds():
    """The noisy bound is not a lucky seed: several draws on the fast
    backend, all within one step."""
    for seed in range(4):
        sc = tunable(seed=seed, noise=NOISE)
        rep = tune_scenario(sc, engine=_engine("numpy"),
                            max_ticks=NOISY_TICKS, settle=2, seed=seed)
        assert _error_steps(rep.best, sc.optimum, sc) <= 1, f"seed {seed}"


def test_noiseless_assignment_is_pure():
    """The determinism the exact lock rests on: a given assignment yields
    bitwise-identical chunks on every tick when noise is off, and distinct
    envelopes otherwise."""
    sc = tunable(seed=SEED)
    a = sc.chunks(0)
    b = sc.chunks(7)
    for sid in a:
        np.testing.assert_array_equal(a[sid], b[sid])
    sc.hooks().apply(sc.optimum)
    c = sc.chunks(0)
    assert not np.array_equal(a["w0000"], c["w0000"])
    noisy = tunable(seed=SEED, noise=NOISE)
    assert not np.array_equal(noisy.chunks(0)["w0000"],
                              noisy.chunks(1)["w0000"])


# ----------------------------------------------------------- knob_hooks seam
def test_knob_validation():
    with pytest.raises(ValueError):
        Knob("empty", ())
    with pytest.raises(ValueError):
        Knob("dup", (1, 1))
    with pytest.raises(ValueError):
        Knob("bad", (1, 2), kind="genetic")
    k = Knob("q", (16, 32, 64))
    assert k.index_of(32) == 1 and k.value(2) == 64 and k.clip(9) == 2
    with pytest.raises(ValueError):
        k.index_of(48)


def test_hooks_apply_is_all_or_nothing():
    state = {"a": 1, "b": 10}
    hooks = KnobHooks.over_state((Knob("a", (1, 2)), Knob("b", (10, 20))),
                                 state)
    with pytest.raises(KeyError):
        hooks.apply({"a": 2, "nope": 1})
    with pytest.raises(ValueError):
        hooks.apply({"a": 2, "b": 99})
    # Both rejections happened before any setter ran.
    assert state == {"a": 1, "b": 10}
    assert hooks.apply({"a": 2}) == {"a": 2}
    assert hooks.snapshot() == {"a": 2, "b": 10}
    with pytest.raises(ValueError):
        hooks.register(Knob("a", (1,)), lambda v: None, lambda: 1)


@pytest.mark.parametrize("mux_cls", [VetMux, ShardedVetMux])
def test_mux_knob_hooks_write_live_budget(mux_cls):
    """The tick_budget knob writes the driver-side budget of a live mux
    (single and sharded variants share the seam)."""
    eng = _engine("numpy")
    mux = (mux_cls(eng, monitor=False) if mux_cls is VetMux
           else mux_cls(2, engine=eng))
    hooks = mux_knob_hooks(mux, budget_values=(8, 16, 32))
    assert hooks.snapshot() == {"tick_budget": 32}  # None -> loosest arm
    hooks.apply({"tick_budget": 16})
    assert mux.budget == 16
    assert hooks.snapshot() == {"tick_budget": 16}
    with pytest.raises(ValueError):
        mux_knob_hooks(VetMux(eng, monitor=False), budget_values=(0, 8))


# ---------------------------------------------------------- objective reader
def test_objective_from_tick_kinds_and_include():
    sc = tunable(seed=SEED)
    mux = VetMux(_engine("numpy"), monitor=False)
    for spec in sc.specs:
        spec.register(mux)
    for sid, chunk in sc.chunks(0).items():
        mux.feed(sid, chunk)
    tick = mux.tick()
    vet = objective_from_tick(tick, "vet")
    pr = objective_from_tick(tick, "pr")
    ei = objective_from_tick(tick, "ei")
    assert vet >= 1.0 and pr > ei > 0
    assert vet == pytest.approx(tick.vet_job)
    only_w0 = objective_from_tick(tick, "vet", include=("w0000",))
    assert only_w0 == float(tick.results["w0000"].vet[-1])
    with pytest.raises(ValueError):
        objective_from_tick(tick, "latency")
    with pytest.raises(ValueError):
        objective_from_tick(tick, "vet", include=("absent",))


# -------------------------------------------------------------- ledger prior
def test_ledger_prior_biases_knob_selection():
    """A ledger whose dispatch stage sits far off its floor should steer
    probing toward the knobs mapped to that stage."""
    hooks = KnobHooks.over_state(
        (Knob("hot", (1, 2, 4)), Knob("cold", (1, 2, 4))),
        {"hot": 1, "cold": 1})
    tuner = VetTuner(hooks, seed=SEED)
    stage = StageLedger("engine.dispatch", 10, 1.0, 0, 0.01, 50.0)
    report = LedgerReport(stages=(stage,), measured_s=1.0, floor_s=0.01,
                          ratio=50.0)
    weights = tuner.update_prior(report, {"engine.dispatch": ("hot",)})
    assert weights["hot"] == 50.0 and weights["cold"] == 1.0
    for _ in range(200):
        tuner.step(1.0)
    picked = [r.knob for r in tuner.history if r.phase == "minus"]
    assert picked.count("hot") > 3 * picked.count("cold")


# --------------------------------------------------------------- trace seam
def test_tuner_spans_in_chrome_trace(tmp_path):
    """PR 9 seam regression: candidate scoring and every tuner phase land
    on the one tracer clock and survive the Chrome export round-trip."""
    tracer = Tracer()
    cand = evaluate_candidate({"n_micro": 2}, np.linspace(1e-3, 2e-3, 64),
                              engine=_engine("numpy"), tracer=tracer)
    assert cand.vet >= 1.0 and cand.mean_step_s > 0
    tune_scenario(tunable(seed=SEED), engine=_engine("numpy"), max_ticks=12,
                  seed=SEED, tracer=tracer)
    path = tmp_path / "tuner_trace.json"
    write_chrome(str(path), tracer)
    trace = json.loads(path.read_text())
    assert validate_chrome(trace) == []
    names = {ev.get("name") for ev in trace["traceEvents"]}
    assert "tuner.candidate" in names
    assert "tuner.phase" in names
    # The untraced path is still measured (timed() stopwatch fallback).
    assert evaluate_candidate({"n_micro": 2}, np.linspace(1e-3, 2e-3, 64),
                              engine=_engine("numpy")).vet >= 1.0
