"""Per-kernel Pallas validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles, interpret=True (kernel bodies executed in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.changepoint import estimate_changepoint
from repro.kernels.changepoint.ops import changepoint_pallas, two_segment_sse_pallas
from repro.kernels.changepoint.ref import two_segment_sse_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


# ------------------------------------------------------------ changepoint SSE
class TestChangepointKernel:
    @pytest.mark.parametrize("n", [300, 1024, 4096, 10_000])
    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_sse_matches_ref(self, n, dtype):
        k = int(n * 0.8)
        y = np.sort(
            np.concatenate([RNG.normal(1, 0.05, k), RNG.normal(4, 0.5, n - k)])
        ).astype(dtype)
        sse_k = np.asarray(two_segment_sse_pallas(jnp.asarray(y)))[: n]
        sse_r = np.asarray(two_segment_sse_ref(jnp.asarray(y, jnp.float32)))
        m = np.isfinite(sse_r)
        assert np.isfinite(sse_k[m]).all()
        np.testing.assert_allclose(sse_k[m], sse_r[m], rtol=5e-3, atol=1e-2)
        # same inf mask inside the probing window
        np.testing.assert_array_equal(np.isinf(sse_k[: n]), np.isinf(sse_r))

    @pytest.mark.parametrize("n", [256, 2000, 8192])
    def test_changepoint_matches_core(self, n):
        k = int(n * 0.7)
        y = np.sort(
            np.concatenate([RNG.normal(1, 0.02, k), 3 + RNG.pareto(1.5, n - k)])
        )
        t_kernel = int(changepoint_pallas(jnp.asarray(y)))
        t_core = int(estimate_changepoint(jnp.asarray(y)))
        assert abs(t_kernel - t_core) <= max(2, int(0.01 * n))

    @pytest.mark.parametrize("omega", [3, 10, 50])
    def test_probing_window(self, omega):
        y = np.sort(RNG.normal(1, 0.1, 1024))
        t = int(changepoint_pallas(jnp.asarray(y), omega=omega))
        assert omega <= t <= 1024 - omega

    def test_vmapped_kernel_matches_per_row(self):
        """The engine's batched pallas path is vmap over the single-row
        kernel; a lifted batch must agree with per-row calls."""
        rows = []
        for i in range(6):
            k = 150 + 10 * i
            rows.append(np.sort(np.concatenate(
                [RNG.normal(1, 0.02, k), 3 + RNG.pareto(1.5, 256 - k)]
            )))
        y = jnp.asarray(np.stack(rows))
        fn = lambda r: changepoint_pallas(r, block=256)  # noqa: E731
        t_batch = np.asarray(jax.vmap(fn)(y))
        assert t_batch.shape == (6,)
        for i in range(6):
            assert t_batch[i] == int(fn(y[i]))


# ------------------------------------------------------------ flash attention
ATTN_SWEEP = [
    # (B, S, H, KH, D, causal, window)
    (1, 128, 4, 4, 64, True, 0),
    (2, 256, 8, 2, 64, True, 0),  # GQA 4:1
    (1, 256, 4, 1, 64, True, 0),  # MQA
    (1, 384, 4, 2, 128, True, 128),  # SWA
    (1, 256, 4, 4, 64, False, 0),  # bidirectional (encoder)
    (1, 200, 4, 4, 64, True, 0),  # ragged S (padding path)
]


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kh,d,causal,window", ATTN_SWEEP)
    def test_matches_ref_f32(self, b, s, h, kh, d, causal, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, window=window)
        ref = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.bfloat16)
        out = flash_attention(q, k, v)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=2e-2, atol=2e-2,
        )

    @pytest.mark.parametrize("bq,bk", [(128, 128), (128, 256), (256, 128)])
    def test_block_shapes(self, bq, bk):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 512, 2, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 512, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 512, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, bq=bq, bk=bk)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------- SSD scan
SSD_SWEEP = [
    # (B, T, H, P, N, chunk)
    (1, 128, 2, 64, 16, 64),
    (2, 256, 4, 32, 64, 64),
    (1, 128, 3, 16, 8, 32),
    (1, 512, 2, 64, 128, 64),  # mamba2-130m state size
]


class TestSSD:
    @pytest.mark.parametrize("b,t,h,p,n,chunk", SSD_SWEEP)
    def test_matches_stepwise_ref(self, b, t, h, p, n, chunk):
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h), jnp.float32))
        a_log = jnp.log(jnp.linspace(1.0, 8.0, h))
        bb = jax.random.normal(ks[2], (b, t, n), jnp.float32)
        cc = jax.random.normal(ks[3], (b, t, n), jnp.float32)
        d = jnp.ones((h,))
        out = ssd(x, dt, a_log, bb, cc, d, chunk=chunk)
        ref = ssd_ref(x, dt, a_log, bb, cc, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16_inputs(self):
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (1, 128, 2, 32), jnp.bfloat16)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, 2), jnp.float32))
        a_log = jnp.log(jnp.linspace(1.0, 4.0, 2))
        bb = jax.random.normal(ks[2], (1, 128, 16), jnp.bfloat16)
        cc = jax.random.normal(ks[3], (1, 128, 16), jnp.bfloat16)
        d = jnp.ones((2,))
        out = ssd(x, dt, a_log, bb, cc, d, chunk=64)
        ref = ssd_ref(x, dt, a_log, bb, cc, d)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=5e-2, atol=5e-2,
        )

    def test_state_continuity_across_chunks(self):
        """Halving the chunk size must not change the result (state carry)."""
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (1, 256, 2, 32), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 2), jnp.float32))
        a_log = jnp.log(jnp.linspace(1.0, 4.0, 2))
        bb = jax.random.normal(ks[2], (1, 256, 16), jnp.float32)
        cc = jax.random.normal(ks[3], (1, 256, 16), jnp.float32)
        d = jnp.zeros((2,))
        o64 = ssd(x, dt, a_log, bb, cc, d, chunk=64)
        o32 = ssd(x, dt, a_log, bb, cc, d, chunk=32)
        np.testing.assert_allclose(np.asarray(o64), np.asarray(o32),
                                   rtol=2e-4, atol=2e-4)
