"""Unit tests for the collective-bytes HLO parser."""

from repro.distributed.hlo_analysis import collective_bytes


HLO = """
HloModule jit_step
  %ag = bf16[16,4096,128]{2,1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[1024,1024]{1,0} all-reduce-start(%y), channel_id=3
  %done = f32[1024,1024]{1,0} all-reduce-done(%ar.1)
  %tuple = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-to-all(%a, %b)
  %rs = f32[256]{0} reduce-scatter(%z)
  %cp = s32[32,2]{1,0} collective-permute(%w)
  %not_a_collective = f32[4]{0} add(%p, %q)
"""


def test_parses_kinds_and_bytes():
    res = collective_bytes(HLO)
    bk = res["bytes_by_kind"]
    assert bk["all-gather"] == 16 * 4096 * 128 * 2
    assert bk["all-reduce"] == 1024 * 1024 * 4  # -start counted, -done not
    assert bk["all-to-all"] == 2 * 8 * 8 * 2
    assert bk["reduce-scatter"] == 256 * 4
    assert bk["collective-permute"] == 32 * 2 * 4
    assert res["counts"]["all-reduce"] == 1


def test_ring_factors():
    res = collective_bytes(HLO)
    expected = (16 * 4096 * 128 * 2  # AG x1
                + 2 * 1024 * 1024 * 4  # AR x2
                + 2 * 8 * 8 * 2  # A2A x1
                + 256 * 4  # RS x1
                + 32 * 2 * 4)  # CP x1
    assert res["ici_bytes"] == expected


def test_empty():
    res = collective_bytes("HloModule empty\n  %r = f32[2]{0} add(%a, %b)\n")
    assert res["ici_bytes"] == 0
    assert res["counts"] == {}
