"""Differential + placement + budget suite for ``repro.fleet.shard``.

The tentpole contract: a ``ShardedVetMux`` partitions the fleet across K
shard muxes (one ``VetEngine`` each — separate model processes) and every
stream's rows stay *equal to the single-mux oracle over the same feeds* —
bitwise on the numpy backend, 1e-5 on jax/pallas (their standing
differential contracts) — while the merged job-level ``vet_job`` matches the
single mux to 1e-9.  Every scenario in the bank is driven through a sharded
mux and a single-mux oracle in lockstep on all three backends.

Also locked here: deterministic placement (same registration/churn history
=> same assignment, for both policies), length-affine bin-packing (shape
buckets never shatter: fleet-total dispatches stay within single-mux + K),
job-budget water-filling across shards with flush convergence, per-shard
engine isolation, and the job-reduction merge algebra.
"""

import numpy as np
import pytest

from repro.engine import VetEngine, VetStream
from repro.fleet import (
    SCENARIOS,
    JobVet,
    ShardedVetMux,
    VetMux,
    build,
    job_reduce,
    merge_job,
    play,
    split_budget,
)

# Per-backend scenario sizes: numpy sweeps a bit wider, the jitted backends
# keep compiles small (pallas runs in interpret mode on CPU containers).
SIZES = {
    "numpy": dict(n_workers=6, n_ticks=5, seed=11),
    "jax": dict(n_workers=5, n_ticks=4, seed=7),
    "pallas": dict(n_workers=4, n_ticks=3, seed=3),
}


def overrides(name, backend):
    ov = dict(SIZES[backend])
    if backend == "pallas":  # small windows: interpret-mode kernel cost
        if name == "mixed_windows":
            ov["windows"] = (8, 12, 16)
        else:
            ov["window"] = 16
    return ov


def assert_rows_match(got, ref, *, bitwise, context=""):
    assert (got is None) == (ref is None), context
    if ref is None:
        return
    assert got.workers == ref.workers, context
    for name in ("vet", "ei", "oc", "pr"):
        a, b = getattr(got, name), getattr(ref, name)
        if bitwise:
            np.testing.assert_array_equal(a, b, err_msg=context)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-9,
                                       err_msg=context)
    np.testing.assert_array_equal(got.t, ref.t, err_msg=context)
    np.testing.assert_array_equal(got.n, ref.n, err_msg=context)


def drive_and_compare(name, backend, *, shards, bitwise, **ov):
    """Lockstep a scenario through a ShardedVetMux and a single-mux oracle,
    comparing every tick's per-stream rows and the merged job reduction."""
    scenario = build(name, **ov)
    smux = ShardedVetMux(shards, backend=backend)
    oracle = VetMux(VetEngine(backend, buckets=64))
    for spec in scenario.specs:
        spec.register(smux)
        spec.register(oracle)
    for k, event in enumerate(scenario.events):
        for spec in event.joins:
            spec.register(smux)
            spec.register(oracle)
        for sid, chunk in event.chunks.items():
            smux.feed(sid, chunk)
            oracle.feed(sid, chunk)
        tick = smux.tick()
        ref = oracle.tick()
        assert not tick.deferred  # no budget => full service every tick
        assert set(tick.results) == set(ref.results)
        for sid in ref.results:
            assert_rows_match(tick.results[sid], ref.results[sid],
                              bitwise=bitwise,
                              context=f"{name} tick {k} stream {sid}")
        if any(r is not None for r in ref.results.values()):
            # The job-level merge across shards equals the single-mux mean.
            assert abs(tick.vet_job - ref.vet_job) <= 1e-9, f"{name} tick {k}"
        for sid in event.leaves:
            smux.deregister(sid)
            oracle.deregister(sid)
    return smux


# ---------------------------------------------------------- differential
class TestShardedDifferential:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_numpy_every_tick_bitwise_equals_single_mux(self, name):
        """Every scenario, every tick, every stream: bitwise vs one mux."""
        smux = drive_and_compare(name, "numpy", shards=3, bitwise=True,
                                 **overrides(name, "numpy"))
        assert smux.stats.rows > 0
        # more than one shard actually carried streams
        assert sum(1 for s in smux.shard_stats if s.rows > 0) > 1

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_jax_every_tick_matches_single_mux_1e5(self, name):
        drive_and_compare(name, "jax", shards=2, bitwise=False,
                          **overrides(name, "jax"))

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_pallas_every_tick_matches_single_mux_1e5(self, name):
        drive_and_compare(name, "pallas", shards=2, bitwise=False,
                          **overrides(name, "pallas"))

    def test_merged_job_reduction_matches_direct_fleet_means(self):
        """JobVet ei/oc are the stream-count-weighted means of every
        stream's newest-window EI/OC, exactly as one process would compute
        over the whole fleet."""
        scenario = build("skewed_stragglers", n_workers=6, n_ticks=4, seed=2)
        smux = ShardedVetMux(3, backend="numpy")
        last = play(scenario, smux)[-1]
        job = last.job
        newest = [(float(r.vet[-1]), float(r.ei[-1]), float(r.oc[-1]))
                  for r in last.results.values() if r is not None]
        assert job.streams == len(newest)
        assert job.vet_job == pytest.approx(np.mean([v for v, _, _ in newest]),
                                            abs=1e-12)
        assert job.ei == pytest.approx(np.mean([e for _, e, _ in newest]),
                                       abs=1e-12)
        assert job.oc == pytest.approx(np.mean([o for _, _, o in newest]),
                                       abs=1e-12)

    def test_merge_job_algebra(self):
        a = JobVet(vet_job=2.0, ei=1.0, oc=1.0, streams=2)
        b = JobVet(vet_job=5.0, ei=1.0, oc=4.0, streams=1)
        m = merge_job([a, None, b])
        assert m == JobVet(vet_job=3.0, ei=1.0, oc=2.0, streams=3)
        with pytest.raises(ValueError, match="complete window"):
            merge_job([None, None])

    def test_job_reduce_is_none_before_any_window(self):
        mux = VetMux(VetEngine("numpy", buckets=64))
        mux.register("a", window=8, stride=4)
        mux.feed("a", np.linspace(1e-3, 2e-3, 4))  # below one window
        assert job_reduce(mux.tick()) is None


# -------------------------------------------------------------- placement
class TestPlacement:
    def assignments(self, placement, scenario_name="churn", shards=3,
                    **ov):
        smux = ShardedVetMux(shards, backend="numpy", placement=placement)
        play(build(scenario_name, **ov), smux)
        return smux.assignment

    @pytest.mark.parametrize("placement", ("pack", "round_robin"))
    def test_same_churn_history_same_assignment(self, placement):
        """Same seed (scenario) => same placement, register/deregister churn
        included — the determinism the differential suites stand on."""
        ov = dict(n_workers=8, n_ticks=8, seed=0)
        a = self.assignments(placement, **ov)
        b = self.assignments(placement, **ov)
        assert a == b

    def test_round_robin_cycles_registration_order(self):
        smux = ShardedVetMux(3, backend="numpy", placement="round_robin")
        for i in range(6):
            smux.register(i, window=8, stride=4)
        assert [smux.shard_of(i) for i in range(6)] == [0, 1, 2, 0, 1, 2]

    def test_pack_balances_a_homogeneous_fleet(self):
        smux = ShardedVetMux(4, backend="numpy")
        for i in range(8):
            smux.register(i, window=8, stride=4)
        per_shard = [0] * 4
        for i in range(8):
            per_shard[smux.shard_of(i)] += 1
        assert per_shard == [2, 2, 2, 2]

    def test_pack_co_locates_window_lengths(self):
        """3 lengths on 3 shards: each shard hosts exactly one distinct
        length, so a shard tick is one dispatch (no bucket shattering)."""
        sc = build("mixed_windows", n_workers=9, n_ticks=2, seed=1)
        smux = ShardedVetMux(3, backend="numpy")
        for spec in sc.specs:
            spec.register(smux)
        lengths_per_shard = [set() for _ in range(3)]
        for spec in sc.specs:
            lengths_per_shard[smux.shard_of(spec.stream_id)].add(spec.window)
        assert all(len(ls) == 1 for ls in lengths_per_shard)
        assert set().union(*lengths_per_shard) == {16, 32, 64}

    def test_deregister_rebalances_deterministically(self):
        smux = ShardedVetMux(2, backend="numpy")
        for sid in "abcd":
            smux.register(sid, window=8, stride=4)
        before = dict(smux.assignment)
        victim = "a"
        smux.deregister(victim)
        # the vacated shard is now lightest *and* still hosts the length:
        # the next same-geometry register lands there
        smux.register("e", window=8, stride=4)
        assert smux.shard_of("e") == before[victim]

    def test_attached_stream_pins_its_engine_shard(self):
        smux = ShardedVetMux(2, backend="numpy")
        own = VetStream(smux.shard(1).engine, window=8, stride=4)
        assert smux.register("pinned", stream=own) is own
        assert smux.shard_of("pinned") == 1
        alien = VetStream(VetEngine("numpy", buckets=64), window=8)
        with pytest.raises(ValueError, match="shard engines"):
            smux.register("alien", stream=alien)


# ------------------------------------------------------ dispatch bounds
class TestDispatchBounds:
    def test_uniform_total_dispatches_le_single_plus_shards(self):
        """K shards cost at most K extra dispatches per tick over one mux
        (one bucket split across at most K shards)."""
        k = 4
        sc = build("uniform", n_workers=16, n_ticks=4, window=16, seed=0)
        single = VetMux(VetEngine("numpy", buckets=64))
        smux = ShardedVetMux(k, backend="numpy")
        ref = play(sc, single)
        got = play(build("uniform", n_workers=16, n_ticks=4, window=16,
                         seed=0), smux)
        for t_ref, t_got in zip(ref, got):
            assert t_got.dispatches <= t_ref.dispatches + k

    def test_mixed_windows_shard_ticks_stay_one_dispatch_per_length(self):
        sc = build("mixed_windows", n_workers=9, n_ticks=4, seed=1)
        n_lengths = len({s.window for s in sc.specs})
        smux = ShardedVetMux(3, backend="numpy")
        ticks = play(sc, smux)
        assert max(t.dispatches for t in ticks) <= n_lengths + 3
        # with co-located lengths the total never exceeds the single-mux
        # bucket count at all
        assert max(t.dispatches for t in ticks) == n_lengths

    def test_shard_engines_are_isolated(self):
        """Each shard's dispatches land on its own engine only (the
        separate-process model), and the merged stats are their sum."""
        smux = ShardedVetMux(2, backend="numpy")
        play(build("uniform", n_workers=4, n_ticks=3, window=16, seed=4),
             smux)
        engines = smux.engines
        assert len({id(e) for e in engines}) == 2
        assert all(e.dispatches > 0 for e in engines)
        assert sum(e.dispatches for e in engines) == smux.stats.dispatches
        per_shard = smux.shard_stats
        assert [s.dispatches for s in per_shard] == \
            [e.dispatches for e in engines]


# ---------------------------------------------------------------- budget
class TestShardBudget:
    def test_budget_bites_and_flush_converges_to_oracle(self):
        """The job budget defers rows across shards but never drops or
        reorders them: after flush the fleet equals the batch oracle."""
        sc = build("uniform", n_workers=6, n_ticks=4, window=16, seed=5)
        smux = ShardedVetMux(2, backend="numpy", budget=4)
        play(sc, smux)
        assert smux.stats.deferred > 0  # the budget actually bit
        last = smux.flush()
        oracle = VetEngine("numpy", buckets=64)
        for spec in sc.specs:
            fed = np.concatenate([e.chunks[spec.stream_id]
                                  for e in sc.events
                                  if spec.stream_id in e.chunks])
            ref = oracle.vet_sliding(fed, window=spec.window,
                                     stride=spec.stride)
            assert_rows_match(last.results[spec.stream_id], ref,
                              bitwise=True, context=spec.stream_id)

    def test_tick_water_fills_the_budget_across_shards(self):
        smux = ShardedVetMux(2, backend="numpy", budget=4)
        for i in range(4):
            smux.register(i, window=8, stride=4, capacity=256)
        for i in range(4):
            smux.feed(i, np.linspace(1e-3, 2e-3, 40))  # 9 windows each
        tick = smux.tick()
        assert tick.budgets == (2, 2)  # equal demand => even split
        assert tick.rows == 4  # job budget respected fleet-wide
        assert sum(tick.deferred.values()) > 0

    def test_budget_validation(self):
        with pytest.raises(ValueError, match="budget"):
            ShardedVetMux(2, backend="numpy", budget=0)

    # ----- split_budget unit behavior (the shard-level water-filling)
    def test_split_budget_respects_demand(self):
        assert split_budget(100, [3, 0, 1]) == [3, 0, 1]

    def test_split_budget_even_and_remainder(self):
        assert split_budget(8, [10, 10]) == [4, 4]
        assert split_budget(5, [10, 10]) == [3, 2]  # remainder round-robin

    def test_split_budget_unused_share_flows(self):
        assert split_budget(8, [2, 10]) == [2, 6]

    def test_split_budget_weights_bias(self):
        assert split_budget(9, [12, 12], weights=[2.0, 1.0]) == [6, 3]

    def test_split_budget_zero_and_negative_budget(self):
        assert split_budget(0, [5, 5]) == [0, 0]
        assert split_budget(-3, [5, 5]) == [0, 0]

    def test_split_budget_validation(self):
        with pytest.raises(ValueError, match="weight"):
            split_budget(4, [1, 1], weights=[1.0, 0.0])
        with pytest.raises(ValueError, match="length"):
            split_budget(4, [1, 1], weights=[1.0])

    def test_split_budget_adversarial_float_weights_stay_in_budget(self):
        """Waterfill regression: with a pool large enough that a float ulp
        of ``pool * w / total_w`` exceeds 1, the unclamped floors summed 28
        rows above the pool and the round silently over-allocated (the
        remainder ``range()`` went empty instead of negative).  The shares
        are now clamped cumulatively to the pool."""
        pool = 699606058459349848
        weights = [0.2122188106686006, 0.035734441736370415,
                   0.6812461849926625, 0.9997187959452691]
        alloc = split_budget(pool, [pool] * 4, weights=weights)
        assert sum(alloc) == pool
        assert all(0 <= a <= pool for a in alloc)

    def test_flush_tick_boundary_is_pinned(self):
        """Same inclusive ``max_ticks`` boundary as ``VetMux.flush`` (shared
        helper): a 9-window backlog at job budget 2 converges in exactly 5
        ticks, one fewer raises, zero is rejected."""
        def backlog():
            smux = ShardedVetMux(2, backend="numpy", budget=2)
            smux.register("a", window=8, stride=4, capacity=256)
            smux.feed("a", np.linspace(1e-3, 2e-3, 40))
            return smux
        last = backlog().flush(max_ticks=5)
        assert not last.deferred
        with pytest.raises(RuntimeError, match="did not converge within 4"):
            backlog().flush(max_ticks=4)
        with pytest.raises(ValueError, match="max_ticks"):
            backlog().flush(max_ticks=0)

    def test_urgent_streams_still_served_past_the_job_budget(self):
        """Ring-overrun urgency is a per-shard correctness rail: a stream at
        the edge of its ring is drained in full regardless of the slice."""
        smux = ShardedVetMux(2, backend="numpy", budget=1)
        smux.register("tight", window=8, stride=4, capacity=16)
        smux.register("other", window=8, stride=4, capacity=256)
        rng = np.random.default_rng(1)
        tight_times = rng.uniform(1e-3, 2e-3, 160)
        smux.feed("other", rng.uniform(1e-3, 2e-3, 64))
        smux.feed("tight", tight_times)  # 10x the ring: pressure ticks
        last = smux.flush()
        ref = VetEngine("numpy", buckets=64).vet_sliding(
            tight_times, window=8, stride=4)
        assert_rows_match(last.results["tight"], ref, bitwise=True)


# -------------------------------------------------------------- lifecycle
class TestShardedLifecycle:
    def test_constructor_validation(self):
        with pytest.raises(ValueError, match="shards must be >= 1"):
            ShardedVetMux(0, backend="numpy")
        with pytest.raises(ValueError, match="placement"):
            ShardedVetMux(2, backend="numpy", placement="random")
        with pytest.raises(ValueError, match="not both"):
            ShardedVetMux(engines=[VetEngine("numpy")],
                          engine=VetEngine("numpy"))
        with pytest.raises(ValueError, match="at least one"):
            ShardedVetMux(engines=[])
        with pytest.raises(ValueError, match="engines given"):
            ShardedVetMux(3, engines=[VetEngine("numpy")])

    def test_engine_template_replicates_config(self):
        template = VetEngine("numpy", omega=4, buckets=32, cut_space="raw",
                             cache_size=7)
        smux = ShardedVetMux(3, engine=template)
        assert smux.engines[0] is template
        for e in smux.engines[1:]:
            assert e is not template
            assert (e.backend, e.omega, e.buckets, e.cut_space) == \
                ("numpy", 4, 32, "raw")
            assert e._cache_size == 7

    def test_register_duplicate_rejected_across_shards(self):
        smux = ShardedVetMux(2, backend="numpy")
        smux.register("a", window=8)
        with pytest.raises(ValueError, match="already registered"):
            smux.register("a", window=8)

    def test_register_needs_window_or_stream(self):
        with pytest.raises(ValueError, match="window"):
            ShardedVetMux(2, backend="numpy").register("a")

    def test_feed_requires_registration(self):
        with pytest.raises(KeyError, match="not registered"):
            ShardedVetMux(2, backend="numpy").feed("ghost", [1.0, 2.0])

    def test_ids_iterate_in_registration_order_across_shards(self):
        smux = ShardedVetMux(3, backend="numpy")
        order = ["z", "a", "m", "b"]
        for sid in order:
            smux.register(sid, window=8, stride=4)
        assert list(smux.ids()) == order
        assert len(smux) == 4 and "m" in smux

    def test_deregistered_stream_survives_standalone(self):
        smux = ShardedVetMux(2, backend="numpy")
        smux.register("a", window=8, stride=4)
        smux.feed("a", np.linspace(1e-3, 2e-3, 16))
        t = smux.tick()
        stream = smux.deregister("a")
        assert "a" not in smux and len(smux) == 0
        stream.append(np.linspace(2e-3, 3e-3, 8))
        res = stream.tick()
        assert res.workers > t.results["a"].workers

    def test_tick_results_follow_registration_order(self):
        smux = ShardedVetMux(2, backend="numpy")
        for sid in ("x", "y", "z"):
            smux.register(sid, window=8, stride=4)
            smux.feed(sid, np.linspace(1e-3, 2e-3, 8))
        tick = smux.tick()
        assert list(tick.results) == ["x", "y", "z"]
