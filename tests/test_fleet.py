"""Differential + scheduling suite for ``repro.fleet`` (the vet mux).

The tentpole contract: a ``VetMux`` tick coalesces every registered stream's
newly complete windows into shared shape-bucketed dispatches, and each
stream's rows are *equal to what its own independent ``tick()`` would have
computed* — bitwise on the numpy backend (the coalesced matrix runs the same
row-independent scalar loop), 1e-5 on jax/pallas (vmap rows are independent;
the backends' standing differential contract).  Every scenario in the bank
is driven through the mux and through a per-stream oracle fleet in lockstep,
comparing every tick's rows for every stream.

Also locked here: the tick planner (budget backpressure, tenant fairness
water-filling, staleness aging, ring-overrun urgency), dispatch-count
coalescing (one dispatch per distinct window length per tick), engine-cache
replay of whole fleets, churn bookkeeping, and the commit safety rails.
"""

import numpy as np
import pytest

from repro.engine import VetEngine, VetStream
from repro.fleet import (
    SCENARIOS,
    StreamRequest,
    VetMux,
    build,
    plan_tick,
    play,
)

JITTED_BACKENDS = ("jax", "pallas")


def oracle_fleet(scenario, backend):
    """Independent per-stream VetStreams on a fresh engine (the pre-mux
    path), stepped in lockstep with the scenario's events."""
    engine = VetEngine(backend, buckets=64)
    streams = {
        s.stream_id: VetStream(engine, window=s.window, stride=s.stride,
                               capacity=s.capacity)
        for s in scenario.specs
    }

    def step(event):
        for spec in event.joins:
            streams[spec.stream_id] = VetStream(
                engine, window=spec.window, stride=spec.stride,
                capacity=spec.capacity)
        for sid, chunk in event.chunks.items():
            streams[sid].feed(chunk)
        return {sid: st.tick() for sid, st in streams.items()}

    return streams, step


def assert_rows_match(got, ref, *, bitwise, context=""):
    assert (got is None) == (ref is None), context
    if ref is None:
        return
    assert got.workers == ref.workers, context
    for name in ("vet", "ei", "oc", "pr"):
        a, b = getattr(got, name), getattr(ref, name)
        if bitwise:
            np.testing.assert_array_equal(a, b, err_msg=context)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-9,
                                       err_msg=context)
    np.testing.assert_array_equal(got.t, ref.t, err_msg=context)
    np.testing.assert_array_equal(got.n, ref.n, err_msg=context)


def drive_and_compare(name, backend, *, bitwise, **overrides):
    scenario = build(name, **overrides)
    mux = VetMux(VetEngine(backend, buckets=64))
    oracle_streams, oracle_step = oracle_fleet(scenario, backend)
    for spec in scenario.specs:
        spec.register(mux)
    for k, event in enumerate(scenario.events):
        for spec in event.joins:
            spec.register(mux)
        for sid, chunk in event.chunks.items():
            mux.feed(sid, chunk)
        tick = mux.tick()
        refs = oracle_step(event)
        assert not tick.deferred  # no budget => full service every tick
        for sid, ref in refs.items():
            assert_rows_match(tick.results[sid], ref, bitwise=bitwise,
                              context=f"{name} tick {k} stream {sid}")
        for sid in event.leaves:  # churn: the oracle fleet mirrors leavers
            mux.deregister(sid)
            oracle_streams.pop(sid)
    return mux


# ---------------------------------------------------------- differential
class TestMuxDifferential:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_numpy_every_tick_bitwise_equals_per_stream_oracle(self, name):
        """Every scenario in the bank, every tick, every stream: bitwise."""
        mux = drive_and_compare(name, "numpy", bitwise=True,
                                n_workers=6, n_ticks=5, seed=11)
        assert mux.stats.rows > 0

    @pytest.mark.parametrize("name", ("uniform", "mixed_windows", "churn"))
    def test_jax_every_tick_matches_oracle_1e5(self, name):
        drive_and_compare(name, "jax", bitwise=False,
                          n_workers=5, n_ticks=4, seed=7)

    def test_pallas_matches_oracle_1e5(self):
        drive_and_compare("uniform", "pallas", bitwise=False,
                          n_workers=4, n_ticks=4, window=16, seed=3)

    def test_budgeted_mux_converges_to_oracle_after_flush(self):
        """Backpressure defers rows, never drops or reorders them: after a
        final flush the fleet equals the unbudgeted oracle bitwise."""
        scenario = build("uniform", n_workers=6, n_ticks=4, window=16, seed=5)
        mux = VetMux(VetEngine("numpy", buckets=64), budget=4)
        play(scenario, mux)
        assert mux.stats.deferred > 0  # the budget actually bit
        last = mux.flush()
        oracle = VetEngine("numpy", buckets=64)
        for spec in scenario.specs:
            fed = np.concatenate([e.chunks[spec.stream_id]
                                  for e in scenario.events
                                  if spec.stream_id in e.chunks])
            ref = oracle.vet_sliding(fed, window=spec.window,
                                     stride=spec.stride)
            assert_rows_match(last.results[spec.stream_id], ref, bitwise=True,
                              context=spec.stream_id)

    def test_fleet_vet_job_matches_mean_of_newest_window_vets(self):
        scenario = build("skewed_stragglers", n_workers=6, n_ticks=4, seed=2)
        mux = VetMux(VetEngine("numpy", buckets=64))
        last = play(scenario, mux)[-1]
        newest = [float(r.vet[-1]) for r in last.results.values()
                  if r is not None]
        assert last.vet_job == pytest.approx(float(np.mean(newest)))
        # stragglers carry a heavier tail: fleet vet_job above the clean
        # workers' median vet
        clean = sorted(newest)[len(newest) // 2]
        assert last.vet_job >= 1.0 and clean >= 1.0


# ------------------------------------------------------------ coalescing
class TestCoalescing:
    def test_homogeneous_fleet_is_one_dispatch_per_tick(self):
        eng = VetEngine("numpy", buckets=64)
        mux = VetMux(eng)
        play(build("uniform", n_workers=16, n_ticks=4, window=16, seed=0),
             mux)
        # every tick that moved rows issued exactly one dispatch
        assert mux.stats.rows > 16
        assert eng.dispatches == mux.stats.dispatches
        assert mux.stats.dispatches <= 4  # <= one per tick, never per stream

    def test_mixed_fleet_dispatches_once_per_window_length(self):
        sc = build("mixed_windows", n_workers=9, n_ticks=4, seed=1)
        n_lengths = len({s.window for s in sc.specs})
        mux = VetMux(VetEngine("numpy", buckets=64))
        ticks = play(sc, mux)
        assert max(t.dispatches for t in ticks) == n_lengths
        assert all(t.dispatches <= n_lengths for t in ticks)

    def test_pow2_padding_bounds_compiled_shapes(self):
        """Jitted backends see pow2 row counts only: deltas of 3 and 5 rows
        share the padded shapes 4 and 8, not two fresh compiles."""
        eng = VetEngine("jax", buckets=64)
        mux = VetMux(eng)
        for i in range(5):
            mux.register(i, window=16, stride=8, capacity=128)
        for i in range(3):  # only 3 of 5 streams have a window ready
            mux.feed(i, np.full(16, 1e-3 * (i + 1)))
        t1 = mux.tick()
        assert t1.rows == 3 and t1.padded_rows == 1  # 3 -> 4
        for i in range(3):  # one more window for the first three...
            mux.feed(i, np.full(8, 2e-3 * (i + 1)))
        for i in range(3, 5):  # ...and a first window for the last two
            mux.feed(i, np.full(16, 3e-3 * (i + 1)))
        t2 = mux.tick()
        assert t2.rows == 5 and t2.padded_rows == 3  # 3+2 = 5 -> 8

    def test_fleet_replay_is_served_from_the_engine_cache(self):
        """Replaying the same fleet into the same engine re-issues zero
        dispatches: the coalesced keys are content-pure."""
        eng = VetEngine("numpy", buckets=64)
        play(build("uniform", n_workers=4, n_ticks=4, window=16, seed=9),
             VetMux(eng))
        before = eng.dispatches
        play(build("uniform", n_workers=4, n_ticks=4, window=16, seed=9),
             VetMux(eng))
        assert eng.dispatches == before
        assert eng.cache_info().hits >= before

    def test_quiet_streams_cost_no_dispatch(self):
        eng = VetEngine("numpy", buckets=64)
        mux = VetMux(eng)
        mux.register("busy", window=16, stride=8)
        mux.register("quiet", window=16, stride=8)
        mux.feed("busy", np.linspace(1e-3, 2e-3, 32))
        mux.tick()
        d = eng.dispatches
        r1 = mux.tick()  # nobody moved: no dispatch, results are reused
        assert eng.dispatches == d
        assert r1.results["quiet"] is None
        assert r1.dispatches == 0 and r1.rows == 0


# -------------------------------------------------------------- planner
class TestTickPlanner:
    def req(self, sid, pending, *, priority=0.0, tenant="default",
            staleness=0, headroom=100):
        return StreamRequest(sid, pending, priority, tenant, staleness,
                             headroom)

    def test_no_budget_serves_everything_in_priority_order(self):
        plan = plan_tick([self.req("a", 2), self.req("b", 3, priority=1.0),
                          self.req("z", 0)])
        assert list(plan.serve) == ["b", "a"]  # z has nothing pending
        assert plan.serve["b"] == 3 and not plan.deferred

    def test_budget_caps_rows_and_defers_the_rest(self):
        plan = plan_tick([self.req("a", 4), self.req("b", 4)], budget=5)
        assert plan.total_rows == 5
        assert plan.deferred and sum(plan.deferred.values()) == 3

    def test_urgent_streams_served_in_full_even_past_budget(self):
        plan = plan_tick([self.req("a", 4), self.req("u", 6, headroom=0)],
                         budget=3)
        assert plan.urgent == ("u",)
        assert plan.serve["u"] == 6  # overrun risk beats the budget
        assert "a" in plan.deferred

    def test_equal_tenants_split_the_budget_evenly(self):
        plan = plan_tick([self.req("a1", 10, tenant="a"),
                          self.req("b1", 10, tenant="b")], budget=8)
        assert plan.serve["a1"] == plan.serve["b1"] == 4

    def test_tenant_weights_bias_the_split(self):
        plan = plan_tick([self.req("a1", 12, tenant="a"),
                          self.req("b1", 12, tenant="b")], budget=9,
                         tenant_weights={"a": 2.0, "b": 1.0})
        assert plan.serve["a1"] == 6 and plan.serve["b1"] == 3

    def test_unused_share_flows_to_tenants_with_demand(self):
        plan = plan_tick([self.req("a1", 2, tenant="a"),
                          self.req("b1", 10, tenant="b")], budget=8)
        assert plan.serve["a1"] == 2 and plan.serve["b1"] == 6

    def test_staleness_out_ages_priority(self):
        """A deferred low-priority stream eventually overtakes a hot one."""
        hot = self.req("hot", 5, priority=2.0)
        old = self.req("old", 5, priority=0.0, staleness=3)
        plan = plan_tick([hot, old], budget=5)
        assert list(plan.serve)[0] == "old"

    def test_deterministic_tiebreak_is_registration_order(self):
        plan = plan_tick([self.req("x", 3), self.req("y", 3)], budget=4)
        assert list(plan.serve) == ["x", "y"]
        assert plan.serve["x"] >= plan.serve["y"]

    def test_duplicate_ids_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            plan_tick([self.req("a", 1), self.req("a", 1)])

    def test_bad_weight_rejected(self):
        with pytest.raises(ValueError, match="weight"):
            plan_tick([self.req("a", 1, tenant="t")], budget=1,
                      tenant_weights={"t": 0.0})

    def test_adversarial_float_weights_never_overgrant_the_budget(self):
        """Waterfill regression: at pools this large a float ulp of
        ``pool * w / total_w`` exceeds 1, so the unclamped floors summed
        *above* the pool and the planner granted more rows than the budget
        (28 extra here).  The clamp pins the grant to exactly the budget."""
        pool = 699606058459349848
        w = [0.2122188106686006, 0.035734441736370415,
             0.6812461849926625, 0.9997187959452691]
        reqs = [self.req(f"s{i}", pool, tenant=f"t{i}") for i in range(4)]
        plan = plan_tick(reqs, budget=pool,
                         tenant_weights={f"t{i}": w[i] for i in range(4)})
        assert plan.total_rows == pool
        assert all(0 <= n <= pool for n in plan.serve.values())


class TestFlushBoundary:
    """``flush(max_ticks=N)`` performs at most N ticks, the first included
    — the boundary the sharded and transport muxes share via the same
    helper."""

    def _backlog(self):
        # 9 pending windows at budget 2: convergence takes exactly 5 ticks.
        mux = VetMux(VetEngine("numpy", buckets=64), budget=2)
        mux.register("a", window=8, stride=4, capacity=256)
        mux.feed("a", np.linspace(1e-3, 2e-3, 40))
        return mux

    def test_flush_converges_exactly_at_the_boundary(self):
        mux = self._backlog()
        last = mux.flush(max_ticks=5)
        assert not last.deferred and mux.stats.ticks == 5

    def test_flush_raises_when_the_boundary_is_one_short(self):
        with pytest.raises(RuntimeError, match="did not converge within 4"):
            self._backlog().flush(max_ticks=4)

    def test_flush_rejects_a_nonpositive_boundary(self):
        with pytest.raises(ValueError, match="max_ticks"):
            self._backlog().flush(max_ticks=0)


# ---------------------------------------------------- mux aging/urgency
class TestMuxScheduling:
    def test_staleness_ages_deferred_streams_to_the_front(self):
        """Under a tight budget with a hot high-priority stream, the cold
        stream is served within a bounded number of ticks (no starvation)."""
        eng = VetEngine("numpy", buckets=64)
        mux = VetMux(eng, budget=2)
        mux.register("hot", window=8, stride=4, capacity=256, priority=3.0)
        mux.register("cold", window=8, stride=4, capacity=256)
        rng = np.random.default_rng(0)
        mux.feed("cold", rng.uniform(1e-3, 2e-3, 64))
        served_cold_at = None
        for k in range(6):
            mux.feed("hot", rng.uniform(1e-3, 2e-3, 16))
            tick = mux.tick()
            if tick.serviced.get("cold"):
                served_cold_at = k
                break
        assert served_cold_at is not None and served_cold_at <= 5

    def test_overrun_pressure_forces_coalesced_service(self):
        """A stream at the edge of its ring is served in full (urgent) and
        never raises, even under a tiny budget."""
        eng = VetEngine("numpy", buckets=64)
        mux = VetMux(eng, budget=1)
        mux.register("tight", window=8, stride=4, capacity=16)
        mux.register("other", window=8, stride=4, capacity=256)
        rng = np.random.default_rng(1)
        other_times = rng.uniform(1e-3, 2e-3, 64)
        tight_times = rng.uniform(1e-3, 2e-3, 160)
        mux.feed("other", other_times)
        # 10x the ring: mux.feed must tick (coalesced) instead of overrun
        mux.feed("tight", tight_times)
        last = mux.flush()
        ref = VetEngine("numpy", buckets=64).vet_sliding(
            tight_times, window=8, stride=4)
        assert_rows_match(last.results["tight"], ref, bitwise=True)

    def test_feed_requires_registration(self):
        mux = VetMux(VetEngine("numpy", buckets=64))
        with pytest.raises(KeyError, match="not registered"):
            mux.feed("ghost", [1.0, 2.0])


# ------------------------------------------------------------- lifecycle
class TestMuxLifecycle:
    def make_mux(self):
        return VetMux(VetEngine("numpy", buckets=64))

    def test_register_duplicate_rejected(self):
        mux = self.make_mux()
        mux.register("a", window=8)
        with pytest.raises(ValueError, match="already registered"):
            mux.register("a", window=8)

    def test_register_needs_window_or_stream(self):
        with pytest.raises(ValueError, match="window"):
            self.make_mux().register("a")

    def test_attached_stream_must_share_the_engine(self):
        mux = self.make_mux()
        alien = VetStream(VetEngine("numpy", buckets=64), window=8)
        with pytest.raises(ValueError, match="share the mux engine"):
            mux.register("a", stream=alien)
        own = VetStream(mux.engine, window=8)
        assert mux.register("b", stream=own) is own

    def test_deregistered_stream_survives_standalone(self):
        mux = self.make_mux()
        mux.register("a", window=8, stride=4)
        mux.feed("a", np.linspace(1e-3, 2e-3, 16))
        t = mux.tick()
        stream = mux.deregister("a")
        assert "a" not in mux and len(mux) == 0
        # the stream keeps its rows and keeps working on its own
        before = stream.stats.vetted
        stream.append(np.linspace(2e-3, 3e-3, 8))
        res = stream.tick()
        assert res.workers > t.results["a"].workers
        assert stream.stats.vetted > before

    def test_commit_rejects_stale_or_misshapen_deltas(self):
        eng = VetEngine("numpy", buckets=64)
        st = VetStream(eng, window=8, stride=4)
        st.append(np.linspace(1e-3, 2e-3, 24))
        delta = st.drain()
        rows = eng.vet_batch(delta.matrix)
        st.commit(delta, rows)
        with pytest.raises(ValueError, match="stale delta"):
            st.commit(delta, rows)  # already committed
        st.append(np.linspace(2e-3, 3e-3, 8))
        d2 = st.drain()
        with pytest.raises(ValueError, match="result rows"):
            st.commit(d2, rows)  # wrong row count for this delta

    def test_commit_rejects_delta_drained_before_pending_window_amend(self):
        """An amend that touches only *pending* windows leaves the vetted
        watermark alone — the epoch rail must still reject the pre-amend
        delta, or stale rows would splice silently and the stream would
        diverge from the oracle forever (tumbling windows never rewind)."""
        eng = VetEngine("numpy", buckets=64)
        st = VetStream(eng, window=32, stride=32, capacity=256)
        times = np.linspace(1e-3, 2e-3, 128)
        st.append(times[:96])
        st.tick()
        st.append(times[96:])
        stale = st.drain()
        st.amend(100, [0.5])  # record only inside the pending window 3
        with pytest.raises(ValueError, match="epoch"):
            st.commit(stale, eng.vet_batch(stale.matrix))
        # a fresh drain picks up the mutation and matches the oracle
        res = st.tick()
        mutated = times.copy()
        mutated[100] = 0.5
        ref = eng.vet_sliding(mutated, window=32, stride=32)
        np.testing.assert_array_equal(res.vet, ref.vet)

    def test_drain_is_side_effect_free(self):
        eng = VetEngine("numpy", buckets=64)
        st = VetStream(eng, window=8, stride=4)
        st.append(np.linspace(1e-3, 2e-3, 24))
        d1 = st.drain()
        d2 = st.drain()
        assert d1.start == d2.start and d1.count == d2.count
        np.testing.assert_array_equal(d1.matrix, d2.matrix)
        assert d1.key == d2.key
        assert st.pending_windows == d1.count  # nothing advanced

    def test_partial_drain_covers_the_stream_exactly_once(self):
        eng = VetEngine("numpy", buckets=64)
        st = VetStream(eng, window=8, stride=4, capacity=64)
        times = np.linspace(1e-3, 2e-3, 40)
        st.append(times)
        seen = 0
        while st.pending_windows:
            d = st.drain(max_windows=2)
            st.commit(d, eng.vet_batch(d.matrix))
            seen += d.count
        ref = eng.vet_sliding(times, window=8, stride=4)
        assert seen == ref.workers
        np.testing.assert_array_equal(st.collect().vet, ref.vet)
