"""Numerical parity of the distributed paths on a real multi-device mesh
(subprocess with 4 host devices): the shard_map MoE (EP over TP ranks) and
the padded-vocab CE must match their single-device references exactly."""

import os
import subprocess
import sys

CHECK_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_ctx
from repro.models import init_params, forward
from repro.distributed.sharding import MeshAxes, param_specs, batch_specs
from jax.sharding import NamedSharding

cfg = dataclasses.replace(get_config("deepseek-moe-16b").reduced(),
                          capacity_factor=8.0)  # no drops: exact parity
params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
batch = {"tokens": tokens}

ref, aux_ref = forward(cfg, params, batch, q_chunk=32)  # single-device

mesh = make_mesh((2, 2), ("data", "model"))
ax = MeshAxes(mesh)
ctx = make_ctx(mesh)
ps = jax.tree.map(lambda sp: NamedSharding(mesh, sp), param_specs(params, ax, cfg))
bs = jax.tree.map(lambda sp: NamedSharding(mesh, sp), batch_specs(cfg, ax, batch))
p_dev = jax.device_put(params, ps)
b_dev = jax.device_put(batch, bs)
with jax.set_mesh(mesh):
    out, aux = jax.jit(lambda p, b: forward(cfg, p, b, ctx, q_chunk=32))(p_dev, b_dev)
err = float(jnp.max(jnp.abs(out - ref)))
aux_err = abs(float(aux) - float(aux_ref))
assert err < 5e-4, ("moe sharded vs local mismatch", err)
assert aux_err < 5e-4, ("aux loss mismatch", aux_err)
print("OK", err, aux_err)
"""

CHECK_VOCAB = r"""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import init_params, forward, loss_fn

# vocab 500 -> padded 512: CE must equal a manual masked CE over real ids
cfg = dataclasses.replace(get_config("qwen3-14b").reduced(), vocab_size=500)
assert cfg.vocab_padded == 512
params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 500)
labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 500)
batch = {"tokens": tokens, "labels": labels}
loss, parts = loss_fn(cfg, params, batch, q_chunk=16)
logits, _ = forward(cfg, params, batch, q_chunk=16)
assert logits.shape[-1] == 512
lf = np.asarray(logits, np.float64)[:, :, :500]   # manual: true-vocab only
lse = np.log(np.exp(lf - lf.max(-1, keepdims=True)).sum(-1)) + lf.max(-1)
ll = np.take_along_axis(lf, np.asarray(labels)[..., None], axis=-1)[..., 0]
manual = float((lse - ll).mean())
assert abs(float(parts["ce"]) - manual) < 1e-3, (float(parts["ce"]), manual)
# padded ids can never win sampling (decode path masks them)
from repro.models import decode_step, init_cache
cache = init_cache(cfg, 2, 16, dtype=jnp.float32)
lg, _ = decode_step(cfg, params, cache, tokens[:, :1], jnp.asarray(0))
assert lg.shape[-1] == 512
assert int(jnp.argmax(lg, -1).max()) < 500
print("OK")
"""


def _run(code):
    env = {**os.environ, "PYTHONPATH": "src"}
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=900, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_moe_shard_map_matches_local():
    _run(CHECK_MOE)


def test_padded_vocab_ce_exact():
    _run(CHECK_VOCAB)
