"""Anomaly-monitor suite: change-point detection on the live vet stream.

The tentpole contract: ``AnomalyMonitor`` runs the repo's own change-point
machinery one level up the stack — per-stream window-vet rings scanned
every mux tick — and for every scenario in the anomaly bank the *first*
flag on an affected stream localizes the injected onset within
``TOLERANCE_TICKS``, on all three detection backends, while unaffected
streams (including the hetero static-tier negative controls) never flag.

The detection ladder is differential the same way the engine ladder is:
``method="numpy"`` is the f64 oracle scan, ``"jax"`` runs
``core.changepoint.estimate_changepoint``, ``"pallas"`` runs the Pallas
kernel — confidence and levels are host-side f64 in all three, so the
backends may only disagree through the argmin, and the tolerance bounds
that disagreement too.

Also locked here: flags surfacing unchanged through ``ShardedVetMux`` and
``TransportVetMux`` (inprocess and real process workers), the
``MuxStats.anomalies`` counter, and monitor state riding the mux
checkpoint (restore never re-flags an onset the snapshot already raised).
"""

import numpy as np
import pytest

from repro.engine import VetEngine
from repro.fleet import (
    ANOMALY_SCENARIOS,
    AnomalyMonitor,
    ShardedVetMux,
    TransportVetMux,
    VetMux,
    build,
    play,
)

# The bank's differential seed: every scenario/backend combination below
# localizes within tolerance at this seed (detection on 16-tick series is
# sample-dependent; the bank pins the sample, the golden hashes in
# test_fleet_scenarios.py pin the bank).
SEED = 1
TOLERANCE_TICKS = 2

PROCESS_KW = dict(driver="process", timeout=30.0, backoff_base=0.01)


def first_flags(ticks):
    """stream_id -> first RegimeShift across a played scenario."""
    firsts = {}
    for t in ticks:
        for f in t.flags:
            firsts.setdefault(f.stream_id, f)
    return firsts


def assert_localizes(sc, firsts):
    affected = set(sc.affected)
    missed = affected - set(firsts)
    assert not missed, f"{sc.name}: affected streams never flagged: {missed}"
    false = set(firsts) - affected
    assert not false, f"{sc.name}: unaffected streams flagged: {false}"
    for sid in sorted(affected):
        err = abs(firsts[sid].onset - sc.onset_tick)
        assert err <= TOLERANCE_TICKS, (
            f"{sc.name}/{sid}: first flag at {firsts[sid].onset}, injected "
            f"onset {sc.onset_tick} (err {err} > {TOLERANCE_TICKS})")


# --------------------------------------------------------------- monitor
class TestMonitorUnit:
    def test_rejects_unknown_method(self):
        with pytest.raises(ValueError, match="method"):
            AnomalyMonitor(method="cuda")

    def test_rejects_ring_below_probing_window(self):
        with pytest.raises(ValueError, match="ring"):
            AnomalyMonitor(ring=4, omega=3)

    def test_quiet_stream_never_flags(self):
        mon = AnomalyMonitor(min_points=8)
        rng = np.random.default_rng(0)
        y = 1.2 + 0.02 * rng.standard_normal(64)
        for k in range(8, 65, 4):
            assert mon.observe("w0", y[:k], first=0) == ()
        assert mon.raised == 0

    def test_step_flag_carries_levels_and_confidence(self):
        mon = AnomalyMonitor(min_points=8)
        series = np.concatenate([np.full(8, 1.2), np.full(8, 4.0)])
        flags = []
        for k in range(8, 17):
            flags += mon.observe("w0", series[:k], first=0, tenant="batch")
        (f,) = flags
        assert f.onset == 8 and f.tenant == "batch"
        assert f.pre == pytest.approx(1.2, rel=1e-6)
        assert f.post == pytest.approx(4.0, rel=1e-6)
        assert 0.0 < f.confidence <= 1.0
        assert mon.raised == 1

    def test_onset_flagged_once_then_suppressed(self):
        """Re-detections of the same onset are deduped; the stream keeps
        being observed without re-raising."""
        mon = AnomalyMonitor(min_points=8)
        series = np.concatenate([np.full(8, 1.2), np.full(12, 4.0)])
        total = []
        for k in range(8, 21):
            total += mon.observe("w0", series[:k], first=0)
        assert len(total) == 1 and mon.raised == 1

    def test_watermark_consumes_only_new_windows(self):
        """Re-observing the same retained span adds nothing and cannot
        confirm a candidate without fresh evidence."""
        mon = AnomalyMonitor(min_points=8)
        series = np.concatenate([np.full(8, 1.2), np.full(4, 4.0)])
        mon.observe("w0", series, first=0)
        for _ in range(5):  # same span again: no new data, no scan
            assert mon.observe("w0", series, first=0) == ()
        assert mon.raised == 0

    def test_ring_eviction_preserves_absolute_onset(self):
        """Once the stream's retained span slides past the monitor ring,
        onsets still report absolute window indices."""
        mon = AnomalyMonitor(min_points=8, ring=16)
        pre, post = np.full(24, 1.2), np.full(10, 4.0)
        series = np.concatenate([pre, post])
        flags = []
        for k in range(8, series.size + 1):
            flags += mon.observe("w0", series[:k], first=0)
        (f,) = flags
        assert f.onset == 24

    def test_rewind_resets_detection(self):
        """A watermark rewind (stream reset / checkpoint restore to an
        earlier span) restarts the ring instead of mixing regimes."""
        mon = AnomalyMonitor(min_points=8)
        series = np.concatenate([np.full(8, 1.2), np.full(6, 4.0)])
        for k in range(8, 15):
            mon.observe("w0", series[:k], first=0)
        assert mon.raised == 1
        quiet = np.full(10, 1.2)
        assert mon.observe("w0", quiet, first=0) == ()  # rewound span
        assert mon.raised == 1

    def test_forget_drops_stream_state_keeps_raised(self):
        mon = AnomalyMonitor(min_points=8)
        series = np.concatenate([np.full(8, 1.2), np.full(6, 4.0)])
        for k in range(8, 15):
            mon.observe("w0", series[:k], first=0)
        assert mon.raised == 1
        mon.forget("w0")
        assert mon.raised == 1
        # The stream can re-register and detect fresh.
        flags = []
        for k in range(8, 15):
            flags += mon.observe("w0", series[:k], first=0)
        assert len(flags) == 1 and mon.raised == 2

    def test_state_dict_roundtrip_never_reflags(self):
        """The crash-recovery invariant: a restored monitor continues
        detection but never re-raises an onset the snapshot flagged."""
        mon = AnomalyMonitor(min_points=8)
        series = np.concatenate([np.full(8, 1.2), np.full(8, 4.0)])
        for k in range(8, 17):
            mon.observe("w0", series[:k], first=0)
        assert mon.raised == 1
        fresh = AnomalyMonitor(min_points=8)
        fresh.load_state_dict(mon.state_dict())
        assert fresh.raised == 1
        for _ in range(3):  # journal replay re-presents the retained span
            assert fresh.observe("w0", series, first=0) == ()
        assert fresh.raised == 1


# --------------------------------------------- scenario bank differential
class TestDetectionDifferential:
    @pytest.mark.parametrize("name", sorted(ANOMALY_SCENARIOS))
    def test_numpy_method_localizes(self, name):
        sc = build(name, seed=SEED)
        mux = VetMux(VetEngine("numpy", buckets=64),
                     monitor=AnomalyMonitor("numpy"))
        ticks = play(sc, mux)
        assert_localizes(sc, first_flags(ticks))
        assert mux.stats.anomalies >= len(sc.affected)

    @pytest.mark.parametrize("name", sorted(ANOMALY_SCENARIOS))
    def test_jax_method_localizes(self, name):
        sc = build(name, seed=SEED)
        mux = VetMux(VetEngine("numpy", buckets=64),
                     monitor=AnomalyMonitor("jax"))
        assert_localizes(sc, first_flags(play(sc, mux)))

    @pytest.mark.parametrize("name", sorted(ANOMALY_SCENARIOS))
    def test_pallas_method_localizes(self, name):
        sc = build(name, seed=SEED)
        mux = VetMux(VetEngine("numpy", buckets=64),
                     monitor=AnomalyMonitor("pallas"))
        assert_localizes(sc, first_flags(play(sc, mux)))

    def test_hetero_static_tiers_are_negative_controls(self):
        """The vet measure is invariant to whole-runtime tier scaling, so
        no static-tier stream may flag — only the migrated group."""
        sc = build("hetero_tiers", seed=SEED)
        mux = VetMux(VetEngine("numpy", buckets=64))
        firsts = first_flags(play(sc, mux))
        static = {s.stream_id for s in sc.specs
                  if s.stream_id not in set(sc.affected)}
        assert not (set(firsts) & static)
        assert {f.tenant for f in firsts.values()} == {"migrated"}

    def test_default_monitor_matches_engine_backend(self):
        for backend, method in [("numpy", "numpy"), ("jax", "jax"),
                                ("pallas", "pallas")]:
            mux = VetMux(VetEngine(backend, buckets=64))
            assert mux.monitor is not None and mux.monitor.method == method

    def test_monitor_false_disables(self):
        sc = build("contention_onset", seed=SEED)
        mux = VetMux(VetEngine("numpy", buckets=64), monitor=False)
        ticks = play(sc, mux)
        assert all(t.flags == () for t in ticks)
        assert mux.stats.anomalies == 0


# --------------------------------------------------- sharded + transport
class TestFlagsThroughShardedFleet:
    def test_sharded_flags_match_single_mux(self):
        """K shard monitors see per-shard stream subsets of the same data,
        so the merged ShardTick.flags equal the single-mux flags per
        stream, and stats.anomalies sums across shards."""
        sc = build("degraded_node", seed=SEED)
        single = VetMux(VetEngine("numpy", buckets=64))
        ref = first_flags(play(sc, single))

        sc2 = build("degraded_node", seed=SEED)
        smux = ShardedVetMux(2, backend="numpy")
        got = first_flags(play(sc2, smux))
        assert set(got) == set(ref)
        for sid in ref:
            assert got[sid].onset == ref[sid].onset
            assert got[sid].confidence == pytest.approx(
                ref[sid].confidence, rel=1e-6)
        assert smux.stats.anomalies == single.stats.anomalies

    def test_sharded_localizes_the_bank(self):
        sc = build("contention_onset", seed=SEED)
        smux = ShardedVetMux(3, backend="numpy")
        assert_localizes(sc, first_flags(play(sc, smux)))


class TestFlagsThroughTransport:
    def test_inprocess_driver_surfaces_flags(self):
        sc = build("contention_onset", seed=SEED)
        with TransportVetMux(2, backend="numpy",
                             driver="inprocess") as fleet:
            ticks = play(sc, fleet)
            assert_localizes(sc, first_flags(ticks))
            assert fleet.stats.anomalies >= len(sc.affected)

    def test_process_driver_ships_flags_over_the_pipe(self):
        """Real worker processes: RegimeShift tuples pickle through
        TickReply and the driver rebuilds them into ShardTick.flags."""
        sc = build("degraded_node", seed=SEED)
        with TransportVetMux(2, backend="numpy", **PROCESS_KW) as fleet:
            ticks = play(sc, fleet)
            assert_localizes(sc, first_flags(ticks))
            assert fleet.stats.anomalies >= len(sc.affected)


# ------------------------------------------------------------ checkpoint
class TestMonitorRidesMuxCheckpoint:
    def test_mux_state_roundtrip_preserves_monitor(self):
        """Snapshot mid-scenario, restore into a fresh mux, finish the
        scenario on both: identical flags and stats (incl. anomalies)."""
        sc = build("contention_onset", seed=SEED)
        half = len(sc.events) // 2

        a = VetMux(VetEngine("numpy", buckets=64))
        for s in sc.specs:
            s.register(a)
        flags_a = []
        for ev in sc.events[:half]:
            for sid, chunk in ev.chunks.items():
                a.feed(sid, chunk)
            flags_a += a.tick().flags

        b = VetMux(VetEngine("numpy", buckets=64))
        for s in sc.specs:
            s.register(b)
        b.load_state_dict(a.state_dict())
        flags_b = list(flags_a)

        for ev in sc.events[half:]:
            for sid, chunk in ev.chunks.items():
                a.feed(sid, chunk)
                b.feed(sid, chunk)
            flags_a += a.tick().flags
            flags_b += b.tick().flags
        assert flags_a == flags_b
        assert a.stats == b.stats
        assert a.stats.anomalies == b.stats.anomalies > 0

    def test_legacy_state_without_monitor_key_loads(self):
        """Checkpoints taken before the monitor existed restore cleanly."""
        mux = VetMux(VetEngine("numpy", buckets=64))
        mux.register("w0", window=8, stride=8, capacity=64)
        state = mux.state_dict()
        state.pop("monitor", None)
        fresh = VetMux(VetEngine("numpy", buckets=64))
        fresh.register("w0", window=8, stride=8, capacity=64)
        fresh.load_state_dict(state)  # must not raise
        assert fresh.stats.anomalies == 0
