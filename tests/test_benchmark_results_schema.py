"""Schema guard for ``benchmarks/results/*.json``.

The result files are committed artifacts that downstream tooling (roofline
injection, README tables, regression triage) reads by key.  A stale file from
an older benchmark revision — or a hand-edited one — used to fail silently at
consumption time; this suite fails it fast in tier-1 instead: every results
file present must match the schema of the benchmark that claims to have
written it, and files no benchmark owns are flagged.
"""

import json
import math
import os

import pytest

RESULTS_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                           "benchmarks", "results")

# Required top-level keys per results file (subset check: benchmarks may add
# keys freely, but dropping one of these means the file predates the current
# benchmark code and must be regenerated).
SCHEMAS = {
    "fig6_ks": {"d", "p", "mean_a", "mean_b"},
    "fig8_distribution": {"base85_cv", "bucket_sums", "top1pct_share",
                          "windowed_vet_max", "windowed_vet_p50"},
    "fig13_io": {"ei_fast", "ei_slow", "vet_fast", "vet_slow"},
    "fig14_correlation": {"pearson", "times", "vets"},
    "table2_slots": {"ei_drift", "pr_growth", "table"},
    "vet_engine": {"workers", "window", "numpy", "jax", "pallas",
                   "jax_speedup_vs_numpy", "windowed", "streaming"},
    "fleet": {"workers", "window", "stride", "chunk", "numpy", "jax",
              "pallas", "dispatch_reduction", "scaling_1024",
              "mixed_windows"},
    "fleet_shard": {"backend", "n_lengths", "shards_list", "w256", "w1024"},
    "fleet_transport": {"workers", "shards", "steps", "backend",
                        "inprocess_sharded_tick_us", "inprocess_driver",
                        "process_driver", "kill_resume", "oracle"},
    "fleet_anomaly": {"seed", "backend", "method", "tolerance_ticks",
                      "scenarios", "overhead_256w"},
    "kernels_bench": {"changepoint", "flash", "ssd", "windowvet",
                      "vet_engine", "vet_engine_windowed",
                      "vet_engine_streaming"},
    "windowvet": {"sliding", "w256", "w1024"},
    "fleet_obs": {"overhead", "ledger", "trace"},
    "fleet_obs_trace": {"traceEvents"},
    "autotune_online": {"seed", "noise", "noisy_ticks", "recovery",
                        "frontier", "overhead"},
    "fig1_gap": None,  # free-form payloads: presence + valid JSON only
    "fig3_spill": None,
    "fig9_tail": None,
    "roofline": None,
    "table3_tuned": None,
}

# Per-backend required keys inside vet_engine's sections.
BACKENDS = ("numpy", "jax", "pallas")
WINDOWED_KEYS = {"n_records", "window", "stride", "num_windows",
                 "cached_tick_us", "batched_speedup_vs_scalar_loop"}
STREAMING_KEYS = {"n_records", "window", "stride", "chunk", "n_ticks",
                  "num_windows", "stream_speedup_vs_regather"}
FLEET_BACKEND_KEYS = {"workers", "loop_tick_us", "mux_tick_us",
                      "tick_speedup", "loop_dispatches_per_tick",
                      "mux_dispatches_per_tick", "dispatch_reduction"}
FLEET_SHARD_SECTION_KEYS = {"workers", "window_lengths", "n_ticks",
                            "single_mux_dispatches_per_tick",
                            "single_mux_tick_us", "shards"}
FLEET_SHARD_ENTRY_KEYS = {"shards", "total_dispatches_per_tick",
                          "per_shard_max_dispatches_per_tick",
                          "per_shard_max_rows_per_tick", "tick_us",
                          "vet_job"}
WINDOWVET_FLEET_KEYS = {"workers", "window_lengths", "n_ticks", "fused",
                        "bucketed", "dispatch_reduction", "bytes_ratio"}
WINDOWVET_PATH_KEYS = {"max_dispatches_per_tick", "peak_tick_bytes", "rows",
                       "wall_s"}
WINDOWVET_SLIDING_KEYS = {"n_records", "window", "stride", "num_windows",
                          "fused_us", "gather_us", "staged_bytes",
                          "materialized_bytes", "bytes_ratio"}


def result_files():
    if not os.path.isdir(RESULTS_DIR):
        return []
    return sorted(f for f in os.listdir(RESULTS_DIR) if f.endswith(".json"))


def load(name):
    with open(os.path.join(RESULTS_DIR, f"{name}.json")) as f:
        return json.load(f)


def test_results_dir_is_not_empty():
    assert result_files(), "no benchmark results committed"


@pytest.mark.parametrize("fname", result_files())
def test_every_results_file_is_owned_and_parseable(fname):
    stem = fname[:-len(".json")]
    assert stem in SCHEMAS, (
        f"benchmarks/results/{fname} has no schema — if a benchmark writes "
        f"it, register its required keys in {__name__}.SCHEMAS")
    payload = load(stem)
    assert isinstance(payload, dict) and payload, f"{fname} is empty"


@pytest.mark.parametrize("stem", sorted(k for k, v in SCHEMAS.items()
                                        if v is not None))
def test_required_keys_present(stem):
    path = os.path.join(RESULTS_DIR, f"{stem}.json")
    if not os.path.exists(path):
        pytest.skip(f"{stem}.json not generated on this machine")
    missing = SCHEMAS[stem] - set(load(stem))
    assert not missing, (
        f"{stem}.json is stale: missing {sorted(missing)} — rerun "
        f"`python -m benchmarks.run --only {stem}`")


def vet_engine_payload():
    path = os.path.join(RESULTS_DIR, "vet_engine.json")
    if not os.path.exists(path):
        pytest.skip("vet_engine.json not generated on this machine")
    return load("vet_engine")


def test_vet_engine_backend_sections_have_timings():
    payload = vet_engine_payload()
    for section in (payload, payload["windowed"]):
        for b in BACKENDS:
            assert b in section, f"backend {b} missing"
            us = section[b]["us_per_call"]
            assert isinstance(us, (int, float)) and math.isfinite(us) and us > 0

    streaming = payload["streaming"]
    for b in BACKENDS:
        st = streaming[b]
        for key in ("stream_tick_us", "regather_tick_us", "tick_speedup"):
            assert math.isfinite(st[key]) and st[key] > 0


def test_vet_engine_windowed_and_streaming_sections_complete():
    payload = vet_engine_payload()
    assert WINDOWED_KEYS <= set(payload["windowed"]), (
        "windowed section stale: rerun `python -m benchmarks.run "
        "--only vet_engine`")
    assert STREAMING_KEYS <= set(payload["streaming"]), (
        "streaming section stale: rerun `python -m benchmarks.run "
        "--only vet_engine`")


def fleet_payload():
    path = os.path.join(RESULTS_DIR, "fleet.json")
    if not os.path.exists(path):
        pytest.skip("fleet.json not generated on this machine")
    return load("fleet")


def test_fleet_backend_sections_complete_and_finite():
    payload = fleet_payload()
    for section in [payload[b] for b in BACKENDS] + [payload["scaling_1024"]]:
        missing = FLEET_BACKEND_KEYS - set(section)
        assert not missing, (
            f"fleet.json section stale: missing {sorted(missing)} — rerun "
            f"`python -m benchmarks.run --only fleet`")
        for key in FLEET_BACKEND_KEYS:
            assert math.isfinite(section[key]) and section[key] > 0


def test_fleet_dispatch_reduction_floor():
    """The tentpole acceptance floor: a mux tick at 256+ workers must issue
    at least 10x fewer engine dispatches than the per-stream tick loop.
    Dispatch counts are exact (``VetEngine.dispatches``), not timings, so
    this floor cannot flake on a loaded machine — a homogeneous 256-worker
    fleet coalesces to one dispatch per tick (256x); anything under 10x
    means the mux silently degenerated into per-stream dispatches."""
    payload = fleet_payload()
    assert payload["dispatch_reduction"] >= 10.0
    for backend in BACKENDS:
        assert payload[backend]["dispatch_reduction"] >= 10.0, backend
    assert payload["scaling_1024"]["dispatch_reduction"] >= 10.0
    # Heterogeneous fleets dispatch once per distinct window length, never
    # once per stream.
    mixed = payload["mixed_windows"]
    assert mixed["max_dispatches_per_tick"] <= mixed["window_lengths"]


def fleet_shard_payload():
    path = os.path.join(RESULTS_DIR, "fleet_shard.json")
    if not os.path.exists(path):
        pytest.skip("fleet_shard.json not generated on this machine")
    return load("fleet_shard")


def test_fleet_shard_sections_complete_and_finite():
    payload = fleet_shard_payload()
    for name in ("w256", "w1024"):
        section = payload[name]
        missing = FLEET_SHARD_SECTION_KEYS - set(section)
        assert not missing, (
            f"fleet_shard.json {name} stale: missing {sorted(missing)} — "
            f"rerun `python -m benchmarks.run --only fleet_shard`")
        for k, entry in section["shards"].items():
            missing = FLEET_SHARD_ENTRY_KEYS - set(entry)
            assert not missing, f"{name} shards[{k}]: {sorted(missing)}"
            assert math.isfinite(entry["tick_us"]) and entry["tick_us"] > 0
            assert entry["vet_job"] >= 1.0


def test_fleet_shard_total_dispatches_bounded_by_single_plus_k():
    """The sharding acceptance guard: placement must not shatter shape
    buckets — across K shards the fleet-total dispatches per tick stay
    within the single-mux bucket count + K.  Dispatch counts are exact
    (``VetEngine.dispatches``), so this cannot flake on a loaded machine."""
    payload = fleet_shard_payload()
    for name in ("w256", "w1024"):
        section = payload[name]
        single = section["single_mux_dispatches_per_tick"]
        for k, entry in section["shards"].items():
            assert entry["total_dispatches_per_tick"] <= single + int(k), \
                f"{name} shards={k}: bucket shattering"


def test_fleet_shard_per_shard_load_strictly_falls_at_1024_workers():
    """The point of sharding: the most estimation work any one shard
    (process) does per tick — dispatches and rows — strictly decreases
    from 1 to 4 shards at 1024 workers."""
    shards = fleet_shard_payload()["w1024"]["shards"]
    for key in ("per_shard_max_dispatches_per_tick",
                "per_shard_max_rows_per_tick"):
        assert shards["1"][key] > shards["2"][key] > shards["4"][key], key


def windowvet_payload():
    path = os.path.join(RESULTS_DIR, "windowvet.json")
    if not os.path.exists(path):
        pytest.skip("windowvet.json not generated on this machine")
    return load("windowvet")


def test_windowvet_sections_complete_and_finite():
    payload = windowvet_payload()
    missing = WINDOWVET_SLIDING_KEYS - set(payload["sliding"])
    assert not missing, (
        f"windowvet.json sliding stale: missing {sorted(missing)} — rerun "
        f"`python -m benchmarks.run --only windowvet`")
    for name in ("w256", "w1024"):
        section = payload[name]
        missing = WINDOWVET_FLEET_KEYS - set(section)
        assert not missing, f"windowvet.json {name}: {sorted(missing)}"
        for path_name in ("fused", "bucketed"):
            entry = section[path_name]
            missing = WINDOWVET_PATH_KEYS - set(entry)
            assert not missing, f"{name}/{path_name}: {sorted(missing)}"
            assert math.isfinite(entry["wall_s"]) and entry["wall_s"] > 0
        assert section["fused"]["rows"] == section["bucketed"]["rows"]


def test_windowvet_fused_tick_is_one_dispatch():
    """The tentpole acceptance floor: a fused mux tick over a ragged
    mixed-window fleet is exactly ONE kernel launch — not one per distinct
    window length.  Dispatch counts are exact (``VetEngine.dispatches``),
    so this cannot flake on a loaded machine."""
    payload = windowvet_payload()
    for name in ("w256", "w1024"):
        section = payload[name]
        assert section["fused"]["max_dispatches_per_tick"] == 1, name
        assert (section["bucketed"]["max_dispatches_per_tick"]
                == section["window_lengths"]), name


def test_windowvet_fused_memory_strictly_below_materialized():
    """The O(ring) claim, as a committed-artifact floor: the fused launch's
    staged bytes (padded arena + per-row metadata) must be strictly below
    the gather path's materialized O(windows x length) matrices — per tick
    at fleet scale and on the dense sliding micro.  Byte counts are exact
    ledgers, not timings."""
    payload = windowvet_payload()
    for name in ("w256", "w1024"):
        section = payload[name]
        assert (section["fused"]["peak_tick_bytes"]
                < section["bucketed"]["peak_tick_bytes"]), name
        assert section["bytes_ratio"] > 1.0, name
    sliding = payload["sliding"]
    assert sliding["staged_bytes"] < sliding["materialized_bytes"]


def test_vet_engine_streaming_tick_is_incremental():
    """Sanity floor on the committed artifact: the incremental tick does
    strictly less work than a full re-gather (it vets ~1/30th of the
    windows at the committed shape), so even a heavily loaded benchmark
    machine must clear 2x.  The acceptance-scale number (>= 5x; 12-20x on
    an idle container) lives in the artifact itself — this guard only
    catches a streaming path that silently degenerated into a re-gather,
    without turning timing noise into tier-1 flakes."""
    payload = vet_engine_payload()
    assert payload["streaming"]["stream_speedup_vs_regather"] >= 2.0


def fleet_transport_payload():
    path = os.path.join(RESULTS_DIR, "fleet_transport.json")
    if not os.path.exists(path):
        pytest.skip("fleet_transport.json not generated on this machine")
    return load("fleet_transport")


TRANSPORT_DRIVER_KEYS = {"tick_us", "vet_job_abs_err", "dispatches", "rows",
                         "retries", "respawns"}


def test_fleet_transport_sections_complete_and_exact():
    """Both transport drivers must reproduce the in-process oracle exactly
    on the committed artifact: vet_job at 1e-9 and identical lifetime
    dispatch/row counters (every window vetted exactly once), with zero
    transport work on a healthy run.  Timings are environment noise and
    are deliberately not pinned."""
    payload = fleet_transport_payload()
    oracle = payload["oracle"]
    for name in ("inprocess_driver", "process_driver"):
        section = payload[name]
        missing = TRANSPORT_DRIVER_KEYS - set(section)
        assert not missing, (
            f"fleet_transport.json {name} stale: missing {sorted(missing)} "
            f"— rerun `python -m benchmarks.run --only fleet_transport`")
        assert section["vet_job_abs_err"] <= 1e-9, name
        assert section["dispatches"] == oracle["dispatches"], name
        assert section["rows"] == oracle["rows"], name
        assert section["retries"] == 0 and section["respawns"] == 0, name


def test_fleet_transport_kill_resume_recovers_exactly_once():
    """The acceptance artifact: a worker killed mid-tick is respawned
    exactly once, the retried tick lands, and the merged vet_job matches
    the oracle at 1e-9 with no dispatch/row drift — a re-vetted or skipped
    window would show up as a counter mismatch."""
    payload = fleet_transport_payload()
    kr, oracle = payload["kill_resume"], payload["oracle"]
    assert kr["vet_job_abs_err"] <= 1e-9
    assert kr["respawns"] == 1 and kr["retries"] >= 1
    assert kr["dispatches"] == oracle["dispatches"]
    assert kr["rows"] == oracle["rows"]
    assert kr["shard0_checkpoints"] >= 1


def fleet_anomaly_payload():
    path = os.path.join(RESULTS_DIR, "fleet_anomaly.json")
    if not os.path.exists(path):
        pytest.skip("fleet_anomaly.json not generated on this machine")
    return load("fleet_anomaly")


ANOMALY_SCENARIO_KEYS = {"onset_tick", "n_affected", "detected",
                         "false_flags", "mean_onset_err_ticks",
                         "max_onset_err_ticks", "mean_flag_latency_ticks",
                         "max_flag_latency_ticks"}
ANOMALY_OVERHEAD_KEYS = {"workers", "ticks", "monitor_on_tick_us",
                         "monitor_off_tick_us", "overhead_us",
                         "overhead_pct"}


def test_fleet_anomaly_detection_floor():
    """The acceptance floor on the committed artifact: every affected
    stream in every bank scenario is detected, each first flag's onset is
    within the bank's +/-2-tick tolerance of the injected onset, and no
    unaffected stream ever flags.  These are exact detector outcomes at
    the bank's pinned seed, not timings, so the floor cannot flake on a
    loaded machine."""
    payload = fleet_anomaly_payload()
    tol = payload["tolerance_ticks"]
    assert tol <= 2
    scenarios = payload["scenarios"]
    assert set(scenarios) == {"contention_onset", "degraded_node",
                              "fail_restart", "diurnal", "hetero_tiers"}
    for name, q in scenarios.items():
        missing = ANOMALY_SCENARIO_KEYS - set(q)
        assert not missing, (
            f"fleet_anomaly.json {name} stale: missing {sorted(missing)} — "
            f"rerun `python -m benchmarks.run --only fleet_anomaly`")
        assert q["n_affected"] >= 1, name
        assert q["detected"] == q["n_affected"], f"{name}: missed streams"
        assert q["false_flags"] == 0, f"{name}: false flags"
        assert q["max_onset_err_ticks"] <= tol, name
        assert q["mean_onset_err_ticks"] <= q["max_onset_err_ticks"], name
        # Confirmation takes a couple of scans by design; latency is still
        # bounded (flags arrive while the regime is ongoing, not post-hoc).
        assert 0 <= q["max_flag_latency_ticks"] <= 8, name


def fleet_obs_payload():
    path = os.path.join(RESULTS_DIR, "fleet_obs.json")
    if not os.path.exists(path):
        pytest.skip("fleet_obs.json not generated on this machine")
    return load("fleet_obs")


OBS_OVERHEAD_KEYS = {"backend", "workers", "ticks", "null_span_ns",
                     "tick_off_us", "tick_on_us", "spans_per_tick",
                     "disabled_overhead_frac", "traced_overhead_frac"}
OBS_TRACE_KEYS = {"events", "pids", "validate_problems", "path"}


def test_fleet_obs_disabled_overhead_gate():
    """The observability acceptance gate on the committed artifact: with no
    tracer attached, the instrumentation seam's bounded cost (null-span
    calls per tick x measured null-span ns) stays under 5% of the untraced
    256-worker mux tick.  The bound is computed from a microbenchmarked
    constant, not a tick-vs-tick wall-clock diff, so it cannot flake on a
    loaded generation machine."""
    ov = fleet_obs_payload()["overhead"]
    missing = OBS_OVERHEAD_KEYS - set(ov)
    assert not missing, (
        f"fleet_obs.json overhead stale: missing {sorted(missing)} — rerun "
        f"`python -m benchmarks.run --only fleet_obs`")
    assert ov["workers"] == 256
    assert math.isfinite(ov["null_span_ns"]) and ov["null_span_ns"] > 0
    assert ov["disabled_overhead_frac"] < 0.05
    assert ov["spans_per_tick"] > 0


def test_fleet_obs_ledger_floor_sound_on_every_backend():
    """The ledger's core contract: the roofline-style floor is *sound* —
    measured time is never below it — for every dispatch stage on all three
    backends.  A ratio under 1.0 means the floor model overestimates what
    the hardware can do and every headroom number built on it is wrong."""
    ledgers = fleet_obs_payload()["ledger"]
    assert set(ledgers) == {"numpy", "jax", "pallas"}
    for backend, rep in ledgers.items():
        assert rep["ratio"] is not None and rep["ratio"] >= 1.0, backend
        assert rep["floor_s"] > 0 and rep["measured_s"] >= rep["floor_s"]
        floored = [s for s in rep["stages"] if s["ratio"] is not None]
        assert floored, f"{backend}: no dispatch stage in the ledger"
        for s in floored:
            assert s["ratio"] >= 1.0, f"{backend}/{s['stage']}"
            assert s["bytes"] > 0 and s["calls"] > 0, f"{backend}/{s['stage']}"


def test_fleet_obs_cross_process_trace_validates():
    """The tentpole acceptance artifact: the committed Chrome trace from a
    process-driver run must validate (well-formed nesting per (pid, tid)
    lane) and span the driver plus both shard worker processes."""
    section = fleet_obs_payload()["trace"]
    missing = OBS_TRACE_KEYS - set(section)
    assert not missing, (
        f"fleet_obs.json trace stale: missing {sorted(missing)} — rerun "
        f"`python -m benchmarks.run --only fleet_obs`")
    assert section["validate_problems"] == []
    assert len(section["pids"]) >= 3  # driver + 2 shard workers

    path = os.path.join(RESULTS_DIR, "fleet_obs_trace.json")
    if not os.path.exists(path):
        pytest.skip("fleet_obs_trace.json not generated on this machine")
    from repro.obs import validate_chrome
    obj = load("fleet_obs_trace")
    assert validate_chrome(obj) == []
    events = obj["traceEvents"]
    assert len(events) == section["events"]
    assert {e["pid"] for e in events if e["ph"] == "X"} >= {0, 1, 2}
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"driver", "shard0", "shard1"} <= names


def test_fleet_anomaly_overhead_section_finite():
    """Wall-clock overhead is environment noise and deliberately not
    pinned; only completeness and basic sanity of the section are."""
    payload = fleet_anomaly_payload()
    ov = payload["overhead_256w"]
    missing = ANOMALY_OVERHEAD_KEYS - set(ov)
    assert not missing, (
        f"fleet_anomaly.json overhead stale: missing {sorted(missing)} — "
        f"rerun `python -m benchmarks.run --only fleet_anomaly`")
    assert ov["workers"] == 256
    for key in ("monitor_on_tick_us", "monitor_off_tick_us"):
        assert math.isfinite(ov[key]) and ov[key] > 0


def autotune_online_payload():
    path = os.path.join(RESULTS_DIR, "autotune_online.json")
    if not os.path.exists(path):
        pytest.skip("autotune_online.json not generated on this machine")
    return load("autotune_online")


AUTOTUNE_RECOVERY_KEYS = {"best", "grid_best", "designed_optimum",
                          "error_steps", "rounds", "rollbacks", "converged",
                          "ticks", "tick_us"}
AUTOTUNE_FRONTIER_KEYS = {"units", "beta", "runtime_s", "cost", "vet",
                          "elbow_index", "elbow_units", "trail"}


def test_autotune_online_recovery_pins():
    """The tentpole acceptance artifact: on every backend the online tuner
    recovers the grid oracle's optimum exactly with noise off (the
    objective is then a pure function of the assignment — any error means
    the walk broke, not that the machine was loaded) and within one knob
    step under seeded noise.  Tick timings are environment noise and stay
    unpinned."""
    payload = autotune_online_payload()
    for backend in BACKENDS:
        rec = payload["recovery"][backend]
        for mode in ("noiseless", "noisy"):
            missing = AUTOTUNE_RECOVERY_KEYS - set(rec[mode])
            assert not missing, (
                f"autotune_online.json {backend}/{mode} stale: missing "
                f"{sorted(missing)} — rerun `python -m benchmarks.run "
                f"--only autotune_online`")
            assert math.isfinite(rec[mode]["tick_us"])
            assert rec[mode]["tick_us"] > 0
        noiseless = rec["noiseless"]
        assert noiseless["error_steps"] == 0, backend
        assert noiseless["best"] == noiseless["grid_best"], backend
        # The oracle itself sits on the scenario's designed optimum.
        assert noiseless["grid_best"] == noiseless["designed_optimum"]
        assert noiseless["converged"], backend
        assert rec["noisy"]["error_steps"] <= 1, backend


def test_autotune_online_frontier_monotone_with_interior_elbow():
    """Frontier pins: runtimes strictly decrease along the unit sweep
    (diminishing returns, still returns), the elbow trail is strictly
    increasing from the reference, and the chosen elbow is interior —
    accepting everything would ignore cost, accepting nothing perf."""
    payload = autotune_online_payload()
    fr = payload["frontier"]
    missing = AUTOTUNE_FRONTIER_KEYS - set(fr)
    assert not missing, (
        f"autotune_online.json frontier stale: missing {sorted(missing)} — "
        f"rerun `python -m benchmarks.run --only autotune_online`")
    rt = fr["runtime_s"]
    assert all(b < a for a, b in zip(rt, rt[1:])), "runtimes not decreasing"
    trail = fr["trail"]
    assert trail[0] == 0
    assert all(b > a for a, b in zip(trail, trail[1:]))
    assert trail[-1] == fr["elbow_index"]
    assert 0 < fr["elbow_index"] < len(fr["units"]) - 1
    # vet agrees with the runtime ordering: more parallelism, less
    # reducible overhead, lower vet.
    vets = fr["vet"]
    assert all(b < a for a, b in zip(vets, vets[1:]))
    ov = payload["overhead"]
    for key in ("plain_tick_us", "tuned_tick_us"):
        assert math.isfinite(ov[key]) and ov[key] > 0
