"""Unit tests for the sharding rules: every assigned arch's parameter /
optimizer / cache specs must be valid NamedShardings on the production mesh
(no duplicate axes, even division at jit I/O) — the class of bugs that
actually bit during bring-up (DuplicateSpecError, 40-head unevenness)."""

import os
import subprocess
import sys

import pytest

CHECKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, jax.numpy as jnp, dataclasses, json
from repro.configs import get_config, ARCH_NAMES
from repro.distributed.sharding import (MeshAxes, cache_specs, opt_state_specs,
                                        param_specs)
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from jax.sharding import NamedSharding

problems = []
for multi in (False, True):
    mesh = make_production_mesh(multi_pod=multi)
    ax = MeshAxes(mesh)
    for name in ARCH_NAMES:
        cfg = dataclasses.replace(get_config(name), q_head_pad_multiple=16)
        p_shape = S.params_shape(cfg)
        for tag, specs, shapes in [
            ("param", param_specs(p_shape, ax, cfg), p_shape),
            ("opt", opt_state_specs(p_shape, ax, cfg), p_shape),
        ]:
            for (path, sp), leaf in zip(
                jax.tree_util.tree_flatten_with_path(specs)[0][:10000],
                jax.tree.leaves(shapes),
            ):
                try:
                    ns = NamedSharding(mesh, sp)  # raises on duplicate axes
                except Exception as e:
                    problems.append((name, tag, str(path), str(e)[:80]))
                    continue
                # even division at jit I/O
                entries = list(sp) + [None] * (len(leaf.shape) - len(sp))
                for dim, entry in zip(leaf.shape, entries):
                    if entry is None:
                        continue
                    n = 1
                    for a in (entry if isinstance(entry, tuple) else (entry,)):
                        n *= mesh.shape[a]
                    if dim % n:
                        problems.append((name, tag, str(path),
                                         f"uneven {dim}%{n}"))
        if cfg.supports_decode:
            c_shape = S.cache_shape(cfg, 128, 1024)
            cs = cache_specs(c_shape, ax, cfg)
            for (path, sp), leaf in zip(
                jax.tree_util.tree_flatten_with_path(cs)[0],
                jax.tree.leaves(c_shape),
            ):
                try:
                    NamedSharding(mesh, sp)
                except Exception as e:
                    problems.append((name, "cache", str(path), str(e)[:80]))
print(json.dumps(problems))
"""


def test_all_arch_specs_valid_on_both_meshes():
    env = {**os.environ, "PYTHONPATH": "src"}
    proc = subprocess.run([sys.executable, "-c", CHECKER],
                          capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    import json

    problems = json.loads(proc.stdout.strip().splitlines()[-1])
    assert problems == [], problems[:20]
