"""Observability suite: tracer, metrics, exports, ledger, and the
instrumented fleet seam.

Four layers of guarantees:

- **Tracer semantics** under an injectable counting clock: exact span
  trees (ts/dur/parent), per-lane nesting, drain/adopt reassembly.
- **Export schema**: every trace we produce passes ``validate_chrome``
  (required keys, types, well-formed per-lane nesting) and corrupt events
  are actually rejected — the validator is tested against both polarities.
- **Zero-cost discipline**: with ``tracer=None`` every seam call site
  returns the shared no-op, and a traced mux computes bit-identical
  results to an untraced one over the scenario bank.
- **The ledger**: floors are exact functions of calls/bytes, cold splits
  keep compile out of the warm rows, and measured >= floor holds live on
  all three backends.
"""

import numpy as np
import pytest

from repro.engine import VetEngine
from repro.fleet import ShardedVetMux, TransportVetMux, VetMux, build, play
from repro.obs import (
    DISPATCH_FLOOR_S,
    LEDGER_MEM_BW,
    MetricsRegistry,
    SpanRecord,
    Tracer,
    flamegraph,
    format_ledger,
    ledger_from,
    span,
    timed,
    to_chrome,
    validate_chrome,
    write_chrome,
)
from repro.obs.trace import _NULL
from repro.profiling import PhaseTimer, RecordProfiler


def fake_clock(step=1.0):
    """Counting monotonic clock: 0, step, 2*step, ..."""
    state = {"t": -step}

    def clock():
        state["t"] += step
        return state["t"]

    return clock


# --------------------------------------------------------------- tracer core


def test_span_tree_deterministic_under_fake_clock():
    tr = Tracer(clock=fake_clock())
    with tr.span("tick"):
        with tr.span("dispatch", rows=3):
            pass
        with tr.span("commit"):
            pass
    # Completion order: children first.  Every clock() call advances by 1.
    assert [(r.name, r.ts, r.dur, r.parent) for r in tr.records] == [
        ("dispatch", 1.0, 1.0, 0),
        ("commit", 3.0, 1.0, 0),
        ("tick", 0.0, 5.0, None),
    ]
    sids = [r.sid for r in tr.records]
    assert sids == [1, 2, 0]  # assigned at __enter__, unique
    assert all(r.pid == 0 and r.tid == 0 for r in tr.records)


def test_span_attrs_sorted_and_late_set():
    tr = Tracer(clock=fake_clock())
    with tr.span("s", zebra=1, alpha=2) as sp:
        sp.set(mid=3)
    (rec,) = tr.records
    assert rec.attrs == (("alpha", 2), ("mid", 3), ("zebra", 1))


def test_nesting_is_per_tid_lane():
    tr = Tracer(clock=fake_clock())
    outer0 = tr.span("outer0", tid=0).__enter__()
    inner1 = tr.span("inner1", tid=1).__enter__()
    inner1.__exit__(None, None, None)
    outer0.__exit__(None, None, None)
    by_name = {r.name: r for r in tr.records}
    # A span on lane 1 never parents to the open span on lane 0.
    assert by_name["inner1"].parent is None
    assert by_name["inner1"].tid == 1
    assert by_name["outer0"].parent is None


def test_exception_inside_span_still_records_and_propagates():
    tr = Tracer(clock=fake_clock())
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert [r.name for r in tr.records] == ["boom"]
    assert not any(tr._stacks.values())  # stack unwound


def test_drain_returns_and_clears():
    tr = Tracer(clock=fake_clock())
    with tr.span("a"):
        pass
    first = tr.drain()
    assert [r.name for r in first] == ["a"]
    assert tr.records == [] and tr.drain() == []
    with tr.span("b"):
        pass
    assert [r.name for r in tr.drain()] == ["b"]


def test_adopt_shifts_ts_remaps_sids_and_labels_process():
    worker = Tracer(clock=fake_clock())
    with worker.span("w.tick"):
        with worker.span("w.dispatch"):
            pass
    driver = Tracer(clock=fake_clock())
    with driver.span("roundtrip"):
        pass
    n = driver.adopt(worker.drain(), pid=3, at=100.0, name="shard2")
    assert n == 2
    adopted = [r for r in driver.records if r.pid == 3]
    by_name = {r.name: r for r in adopted}
    # Earliest adopted ts lands exactly at the anchor; relative offsets kept.
    assert min(r.ts for r in adopted) == 100.0
    assert by_name["w.dispatch"].ts - by_name["w.tick"].ts == 1.0
    # Parent links survive the sid remap, and remapped sids never collide
    # with the driver's own.
    assert by_name["w.dispatch"].parent == by_name["w.tick"].sid
    own = [r.sid for r in driver.records if r.pid == 0]
    assert set(own).isdisjoint({r.sid for r in adopted})
    assert driver.process_names[3] == "shard2"
    # Adopting nothing is a no-op that allocates no ids.
    assert driver.adopt([], pid=9, at=5.0, name="ghost") == 0
    assert 9 not in driver.process_names


# ------------------------------------------------------------ disabled path


def test_disabled_span_is_shared_noop():
    s1 = span(None, "a", tid=3, rows=7)
    s2 = span(None, "b")
    assert s1 is s2 is _NULL
    with s1 as s:
        assert s.set(x=1) is s
    assert s1.dur == 0.0 and s1.sid is None


def test_timed_always_measures():
    sw = timed(None, "x")
    with sw:
        sum(range(1000))
    assert sw.dur > 0.0
    tr = Tracer(clock=fake_clock())
    sw = timed(tr, "x", tid=2, op="tick")
    with sw:
        pass
    assert sw.dur == 1.0  # the tracer clock, not wall time
    (rec,) = tr.records
    assert rec.name == "x" and rec.tid == 2 and ("op", "tick") in rec.attrs


# ------------------------------------------------------------------- metrics


def test_metrics_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    assert reg.counter("c").value == 3
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)
    g = reg.gauge("g")
    g.set(5)
    g.inc()
    g.dec(3)
    assert g.value == 3
    h = reg.histogram("h", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["buckets"] == {"le_0.1": 1, "le_1": 1, "le_inf": 1}
    assert snap["count"] == 3 and snap["min"] == 0.05 and snap["max"] == 5.0
    assert h.mean == pytest.approx((0.05 + 0.5 + 5.0) / 3)
    with pytest.raises(TypeError):
        reg.gauge("c")  # kind mismatch is loud
    with pytest.raises(ValueError):
        reg.histogram("bad", bounds=(1.0, 0.1))
    assert set(reg.snapshot()) == {"c", "g", "h"}


def test_tracer_feeds_span_histograms():
    reg = MetricsRegistry()
    tr = Tracer(clock=fake_clock(), metrics=reg)
    for _ in range(3):
        with tr.span("tick"):
            pass
    h = reg.histogram("span.tick")
    assert h.count == 3 and h.sum == 3.0


# -------------------------------------------------------------------- export


def test_to_chrome_schema_and_normalization():
    tr = Tracer(clock=fake_clock())
    with tr.span("outer", rows=2):
        with tr.span("inner"):
            pass
    obj = to_chrome(tr.records, process_names=tr.process_names)
    assert validate_chrome(obj) == []
    xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    ms = [e for e in obj["traceEvents"] if e["ph"] == "M"]
    assert len(xs) == 2 and len(ms) == 1
    assert ms[0]["args"]["name"] == "driver"
    by_name = {e["name"]: e for e in xs}
    # ts normalized to the earliest span, scaled to us.
    assert by_name["outer"]["ts"] == 0.0
    assert by_name["inner"]["ts"] == 1.0 * 1e6
    assert by_name["inner"]["dur"] == 1.0 * 1e6
    assert by_name["outer"]["args"]["rows"] == 2
    assert by_name["inner"]["args"]["parent"] == by_name["outer"]["args"]["sid"]


def test_write_chrome_roundtrip(tmp_path):
    import json

    tr = Tracer(clock=fake_clock())
    with tr.span("t"):
        pass
    path = tmp_path / "trace.json"
    obj = write_chrome(path, tr)
    on_disk = json.loads(path.read_text())
    assert on_disk == obj
    assert validate_chrome(on_disk) == []


def test_validate_chrome_rejects_corruption():
    assert validate_chrome([]) != []
    assert validate_chrome({"events": []}) != []
    base = {"name": "a", "ph": "X", "ts": 0.0, "dur": 5.0,
            "pid": 0, "tid": 0, "args": {}}
    # Missing/mistyped required key.
    bad = dict(base)
    del bad["dur"]
    assert any("dur" in p for p in validate_chrome({"traceEvents": [bad]}))
    bad = dict(base, pid="zero")
    assert any("pid" in p for p in validate_chrome({"traceEvents": [bad]}))
    assert any("negative" in p for p in validate_chrome(
        {"traceEvents": [dict(base, ts=-1.0)]}))
    assert any("unsupported ph" in p for p in validate_chrome(
        {"traceEvents": [dict(base, ph="B")]}))
    # Partial overlap in one lane is the nesting violation.
    overlap = [dict(base, name="a", ts=0.0, dur=10.0),
               dict(base, name="b", ts=5.0, dur=10.0)]
    assert any("partially overlaps" in p
               for p in validate_chrome({"traceEvents": overlap}))
    # The same two spans on different lanes are fine.
    ok = [dict(base, name="a", ts=0.0, dur=10.0),
          dict(base, name="b", ts=5.0, dur=10.0, tid=1)]
    assert validate_chrome({"traceEvents": ok}) == []


def test_flamegraph_aggregates_by_path():
    tr = Tracer(clock=fake_clock())
    for _ in range(2):
        with tr.span("tick"):
            with tr.span("dispatch"):
                pass
    text = flamegraph(tr.records)
    lines = text.splitlines()
    assert lines[0].startswith("tick")
    assert lines[1].startswith("  dispatch")
    assert "x2" in lines[0] and "x2" in lines[1]
    assert flamegraph([]) == "(no spans)"


# -------------------------------------------------------------------- ledger


def _rec(name, dur, sid, attrs=(), parent=None):
    return SpanRecord(name, 0.0, dur, 0, 0, sid, parent, tuple(attrs))


def test_ledger_floor_math_and_cold_split():
    records = [
        _rec("engine.dispatch", 1e-3, 0,
             [("bytes", 1_000_000), ("cold", False)]),
        _rec("engine.dispatch", 1e-3, 1,
             [("bytes", 1_000_000), ("cold", False)]),
        _rec("engine.dispatch", 0.5, 2, [("bytes", 1_000_000), ("cold", True)]),
        _rec("mux.plan", 1e-4, 3),
    ]
    rep = ledger_from(records)
    by_stage = {s.stage: s for s in rep.stages}
    warm = by_stage["engine.dispatch"]
    assert warm.calls == 2 and warm.bytes == 2_000_000
    expected_floor = 2 * DISPATCH_FLOOR_S + 2_000_000 / LEDGER_MEM_BW
    assert warm.floor_s == pytest.approx(expected_floor)
    assert warm.ratio == pytest.approx(2e-3 / expected_floor)
    cold = by_stage["engine.dispatch [cold]"]
    assert cold.calls == 1 and cold.measured_s == 0.5
    plan = by_stage["mux.plan"]
    assert plan.floor_s is None and plan.ratio is None
    # Headline ratio covers exactly the floor-bearing stages.
    assert rep.measured_s == pytest.approx(2e-3 + 0.5)
    assert rep.ratio == pytest.approx(rep.measured_s / rep.floor_s)
    # Floor-bearing stages sort first; the table renders.
    assert rep.stages[0].floor_s is not None
    assert "x over floor" in format_ledger(rep)


def test_ledger_empty_records():
    rep = ledger_from([])
    assert rep.stages == () and rep.ratio is None
    assert "ledger" in format_ledger(rep)


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_ledger_floor_sound_live(backend):
    """measured >= floor on a real traced mux run, every backend."""
    tr = Tracer()
    mux = VetMux(VetEngine(backend, buckets=16), tracer=tr)
    play(build("mixed_windows", n_workers=12, n_ticks=3, seed=0), mux)
    rep = ledger_from(tr.records)
    assert rep.ratio is not None and rep.ratio >= 1.0
    for s in rep.stages:
        if s.ratio is not None:
            assert s.ratio >= 1.0, s.stage


# ------------------------------------------------- the instrumented fleet


def _feed_all(mux, n=8, chunk=24, seed=0):
    rng = np.random.default_rng(seed)
    for w in range(n):
        mux.register(f"w{w}", window=8, stride=4, capacity=64)
    for w in range(n):
        mux.feed(f"w{w}", rng.standard_normal(chunk) ** 2 + 1e-3)


def test_mux_tick_span_tree():
    tr = Tracer(clock=fake_clock())
    mux = VetMux(VetEngine("numpy", buckets=16), tracer=tr)
    _feed_all(mux)
    mux.tick()
    by_name = {}
    for r in tr.records:
        by_name.setdefault(r.name, []).append(r)
    sid_name = {r.sid: r.name for r in tr.records}
    assert {"mux.tick", "mux.plan", "mux.coalesce", "mux.dispatch",
            "mux.commit", "mux.collect", "mux.anomaly",
            "engine.dispatch", "stream.drain", "stream.commit",
            "stream.collect"} <= set(by_name)
    (tick,) = by_name["mux.tick"]
    assert tick.parent is None
    for name in ("mux.plan", "mux.coalesce", "mux.dispatch", "mux.commit",
                 "mux.collect", "mux.anomaly"):
        for r in by_name[name]:
            assert r.parent == tick.sid, name
    for r in by_name["engine.dispatch"]:
        assert sid_name[r.parent] == "mux.dispatch"
        attrs = dict(r.attrs)
        assert attrs["bytes"] > 0 and attrs["backend"] == "numpy"
    for r in by_name["stream.drain"]:
        assert sid_name[r.parent] == "mux.coalesce"
    # The whole tree exports and nests cleanly.
    assert validate_chrome(to_chrome(tr.records)) == []


def test_traced_mux_results_identical_to_untraced():
    plain = VetMux(VetEngine("numpy", buckets=16))
    traced = VetMux(VetEngine("numpy", buckets=16), tracer=Tracer())
    scenario = build("mixed_windows", n_workers=16, n_ticks=4, seed=1)
    ticks_p = play(scenario, plain)
    ticks_t = play(scenario, traced)
    for tp, tt in zip(ticks_p, ticks_t):
        assert tp.dispatches == tt.dispatches and tp.rows == tt.rows
        assert set(tp.results) == set(tt.results)
        for sid, rp in tp.results.items():
            rt = tt.results[sid]
            if rp is None:
                assert rt is None
            else:
                np.testing.assert_array_equal(rp.vet, rt.vet)
                np.testing.assert_array_equal(rp.ei, rt.ei)
    assert plain.stats.dispatches == traced.stats.dispatches


def test_sharded_mux_uses_shard_lanes():
    tr = Tracer(clock=fake_clock())
    fleet = ShardedVetMux(2, backend="numpy", tracer=tr)
    _feed_all(fleet, n=8)
    fleet.tick()
    tids = {r.tid for r in tr.records if r.name == "mux.tick"}
    assert tids == {0, 1}  # one lane per shard
    fleet_ticks = [r for r in tr.records if r.name == "fleet.tick"]
    assert len(fleet_ticks) == 1 and fleet_ticks[0].tid == 0
    assert {r.name for r in tr.records} >= {"fleet.plan", "fleet.merge"}
    assert validate_chrome(to_chrome(tr.records)) == []


def test_set_tracer_never_detaches_shared_engine():
    engine = VetEngine("numpy", buckets=16)
    tr = Tracer()
    VetMux(engine, tracer=tr)
    assert engine.tracer is tr
    # A second, untraced mux over the same engine must not detach it.
    VetMux(engine)
    assert engine.tracer is tr


def test_transport_inprocess_cross_process_trace():
    tr = Tracer()
    with TransportVetMux(2, backend="numpy", driver="inprocess",
                         tracer=tr) as fleet:
        _feed_all(fleet, n=6)
        fleet.tick()
    pids = {r.pid for r in tr.records}
    assert pids == {0, 1, 2}
    assert tr.process_names == {0: "driver", 1: "shard0", 2: "shard1"}
    # Driver-side transport spans ride the shard's tid lane on pid 0;
    # worker-side spans land under the shard's own pid.
    for k in (0, 1):
        worker = {r.name for r in tr.records if r.pid == k + 1}
        assert "mux.tick" in worker and "engine.dispatch" in worker
        sends = [r for r in tr.records
                 if r.pid == 0 and r.name == "transport.send" and r.tid == k]
        assert sends
    assert validate_chrome(to_chrome(tr.records,
                                     process_names=tr.process_names)) == []


def test_transport_worker_spans_adopted_inside_tick_window():
    """Adopted worker spans are anchored at the driver's send time: they
    start at-or-after the driver's fleet.tick span starts."""
    tr = Tracer()
    with TransportVetMux(1, backend="numpy", driver="inprocess",
                         tracer=tr) as fleet:
        _feed_all(fleet, n=4)
        fleet.tick()
    (tick,) = [r for r in tr.records
               if r.name == "fleet.tick" and r.pid == 0]
    worker_ts = [r.ts for r in tr.records if r.pid == 1]
    assert worker_ts and min(worker_ts) >= tick.ts


def test_transport_process_driver_trace():
    tr = Tracer()
    with TransportVetMux(2, backend="numpy", driver="process",
                         tracer=tr) as fleet:
        _feed_all(fleet, n=6)
        fleet.tick()
        rng = np.random.default_rng(9)
        for w in range(6):
            fleet.feed(f"w{w}", rng.standard_normal(24) ** 2 + 1e-3)
        fleet.tick()
    obj = to_chrome(tr.records, process_names=tr.process_names)
    assert validate_chrome(obj) == []
    assert {r.pid for r in tr.records} == {0, 1, 2}
    for pid in (1, 2):
        assert sum(1 for r in tr.records
                   if r.pid == pid and r.name == "mux.tick") == 2


def test_transport_respawn_keeps_tracing():
    """A revived worker is explicitly told to keep tracing (the trace op is
    not journaled), so post-crash ticks still ship spans."""
    tr = Tracer()
    with TransportVetMux(2, backend="numpy", driver="process",
                         backoff_base=0.01, tracer=tr) as fleet:
        _feed_all(fleet, n=6)
        fleet.tick()
        fleet.inject_fault(0, at_tick=2, mode="before")
        rng = np.random.default_rng(9)
        for w in range(6):
            fleet.feed(f"w{w}", rng.standard_normal(24) ** 2 + 1e-3)
        fleet.tick()
        assert fleet.stats.respawns == 1
    post = [r for r in tr.records if r.pid == 1 and r.name == "mux.tick"]
    assert len(post) >= 2  # the revived worker's retried tick traced too
    assert validate_chrome(to_chrome(tr.records)) == []


def test_transport_untraced_replies_ship_no_spans():
    with TransportVetMux(1, backend="numpy", driver="inprocess") as fleet:
        _feed_all(fleet, n=4)
        reply = fleet._handles[0].call("tick", None)
        assert reply.spans == ()


# ------------------------------------------------------- recorder compat


def test_record_profiler_unchanged_without_tracer():
    prof = RecordProfiler(unit=2)
    for _ in range(5):
        with prof.record():
            pass
    assert prof.num_records == 5
    assert prof.unit_times().shape == (2,)
    assert prof.record_times().shape == (5,)
    with pytest.raises(RuntimeError):
        with prof.record():
            raise RuntimeError("x")
    assert prof.num_records == 6  # records survive exceptions, as before
    prof.reset()
    assert prof.num_records == 0


def test_record_profiler_rides_the_tracer():
    tr = Tracer(clock=fake_clock())
    prof = RecordProfiler(unit=1, name="step", tracer=tr)
    for _ in range(3):
        with prof.record():
            pass
    assert [r.name for r in tr.records] == ["record.step"] * 3
    # The stored nanoseconds ARE the span durations — one clock source.
    assert prof._raw_ns == [int(r.dur * 1e9) for r in tr.records]
    np.testing.assert_allclose(prof.unit_times(), [1.0, 1.0, 1.0])


def test_phase_timer_rides_the_tracer():
    tr = Tracer(clock=fake_clock())
    pt = PhaseTimer(tracer=tr)
    with pt.phase("spill"):
        pass
    with pt.phase("merge"):
        pass
    assert [r.name for r in tr.records] == ["phase.spill", "phase.merge"]
    assert pt.totals() == {"spill": 1.0, "merge": 1.0}
    assert pt.times("spill").tolist() == [1.0]
