"""Tests for tail diagnostics (Hill/emplot), KS test, and the profiling layer."""

import numpy as np
import pytest

from repro.core import bucketize, hill_estimator, ks_2samp, pearson, tail_report
from repro.profiling import (
    PhaseTimer,
    RecordProfiler,
    run_contended_job,
    simulate_job,
    simulate_records,
)


class TestTail:
    def test_hill_recovers_pareto_alpha(self):
        rng = np.random.default_rng(0)
        for alpha in (1.3, 2.0):
            x = rng.pareto(alpha, 300_000) + 1.0
            est = float(hill_estimator(x, 30_000))
            assert abs(est - alpha) / alpha < 0.1, (alpha, est)

    def test_paper_alpha_band(self):
        """Paper §5.3: read-map record times have alpha ~ 1.3 (heavy)."""
        rng = np.random.default_rng(1)
        x = rng.pareto(1.3, 200_000) + 1.0
        rep = tail_report(x)
        assert rep.heavy
        assert 1.1 < rep.alpha < 1.5
        # emplot linear with slope ~ -alpha
        assert abs(-rep.emplot_slope - rep.alpha) < 0.3

    def test_light_tail_not_heavy(self):
        rng = np.random.default_rng(2)
        x = np.abs(rng.normal(0, 1, 100_000)) + 1.0
        rep = tail_report(x)
        assert rep.alpha > 2.0


class TestStats:
    def test_ks_same_population(self):
        rng = np.random.default_rng(3)
        a, b = rng.pareto(1.3, 800), rng.pareto(1.3, 800)
        assert ks_2samp(a, b).pvalue > 0.05  # no evidence against same pop.

    def test_ks_different_population(self):
        rng = np.random.default_rng(4)
        a, b = rng.normal(0, 1, 800), rng.normal(1.0, 1, 800)
        assert ks_2samp(a, b).pvalue < 1e-6

    def test_pearson(self):
        x = np.arange(100.0)
        assert pearson(x, 3 * x + 1) == pytest.approx(1.0, abs=1e-5)
        assert pearson(x, -x) == pytest.approx(-1.0, abs=1e-5)

    def test_bucketize_preserves_total(self):
        rng = np.random.default_rng(5)
        x = rng.pareto(1.3, 12_345)
        b = np.asarray(bucketize(x, 1000))
        assert b.shape == (1000,)
        np.testing.assert_allclose(b.sum(), x.sum(), rtol=1e-5)


class TestProfiler:
    def test_record_and_unit_grouping(self):
        prof = RecordProfiler(unit=5)
        for _ in range(23):
            with prof.record():
                pass
        assert prof.num_records == 23
        assert prof.unit_times().shape == (4,)  # 20 records -> 4 units of 5
        assert prof.total() >= 0

    def test_wrap(self):
        prof = RecordProfiler(unit=1)
        f = prof.wrap(lambda x: x + 1)
        assert f(1) == 2
        assert prof.num_records == 1

    def test_phase_timer(self):
        pt = PhaseTimer()
        with pt.phase("spill"):
            pass
        with pt.phase("read-map"):
            pass
        assert set(pt.names()) == {"spill", "read-map"}
        assert pt.times("spill").shape == (1,)


class TestSimulator:
    def test_decomposition_consistent(self):
        p = simulate_records(10_000, seed=0)
        np.testing.assert_allclose(p.times, p.ideal + p.overhead)
        assert p.true_vet >= 1.0

    def test_job_utilization_scales_overhead_only(self):
        lo = simulate_job(3, 5000, utilization_factor=1.0, seed=1)
        hi = simulate_job(3, 5000, utilization_factor=6.0, seed=1)
        assert np.mean([p.true_oc for p in hi]) > np.mean([p.true_oc for p in lo])
        np.testing.assert_allclose(
            np.mean([p.true_ei for p in hi]),
            np.mean([p.true_ei for p in lo]),
            rtol=0.05,
        )


class TestContention:
    def test_oversubscription_increases_pr(self):
        """2 workers on 1 core: wall-per-record must grow vs 1 worker."""
        t1 = run_contended_job(1, 120, unit=5)
        t2 = run_contended_job(2, 120, unit=5)
        pr1 = np.mean([t.sum() for t in t1])
        pr2 = np.mean([t.sum() for t in t2])
        assert pr2 > pr1 * 1.3
