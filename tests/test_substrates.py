"""Integration tests: data pipeline determinism, checkpoint/resume, optimizer,
gradient compression, elastic resharding, vet controller, end-to-end driver."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticTokenPipeline
from repro.launch.train import SimulatedFailure, train
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.optim.compression import (
    compress_with_feedback,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)
from repro.sched import VetController


class TestPipeline:
    def test_deterministic_per_step(self):
        p1 = SyntheticTokenPipeline(1000, 8, 32, seed=7)
        p2 = SyntheticTokenPipeline(1000, 8, 32, seed=7)
        b1, b2 = p1.batch_at(5), p2.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(p1.batch_at(6)["tokens"], b1["tokens"])

    def test_labels_are_shifted_tokens(self):
        p = SyntheticTokenPipeline(1000, 4, 16, seed=0)
        b = p.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_disjoint(self):
        a = SyntheticTokenPipeline(1000, 8, 16, seed=0, host_id=0, num_hosts=2)
        b = SyntheticTokenPipeline(1000, 8, 16, seed=0, host_id=1, num_hosts=2)
        assert a.batch == 4
        assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])


class TestCheckpoint:
    def _state(self, k=0):
        return {"w": jnp.arange(12.0).reshape(3, 4) + k, "b": jnp.ones((4,)) * k}

    def test_save_restore_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            save(d, 5, self._state(1))
            out, step = restore(d, self._state())
            assert step == 5
            np.testing.assert_array_equal(out["w"], self._state(1)["w"])

    def test_latest_and_keep_n(self):
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 3, 4):
                save(d, s, self._state(s), keep_n=2)
            assert latest_step(d) == 4
            kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
            assert len(kept) == 2

    def test_crash_tmp_dir_ignored(self):
        with tempfile.TemporaryDirectory() as d:
            save(d, 1, self._state(1))
            os.makedirs(os.path.join(d, ".tmp-000000009"))  # simulated crash
            assert latest_step(d) == 1

    def test_async_checkpointer(self):
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer(d)
            ck.save(3, self._state(3))
            ck.wait()
            out, step = restore(d, self._state())
            assert step == 3
            np.testing.assert_array_equal(out["b"], self._state(3)["b"])

    def test_shape_mismatch_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            save(d, 1, self._state())
            bad = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((4,))}
            with pytest.raises(ValueError):
                restore(d, bad)


class TestOptimizer:
    def test_adamw_descends_quadratic(self):
        params = {"x": jnp.asarray([4.0, -3.0])}
        opt = init_opt_state(params)
        cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=1, total_steps=100)
        for _ in range(60):
            grads = {"x": 2 * params["x"]}
            params, opt, m = adamw_update(cfg, params, grads, opt)
        assert float(jnp.abs(params["x"]).max()) < 0.5
        assert int(opt.step) == 60

    def test_clipping(self):
        params = {"x": jnp.zeros((3,))}
        opt = init_opt_state(params)
        cfg = AdamWConfig(clip_norm=1.0)
        _, _, m = adamw_update(cfg, params, {"x": jnp.full((3,), 1e6)}, opt)
        assert float(m["grad_norm"]) > 1e5  # raw norm reported


class TestCompression:
    def test_quantize_roundtrip_small_error(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((1000,)), jnp.float32)
        qs = quantize_int8(g)
        deq = dequantize_int8(qs, g.shape)
        rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
        assert rel < 0.01

    def test_error_feedback_reduces_bias(self):
        """With feedback, the accumulated quantization error stays bounded and
        the *sum* of dequantized grads tracks the true sum."""
        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.standard_normal((512,)), jnp.float32) * 1e-3
        params = {"g": g_true}
        err = init_error_feedback(params)
        total_deq = jnp.zeros_like(g_true)
        for _ in range(50):
            qtree, err = compress_with_feedback({"g": g_true}, err)
            total_deq = total_deq + dequantize_int8(qtree["g"], g_true.shape)
        drift = float(jnp.linalg.norm(total_deq - 50 * g_true)
                      / jnp.linalg.norm(50 * g_true))
        assert drift < 0.02


class TestController:
    def test_oversubscribed_shrinks(self):
        rng = np.random.default_rng(0)
        ctl = VetController(n_workers=4)
        base = 1.0 + 0.01 * rng.random(400)
        heavy = base.copy()
        heavy[::2] += rng.pareto(1.3, heavy[::2].shape) * 5.0
        for w in range(4):
            ctl.feed(w, heavy)
        d = ctl.decide()
        assert d.vet_job > 1.5
        assert d.target_workers == 3

    def test_healthy_grows(self):
        rng = np.random.default_rng(1)
        ctl = VetController(n_workers=2, max_workers=4)
        for w in range(2):
            ctl.feed(w, 1.0 + 0.01 * rng.random(400))
        d = ctl.decide()
        assert d.vet_job < 1.1
        assert d.target_workers == 3

    def test_straggler_flagged(self):
        rng = np.random.default_rng(2)
        ctl = VetController(n_workers=4)
        for w in range(3):
            ctl.feed(w, 1.0 + 0.01 * rng.random(400))
        bad = 1.0 + 0.01 * rng.random(400)
        bad[::2] += rng.pareto(1.3, bad[::2].shape) * 5
        ctl.feed(3, bad)
        d = ctl.decide()
        assert 3 in d.stragglers


class TestEndToEnd:
    def test_train_fail_resume_continues_losses(self):
        cfg = get_config("qwen3-14b").reduced()
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(SimulatedFailure):
                train(cfg, steps=24, batch=4, seq_len=32, ckpt_dir=d,
                      ckpt_every=8, fail_at_step=13, verbose=False, q_chunk=32)
            res = train(cfg, steps=24, batch=4, seq_len=32, ckpt_dir=d,
                        ckpt_every=8, verbose=False, q_chunk=32)
            assert res.resumed_from == 8
            assert res.final_step == 23

    def test_resume_matches_uninterrupted(self):
        """Deterministic pipeline + checkpointing => same final loss whether
        or not training was interrupted."""
        cfg = get_config("mamba2-130m").reduced()
        with tempfile.TemporaryDirectory() as d1:
            r_full = train(cfg, steps=16, batch=4, seq_len=32, ckpt_dir=None,
                           verbose=False)
            with tempfile.TemporaryDirectory() as d2:
                with pytest.raises(SimulatedFailure):
                    train(cfg, steps=16, batch=4, seq_len=32, ckpt_dir=d2,
                          ckpt_every=8, fail_at_step=10, verbose=False)
                r_resumed = train(cfg, steps=16, batch=4, seq_len=32,
                                  ckpt_dir=d2, ckpt_every=8, verbose=False)
        np.testing.assert_allclose(
            r_full.losses[-1], r_resumed.losses[-1], rtol=1e-4
        )

    def test_elastic_reshard_roundtrip(self):
        import os as _os

        from repro.distributed.elastic import choose_mesh_shape

        assert choose_mesh_shape(256, model_axis=16) == (16, 16)
        assert choose_mesh_shape(240, model_axis=16) == (15, 16)
        assert choose_mesh_shape(24) == (3, 8)
