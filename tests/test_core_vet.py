"""Deterministic unit tests for the paper's core: change-point, g-hat,
EI/OC/vet.  (Property-based cases live in ``test_core_vet_properties.py`` so
this module collects on checkouts without ``hypothesis``.)"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    ei_oc,
    estimate_changepoint,
    estimate_changepoint_naive,
    ghat_curve,
    two_segment_sse,
    vet_job,
    vet_task,
)

@pytest.fixture
def rng():
    """Fresh deterministic generator per test, so a test's draws (and thus
    its profile) never depend on module execution order."""
    return np.random.default_rng(42)


# ---------------------------------------------------------------- change-point
class TestChangepoint:
    def test_exact_two_segment(self):
        """Piecewise-linear data with a sharp slope break: exact recovery."""
        k_true = 60
        y = np.concatenate(
            [1.0 + 0.01 * np.arange(k_true), 1.6 + 1.0 * np.arange(40)]
        )
        t = int(estimate_changepoint(jnp.asarray(y)))
        assert abs(t - k_true) <= 1

    @pytest.mark.parametrize("n,k_frac", [(50, 0.3), (200, 0.5), (1000, 0.8)])
    def test_matches_naive_oracle(self, n, k_frac, rng):
        """O(n) prefix-sum form == the paper's literal O(n^2) double loop."""
        k = int(n * k_frac)
        y = np.sort(
            np.concatenate(
                [rng.normal(1.0, 0.05, k), rng.normal(3.0, 0.5, n - k) + 2.0]
            )
        )
        t_fast = int(estimate_changepoint(jnp.asarray(y)))
        t_naive = estimate_changepoint_naive(y)
        assert t_fast == t_naive

    def test_probing_window_respected(self, rng):
        y = np.sort(rng.normal(1.0, 0.1, 64))
        for omega in (3, 5, 10):
            t = int(estimate_changepoint(jnp.asarray(y), omega=omega))
            assert omega <= t <= 64 - omega

    def test_sse_inf_outside_window(self, rng):
        y = np.sort(rng.normal(0.0, 1.0, 32))
        sse = np.asarray(two_segment_sse(jnp.asarray(y), omega=4))
        assert np.all(np.isinf(sse[:3]))  # k = 1..3 invalid
        assert np.all(np.isinf(sse[29:]))  # k = 30..32 invalid
        assert np.all(np.isfinite(sse[3:28]))


# ------------------------------------------------------------------ g-hat curve
class TestGhat:
    def test_continuity_and_monotone(self, rng):
        y = np.sort(rng.pareto(1.3, 500) + 1.0)
        t = 300
        g = np.asarray(ghat_curve(jnp.asarray(y), t))
        # matches observations up to t
        np.testing.assert_allclose(g[:t], y[:t], rtol=1e-6)
        # continuous at t, then linear with slope Y_t - Y_{t-1}
        slope = y[t - 1] - y[t - 2]
        np.testing.assert_allclose(
            g[t:], y[t - 1] + slope * np.arange(1, 500 - t + 1), rtol=1e-5
        )
        # monotone beyond t
        assert np.all(np.diff(g[t - 1 :]) >= -1e-9)

    def test_paper_recursion_telescopes(self, rng):
        """g(r+1) = 2 g(r) - g(r-1) holds for the closed form."""
        y = np.sort(rng.exponential(1.0, 100))
        g = np.asarray(ghat_curve(jnp.asarray(y), 40))
        lhs = g[42:]
        rhs = 2 * g[41:-1] - g[40:-2]
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


# ------------------------------------------------------------------- EI/OC/vet
class TestVet:
    def test_conservation(self, rng):
        """EI + OC == PR exactly (the measure is a decomposition)."""
        x = rng.pareto(1.3, 2000) * 1e-3 + 1e-3
        r = vet_task(x)
        np.testing.assert_allclose(float(r.ei + r.oc), float(r.pr), rtol=1e-5)

    def test_clean_profile_vet_is_one(self, rng):
        """A perfectly linear profile has no overhead: vet == 1."""
        x = 1.0 + 0.001 * np.arange(512)
        for kwargs in ({}, {"buckets": None, "cut_space": "raw"}):
            r = vet_task(rng.permutation(x), **kwargs)
            assert abs(float(r.vet) - 1.0) < 1e-3

    def test_permutation_invariance(self, rng):
        x = rng.pareto(1.3, 1000) + 1.0
        r1 = vet_task(x)
        r2 = vet_task(rng.permutation(x))
        np.testing.assert_allclose(float(r1.vet), float(r2.vet), rtol=1e-6)

    def test_scale_equivariance(self, rng):
        """times -> c*times scales EI/OC/PR by c and leaves vet unchanged."""
        x = rng.pareto(1.3, 1000) + 1.0
        r1, r2 = vet_task(x), vet_task(7.5 * x)
        np.testing.assert_allclose(float(r2.vet), float(r1.vet), rtol=1e-4)
        np.testing.assert_allclose(float(r2.ei), 7.5 * float(r1.ei), rtol=1e-4)

    def test_overhead_increases_vet(self):
        base = 1.0 + 0.001 * np.arange(2000)
        light, heavy = base.copy(), base.copy()
        light[-100:] += 5.0
        heavy[-100:] += 50.0
        assert float(vet_task(heavy).vet) > float(vet_task(light).vet) > 1.0

    def test_vet_job_is_mean_of_tasks(self, rng):
        tasks = [rng.pareto(1.3, 500) + 1.0 for _ in range(4)]
        jr = vet_job(tasks)
        mean = np.mean([float(r.vet) for r in jr.tasks])
        np.testing.assert_allclose(float(jr.vet_job), mean, rtol=1e-6)

    def test_ei_consistency_under_utilization(self):
        """The paper's Table 2 claim: EI stays ~constant as overhead scales."""
        from repro.profiling import simulate_records

        eis = []
        for u in (1.0, 4.0, 8.0):
            p = simulate_records(
                200_000, seed=7, base=1e-6, base_jitter=0.1, io_frac=0.1,
                io_cost=2e-6, overhead_frac=0.05, overhead_scale=2e-5 * u,
            )
            eis.append(float(vet_task(p.times).ei))
        spread = (max(eis) - min(eis)) / min(eis)
        assert spread < 0.15, f"EI drifted {spread:.1%} across utilization"

    def test_ei_recovers_truth_on_simulated(self):
        from repro.profiling import simulate_records

        p = simulate_records(
            200_000, seed=11, base=1e-6, base_jitter=0.1, io_frac=0.1,
            io_cost=2e-6, overhead_frac=0.05, overhead_scale=2e-5,
        )
        r = vet_task(p.times)
        assert abs(float(r.ei) - p.true_ei) / p.true_ei < 0.25


# ----------------------------------------------------------------- online vet
class TestOnlineVet:
    def test_stream_matches_batch_on_stationary(self):
        from repro.core.online import OnlineVet

        rng = np.random.default_rng(0)
        times = 1e-3 * (1 + 0.05 * rng.random(4096))
        times[:: 7] += rng.pareto(1.3, times[::7].shape) * 5e-3
        ov = OnlineVet(window=512)
        snap = None
        for lo in range(0, times.size, 64):
            snaps = ov.feed(times[lo:lo + 64])
            snap = snaps[-1] if snaps else snap
        batch = float(vet_task(times, buckets=64).vet)
        assert snap is not None
        assert abs(snap.smoothed_vet - batch) / batch < 0.35

    def test_regime_change_detected(self):
        from repro.core.online import OnlineVet

        rng = np.random.default_rng(1)
        clean = 1e-3 * (1 + 0.05 * rng.random(2048))
        dirty = clean.copy()
        dirty[::3] += rng.pareto(1.3, dirty[::3].shape) * 2e-2
        ov = OnlineVet(window=512, alpha=0.5)
        for lo in range(0, 2048, 128):
            ov.feed(clean[lo:lo + 128])
        v_clean = ov.snapshot.smoothed_vet
        for lo in range(0, 2048, 128):
            ov.feed(dirty[lo:lo + 128])
        v_dirty = ov.snapshot.smoothed_vet
        assert v_clean < 1.3
        assert v_dirty > v_clean * 1.5

    def test_feed_spanning_multiple_windows_returns_all_snapshots(self):
        """One feed() covering several window completions must emit every
        intermediate snapshot, not just the last (regression: last-wins)."""
        from repro.core.online import OnlineVet

        rng = np.random.default_rng(2)
        ov = OnlineVet(window=64)
        # 64 (fill) + 3 * 32 (half-window refresh cadence) => 4 snapshots
        snaps = ov.feed(1.0 + 0.01 * rng.random(160))
        assert len(snaps) == 4
        assert snaps[-1] == ov.snapshot
        assert all(s.n_window == 64 for s in snaps)
        # snapshots are in stream order: EMA folds left to right
        assert snaps[0].smoothed_vet == snaps[0].vet

    def test_feed_without_window_completion_returns_empty_list(self):
        from repro.core.online import OnlineVet

        ov = OnlineVet(window=128)
        assert ov.feed(np.ones(100)) == []
        assert ov.snapshot is None
