"""Property-based (hypothesis) tests for the vet estimator.

Split from ``test_core_vet.py`` so the deterministic suite always collects;
this module is skipped wholesale when ``hypothesis`` is not installed
(``scripts/ci.sh`` installs it as a test extra).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import vet_task  # noqa: E402


@st.composite
def time_profiles(draw):
    n = draw(st.integers(min_value=16, max_value=400))
    base = draw(st.floats(min_value=1e-6, max_value=1.0))
    vals = draw(
        st.lists(
            st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
            min_size=n, max_size=n,
        )
    )
    return base + np.asarray(vals)


@settings(max_examples=30, deadline=None)
@given(time_profiles())
def test_prop_conservation_and_positivity(times):
    r = vet_task(times, buckets=64)
    ei, oc, pr = float(r.ei), float(r.oc), float(r.pr)
    assert ei > 0
    np.testing.assert_allclose(ei + oc, pr, rtol=1e-4, atol=1e-6)
    # EI never exceeds PR by more than fp slack: the ideal is a lower bound.
    assert ei <= pr * (1 + 1e-5) + 1e-6


@settings(max_examples=30, deadline=None)
@given(time_profiles(), st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_permutation_invariance(times, seed):
    perm = np.random.default_rng(seed).permutation(times)
    r1, r2 = vet_task(times, buckets=64), vet_task(perm, buckets=64)
    np.testing.assert_allclose(float(r1.vet), float(r2.vet), rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(time_profiles(), st.floats(min_value=0.1, max_value=1000.0))
def test_prop_scale_equivariance(times, c):
    r1, r2 = vet_task(times, buckets=64), vet_task(c * times, buckets=64)
    np.testing.assert_allclose(float(r2.vet), float(r1.vet), rtol=1e-3, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=128, max_value=1024),
    st.floats(min_value=0.5, max_value=50.0),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_prop_suffix_overhead_never_decreases_vet(n, boost, seed):
    """On profiles satisfying the estimator's premise (a continuous, near-flat
    base population), adding pure overhead to the slowest 10% of records is
    absorbed by OC: vet must not decrease (and PR must grow)."""
    rng = np.random.default_rng(seed)
    y = np.sort(1.0 + 0.1 * rng.random(n))  # continuous near-flat base
    k = max(1, n // 10)
    heavy = y.copy()
    heavy[-k:] = heavy[-k:] + boost
    r0, r1 = vet_task(y, buckets=64), vet_task(heavy, buckets=64)
    assert float(r1.pr) > float(r0.pr)
    assert float(r1.vet) >= float(r0.vet) * (1 - 5e-2)
