"""Property-based (hypothesis) tests for the fused window-vet kernel.

Split from ``test_windowvet.py`` so the deterministic suite always collects;
this module is skipped wholesale when ``hypothesis`` is not installed
(``scripts/ci.sh`` installs it as a test extra).

Two property layers, mirroring the deterministic ladder:

- fused vs the engine's gather path (same f32 rounding): vet/ei/oc/pr to
  1e-5 with the change-point exact, on arbitrary overlapping / ragged /
  degenerate window sets — the differential contract that cannot near-tie.
- fused vs the f64 scalar oracle: measures to 2e-2 (the documented pallas
  near-tie caveat; OC gets an atol because it crosses zero when the cut
  lands on n), plus the estimator's EI <= PR conservation bound.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.engine import VetEngine, VetStream  # noqa: E402
from repro.kernels.windowvet import fused_window_vet, ref_window_vet  # noqa: E402


@st.composite
def arenas_with_windows(draw):
    """A positive record-time arena plus a ragged overlapping window set
    (degenerate 2-record windows and whole-arena windows included)."""
    n = draw(st.integers(min_value=16, max_value=300))
    base = draw(st.floats(min_value=1e-6, max_value=1.0))
    vals = draw(st.lists(
        st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
        min_size=n, max_size=n))
    arena = base + np.asarray(vals)
    n_windows = draw(st.integers(min_value=1, max_value=24))
    starts, lengths = [], []
    for _ in range(n_windows):
        ln = draw(st.integers(min_value=2, max_value=n))
        starts.append(draw(st.integers(min_value=0, max_value=n - ln)))
        lengths.append(ln)
    return (arena, np.asarray(starts, dtype=np.int64),
            np.asarray(lengths, dtype=np.int64))


@settings(max_examples=25, deadline=None)
@given(arenas_with_windows())
def test_prop_fused_matches_gather_path_bitwise_t(case):
    arena, starts, lengths = case
    vet, ei, oc, pr, t, n = fused_window_vet(arena, starts, lengths)
    gather = VetEngine("pallas", cache_size=0, fused=False)
    slices = list(zip(starts.tolist(), (starts + lengths).tolist()))
    g = gather.vet_windows(arena, slices)
    np.testing.assert_allclose(vet, g.vet, rtol=1e-5, atol=1e-9)
    np.testing.assert_allclose(ei, g.ei, rtol=1e-5, atol=1e-9)
    np.testing.assert_allclose(oc, g.oc, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(pr, g.pr, rtol=1e-5, atol=1e-9)
    np.testing.assert_array_equal(t, g.t)
    np.testing.assert_array_equal(n, g.n)


@settings(max_examples=25, deadline=None)
@given(arenas_with_windows())
def test_prop_fused_tracks_scalar_oracle_and_conserves(case):
    arena, starts, lengths = case
    vet, ei, oc, pr, t, n = fused_window_vet(arena, starts, lengths)
    want = ref_window_vet(arena, starts, lengths)
    np.testing.assert_allclose(vet, want[0], rtol=2e-2, atol=1e-3)
    np.testing.assert_allclose(ei, want[1], rtol=2e-2, atol=1e-3)
    np.testing.assert_allclose(oc, want[2], rtol=2e-2, atol=1e-3)
    np.testing.assert_allclose(pr, want[3], rtol=1e-6, atol=1e-9)
    # Conservation and the ideal-is-a-lower-bound invariant, rowwise.
    np.testing.assert_allclose(ei + oc, pr, rtol=1e-4, atol=1e-6)
    assert (ei > 0).all()
    assert (ei <= pr * (1 + 1e-5) + 1e-6).all()
    assert ((t >= 1) & (t <= n)).all()


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.integers(min_value=1, max_value=72), min_size=3, max_size=12),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_prop_stream_chunking_invariance_across_ring_wrap(chunks, seed):
    """However a stream's feed is chunked (wrapping the ring arbitrarily),
    the fused ticks' concatenated rows equal the gather-path stream's —
    bitwise on the change-point, 1e-5 on the measures."""
    from repro.profiling import simulate_records

    total = sum(chunks)
    times = simulate_records(max(total, 32), seed=seed % 1000).times[:total]
    fused = VetStream(VetEngine("pallas"), window=24, stride=8, capacity=96)
    gather = VetStream(VetEngine("pallas", fused=False), window=24, stride=8,
                       capacity=96)
    fed = 0
    for chunk in chunks:
        part = times[fed:fed + chunk]
        fed += chunk
        fused.append(part)
        gather.append(part)
        a, b = fused.tick(), gather.tick()
        aw = 0 if a is None else a.workers
        assert aw == (0 if b is None else b.workers)
        if aw:
            np.testing.assert_allclose(a.vet, b.vet, rtol=1e-5, atol=1e-9)
            np.testing.assert_allclose(a.ei, b.ei, rtol=1e-5, atol=1e-9)
            np.testing.assert_array_equal(a.t, b.t)
