"""VetEngine: cross-backend equivalence, batching, ragged routing, call sites.

The ``numpy`` backend (a host loop of scalar ``vet_task`` calls — the
pre-engine code path) is the numerical oracle; ``jax`` and ``pallas`` must
match it on simulator ground-truth profiles.
"""

import numpy as np
import pytest

from repro.core import vet_task
from repro.engine import BACKENDS, BatchVetResult, VetEngine, default_engine
from repro.profiling import simulate_records


def sim_matrix(workers=8, window=512, seed=0):
    return np.stack(
        [simulate_records(window, seed=seed + i).times for i in range(workers)]
    )


def noiseless_matrix(workers=4, window=256, k=160):
    """Exact two-segment piecewise-linear rows: unambiguous change-point."""
    rows = []
    for w in range(workers):
        base = 1.0 + 0.001 * (w + 1) * np.arange(k)
        tail = base[-1] + 0.5 * (w + 1) * np.arange(1, window - k + 1)
        rows.append(np.concatenate([base, tail]))
    return np.stack(rows)


def _sse64(y, omega=3):
    """Float64 two-segment SSE oracle (well-conditioned: centered y)."""
    y = np.asarray(y, np.float64)
    n = y.size
    y = y - y.mean()
    idx = np.arange(1, n + 1, dtype=np.float64)
    cy, cyy, cxy = np.cumsum(y), np.cumsum(y * y), np.cumsum(idx * y)
    k = idx
    sx1, sxx1 = k * (k + 1) / 2, k * (k + 1) * (2 * k + 1) / 6
    sxt, sxxt = n * (n + 1) / 2, n * (n + 1) * (2 * n + 1) / 6

    def seg(m, sx, sy, sxx, sxy, syy):
        m = np.maximum(m, 1.0)
        sxx_c, sxy_c, syy_c = sxx - sx * sx / m, sxy - sx * sy / m, syy - sy * sy / m
        safe = sxx_c > 0
        return np.maximum(
            syy_c - np.where(safe, sxy_c**2 / np.where(safe, sxx_c, 1.0), 0.0), 0.0
        )

    tot = seg(k, sx1, cy, sxx1, cxy, cyy) + seg(
        n - k, sxt - sx1, cy[-1] - cy, sxxt - sxx1, cxy[-1] - cxy, cyy[-1] - cyy
    )
    return np.where((k >= omega) & (k <= n - omega), tot, np.inf)


# ------------------------------------------------------------- equivalence
class TestBackendEquivalence:
    def test_jax_matches_numpy_oracle_on_simulator_profiles(self):
        """The acceptance bar: jax backend == scalar oracle within 1e-5."""
        m = sim_matrix(32, 512)
        oracle = VetEngine("numpy", buckets=64).vet_batch(m)
        res = VetEngine("jax", buckets=64).vet_batch(m)
        np.testing.assert_allclose(res.ei, oracle.ei, rtol=1e-5)
        np.testing.assert_allclose(res.oc, oracle.oc, rtol=1e-5, atol=1e-9)
        np.testing.assert_allclose(res.vet, oracle.vet, rtol=1e-5)
        np.testing.assert_allclose(res.pr, oracle.pr, rtol=1e-5)
        np.testing.assert_array_equal(res.t, oracle.t)

    def test_pallas_matches_numpy_oracle_on_simulator_profiles(self):
        """The pallas path may flip the cut between *statistical near-ties*
        (its batched trace fuses differently by a few hundred ulp, and the
        bucketed log landscape has 1e-4-relative ties), shifting t by one
        bucket on a small fraction of rows.  Contract: EI/OC/vet within 2%
        everywhere, and the overwhelming majority of rows bit-match."""
        m = sim_matrix(32, 512)
        oracle = VetEngine("numpy", buckets=64).vet_batch(m)
        res = VetEngine("pallas", buckets=64).vet_batch(m)
        np.testing.assert_allclose(res.ei, oracle.ei, rtol=2e-2)
        np.testing.assert_allclose(res.oc, oracle.oc, rtol=2e-2, atol=1e-6)
        np.testing.assert_allclose(res.vet, oracle.vet, rtol=2e-2)
        np.testing.assert_allclose(res.pr, oracle.pr, rtol=1e-5)  # PR is a sum
        assert np.mean(res.t == oracle.t) >= 0.9

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_noiseless_changepoint_index_identical(self, backend):
        m = noiseless_matrix()
        oracle = VetEngine("numpy", buckets=None).vet_batch(m)
        res = VetEngine(backend, buckets=None).vet_batch(m)
        np.testing.assert_array_equal(res.t, oracle.t)

    def test_paper_literal_estimator_matches_jax(self):
        """Equivalence must also hold for buckets=None / cut_space='raw'."""
        m = sim_matrix(4, 300, seed=10)
        kw = dict(buckets=None, cut_space="raw")
        oracle = VetEngine("numpy", **kw).vet_batch(m)
        res = VetEngine("jax", **kw).vet_batch(m)
        np.testing.assert_allclose(res.ei, oracle.ei, rtol=1e-5)
        np.testing.assert_allclose(res.vet, oracle.vet, rtol=1e-5)

    def test_paper_literal_pallas_cut_is_near_optimal(self):
        """In raw cut space on heavy tails the SSE landscape is near-flat at
        the minimum (the documented drift pathology, see core/vet.py), so the
        Pallas kernel's f32 arithmetic can flip the argmin between near-ties
        — exact index equality is only asserted on the well-posed
        framework-default and noiseless cases above.  The raw-space contract
        (mirroring tests/test_kernels.py tolerances) is that the kernel's cut
        is a near-tie of the true optimum: its float64 two-segment SSE must be
        within a few percent of the true minimum."""
        import jax.numpy as jnp

        from repro.kernels.changepoint.ops import changepoint_pallas

        for row in sim_matrix(4, 300, seed=10):
            y = np.sort(row)
            truth = _sse64(y)
            t_pal = int(changepoint_pallas(jnp.asarray(y)))
            assert truth[t_pal - 1] <= truth.min() * 1.05


# ----------------------------------------------------------------- batching
class TestBatching:
    def test_batched_equals_per_worker_loop(self):
        """Regression: one batched call == the old per-worker vet_task loop."""
        m = sim_matrix(6, 400, seed=3)
        batch = VetEngine("jax", buckets=64).vet_batch(m)
        for i, row in enumerate(m):
            r = vet_task(row, buckets=64)
            np.testing.assert_allclose(batch.vet[i], float(r.vet), rtol=1e-5)
            np.testing.assert_allclose(batch.ei[i], float(r.ei), rtol=1e-5)
            assert batch.t[i] == int(r.t)

    def test_64x512_in_one_jitted_call(self):
        """The acceptance shape: (64 workers x 512 records) in one call."""
        m = sim_matrix(64, 512, seed=100)
        eng = VetEngine("jax", buckets=64)
        res = eng.vet_batch(m)
        assert isinstance(res, BatchVetResult)
        assert res.vet.shape == (64,)
        assert res.workers == 64
        assert np.all(res.vet >= 1.0 - 1e-5)
        np.testing.assert_allclose(res.ei + res.oc, res.pr, rtol=1e-5)
        oracle = VetEngine("numpy", buckets=64).vet_batch(m)
        np.testing.assert_allclose(res.ei, oracle.ei, rtol=1e-5)

    def test_vet_one_matches_vet_task(self):
        x = simulate_records(512, seed=5).times
        r_engine = VetEngine("jax", buckets=64).vet_one(x)
        r_task = vet_task(x, buckets=64)
        np.testing.assert_allclose(float(r_engine.vet), float(r_task.vet),
                                   rtol=1e-6)
        assert r_engine.n == r_task.n

    def test_vet_many_ragged_matches_per_profile(self):
        profiles = [
            simulate_records(300, seed=20).times,
            simulate_records(500, seed=21).times,
            simulate_records(300, seed=22).times,
        ]
        res = VetEngine("jax", buckets=64).vet_many(profiles)
        assert list(res.n) == [300, 500, 300]
        for i, p in enumerate(profiles):
            np.testing.assert_allclose(
                res.vet[i], float(vet_task(p, buckets=64).vet), rtol=1e-5
            )
        np.testing.assert_allclose(res.vet_job, res.vet.mean())


# ---------------------------------------------------------------- interface
class TestEngineInterface:
    def test_bad_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            VetEngine("tpu9000")

    def test_bad_cut_space_rejected(self):
        with pytest.raises(ValueError, match="cut_space"):
            VetEngine("jax", cut_space="sqrt")

    def test_vet_many_empty_rejected(self):
        with pytest.raises(ValueError):
            VetEngine("numpy").vet_many([])

    def test_default_engine_is_shared(self):
        assert default_engine("jax") is default_engine("jax")
        assert default_engine("jax") is not default_engine("numpy")


# -------------------------------------------------------- routed call sites
class TestCallSiteRouting:
    def test_online_vet_accepts_engine(self):
        from repro.core.online import OnlineVet

        rng = np.random.default_rng(0)
        times = 1.0 + 0.01 * rng.random(256)
        engines = {b: VetEngine(b, buckets=64) for b in ("numpy", "jax")}
        snaps = {}
        for name, eng in engines.items():
            ov = OnlineVet(window=128, engine=eng)
            out = ov.feed(times)
            assert out, "window should have completed"
            snaps[name] = out[-1]
        np.testing.assert_allclose(snaps["jax"].vet, snaps["numpy"].vet,
                                   rtol=1e-5)

    def test_controller_decide_is_batched_and_reports_worker_vets(self):
        from repro.sched import VetController

        rng = np.random.default_rng(4)
        ctl = VetController(n_workers=3, engine=VetEngine("jax", buckets=64))
        for w in range(3):
            ctl.feed(w, 1.0 + 0.01 * rng.random(200))
        d = ctl.decide()
        assert set(d.worker_vets) == {0, 1, 2}
        np.testing.assert_allclose(
            d.vet_job, np.mean(list(d.worker_vets.values())), rtol=1e-6
        )

    def test_controller_handles_ragged_buffers(self):
        from repro.sched import VetController

        rng = np.random.default_rng(5)
        ctl = VetController(n_workers=2)
        ctl.feed(0, 1.0 + 0.01 * rng.random(200))
        ctl.feed(1, 1.0 + 0.01 * rng.random(90))  # shorter buffer
        d = ctl.decide()
        assert set(d.worker_vets) == {0, 1}
