"""Differential suite for the streaming vet path (``repro.engine.stream``).

The contract under test: every ``VetStream.tick()`` result equals the batch
oracle — ``vet_sliding`` over the same logical prefix of the stream — no
matter how the stream was chunked into appends.  Because window ``k`` depends
only on its own records, the oracle over any prefix is a row-prefix of the
oracle over the full stream, so each case computes the full-stream oracle
once and checks every tick against its leading rows: bitwise for the numpy
backend (the stream's incremental dispatch runs the very same scalar loop on
the very same float64 rows), 1e-5 for jax/pallas (their standing differential
contract vs the numpy oracle).

Also locks the invalidation story (amend / blanket invalidate / engine-level
``invalidate(buffer)``: a mutated buffer can never serve a stale hit), the
ring-wraparound and overrun edge cases, and the ``OnlineVet`` rewrite
(chunked and record-at-a-time feeds emit identical snapshot lists).
"""

import numpy as np
import pytest

from repro.core.online import OnlineVet
from repro.engine import BACKENDS, StreamStats, VetEngine, VetStream
from repro.profiling import simulate_records

JITTED_BACKENDS = ("jax", "pallas")


def stream_times(n=320, seed=0):
    return simulate_records(n, seed=seed).times


def oracle_for(times, window, stride):
    """Full-stream batch oracle (numpy backend == per-window scalar loop)."""
    return VetEngine("numpy", buckets=64).vet_sliding(times, window=window,
                                                      stride=stride)


def drive(stream, times, chunk):
    """Append chunk-by-chunk, tick after every append; yield (tick, result)."""
    for lo in range(0, times.size, chunk):
        stream.append(times[lo:lo + chunk])
        yield stream.complete_windows, stream.tick()


def assert_rows_equal(res, oracle, k, *, bitwise):
    """res must equal the first k oracle rows (field by field)."""
    assert res.workers == k
    if bitwise:
        for name in ("vet", "ei", "oc", "pr"):
            np.testing.assert_array_equal(getattr(res, name),
                                          getattr(oracle, name)[:k])
    else:
        for name in ("vet", "ei", "oc", "pr"):
            np.testing.assert_allclose(getattr(res, name),
                                       getattr(oracle, name)[:k], rtol=1e-5,
                                       atol=1e-9)
    np.testing.assert_array_equal(res.t, oracle.t[:k])
    np.testing.assert_array_equal(res.n, oracle.n[:k])


# ---------------------------------------------------------- differential
class TestStreamDifferential:
    WINDOW, STRIDE = 64, 16

    @pytest.mark.parametrize("chunk", (1, 7, 64, 197))
    def test_numpy_every_tick_bitwise_equals_batch_oracle(self, chunk):
        """Chunk sizes 1 / 7 / window-sized / multi-window: bitwise."""
        times = stream_times(320, seed=0)
        oracle = oracle_for(times, self.WINDOW, self.STRIDE)
        st = VetStream(VetEngine("numpy", buckets=64), window=self.WINDOW,
                       stride=self.STRIDE, capacity=512)
        ticked = 0
        for k, res in drive(st, times, chunk):
            if k == 0:
                assert res is None
                continue
            assert_rows_equal(res, oracle, k, bitwise=True)
            ticked += 1
        assert ticked > 0 and st.complete_windows == oracle.workers

    @pytest.mark.parametrize("backend", JITTED_BACKENDS)
    @pytest.mark.parametrize("chunk", (7, 64, 197))
    def test_jitted_every_tick_matches_oracle_1e5(self, backend, chunk):
        times = stream_times(320, seed=3)
        oracle = oracle_for(times, self.WINDOW, self.STRIDE)
        st = VetStream(VetEngine(backend, buckets=64), window=self.WINDOW,
                       stride=self.STRIDE, capacity=512)
        for k, res in drive(st, times, chunk):
            if k:
                assert_rows_equal(res, oracle, k, bitwise=False)

    def test_stream_equals_vet_sliding_same_engine_exactly(self):
        """Same engine, same backend: stream rows == vet_sliding rows."""
        times = stream_times(300, seed=5)
        eng = VetEngine("jax", buckets=64)
        st = VetStream(eng, window=64, stride=32, capacity=512)
        st.append(times)
        res = st.tick()
        batch = eng.vet_sliding(times, window=64, stride=32)
        np.testing.assert_array_equal(res.vet, batch.vet)
        np.testing.assert_array_equal(res.t, batch.t)

    def test_final_result_independent_of_chunking(self):
        """1-record and multi-window chunkings end bitwise identical."""
        times = stream_times(256, seed=8)
        finals = []
        for chunk in (1, 256):
            st = VetStream(VetEngine("numpy", buckets=64), window=64,
                           stride=16, capacity=256)
            for _, res in drive(st, times, chunk):
                final = res
            finals.append(final)
        for a, b in zip(finals[0], finals[1]):
            np.testing.assert_array_equal(a, b)

    def test_tick_is_incremental_not_recomputed(self):
        """Rows are dispatched once: vetted == windows, reuse grows."""
        times = stream_times(320, seed=1)
        st = VetStream(VetEngine("numpy", buckets=64), window=64, stride=16,
                       capacity=512)
        for _ in drive(st, times, 32):
            pass
        stats = st.stats
        assert isinstance(stats, StreamStats)
        assert stats.windows == (320 - 64) // 16 + 1
        assert stats.vetted == stats.windows  # each window vetted exactly once
        assert stats.reused > 0


# ------------------------------------------------------- ring wraparound
class TestRingWraparound:
    def test_small_capacity_many_wraps_matches_oracle(self):
        """capacity=64 over a 400-record stream (several full wraps)."""
        times = stream_times(400, seed=2)
        oracle = oracle_for(times, 32, 8)
        st = VetStream(VetEngine("numpy", buckets=64), window=32, stride=8,
                       capacity=64)
        for k, res in drive(st, times, 16):
            if k:
                assert_rows_equal(res, oracle, k, bitwise=True)

    def test_capacity_equals_window_tumbling(self):
        """The tightest legal ring: capacity == window == stride == chunk."""
        times = stream_times(256, seed=4)
        oracle = oracle_for(times, 64, 64)
        st = VetStream(VetEngine("numpy", buckets=64), window=64, stride=64,
                       capacity=64)
        for k, res in drive(st, times, 64):
            assert_rows_equal(res, oracle, k, bitwise=True)

    def test_chunk_larger_than_capacity_keeps_tail(self):
        """An oversized append retains the newest capacity records."""
        times = stream_times(300, seed=6)
        st = VetStream(VetEngine("numpy", buckets=64), window=64, stride=64,
                       capacity=128)
        st.append(times)  # 300 > 128: records 172..299 resident
        np.testing.assert_array_equal(st.resident(), times[-128:])
        assert st.total_records == 300

    def test_overrun_raises_informative_error(self):
        """Appends that outrun the ring must raise, not skip windows."""
        st = VetStream(VetEngine("numpy", buckets=64), window=64, stride=16,
                       capacity=64)
        st.append(stream_times(200, seed=7))
        with pytest.raises(ValueError, match="overran the ring"):
            st.tick()

    def test_latest_and_resident_views(self):
        times = stream_times(100, seed=9)
        st = VetStream(VetEngine("numpy", buckets=64), window=32, capacity=64)
        st.append(times)
        np.testing.assert_array_equal(st.resident(), times[-64:])
        np.testing.assert_array_equal(st.latest(10), times[-10:])
        np.testing.assert_array_equal(st.latest(1000), times[-64:])


# --------------------------------------------------------- invalidation
class TestInvalidation:
    def test_amend_re_vets_affected_windows_to_mutated_oracle(self):
        """mutate -> no stale rows: post-amend ticks equal the oracle over
        the mutated stream, and only the affected suffix is re-dispatched."""
        times = stream_times(320, seed=0)
        st = VetStream(VetEngine("numpy", buckets=64), window=64, stride=16,
                       capacity=512)
        st.append(times)
        st.tick()
        vetted_before = st.stats.vetted
        mutated = times.copy()
        mutated[300] *= 40.0
        st.amend(300, mutated[300])
        res = st.tick()
        oracle = oracle_for(mutated, 64, 16)
        assert_rows_equal(res, oracle, oracle.workers, bitwise=True)
        # windows before the first one covering record 300 were NOT re-vetted
        first_affected = (300 - 64) // 16 + 1
        assert st.stats.vetted - vetted_before == oracle.workers - first_affected

    def test_amend_through_cached_engine_never_serves_stale_rows(self):
        """The epoch-tagged fingerprint: same engine cache, pre- and
        post-mutation ticks must differ where the oracle differs."""
        times = stream_times(128, seed=3)
        eng = VetEngine("jax", buckets=64)  # cache enabled
        st = VetStream(eng, window=64, stride=64, capacity=256)
        st.append(times)
        r1 = st.tick()
        st.amend(100, np.asarray([times[100] * 80.0]))
        r2 = st.tick()
        assert r2 is not r1
        assert r2.vet[1] != r1.vet[1]  # window [64,128) saw the mutation
        assert r2.vet[0] == r1.vet[0]  # window [0,64) did not

    def test_amend_bounds_checked(self):
        st = VetStream(VetEngine("numpy", buckets=64), window=32, capacity=64)
        st.append(stream_times(200, seed=1))
        with pytest.raises(ValueError, match="outside the appended stream"):
            st.amend(500, [1.0])
        with pytest.raises(ValueError, match="resident"):
            st.amend(10, [1.0])  # record 10 already evicted (only 136.. live)

    def test_blanket_invalidate_re_vets_resident_windows(self):
        times = stream_times(256, seed=5)
        st = VetStream(VetEngine("numpy", buckets=64), window=64, stride=32,
                       capacity=256)
        st.append(times)
        r1 = st.tick()
        dropped = st.invalidate()
        assert dropped == r1.workers  # everything resident -> all re-vetted
        r2 = st.tick()
        assert r2 is not r1
        for a, b in zip(r1, r2):
            np.testing.assert_array_equal(a, b)  # content unchanged => equal
        assert st.stats.epoch == 1
        assert st.stats.vetted == 2 * r1.workers

    def test_engine_invalidate_evicts_matching_entries(self):
        times = stream_times(256, seed=6)
        other = stream_times(256, seed=7)
        eng = VetEngine("jax", buckets=64)
        eng.vet_sliding(times, window=64, stride=64)
        eng.vet_sliding(other, window=64, stride=64)
        eng.vet_many([times, other])
        assert eng.cache_info().size == 3
        # evicts the entries computed from `times`, including the
        # multi-buffer vet_many entry; `other`'s own entry survives
        assert eng.invalidate(times) == 2
        assert eng.cache_info().size == 1
        assert eng.invalidate(np.ones(10)) == 0

    def test_engine_invalidate_then_recompute_is_a_miss(self):
        times = stream_times(128, seed=8)
        eng = VetEngine("jax", buckets=64)
        eng.vet_batch(times[None, :])
        misses = eng.cache_info().misses
        eng.invalidate(times)
        eng.vet_batch(times[None, :])
        assert eng.cache_info().misses == misses + 1


# ------------------------------------------------------------- API edges
class TestStreamAPI:
    def test_tick_before_first_window_returns_none(self):
        st = VetStream(VetEngine("numpy", buckets=64), window=64)
        st.append(stream_times(32, seed=0))
        assert st.tick() is None
        assert st.complete_windows == 0

    def test_noop_tick_returns_same_object_without_dispatch(self):
        eng = VetEngine("numpy", buckets=64)
        st = VetStream(eng, window=64, stride=64, capacity=256)
        st.append(stream_times(128, seed=1))
        r1 = st.tick()
        vetted = st.stats.vetted
        r2 = st.tick()
        assert r2 is r1
        assert st.stats.vetted == vetted

    def test_results_are_frozen(self):
        st = VetStream(VetEngine("numpy", buckets=64), window=64, stride=64)
        st.append(stream_times(128, seed=2))
        res = st.tick()
        with pytest.raises(ValueError):
            res.vet[0] = 0.0

    def test_earlier_tick_results_are_stable_snapshots(self):
        """A result handed out must not change as the stream grows."""
        times = stream_times(256, seed=3)
        st = VetStream(VetEngine("numpy", buckets=64), window=64, stride=32,
                       capacity=256)
        st.append(times[:128])
        r1 = st.tick()
        saved = r1.vet.copy()
        st.append(times[128:])
        st.tick()
        np.testing.assert_array_equal(r1.vet, saved)

    def test_rolling_fingerprint_changes_on_append_and_amend(self):
        st = VetStream(VetEngine("numpy", buckets=64), window=32)
        f0 = st.fingerprint
        st.append(stream_times(64, seed=4))
        f1 = st.fingerprint
        st.amend(60, [1.0])
        f2 = st.fingerprint
        assert len({f0, f1, f2}) == 3

    def test_constructor_contract(self):
        eng = VetEngine("numpy", buckets=64)
        with pytest.raises(ValueError, match="window"):
            VetStream(eng, window=1)
        with pytest.raises(ValueError, match="stride"):
            VetStream(eng, window=8, stride=0)
        with pytest.raises(ValueError, match="capacity"):
            VetStream(eng, window=8, capacity=4)
        with pytest.raises(ValueError, match="1-D"):
            VetStream(eng, window=8).append(np.ones((2, 8)))

    def test_empty_append_is_noop(self):
        st = VetStream(VetEngine("numpy", buckets=64), window=32)
        f0 = st.fingerprint
        assert st.append([]) == 0
        assert st.total_records == 0 and st.fingerprint == f0

    def test_feed_self_manages_the_ring_budget(self):
        """One feed() far beyond capacity never overruns and stays oracle
        equal — the stream ticks itself exactly when forced."""
        times = stream_times(400, seed=10)
        oracle = oracle_for(times, 32, 8)
        st = VetStream(VetEngine("numpy", buckets=64), window=32, stride=8,
                       capacity=64)
        st.feed(times)  # 400 records through a 64-slot ring, one call
        res = st.tick()
        assert_rows_equal(res, oracle, oracle.workers, bitwise=True)
        assert st.stats.vetted == oracle.workers  # each window vetted once

    def test_feed_without_pressure_does_not_dispatch(self):
        """feed() is pure ingest while the ring has headroom."""
        st = VetStream(VetEngine("numpy", buckets=64), window=32, stride=8,
                       capacity=256)
        st.feed(stream_times(128, seed=11))
        assert st.stats.vetted == 0  # no tick happened during feed
        assert st.tick().workers == (128 - 32) // 8 + 1


# ------------------------------------------------------- bounded history
class TestBoundedHistory:
    """``history=`` caps retained result rows (the ROADMAP follow-up for
    indefinitely long streams): ticks return only the newest ``history``
    windows, each still equal to its batch-oracle row, memory stays
    O(capacity + history), and exposed snapshots survive eviction."""

    def test_rows_equal_oracle_tail_every_tick(self):
        times = stream_times(400, seed=12)
        oracle = oracle_for(times, 32, 8)
        st = VetStream(VetEngine("numpy", buckets=64), window=32, stride=8,
                       capacity=128, history=5)
        for k, res in drive(st, times, 16):
            if k == 0:
                continue
            lo = st.first_retained
            assert lo == max(0, k - 5)
            assert res.workers == k - lo
            for name in ("vet", "ei", "oc", "pr"):
                np.testing.assert_array_equal(getattr(res, name),
                                              getattr(oracle, name)[lo:k])
        assert st.stats.evicted == oracle.workers - 5

    def test_memory_stays_bounded_for_long_streams(self):
        """200+ windows through a history=4 stream: row storage never grows
        with stream length (an unbounded stream would need >= 200 slots)."""
        st = VetStream(VetEngine("numpy", buckets=64), window=8, stride=4,
                       capacity=64, history=4)
        rng = np.random.default_rng(0)
        for _ in range(50):
            st.feed(rng.uniform(1e-3, 2e-3, 20))
            st.tick()
        assert st.complete_windows > 200
        assert st.tick().workers == 4
        assert st._rows["vet"].size <= 128  # physical storage, not windows

    def test_exposed_snapshots_survive_eviction(self):
        times = stream_times(320, seed=13)
        st = VetStream(VetEngine("numpy", buckets=64), window=32, stride=8,
                       capacity=512, history=6)
        st.append(times[:120])
        r1 = st.tick()
        saved = r1.vet.copy()
        st.append(times[120:])
        st.tick()
        np.testing.assert_array_equal(r1.vet, saved)

    def test_amend_into_retained_rows_matches_mutated_oracle(self):
        times = stream_times(256, seed=14)
        st = VetStream(VetEngine("numpy", buckets=64), window=32, stride=8,
                       capacity=256, history=6)
        st.append(times)
        st.tick()
        mutated = times.copy()
        mutated[250] *= 30.0
        st.amend(250, mutated[250])
        res = st.tick()
        oracle = oracle_for(mutated, 32, 8)
        lo = st.first_retained
        np.testing.assert_array_equal(res.vet, oracle.vet[lo:])

    def test_amend_below_retained_rows_revets_only_retained(self):
        """Amending records whose affected windows were already evicted only
        re-vets retained rows; evicted history is immutable."""
        times = stream_times(256, seed=15)
        st = VetStream(VetEngine("numpy", buckets=64), window=32, stride=8,
                       capacity=256, history=4)
        st.append(times)
        st.tick()
        vetted = st.stats.vetted
        lo = st.first_retained
        # record 10 is resident (capacity=256) but windows covering it were
        # evicted long ago (first retained window starts at lo*8 >> 10+32)
        st.amend(10, [times[10] * 50.0])
        res = st.tick()
        assert st.stats.vetted == vetted  # no retained row saw record 10
        assert st.first_retained == lo and res.workers == 4

    def test_constructor_validates_history(self):
        with pytest.raises(ValueError, match="history"):
            VetStream(VetEngine("numpy", buckets=64), window=8, history=0)


# ----------------------------------------------- OnlineVet stream rewrite
class TestOnlineVetStreaming:
    def make_times(self, n=640, seed=0):
        rng = np.random.default_rng(seed)
        t = 1e-3 * (1 + 0.05 * rng.random(n))
        t[::7] += rng.pareto(1.3, t[::7].shape) * 5e-3
        return t

    def test_chunked_and_record_at_a_time_feeds_identical_numpy(self):
        """The satellite contract, bitwise on the numpy backend."""
        times = self.make_times()
        snaps = {}
        for label, chunk in (("chunked", 160), ("scalar", 1)):
            ov = OnlineVet(window=64, engine=VetEngine("numpy", buckets=64))
            out = []
            for lo in range(0, times.size, chunk):
                out.extend(ov.feed(times[lo:lo + chunk]))
            snaps[label] = out
        assert len(snaps["chunked"]) == len(snaps["scalar"]) > 0
        assert snaps["chunked"] == snaps["scalar"]  # NamedTuple equality

    def test_chunked_and_whole_stream_feeds_identical_jax(self):
        times = self.make_times(seed=1)
        ov_a = OnlineVet(window=64, engine=VetEngine("jax", buckets=64))
        ov_b = OnlineVet(window=64, engine=VetEngine("jax", buckets=64))
        a = ov_a.feed(times)
        b = []
        for lo in range(0, times.size, 48):
            b.extend(ov_b.feed(times[lo:lo + 48]))
        assert len(a) == len(b)
        for sa, sb in zip(a, b):
            np.testing.assert_allclose(sa.vet, sb.vet, rtol=1e-6)
            np.testing.assert_allclose(sa.smoothed_vet, sb.smoothed_vet,
                                       rtol=1e-6)

    def test_feed_is_vectorized_no_per_record_estimates(self):
        """One big feed dispatches batches, not one call per record: the
        backing stream vets every window exactly once."""
        ov = OnlineVet(window=64, engine=VetEngine("numpy", buckets=64))
        snaps = ov.feed(self.make_times(640, seed=2))
        stats = ov.stream.stats
        assert stats.vetted == len(snaps) == stats.windows

    def test_huge_feed_does_not_overrun_ring(self):
        """A feed far beyond ring capacity still emits every snapshot."""
        ov = OnlineVet(window=64, engine=VetEngine("numpy", buckets=64))
        n = 64 * 40  # 10x the stream capacity
        snaps = ov.feed(self.make_times(n, seed=3))
        assert len(snaps) == (n - 64) // 32 + 1

    def test_matches_pre_stream_window_convention(self):
        """Snapshots still cover [k*w/2, k*w/2 + w): equal to vet_task on
        those slices (the old deque semantics)."""
        from repro.core import vet_task

        times = self.make_times(256, seed=4)
        ov = OnlineVet(window=128, engine=VetEngine("numpy", buckets=64))
        snaps = ov.feed(times)
        assert len(snaps) == 3  # completions at 128, 192, 256
        for k, s in enumerate(snaps):
            ref = vet_task(times[k * 64:k * 64 + 128], buckets=64)
            np.testing.assert_allclose(s.vet, float(ref.vet), rtol=1e-12)

    def test_2d_feed_rejected(self):
        with pytest.raises(ValueError, match="1-D"):
            OnlineVet(window=64,
                      engine=VetEngine("numpy", buckets=64)).feed(np.ones((4, 4)))

    def test_tiny_history_cap_never_skips_snapshots_on_big_chunks(self):
        """Regression: a cap far below the per-feed window count must not
        break the chunked == record-at-a-time contract — feed folds after
        every internal tick, before eviction can outrun it."""
        times = self.make_times(640, seed=7)
        snaps = {}
        for label, chunk in (("chunked", 640), ("scalar", 1)):
            ov = OnlineVet(window=64, engine=VetEngine("numpy", buckets=64),
                           history=1)
            out = []
            for lo in range(0, times.size, chunk):
                out.extend(ov.feed(times[lo:lo + chunk]))
            snaps[label] = out
        assert len(snaps["chunked"]) == (640 - 64) // 32 + 1
        assert snaps["chunked"] == snaps["scalar"]

    def test_history_capped_online_vet_emits_identical_snapshots(self):
        """A history cap >= the per-feed window count is invisible to the
        EMA: same snapshot list, bounded retained rows."""
        times = self.make_times(640, seed=6)
        ov_full = OnlineVet(window=64, engine=VetEngine("numpy", buckets=64))
        ov_cap = OnlineVet(window=64, engine=VetEngine("numpy", buckets=64),
                           history=8)
        full, capped = [], []
        for lo in range(0, times.size, 96):
            full.extend(ov_full.feed(times[lo:lo + 96]))
            capped.extend(ov_cap.feed(times[lo:lo + 96]))
        assert capped == full and len(full) > 8
        assert ov_cap.stream.first_retained > 0
        assert ov_cap.stream.stats.evicted > 0

    def test_amend_refolds_corrected_windows_into_ema(self):
        """stream.amend() on an already-emitted window must surface in the
        next feed: the corrected rows re-fold, snapshots track the fix."""
        times = self.make_times(256, seed=5)
        ov = OnlineVet(window=128, engine=VetEngine("numpy", buckets=64))
        ov.feed(times)
        stale_vet = ov.snapshot.vet
        # blow up a record inside the last emitted window [128, 256)
        ov.stream.amend(200, [times[200] + 5.0])
        snaps = ov.feed([])  # no new records: only the re-vetted rows emit
        assert snaps, "corrected windows must re-emit"
        assert ov.snapshot.vet != stale_vet
        oracle = VetEngine("numpy", buckets=64)
        fixed = times.copy()
        fixed[200] += 5.0
        np.testing.assert_allclose(
            ov.snapshot.vet,
            float(oracle.vet_one(fixed[128:256]).vet), rtol=1e-12)
