"""Property suite for the online autotuner (skips if hypothesis is absent).

Elbow-walk invariants (nes-spark ``extract_opt_conf`` semantics):

- the accepted trail is strictly increasing in candidate index and always
  starts at the reference candidate;
- the stopping point is invariant to uniformly rescaling every runtime or
  every unit count (only the frontier's *shape* matters);
- a one-candidate frontier is its own elbow.

SPSA invariants (arXiv:1611.10052 estimator on the index grid):

- on a separable quadratic the estimate satisfies the descent property
  ``<ghat, grad> = <grad, delta>**2 >= 0``, so a sign step never moves
  against the seeded gradient;
- the rollback guard never accepts a base-phase regression beyond the
  noise band: whenever the operating point measures worse than
  ``best * (1 + band)``, the tuner reverts to the best-seen assignment —
  verified by replaying the tuner's own history against independently
  reconstructed running statistics.
"""

import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fleet import Knob, KnobHooks
from repro.sched.tuner import (
    FrontierPoint,
    VetTuner,
    elbow_walk,
    spsa_gradient,
)

runtimes = st.lists(st.floats(1e-3, 1e3, allow_nan=False,
                              allow_infinity=False),
                    min_size=1, max_size=12)


def _frontier(rts):
    # Units grow with candidate index (the nes-spark executor sweep shape);
    # runtimes are arbitrary — the walk must cope with non-monotone perf.
    return [FrontierPoint({"v": i}, rt, float(i + 1))
            for i, rt in enumerate(rts)]


# ------------------------------------------------------------- elbow walk
@given(runtimes)
def test_elbow_trail_is_monotone_and_anchored(rts):
    res = elbow_walk(_frontier(rts))
    assert res.trail[0] == 0
    assert list(res.trail) == sorted(set(res.trail))
    assert all(b > a for a, b in zip(res.trail, res.trail[1:]))
    assert res.index == res.trail[-1]
    assert res.point is _frontier(rts)[res.index] or \
        res.point == _frontier(rts)[res.index]


@given(runtimes, st.floats(1e-3, 1e3), st.floats(1e-3, 1e3))
def test_elbow_invariant_to_uniform_rescaling(rts, rt_scale, unit_scale):
    """Scaling every runtime (or every unit count) by one constant scales
    ``perf_inc`` and ``cost_inc`` numerator and denominator alike, so the
    accepted trail cannot move."""
    base = elbow_walk(_frontier(rts))
    scaled_rt = elbow_walk([FrontierPoint(p.knobs, p.runtime * rt_scale,
                                          p.units)
                            for p in _frontier(rts)])
    scaled_units = elbow_walk([FrontierPoint(p.knobs, p.runtime,
                                             p.units * unit_scale)
                               for p in _frontier(rts)])
    assert scaled_rt.trail == base.trail
    assert scaled_units.trail == base.trail


def test_elbow_single_candidate_returns_it():
    p = FrontierPoint({"v": 1}, 2.0, 1.0)
    res = elbow_walk([p])
    assert res.index == 0 and res.trail == (0,) and res.point == p
    with pytest.raises(ValueError):
        elbow_walk([])


def test_elbow_diminishing_returns_interior():
    """The canonical shape: runtime ~ (1 + beta/v) on a doubling unit grid
    puts the elbow strictly inside the sweep (accepting everything would
    ignore cost; accepting nothing would ignore perf)."""
    units = (1, 2, 4, 8, 16)
    pts = [FrontierPoint({"v": v}, 1.0 + 8.0 / v, float(v)) for v in units]
    res = elbow_walk(pts)
    assert 0 < res.index < len(pts) - 1


# ------------------------------------------------------------------- SPSA
@given(
    st.integers(1, 6).flatmap(lambda d: st.tuples(
        st.lists(st.floats(0.1, 10.0), min_size=d, max_size=d),   # curvature
        st.lists(st.integers(-5, 5), min_size=d, max_size=d),     # optimum
        st.lists(st.integers(-6, 6), min_size=d, max_size=d),     # point
        st.lists(st.sampled_from((-1, 1)), min_size=d, max_size=d))))
def test_spsa_descent_property_on_quadratics(case):
    """Seeded-gradient sign match: on y = sum a_i (x_i - o_i)^2 the SPSA
    estimate from one +/-delta probe pair satisfies <ghat, grad> >= 0."""
    a, o, x, delta = (np.asarray(v, np.float64) for v in case)

    def y(p):
        return float(np.sum(a * (p - o) ** 2))

    ghat = np.asarray(spsa_gradient(y(x + delta), y(x - delta),
                                    x + delta, x - delta))
    grad = 2.0 * a * (x - o)
    assert float(ghat @ grad) >= -1e-9 * max(1.0, float(np.abs(grad).sum()))
    # And the estimator is exact along the probe direction:
    # ghat = <grad, delta> * delta elementwise on a quadratic.
    np.testing.assert_allclose(ghat, float(grad @ delta) * delta,
                               rtol=1e-9, atol=1e-9)


def test_spsa_gradient_zero_span_and_shape_guard():
    assert spsa_gradient(2.0, 1.0, (3, 1), (3, 0)) == (0.0, 1.0)
    with pytest.raises(ValueError):
        spsa_gradient(1.0, 0.0, (1, 2), (1,))


# --------------------------------------------------------- rollback guard
@given(st.lists(st.floats(0.1, 10.0, allow_nan=False,
                          allow_infinity=False),
                min_size=8, max_size=60),
       st.floats(0.0, 1.0), st.integers(0, 2 ** 31 - 1))
@settings(deadline=None)
def test_rollback_guard_never_accepts_banded_regression(ys, band, seed):
    """Replay the tuner's own history against independently reconstructed
    running means: every base phase that measured beyond the noise band of
    the then-best assignment must carry the rollback action (and only
    those may)."""
    hooks = KnobHooks.over_state(
        (Knob("a", (1, 2, 4)), Knob("m", (0, 1), kind="bandit")),
        {"a": 1, "m": 0})
    tuner = VetTuner(hooks, seed=seed, noise_band=band)
    for y in ys:
        tuner.step(y)

    stats = {}

    def _push(assignment, y):
        key = tuple(sorted(assignment.items()))
        n, mean = stats.get(key, (0, 0.0))
        stats[key] = (n + 1, (mean * n + y) / (n + 1))
        return key

    rollbacks = 0
    for rec in tuner.history:
        key = _push(rec.assignment, rec.y)
        if rec.phase != "base":
            assert rec.action != "rollback"
            continue
        best_key = min(stats, key=lambda k: stats[k][1])
        regressed = (best_key != key
                     and rec.y > stats[best_key][1] * (1.0 + band))
        assert (rec.action == "rollback") == regressed
        rollbacks += regressed
    assert rollbacks == tuner.rollbacks


@given(st.integers(0, 2 ** 31 - 1))
@settings(deadline=None, max_examples=25)
def test_walk_converges_on_deterministic_unimodal_objective(seed):
    """Any seed, deterministic separable objective: the walk must end with
    both knobs exactly on their optimum (the noiseless-exactness argument,
    free of the simulator)."""
    state = {"a": 1, "m": 0}
    hooks = KnobHooks.over_state(
        (Knob("a", (1, 2, 4, 8)), Knob("m", (0, 1, 2), kind="bandit")),
        state)
    tuner = VetTuner(hooks, seed=seed)
    target = {"a": 4, "m": 2}
    factors = {0: 1.5, 1: 1.2, 2: 1.0}

    def y():
        ka = hooks.knob("a")
        return ((1.0 + 0.5 * abs(ka.index_of(state["a"])
                                 - ka.index_of(target["a"])))
                * factors[state["m"]])

    for _ in range(120):
        tuner.step(y())
    assert tuner.best[0] == target
    assert tuner.current == target
