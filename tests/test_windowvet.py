"""Deterministic suite for the fused window-vet kernel (the one-launch path).

The equivalence ladder, root to top:

    scalar numpy oracle (``windowvet.ref.ref_window_vet`` — a host loop of
    ``vet_pipeline`` calls, f64)
      -> engine gather path (materialize + batch, the pre-fused production
         path; doubles as the fused kernel's differential oracle)
        -> fused kernel (``fused_window_vet`` — one launch, block-sparse
           row map, ring prefix sums)

Every rung must agree to 1e-5 on vet/ei/oc/pr with the change-point exact,
on overlapping, ragged, and degenerate window sets — plus the ring-wrap
seam (a ``VetStream`` drained across its circular-buffer boundary) and the
fused mux tick (one dispatch for a mixed-window fleet).

``tests/test_windowvet_properties.py`` is the hypothesis twin; this module
always collects (hypothesis is optional).
"""

import numpy as np
import pytest

from repro.engine import VetEngine, VetStream
from repro.fleet import VetMux, build, play
from repro.kernels.windowvet import fused_window_vet, ref_window_vet
from repro.kernels.windowvet.ops import staged_bytes
from repro.profiling import simulate_records


def stream(n, seed=0):
    return simulate_records(n, seed=seed).times


def assert_matches(got, want, rtol=1e-5, atol=1e-9, exact_t=True):
    """(vet, ei, oc, pr, t, n) tuples: rtol on measures, exact cut/count.

    ``exact_t=False`` is for f32-vs-f64 cross-rung comparisons on inputs
    whose SSE landscape has statistical near-ties (the documented pallas
    caveat): the cut may sit one bucket off, so only the measures (at the
    caller's looser rtol/atol — OC crosses zero when the cut lands on n)
    and the row counts are pinned.
    """
    for g, w, name in zip(got[:4], want[:4], ("vet", "ei", "oc", "pr")):
        np.testing.assert_allclose(g, w, rtol=rtol, atol=atol, err_msg=name)
    if exact_t:
        np.testing.assert_array_equal(np.asarray(got[4]),
                                      np.asarray(want[4]), err_msg="t")
    np.testing.assert_array_equal(np.asarray(got[5]), np.asarray(want[5]),
                                  err_msg="n")


def sliding_bounds(n, window, stride):
    starts = np.arange(0, n - window + 1, stride, dtype=np.int64)
    return starts, np.full(starts.size, window, dtype=np.int64)


# --------------------------------------------------- kernel vs scalar oracle
@pytest.mark.parametrize("seed", [0, 3, 7])
def test_sliding_windows_match_scalar_oracle(seed):
    times = stream(600, seed=seed)
    starts, lengths = sliding_bounds(600, 64, 16)
    got = fused_window_vet(times, starts, lengths)
    want = ref_window_vet(times, starts, lengths)
    assert_matches(got, want)


def test_ragged_overlapping_windows_match_both_rungs():
    """37 random overlapping windows, lengths 8..199: bitwise-t agreement
    with the gather rung (same f32 rounding), and 2e-2 against the f64
    scalar root — long random windows routinely sit on SSE near-ties, the
    documented pallas caveat, so the cut may differ by one rank there."""
    times = stream(512, seed=5)
    rng = np.random.default_rng(11)
    lengths = rng.integers(8, 200, size=37).astype(np.int64)
    starts = np.array([rng.integers(0, 512 - ln + 1) for ln in lengths],
                      dtype=np.int64)
    got = fused_window_vet(times, starts, lengths)
    slices = list(zip(starts.tolist(), (starts + lengths).tolist()))
    gather = VetEngine("pallas", cache_size=0, fused=False)
    g = gather.vet_windows(times, slices)
    assert_matches(got, (g.vet, g.ei, g.oc, g.pr, g.t, g.n))
    want = ref_window_vet(times, starts, lengths)
    assert_matches(got, want, rtol=2e-2, exact_t=False)


def test_degenerate_windows_match_scalar_oracle():
    times = stream(64, seed=2)
    starts = np.array([0, 5, 10, 0, 62], dtype=np.int64)
    lengths = np.array([2, 3, 7, 64, 2], dtype=np.int64)
    got = fused_window_vet(times, starts, lengths)
    want = ref_window_vet(times, starts, lengths)
    assert_matches(got, want)


def test_single_window_matches_scalar_oracle():
    times = stream(128, seed=8)
    got = fused_window_vet(times, np.array([17]), np.array([96]))
    want = ref_window_vet(times, np.array([17]), np.array([96]))
    assert_matches(got, want)


def test_raw_cut_space_matches_scalar_oracle():
    times = stream(400, seed=6)
    starts, lengths = sliding_bounds(400, 64, 32)
    got = fused_window_vet(times, starts, lengths, cut_space="raw")
    want = ref_window_vet(times, starts, lengths, cut_space="raw")
    assert_matches(got, want)


def test_kernel_validates_inputs():
    times = stream(64, seed=0)
    with pytest.raises(ValueError, match="at least one window"):
        fused_window_vet(times, np.array([], dtype=np.int64),
                         np.array([], dtype=np.int64))
    with pytest.raises(ValueError, match=">= 2 records"):
        fused_window_vet(times, np.array([0]), np.array([1]))
    with pytest.raises(ValueError, match="out of arena bounds"):
        fused_window_vet(times, np.array([60]), np.array([8]))
    with pytest.raises(ValueError, match="disagree"):
        fused_window_vet(times, np.array([0, 8]), np.array([8]))


def test_staged_bytes_is_o_ring_not_o_windows():
    # Dense overlap: 253 64-wide windows over 4096 records.  The gather
    # matrix is O(windows x length); the fused launch stages O(ring).
    n, window, stride = 4096, 64, 16
    num = (n - window) // stride + 1
    rows_p = 1 << (num - 1).bit_length()
    materialized = rows_p * window * 8
    assert staged_bytes(n, num, window) < materialized
    # Denser overlap (same ring, more windows) must not grow the arena term.
    assert (staged_bytes(n, 4 * num, window) - staged_bytes(n, num, window)
            <= 16 * 4 * num * 4)


# --------------------------------------------------- engine-level routing
def test_engine_fused_matches_gather_path_exactly_on_t():
    """Fused vs gather on the SAME pallas backend: identical f32 rounding
    (both scan with reference cumsum), so the change-point is bitwise equal
    even on near-tie SSE landscapes — the strongest rung of the ladder."""
    times = stream(600, seed=0)
    fused = VetEngine("pallas", cache_size=0)
    gather = VetEngine("pallas", cache_size=0, fused=False)
    assert fused.fused and not gather.fused
    a = fused.vet_sliding(times, window=64, stride=16)
    b = gather.vet_sliding(times, window=64, stride=16)
    assert_matches((a.vet, a.ei, a.oc, a.pr, a.t, a.n),
                   (b.vet, b.ei, b.oc, b.pr, b.t, b.n))
    assert fused.dispatches == gather.dispatches == 1
    # The fused launch stages strictly fewer bytes than the gather matrix.
    assert 0 < fused.dispatch_bytes < gather.dispatch_bytes


def test_engine_vet_windows_fused_handles_ragged_bounds():
    times = stream(512, seed=9)
    slices = [(0, 64), (10, 200), (100, 116), (300, 512), (505, 510)]
    fused = VetEngine("pallas", cache_size=0)
    got = fused.vet_windows(times, slices)
    starts = np.array([lo for lo, _ in slices], dtype=np.int64)
    lengths = np.array([hi - lo for lo, hi in slices], dtype=np.int64)
    want = ref_window_vet(times, starts, lengths)
    assert_matches((got.vet, got.ei, got.oc, got.pr, got.t, got.n), want)
    assert fused.dispatches == 1  # one launch despite 5 distinct lengths


def test_engine_bucketed_rows_stay_on_gather_path():
    # The fused path is the non-bucketed estimator; rows long enough to
    # bucket (n >= 4*buckets) must keep the gather route.
    eng = VetEngine("pallas", buckets=16, cache_size=0)
    assert eng.fused_supported(63) and not eng.fused_supported(64)
    times = stream(256, seed=1)
    eng.vet_sliding(times, window=64, stride=64)
    assert eng.dispatches == 1
    oracle = VetEngine("numpy", buckets=16, cache_size=0)
    a = eng.vet_sliding(times, window=64, stride=64)
    b = oracle.vet_sliding(times, window=64, stride=64)
    np.testing.assert_allclose(a.ei, b.ei, rtol=3e-2)


# --------------------------------------------------- ring seam + fused mux
def test_stream_fused_ticks_survive_ring_wrap():
    """Feed far past capacity so drained spans cross the circular-buffer
    seam: drain_ring's modular gather must linearize the arena exactly —
    every tick matches a numpy-oracle stream over the same logical prefix."""
    times = stream(400, seed=4)
    fused = VetStream(VetEngine("pallas"), window=32, stride=8, capacity=128)
    # The gather-pallas stream drains through its own (matrix) modular
    # gather with identical f32 rounding: bitwise-t differential oracle for
    # drain_ring's arena linearization.  The f64 numpy stream roots the
    # ladder at the near-tie-tolerant rtol.
    gather = VetStream(VetEngine("pallas", fused=False), window=32, stride=8,
                       capacity=128)
    oracle = VetStream(VetEngine("numpy"), window=32, stride=8, capacity=128)
    fed = 0
    # Chunks stay under capacity - window + stride (= 104): larger feeds
    # overrun the ring, which is the stream's own (tested) error path.
    for k, chunk in enumerate([23, 57, 23, 64, 23, 96, 64, 36]):
        part = times[fed:fed + chunk]
        fed += chunk
        for st in (fused, gather, oracle):
            st.append(part)
        a, g, b = fused.tick(), gather.tick(), oracle.tick()
        workers = 0 if a is None else a.workers
        assert workers == (0 if g is None else g.workers) \
            == (0 if b is None else b.workers), f"tick {k}"
        if workers:
            assert_matches((a.vet, a.ei, a.oc, a.pr, a.t, a.n),
                           (g.vet, g.ei, g.oc, g.pr, g.t, g.n))
            assert_matches((a.vet, a.ei, a.oc, a.pr, a.t, a.n),
                           (b.vet, b.ei, b.oc, b.pr, b.t, b.n),
                           rtol=2e-2, atol=1e-3, exact_t=False)


def test_mux_fused_mixed_fleet_is_one_dispatch_per_tick():
    """The tentpole: a ragged mixed-window fleet tick is ONE launch on the
    fused path (the bucketed path pays one per distinct length), and every
    row still matches the numpy-oracle mux."""
    sc = build("mixed_windows", n_workers=9, n_ticks=6, seed=0)
    eng = VetEngine("pallas", cache_size=0)
    mux = VetMux(eng)
    oracle = VetMux(VetEngine("numpy", cache_size=0))
    ticks = play(sc, mux)
    want = play(sc, oracle)
    n_lengths = len({s.window for s in sc.specs})
    assert n_lengths == 3
    for k, (t, w) in enumerate(zip(ticks, want)):
        if t.rows:
            assert t.dispatches == 1, f"tick {k}"
        for sid in w.results:
            a, b = t.results[sid], w.results[sid]
            if b is None or not b.workers:
                assert a is None or not a.workers
                continue
            # f32 pallas vs the f64 numpy root: near-tie-tolerant rtol
            # (the bitwise-t contract vs the gather rung is the test below).
            assert_matches((a.vet, a.ei, a.oc, a.pr, a.t, a.n),
                           (b.vet, b.ei, b.oc, b.pr, b.t, b.n),
                           rtol=2e-2, atol=1e-3, exact_t=False)


def test_mux_fused_and_bucketed_paths_agree():
    sc = build("mixed_windows", n_workers=6, n_ticks=5, seed=3,
               strides_per_tick=2)
    fused = VetMux(VetEngine("pallas", cache_size=0))
    bucketed = VetMux(VetEngine("pallas", cache_size=0, fused=False))
    ticks = play(sc, fused)
    want = play(sc, bucketed)
    for t, w in zip(ticks, want):
        if t.rows:
            assert t.dispatches == 1
        if w.rows:
            assert w.dispatches > 1
        for sid in w.results:
            a, b = t.results[sid], w.results[sid]
            if b is None or not b.workers:
                continue
            assert_matches((a.vet, a.ei, a.oc, a.pr, a.t, a.n),
                           (b.vet, b.ei, b.oc, b.pr, b.t, b.n))
