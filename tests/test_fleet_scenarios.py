"""Edge cases for ``repro.fleet.scenarios`` not pinned by the mux suites.

The differential suites replay the scenario bank through muxes and compare
rows; they never exercise the degenerate fleet states a real deployment
hits (a churn step that leaves *zero* live workers, arrival chunks smaller
than any window) and they only pin the simulator's raw draws — not the
*event scripts* the bank assembles around them.  ``bursty`` consumes an
extra RNG for its arrival sizes and ``churn`` derives a join/leave schedule,
neither of which the simulator determinism suite
(``tests/test_simulator_determinism.py``) covers; their golden hashes here
pin the full compiled event stream, so an incidental reordering of the
bank's RNG consumption (or its schedule arithmetic) fails loudly instead of
silently moving every fleet oracle.
"""

import hashlib

import numpy as np
import pytest

from repro.engine import VetEngine
from repro.fleet import (
    FleetEvent,
    FleetScenario,
    ShardedVetMux,
    StreamSpec,
    VetMux,
    build,
    play,
)


def scenario_hash(sc: FleetScenario) -> str:
    """Content hash of a compiled scenario: specs + every event's chunks
    (bytes), joins and leaves, in script order."""
    h = hashlib.blake2b(digest_size=16)
    h.update(sc.name.encode())
    for s in sc.specs:
        h.update(f"{s.stream_id}|{s.window}|{s.stride}|{s.capacity}"
                 f"|{s.priority}|{s.tenant}".encode())
    for e in sc.events:
        for sid in sorted(e.chunks):
            h.update(sid.encode())
            h.update(np.ascontiguousarray(e.chunks[sid]).tobytes())
        h.update(("J" + ",".join(s.stream_id for s in e.joins)).encode())
        h.update(("L" + ",".join(e.leaves)).encode())
    return h.hexdigest()


class TestScenarioDeterminism:
    @pytest.mark.parametrize("name", ("bursty", "churn"))
    def test_same_seed_is_bitwise_stable(self, name):
        a, b = build(name, seed=0), build(name, seed=0)
        assert scenario_hash(a) == scenario_hash(b)
        assert a.specs == b.specs

    def test_golden_hash_pins_bursty_event_stream(self):
        """bursty's arrival sizes come from an RNG the simulator suite does
        not see; this pins the exact compiled script.  If it moves, every
        bursty-driven oracle moved — bump deliberately, never incidentally."""
        assert scenario_hash(build("bursty", seed=0)) == \
            "a27058c4c660e3b50585743eec2adbc1"

    def test_golden_hash_pins_churn_event_stream(self):
        """churn's join/leave schedule is derived arithmetic on top of the
        simulator draws; pinned for the same reason as bursty.  (Hash bumped
        when the joiner chunk-indexing bug was fixed: joiners are now fed by
        ticks-since-join, so their first simulated records are no longer
        dropped — previous golden 8a6d5670dc24a4b014d9695a995bff85.)"""
        assert scenario_hash(build("churn", seed=0)) == \
            "03f7010598bc9e1f0548846b4f6fb4d2"

    def test_churn_joiners_fed_from_their_first_records(self):
        """Regression for the joiner chunk-indexing bug: the first chunk a
        joiner receives must be the *start* of its simulated run, not the
        global-tick offset into it."""
        n_ticks = 8
        sc = build("churn", n_ticks=n_ticks, seed=0)
        joiners = {s.stream_id for e in sc.events for s in e.joins}
        assert joiners
        from repro.fleet.scenarios import _worker_times
        for sid in joiners:
            first = next(e.chunks[sid] for e in sc.events if sid in e.chunks)
            whole = _worker_times(n_ticks * first.size, 0, int(sid[1:]))
            np.testing.assert_array_equal(first, whole[:first.size])

    ANOMALY_GOLDENS = {
        "contention_onset": "c7f26f3e75c7d3a096079cd639630339",
        "degraded_node": "9c36498bfb60abde85a0be5c6566f0b4",
        "fail_restart": "2f9b2fd5f21cdd24eacb037c492ff94f",
        "diurnal": "4cb6298f95acec4c47499ec92b273a41",
        "hetero_tiers": "90c267c357ca7c173d91510a212dcfa3",
    }

    @pytest.mark.parametrize("name", sorted(ANOMALY_GOLDENS))
    def test_golden_hash_pins_anomaly_bank(self, name):
        """The anomaly bank's envelopes are derived arithmetic over the
        simulator draws; each compiled event stream is pinned so the
        detection-quality suites measure the detector, not drift in the
        injected ground truth."""
        sc = build(name, seed=0)
        assert scenario_hash(sc) == self.ANOMALY_GOLDENS[name]
        assert sc.onset_tick is not None and sc.affected

    def test_anomaly_bank_carries_ground_truth(self):
        """Every anomaly scenario declares its injected onset and affected
        streams; hetero_tiers' static tiers are the negative control."""
        from repro.fleet.scenarios import ANOMALY_SCENARIOS
        for name in ANOMALY_SCENARIOS:
            sc = build(name, seed=0)
            assert 0 < sc.onset_tick < len(sc.events)
            sids = {s.stream_id for s in sc.specs}
            assert set(sc.affected) <= sids
        hetero = build("hetero_tiers", seed=0)
        assert set(hetero.affected) < {s.stream_id for s in hetero.specs}

    def test_different_seeds_differ(self):
        assert scenario_hash(build("bursty", seed=0)) != \
            scenario_hash(build("bursty", seed=1))


class TestDegenerateFleetStates:
    def test_zero_worker_churn_step(self):
        """A churn script that deregisters *every* stream mid-run: the empty
        ticks stay well-defined (no rows, no dispatches) and later joins
        repopulate the fleet deterministically."""
        w = StreamSpec("w0", window=8, stride=4, capacity=64)
        j = StreamSpec("j0", window=8, stride=4, capacity=64)
        times = np.linspace(1e-3, 2e-3, 16)
        sc = FleetScenario("empty_step", (w,), (
            FleetEvent(chunks={"w0": times}),
            FleetEvent(chunks={}, leaves=("w0",)),  # fleet drops to zero
            FleetEvent(chunks={}),                  # zero-worker tick
            FleetEvent(chunks={"j0": times * 2}, joins=(j,)),
        ))
        for mux in (VetMux(VetEngine("numpy", buckets=64)),
                    ShardedVetMux(2, backend="numpy")):
            ticks = play(sc, mux)
            assert ticks[1].rows > 0 or ticks[0].rows > 0
            empty = ticks[2]
            assert empty.rows == 0 and empty.dispatches == 0
            assert empty.results == {} and not empty.deferred
            assert len(mux) == 1  # only the joiner remains
            assert ticks[3].results["j0"].workers == 3

    def test_vet_job_raises_on_a_windowless_fleet(self):
        mux = ShardedVetMux(2, backend="numpy")
        mux.register("a", window=8, stride=4)
        tick = mux.tick()  # nothing fed at all
        with pytest.raises(ValueError, match="complete window"):
            tick.vet_job

    def test_single_record_bursts_below_the_smallest_window(self):
        """Chunks of one record — far below any window — must accumulate
        without dispatching until the window'th record, then vet exactly
        once, identical to one big append."""
        window = 8
        spec = StreamSpec("w0", window=window, stride=window, capacity=64)
        times = np.linspace(1e-3, 2e-3, window)
        sc = FleetScenario("trickle", (spec,), tuple(
            FleetEvent(chunks={"w0": times[k:k + 1]}) for k in range(window)))
        eng = VetEngine("numpy", buckets=64)
        ticks = play(sc, VetMux(eng))
        assert all(t.rows == 0 and t.dispatches == 0
                   for t in ticks[:window - 1])
        assert all(t.results["w0"] is None for t in ticks[:window - 1])
        assert ticks[-1].rows == 1 and ticks[-1].dispatches == 1
        ref = VetEngine("numpy", buckets=64).vet_sliding(
            times, window=window, stride=window)
        np.testing.assert_array_equal(ticks[-1].results["w0"].vet, ref.vet)

    def test_bursty_quiet_ticks_cost_nothing(self):
        """The bank's bursty scenario has genuinely empty per-worker ticks;
        a tick where nobody moved must issue zero dispatches."""
        sc = build("bursty", n_workers=4, n_ticks=8, seed=3)
        eng = VetEngine("numpy", buckets=64)
        mux = VetMux(eng)
        for spec in sc.specs:
            spec.register(mux)
        for event in sc.events:
            before = eng.dispatches
            for sid, chunk in event.chunks.items():
                mux.feed(sid, chunk)
            tick = mux.tick()
            if tick.rows == 0:
                assert eng.dispatches == before
