"""Fast tier-1 smoke for the sharded fleet path: <= 64 workers, 2 shards,
numpy backend only.

The full suite (``tests/test_fleet_shard.py``) sweeps every scenario and
backend; this file keeps tier-1 cheap while proving the load-bearing
properties end to end at a realistic width: single-mux oracle equality,
job-level merge equality, dispatch distribution across shards, a working
benchmark harness, and the quickstart's sharded stanza (the docs-gate
snippet) actually running.
"""

import importlib.util
import os

import numpy as np

import benchmarks.fleet_shard as shard_bench
from repro.engine import VetEngine
from repro.fleet import ShardedVetMux, VetMux, build, play


def test_64_worker_2shard_fleet_matches_batch_oracle_bitwise():
    """64 streams over 2 shards: final rows == the vet_sliding oracle."""
    scenario = build("uniform", n_workers=64, n_ticks=3, window=16, seed=21)
    last = play(scenario, ShardedVetMux(2, backend="numpy"))[-1]
    oracle = VetEngine("numpy", buckets=64)
    for spec in scenario.specs:
        fed = np.concatenate([e.chunks[spec.stream_id]
                              for e in scenario.events])
        ref = oracle.vet_sliding(fed, window=spec.window, stride=spec.stride)
        got = last.results[spec.stream_id]
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a, b)


def test_64_worker_2shard_merged_vet_job_matches_single_mux():
    sc_args = dict(n_workers=64, n_ticks=3, window=16, seed=22)
    sharded = play(build("uniform", **sc_args),
                   ShardedVetMux(2, backend="numpy"))[-1]
    single = play(build("uniform", **sc_args),
                  VetMux(VetEngine("numpy", buckets=64)))[-1]
    assert abs(sharded.vet_job - single.vet_job) <= 1e-9
    assert sharded.job.streams == 64


def test_64_worker_2shard_dispatch_distribution():
    """A homogeneous fleet splits its one bucket across exactly the two
    shards: 2 dispatches per moving tick (single mux + K bound), half the
    rows on each shard."""
    smux = ShardedVetMux(2, backend="numpy")
    ticks = play(build("uniform", n_workers=64, n_ticks=3, window=16,
                       seed=23), smux)
    moving = [t for t in ticks if t.rows]
    assert moving and all(t.dispatches == 2 for t in moving)
    for t in moving:
        shard_rows = [s.rows for s in t.shards]
        assert sum(shard_rows) == t.rows
        assert max(shard_rows) == t.rows // 2  # balanced split
    assert sum(e.dispatches for e in smux.engines) == smux.stats.dispatches


def test_benchmark_harness_smoke_tiny():
    """The shard-scaling benchmark loop at toy size (8 workers, numpy):
    payload complete, total-dispatch bound holds, per-shard max falls."""
    out = shard_bench.bench_shard_scaling(
        8, shards_list=(1, 2), n_lengths=2, n_ticks=2, backend="numpy",
        seed=5)
    single = out["single_mux_dispatches_per_tick"]
    assert single == 2  # one bucket per window length
    for k, entry in out["shards"].items():
        assert entry["total_dispatches_per_tick"] <= single + int(k)
        assert np.isfinite(entry["tick_us"]) and entry["vet_job"] >= 1.0
    assert (out["shards"]["2"]["per_shard_max_dispatches_per_tick"]
            < out["shards"]["1"]["per_shard_max_dispatches_per_tick"])
    assert (out["shards"]["2"]["per_shard_max_rows_per_tick"]
            < out["shards"]["1"]["per_shard_max_rows_per_tick"])


def test_quickstart_stanza6_runs_end_to_end():
    """The docs-gate snippet: quickstart's sharded-fleet stanza runs and
    reports a merged job-level vet over every stream."""
    path = os.path.join(os.path.dirname(__file__), os.pardir, "examples",
                        "quickstart.py")
    spec = importlib.util.spec_from_file_location("quickstart_module", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    out = mod.stanza6(n_workers=9, shards=2, n_ticks=3, backend="numpy",
                      verbose=False)
    assert out["vet_job"] >= 1.0
    assert sum(out["balance"]) == 9 and out["streams"] == 9
    assert len(out["dispatches_per_shard"]) == 2
